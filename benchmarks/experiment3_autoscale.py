"""Experiment 3 — entitlement-driven autoscaling + cross-pool
rebalancing (the paper's consistency story, beyond-paper at fleet
scale).

Scenario: "A coding assistant (guaranteed) and an analytics tenant
(elastic) share pool *east*; a batch pipeline rides spot.  At t=20 s
the analytics demand surges 4×.  At t=30 s — mid-surge — east loses
two replicas to a node failure, and the replacement capacity takes
``provision_lag_s`` to come up."

What the closed control loop (admission → batched tick → plan_fleet →
authorize/provision → admission) must show:

  C1  the surge raises east's desired replicas (scale_up:demand) —
      the SAME demand signal that admission uses (denied demand
      included) drives provisioning;
  C2  during the outage east is SCARCE (need > maxReplicas): the
      starved elastic tenant accumulates debt and is MIGRATED to the
      slack pool *west*, its debt carried across the move;
  C3  guaranteed-class P99 stays bounded through surge + outage
      (reservations + spill-over + rebalancing absorb the pressure);
  C4  after the surge ends, cooldown hysteresis drains east back down
      (scale-down, no flapping).

Also benchmarked: one fused ``plan_fleet`` dispatch planning 8 / 64 /
512 pools (the fleet-scale headline).  Pass ``out_json`` to dump
``BENCH_autoscale.json`` (plan latency + surge P99 trajectory) —
``benchmarks/run.py`` does; CI uploads it.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import FleetPlannerConfig, ServiceClass
from repro.core.fleet import plan_fleet
from repro.serving import MultiPoolSimulator, PoolSite, Workload


def build(provision_lag_s: float = 3.0) -> MultiPoolSimulator:
    workloads = [
        Workload(name="assist", service_class=ServiceClass.GUARANTEED,
                 slots=4, slo_ms=500.0, rate_rps=1.0, in_tokens=64,
                 out_tokens=64, pools=("east", "west"), max_retries=2),
        # the surging analytics tenant — entitled on east only; the
        # REBALANCER (not a client route) moves it when east starves it
        Workload(name="analytics", service_class=ServiceClass.ELASTIC,
                 slots=8, slo_ms=2000.0, rate_rps=0.8, in_tokens=64,
                 out_tokens=64, pools=("east",), max_retries=2),
        Workload(name="batch", service_class=ServiceClass.SPOT,
                 slots=4, slo_ms=30000.0, rate_rps=0.6, in_tokens=64,
                 out_tokens=64, pools=("east",), max_retries=1),
    ]
    sim = MultiPoolSimulator(
        workloads,
        sites=[PoolSite("east", n_replicas=2, replica_slots=8,
                        replica_tps=120.0, max_replicas=3),
               PoolSite("west", n_replicas=1, replica_slots=8,
                        replica_tps=120.0, max_replicas=3)],
        autoscale=True,
        provision_lag_s=provision_lag_s, drain_s=2.0,
        planner_config=FleetPlannerConfig(
            cooldown_ticks=5, debt_migrate_threshold=0.2,
            starve_persistence_ticks=3, migrate_cooldown_ticks=15))
    sim.at(20.0, "set_rate", workload="analytics", rate=3.2)  # 4× surge
    sim.at(30.0, "fail_replica", pool="east", idx=1)
    sim.at(30.0, "fail_replica", pool="east", idx=2)
    sim.at(55.0, "recover_replica", pool="east", idx=1)
    sim.at(55.0, "recover_replica", pool="east", idx=2)
    sim.at(65.0, "set_rate", workload="analytics", rate=0.8)  # surge ends
    return sim


def windowed_p99(sim: MultiPoolSimulator, workload: str,
                 windows: list[tuple[str, float, float]]) -> dict:
    out = {}
    for label, t0, t1 in windows:
        e2es = [r.e2e for r in sim.requests.values()
                if r.entitlement == workload and r.e2e is not None
                and t0 <= r.arrival_s < t1]
        out[label] = (float(np.percentile(e2es, 99)) if e2es
                      else float("nan"))
    return out


def plan_latency_us(n_pools: int, reps: int = 50) -> float:
    """One fused plan_fleet dispatch for ``n_pools`` pools (the
    fleet-scale headline: 512 pools plan in one kernel call)."""
    import jax.numpy as jnp
    rng = np.random.RandomState(0)
    f32 = lambda a: jnp.asarray(a, jnp.float32)          # noqa: E731
    args = dict(
        current=jnp.asarray(rng.randint(1, 8, n_pools), jnp.int32),
        lo=jnp.ones(n_pools, jnp.int32),
        hi=jnp.full(n_pools, 8, jnp.int32),
        per_tps=f32(np.full(n_pools, 240.0)),
        per_kv=f32(np.zeros(n_pools)),
        per_conc=f32(np.full(n_pools, 16.0)),
        res_tps=f32(rng.uniform(0, 960, n_pools)),
        res_kv=f32(np.zeros(n_pools)),
        res_conc=f32(rng.uniform(0, 32, n_pools)),
        demand_tps=f32(rng.uniform(0, 2000, n_pools)),
        ewma_prev=f32(rng.uniform(0, 2000, n_pools)),
        seeded=jnp.ones(n_pools, bool),
        low_ticks=jnp.zeros(n_pools, jnp.int32))
    plan_fleet(**args)[0].block_until_ready()            # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = plan_fleet(**args)
    out[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(duration: float = 90.0) -> dict:
    sim = build()
    res = sim.run(duration)

    east = sim.replica_timeline["east"]
    west = sim.replica_timeline["west"]
    peak_east = max((n for t, n in east if t < 30.0), default=0)
    surge_scaled = any(n >= 3 for t, n in east if 20.0 <= t < 30.0)
    west_scaled = max((n for _, n in west), default=0)
    final_east = east[-1][1] if east else 0

    migrations = res["migrations"]
    debt_moves = [m for m in migrations if m.debt > 0.0]

    windows = [("before", 5.0, 20.0), ("surge", 20.0, 30.0),
               ("outage", 30.0, 55.0), ("after", 70.0, duration)]
    p99 = windowed_p99(sim, "assist", windows)
    scale_reasons = {}
    for _, plan in sim.plans:
        for d in plan.decisions.values():
            scale_reasons[d.reason] = scale_reasons.get(d.reason, 0) + 1

    return {
        "p99_assist": p99,
        "peak_east_before_outage": peak_east,
        "surge_scaled_east": surge_scaled,
        "west_peak": west_scaled,
        "final_east": final_east,
        "migrations": [
            {"entitlement": m.entitlement, "src": m.src, "dst": m.dst,
             "debt": round(m.debt, 4), "reason": m.reason}
            for m in migrations],
        "debt_carried_moves": len(debt_moves),
        "scale_reasons": scale_reasons,
        "per_workload": {
            w: {k: s[k] for k in ("finished", "denied_total",
                                  "e2e_p99")}
            for w, s in res["per_workload"].items()},
        "replica_timeline": {"east": east, "west": west},
    }


def main(duration: float = 90.0, out_json: str | None = None) -> None:
    r = run(duration)
    p99 = r["p99_assist"]
    print("experiment3,metric,value,claim")
    print(f"experiment3,p99_assist_before,{p99['before']:.2f},baseline")
    print(f"experiment3,p99_assist_surge,{p99['surge']:.2f},bounded")
    print(f"experiment3,p99_assist_outage,{p99['outage']:.2f},bounded")
    print(f"experiment3,surge_scaled_east,{r['surge_scaled_east']},"
          "True (scale_up:demand before the outage)")
    print(f"experiment3,west_peak_replicas,{r['west_peak']},"
          ">1 (rebalanced demand provisions west)")
    print(f"experiment3,final_east_replicas,{r['final_east']},"
          "scale-down after the surge")
    print(f"experiment3,migrations,{len(r['migrations'])},>=1")
    print(f"experiment3,debt_carried_moves,{r['debt_carried_moves']},"
          ">=1 (debt preserved across the move)")
    for m in r["migrations"]:
        print(f"experiment3,migrated,{m['entitlement']}:"
              f"{m['src']}->{m['dst']},debt={m['debt']} ({m['reason']})")
    up = r["scale_reasons"].get("scale_up:demand", 0)
    down = r["scale_reasons"].get("scale_down", 0)
    print(f"experiment3,scale_up_demand_decisions,{up},>=1")
    print(f"experiment3,scale_down_decisions,{down},>=1")

    lat = [{"pools": n, "plan_us": round(plan_latency_us(n), 1)}
           for n in (8, 64, 512)]
    for row in lat:
        print(f"experiment3,plan_fleet_{row['pools']}pools,"
              f"{row['plan_us']},us_per_fused_plan")

    if out_json:
        os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
        # an empty P99 window is NaN — not valid strict JSON; ship null
        p99_json = {k: (None if np.isnan(v) else round(v, 3))
                    for k, v in p99.items()}
        with open(out_json, "w") as f:
            json.dump({
                "benchmark": "experiment3_autoscale",
                "duration_s": duration,
                "plan_latency": lat,
                "surge_p99_trajectory": p99_json,
                "migrations": r["migrations"],
                "scale_reasons": r["scale_reasons"],
                "replica_timeline": r["replica_timeline"],
            }, f, indent=2)
        print(f"# wrote {out_json}")


if __name__ == "__main__":
    import sys
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    main(duration=float(args[0]) if args else 90.0,
         out_json=args[1] if len(args) > 1 else None)
