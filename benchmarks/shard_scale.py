"""Sharded control-plane scale trajectory (``core.shard_plane``).

Measures ``shard_tick`` against the single-device ``control_tick`` at
10^6–10^7+ entitlement rows across 1/2/4/8-way forced-host CPU meshes
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), and checks
that the sharded decisions are BIT-IDENTICAL to the unsharded kernel at
every cell.

The flag must be set before jax imports, so the measurement runs in a
fresh subprocess (the ``--worker`` entry below); the importing driver
(``sharded_tick_trajectory``) spawns it and parses one JSON blob back.

**Reading the numbers on this host.**  CI and this container expose ONE
physical core, so the S forced-host "devices" of a mesh execute their
per-shard blocks serially: the mesh wall time is ``S * T_block + O``
where ``O`` is the fixed mesh overhead (collective lowering + dispatch)
— a single core can never show a wall-clock win.  The trajectory
therefore reports, per cell:

- ``measured_speedup``  = T_full / T_mesh_wall (honest, ~<=1 here);
- ``overhead_us``       = max(0, T_mesh_wall - S * T_block);
- ``projected_speedup`` = T_full / (T_block + overhead) — the wall
  time S real devices would see, each running its own block
  concurrently and paying the measured overhead once;
- ``serial_projected_speedup`` = S * T_full / T_mesh_wall — the S
  identical per-device programs execute back-to-back on one core, so
  T_mesh/S bounds one device's program (collective payloads here are
  shard roots and scalars, a few KB — negligible on real links).

The mesh cells are measured at STEADY STATE: inputs are pre-sharded
onto their devices (``NamedSharding(mesh, P("rows"))``) exactly as a
sharded resident store holds them between ticks — row-sharded kernel
outputs feed the next tick's inputs without resharding, so a per-call
device-0 scatter would charge the mesh for a copy the production loop
never performs.

The acceptance gate is on the conservative PROJECTED speedup (>=2x at
4M rows on the 4-device mesh) plus bitwise decision parity at every
cell; the raw terms are all in ``BENCH_shard.json`` so the projection
is auditable.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

#: rows are powers of two so every mesh splits them evenly and the
#: single-device width is identical to the sharded width (bitwise
#: comparison needs the exact same padded arrays).
FULL_ROWS = [1_048_576, 4_194_304, 16_777_216]
QUICK_ROWS = [65_536, 262_144]
DEVICES = [1, 2, 4, 8]
MARK = "SHARD_SCALE_JSON:"


# ---------------------------------------------------------------------------
# worker — runs in the forced-host subprocess
# ---------------------------------------------------------------------------

def _median_us(fn, reps: int) -> float:
    fn()                                           # warm / compile
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2] * 1e6


def _worker(cfg: dict) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.core import PriorityCoefficients
    from repro.core.control_plane import ControlState, control_tick
    from repro.core.shard_plane import (
        row_mesh,
        shard_admit_quantum,
        shard_tick,
    )
    from repro.core.vectorized import admit_quantum

    coeff = PriorityCoefficients()
    devices = [s for s in cfg["devices"] if s <= len(jax.devices())]
    out = {
        "devices_visible": len(jax.devices()),
        "cells": [],
        "admission": None,
    }

    def build(n):
        rng = np.random.RandomState(7)
        f32 = lambda x: jnp.asarray(x, jnp.float32)       # noqa: E731
        state = ControlState(
            class_code=jnp.asarray(rng.randint(0, 5, n), jnp.int32),
            bound=jnp.ones(n, bool),
            baseline_tps=f32(rng.uniform(10, 100, n)),
            baseline_kv=jnp.zeros(n, jnp.float32),
            baseline_conc=jnp.full(n, 8.0, jnp.float32),
            slo_ms=f32(rng.uniform(100, 30000, n)),
            burst=f32(rng.uniform(0, 0.5, n)),
            debt=f32(rng.uniform(-0.1, 0.5, n)))
        cols = (f32(rng.uniform(0, 120, n)), jnp.zeros(n, jnp.float32),
                f32(rng.randint(0, 8, n)), f32(rng.uniform(0, 200, n)))
        return state, cols

    for n in cfg["rows"]:
        reps = max(1, cfg["reps"] if n <= 2_000_000 else cfg["reps"] // 2)
        state, cols = build(n)
        cap = jnp.float32(25.0 * n)
        slo = jnp.float32(10_000.0)

        def full():
            control_tick(state, cap, *cols, slo,
                         coeff=coeff)[1].block_until_ready()
        t_full = _median_us(full, reps)
        ref = control_tick(state, cap, *cols, slo, coeff=coeff)

        for s in devices:
            mesh = row_mesh(s)
            # steady state: a sharded resident store keeps each block
            # ON its device between ticks (out_specs feed in_specs),
            # so the measured call must not pay a device-0 reshard —
            # pre-shard the inputs exactly as the store would hold them
            rowsh = NamedSharding(mesh, PartitionSpec("rows"))
            sstate = jax.device_put(state, rowsh)
            scols = tuple(jax.device_put(c, rowsh) for c in cols)

            def mesh_tick():
                shard_tick(sstate, cap, *scols, slo, coeff=coeff,
                           mesh=mesh)[1].block_until_ready()
            t_mesh = _median_us(mesh_tick, reps)

            got = shard_tick(sstate, cap, *scols, slo, coeff=coeff,
                             mesh=mesh)
            bit = bool(jnp.array_equal(ref[1], got[1])) and all(
                bool(jnp.array_equal(a, b)) for a, b in
                zip(jax.tree_util.tree_leaves(ref[0]),
                    jax.tree_util.tree_leaves(got[0])))

            # one device's shard of work, on the single-device kernel
            b = n // s
            bstate = jax.tree_util.tree_map(lambda x: x[:b], state)
            bcols = tuple(c[:b] for c in cols)

            def block():
                control_tick(bstate, cap, *bcols, slo,
                             coeff=coeff)[1].block_until_ready()
            t_block = _median_us(block, reps)

            overhead = max(0.0, t_mesh - s * t_block)
            out["cells"].append({
                "rows": n,
                "devices": s,
                "full_tick_us": round(t_full, 1),
                "block_tick_us": round(t_block, 1),
                "mesh_wall_us": round(t_mesh, 1),
                "overhead_us": round(overhead, 1),
                "measured_speedup": round(t_full / t_mesh, 3),
                "projected_speedup": round(
                    t_full / (t_block + overhead), 2),
                # the S per-device programs serialize on this host's
                # one core, so T_mesh/S bounds one device's program
                "serial_projected_speedup": round(
                    s * t_full / t_mesh, 2),
                "decisions_equal": bit,
            })

    # sharded admission parity at scale: same requests, same answers
    n, m = cfg["admit_rows"], cfg["admit_reqs"]
    rng = np.random.RandomState(11)
    state, _ = build(n)
    kw = dict(
        bucket_level=jnp.asarray(rng.uniform(0, 200, n), jnp.float32),
        in_flight=jnp.asarray(rng.randint(0, 4, n), jnp.int32),
        kv_in_use=jnp.zeros(n, jnp.float32),
        pool_in_flight=jnp.int32(3),
        pool_conc_cap=jnp.float32(float(n)),
        running_min_priority=jnp.float32(np.inf),
        pool_avg_slo=jnp.float32(1000.0),
        req_ent=jnp.asarray(rng.randint(0, n, m), jnp.int32),
        req_tokens=jnp.full(m, 128.0, jnp.float32),
        req_kv=jnp.zeros(m, jnp.float32))
    ref_adm = admit_quantum(state, **kw, coeff=coeff)
    adm_equal = True
    for s in devices:
        got_adm = shard_admit_quantum(state, **kw, coeff=coeff,
                                      mesh=row_mesh(s))
        adm_equal &= all(bool(jnp.array_equal(a, b))
                         for a, b in zip(ref_adm, got_adm))
    out["admission"] = {"rows": n, "requests": m,
                        "devices": devices,
                        "decisions_equal": bool(adm_equal)}
    return out


# ---------------------------------------------------------------------------
# driver — spawns the forced-host subprocess
# ---------------------------------------------------------------------------

def sharded_tick_trajectory(quick: bool = False,
                            max_devices: int = 8) -> dict:
    cfg = {
        "rows": QUICK_ROWS if quick else FULL_ROWS,
        "devices": [s for s in DEVICES if s <= max_devices],
        "reps": 3 if quick else 5,
        "admit_rows": 4_096 if quick else 65_536,
        "admit_reqs": 1_024 if quick else 8_192,
    }
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{max_devices}")
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"),
                    env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.shard_scale", "--worker",
         json.dumps(cfg)],
        capture_output=True, text=True, env=env, cwd=root,
        timeout=600 if quick else 3600, check=False)
    for line in proc.stdout.splitlines():
        if line.startswith(MARK):
            return json.loads(line[len(MARK):])
    raise RuntimeError(
        f"shard_scale worker produced no result "
        f"(rc={proc.returncode}):\n{proc.stdout[-2000:]}"
        f"\n{proc.stderr[-2000:]}")


def main(quick: bool = False, out_json: str | None = None) -> None:
    res = sharded_tick_trajectory(quick=quick)
    gate_rows, gate_dev = (None, None) if quick else (4_194_304, 4)
    gates = {}
    for c in res["cells"]:
        tag = f"{c['rows'] // 1000}k_x{c['devices']}dev"
        print(f"shard_tick_mesh_wall_{tag},{c['mesh_wall_us']:.0f},"
              f"us (block {c['block_tick_us']:.0f} + overhead "
              f"{c['overhead_us']:.0f})")
        print(f"shard_tick_projected_{tag},{c['projected_speedup']:.2f},"
              f"x over single-device (serial-program bound "
              f"{c['serial_projected_speedup']:.2f}x; measured on "
              f"1 core: {c['measured_speedup']:.2f}x)")
        print(f"shard_tick_decisions_equal_{tag},"
              f"{int(c['decisions_equal'])},bitwise")
        if c["rows"] == gate_rows and c["devices"] == gate_dev:
            ok = c["projected_speedup"] >= 2.0
            gates["shard_projected_ge_2x_at_4m_x4"] = bool(ok)
            print(f"gate_shard_projected_ge_2x_4m_x4,"
                  f"{c['projected_speedup']:.2f},x "
                  f"({'PASS' if ok else 'FAIL'})")
    parity_ok = (all(c["decisions_equal"] for c in res["cells"])
                 and res["admission"]["decisions_equal"])
    gates["shard_decisions_bitwise_equal"] = bool(parity_ok)
    print(f"gate_shard_decisions_equal,{int(parity_ok)},"
          f"bitwise incl. admission "
          f"({'PASS' if parity_ok else 'FAIL'})")

    if out_json:
        os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
        with open(out_json, "w") as f:
            json.dump({
                "benchmark": "shard_scale",
                "quick": quick,
                "devices_visible": res["devices_visible"],
                "acceptance": ("projected >=2x over single-device at "
                               "4M rows on the 4-device mesh; sharded "
                               "decisions bitwise equal everywhere"),
                "projection": ("steady-state mesh cells (inputs "
                               "pre-sharded as the resident store "
                               "holds them); projected = T_full / "
                               "(T_block + overhead) with overhead = "
                               "mesh_wall - S*T_block — the forced-"
                               "host devices serialize on one core, "
                               "so wall time projects to one block "
                               "plus the measured mesh overhead; "
                               "serial_projected = S*T_full/mesh_wall "
                               "is the per-device-program bound"),
                "tick_trajectory": res["cells"],
                "admission_parity": res["admission"],
                "gates": gates,
            }, f, indent=2)
        print(f"# wrote {out_json}")


if __name__ == "__main__":
    if "--worker" in sys.argv:
        cfg = json.loads(sys.argv[sys.argv.index("--worker") + 1])
        print(MARK + json.dumps(_worker(cfg)))
    else:
        args = [a for a in sys.argv[1:] if a != "--quick"]
        main(quick="--quick" in sys.argv,
             out_json=args[0] if args else None)
