"""Benchmark harness — one entry per paper table/figure + the framework
benches.  Prints ``name,value,details`` CSV rows.

  experiment1   paper §5.2 Figs 2–4 (cross-class protection)
  experiment2   paper §5.3 Fig 5/6 + Table 2 (SLO fair share, debt)
  experiment3   fleet autoscaling + cross-pool rebalancing (closed
                control loop; plan_fleet latency at 8/64/512 pools)
  admission     control-plane throughput (scalar oracle vs unified tick)
  kernels       kernel/oracle micro-timings
  roofline      per-cell roofline table from dry-run artifacts (if
                benchmarks/artifacts/dryrun is populated)

``--quick`` runs a CI-sized smoke pass: tiny entitlement counts and
short simulation windows, no wall-clock thresholds asserted — it only
proves every benchmark path still executes (control-plane perf
regressions then surface as timing rows in the PR log).
"""
from __future__ import annotations

import os
import sys
import traceback


def _section(name):
    print(f"# --- {name} " + "-" * max(0, 60 - len(name)))


def main(quick: bool = False) -> None:
    failures = []

    _section("experiment1: cross-class protection (paper Figs 2-4)")
    try:
        from benchmarks.experiment1_protection import main as e1
        # TELEMETRY_snapshot.json + TRACE_overload.json: the registry
        # snapshot and Perfetto timeline of the overload incident —
        # uploaded as CI artifacts
        e1(duration=30.0 if quick else 90.0,
           artifacts_dir=os.path.join(
               os.path.dirname(__file__), "artifacts"))
    except Exception:                              # noqa: BLE001
        failures.append("experiment1")
        traceback.print_exc()

    _section("experiment2: SLO-aware fair share (paper Fig 5/6, Tab 2)")
    try:
        from benchmarks.experiment2_fairshare import main as e2
        e2(duration=60.0 if quick else 300.0)
    except Exception:                              # noqa: BLE001
        failures.append("experiment2")
        traceback.print_exc()

    _section("experiment3: fleet autoscaling + rebalancing")
    try:
        from benchmarks.experiment3_autoscale import main as e3
        # BENCH_autoscale.json: plan_fleet latency (8/64/512 pools) +
        # the surge P99 trajectory — uploaded as a CI artifact.  The
        # scenario's event timeline (surge end 65 s, scale-down after
        # cooldown) is fixed, so even --quick must run past it.
        e3(duration=80.0 if quick else 90.0,
           out_json=os.path.join(
               os.path.dirname(__file__), "artifacts",
               "BENCH_autoscale.json"))
    except Exception:                              # noqa: BLE001
        failures.append("experiment3")
        traceback.print_exc()

    _section("admission throughput (scalar oracle vs unified tick)")
    try:
        from benchmarks.admission_throughput import main as adm
        # BENCH_admission.json: scalar-vs-quantum gateway decisions/s
        # trajectory — uploaded as a CI artifact
        adm(quick=quick, out_json=os.path.join(
            os.path.dirname(__file__), "artifacts",
            "BENCH_admission.json"))
    except Exception:                              # noqa: BLE001
        failures.append("admission")
        traceback.print_exc()

    _section("kernel micro-bench")
    try:
        from benchmarks.kernel_bench import main as kb
        kb()
    except Exception:                              # noqa: BLE001
        failures.append("kernels")
        traceback.print_exc()

    _section("roofline (from dry-run artifacts)")
    art = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
    if os.path.isdir(art) and os.listdir(art):
        try:
            from repro.launch.roofline import analyze, load_artifacts
            print("arch,shape,mesh,chips,compute_s,memory_s,"
                  "collective_s,dominant,useful_ratio")
            for a in load_artifacts(art):
                r = analyze(a)
                if r is None:
                    print(f"{a['arch']},{a['shape']},{a['mesh']},,,,,SKIP,")
                else:
                    print(f"{r.arch},{r.shape},{r.mesh},{r.chips},"
                          f"{r.compute_s:.3e},{r.memory_s:.3e},"
                          f"{r.collective_s:.3e},{r.dominant},"
                          f"{r.useful_ratio:.3f}")
        except Exception:                          # noqa: BLE001
            failures.append("roofline")
            traceback.print_exc()
    else:
        print("roofline,skipped,no dry-run artifacts "
              "(run benchmarks/run_dryrun_sweep.sh)")

    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)
    print("# all benchmarks completed")


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
