"""Chaos scenario sweep — the CI chaos job's entry point.

Runs every library scenario under the full invariant registry, then
differentially replays each one (scalar vs quantum vs fast-path), and
writes ``benchmarks/artifacts/SCENARIO_report.json`` next to the
BENCH_* artifacts.  Exit status is non-zero if any invariant fired or
any replay diverged, so the CI job fails loudly.

Usage::

    PYTHONPATH=src python benchmarks/chaos_scenarios.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.chaos import (
    SCENARIOS,
    checker_catalog,
    run_replay,
    run_scenario,
)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="first two scenarios only, no replay")
    ap.add_argument("--out", default=os.path.join(
        ARTIFACTS, "SCENARIO_report.json"))
    args = ap.parse_args(argv)

    scenarios = SCENARIOS[:2] if args.quick else SCENARIOS
    report = {"checkers": checker_catalog(), "scenarios": []}
    ok = True
    for sc in scenarios:
        t0 = time.time()
        rep = run_scenario(sc)
        if not args.quick:
            replay = run_replay(sc)
            rep["replay_identical"] = replay.identical
            rep["replay_mismatches"] = replay.mismatches[:20]
            ok = ok and replay.identical
        rep["wall_s"] = round(time.time() - t0, 2)
        ok = ok and rep["passed"]
        report["scenarios"].append(rep)
        print(f"{sc.name:24s} "
              f"{'ok' if rep['passed'] else 'VIOLATIONS'} "
              f"replay={'ok' if rep.get('replay_identical', True) else 'DIVERGED'} "
              f"({rep['wall_s']}s, {rep['requests_total']} requests)")
        for v in rep["violations"][:5]:
            print(f"    {v['checker']} @ t={v['t']:.2f}: {v['message']}")
    report["passed"] = ok

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2, default=str)
    print(f"wrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
