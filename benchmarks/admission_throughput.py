"""Control-plane throughput: the retained scalar ORACLE (paper-style
per-entitlement Python loop) vs the unified vectorized tick that now
drives ``TokenPool.tick`` — plus admission decisions/second (both the
raw ``admit_quantum`` kernel and the full gateway request path) and
the multi-pool batched tick.

Headline rows:

- ``tick_speedup_100k`` — the unified tick must be ≥10× the scalar
  oracle at 10^5 entitlements (usually 100×+);
- ``gateway_speedup_10000`` — ``Gateway.handle_quantum`` (ONE fused
  kernel dispatch per quantum + batched scatter) must be ≥5× the
  per-request scalar gateway loop at 10k requests per quantum.

Pass ``out_json`` to ``main`` to dump the scalar-vs-quantum
decisions/s trajectory as a ``BENCH_admission.json`` artifact
(``benchmarks/run.py`` does; CI uploads it)."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AdmissionController,
    AdmissionRequest,
    EntitlementSpec,
    OracleRow,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
    control_tick,
    control_tick_pools,
    reference_tick,
)
from repro.core.control_plane import state_from_rows
from repro.core.vectorized import PoolArrays, admit_quantum


def scalar_admission_rate(n_requests: int = 2000) -> float:
    pool = TokenPool(PoolSpec(
        name="p", model="m", scaling=ScalingBounds(1, 1),
        per_replica=Resources(1e9, 1e12, 1e6)))
    for i in range(16):
        pool.add_entitlement(EntitlementSpec(
            name=f"e{i}", tenant_id=f"t{i}", pool="p",
            qos=QoS(ServiceClass.ELASTIC, 1000.0),
            baseline=Resources(1e6, 0.0, 1e4)))
    ctrl = AdmissionController(pool)
    t0 = time.perf_counter()
    for i in range(n_requests):
        ctrl.decide(AdmissionRequest(f"e{i % 16}", 64, 64,
                                     arrival_s=i * 1e-4,
                                     request_id=f"r{i}"))
    return n_requests / (time.perf_counter() - t0)


def vectorized_admission_rate(n_requests: int = 65536,
                              n_entitlements: int = 4096) -> float:
    rng = np.random.RandomState(0)
    arr = PoolArrays(
        class_code=jnp.asarray(rng.randint(0, 5, n_entitlements),
                               jnp.int32),
        bound=jnp.ones(n_entitlements, bool),
        baseline_tps=jnp.asarray(rng.uniform(10, 100, n_entitlements),
                                 jnp.float32),
        baseline_kv=jnp.zeros(n_entitlements, jnp.float32),
        baseline_conc=jnp.full(n_entitlements, 64.0, jnp.float32),
        slo_ms=jnp.asarray(rng.uniform(100, 30000, n_entitlements),
                           jnp.float32),
        burst=jnp.zeros(n_entitlements, jnp.float32),
        debt=jnp.zeros(n_entitlements, jnp.float32))
    req_ent = jnp.asarray(rng.randint(0, n_entitlements, n_requests),
                          jnp.int32)
    req_tok = jnp.full(n_requests, 128.0, jnp.float32)
    req_kv = jnp.zeros(n_requests, jnp.float32)
    args = dict(bucket_level=jnp.full(n_entitlements, 1e6, jnp.float32),
                in_flight=jnp.zeros(n_entitlements, jnp.int32),
                kv_in_use=jnp.zeros(n_entitlements, jnp.float32),
                pool_in_flight=jnp.int32(0),
                pool_conc_cap=jnp.float32(1e6),
                running_min_priority=jnp.float32(np.inf),
                pool_avg_slo=jnp.float32(1000.0))
    admit_quantum(arr, req_ent=req_ent, req_tokens=req_tok,
                  req_kv=req_kv, **args)[0].block_until_ready()
    times = []
    for _ in range(5):                   # median-of-5 damps jitter
        t0 = time.perf_counter()
        out = admit_quantum(arr, req_ent=req_ent, req_tokens=req_tok,
                            req_kv=req_kv, **args)
        out[0].block_until_ready()
        times.append(time.perf_counter() - t0)
    return n_requests / sorted(times)[len(times) // 2]


def _bench_gateway(n_entitlements: int, telemetry: bool = False):
    """One big pool of bound elastic tenants behind a gateway — the
    §4.3 hot path at multi-tenant scale (one key per entitlement)."""
    from repro.gateway import Gateway
    pool = TokenPool(PoolSpec(
        name="p", model="m", scaling=ScalingBounds(1, 1),
        per_replica=Resources(1e9, 1e15, 1e6)))
    gw = Gateway(pool, telemetry=telemetry)
    for i in range(n_entitlements):
        pool.add_entitlement(EntitlementSpec(
            name=f"e{i}", tenant_id=f"t{i}", pool="p",
            qos=QoS(ServiceClass.ELASTIC, 1000.0),
            baseline=Resources(1e6, 0.0, 1e3)))
        gw.register_key(f"k{i}", f"e{i}", pool="p")
    return gw


def gateway_admission_rates(n_requests: int, n_entitlements: int = 512
                            ) -> tuple[float, float]:
    """(scalar gateway loop, batched handle_quantum) decisions/s for
    one scheduling quantum of ``n_requests`` — same workload, full
    bookkeeping on both paths.  The quantum path is measured at
    STEADY STATE: one warm-up quantum pays the per-deployment
    one-time costs (kernel compile, route-JSON first touch, request
    table growth), then best-of-3 timed quanta with fresh request
    ids — a production gateway serves quanta continuously, so
    per-quantum throughput is the meaningful rate."""
    from repro.gateway import QuantumRequest

    gw = _bench_gateway(n_entitlements)
    t0 = time.perf_counter()
    for i in range(n_requests):
        gw.handle(f"k{i % n_entitlements}", f"r{i}", 64, 64, now=0.0)
    scalar = n_requests / (time.perf_counter() - t0)

    mkreqs = lambda tag: [                                  # noqa: E731
        QuantumRequest(f"k{i % n_entitlements}", f"{tag}{i}", 64, 64)
        for i in range(n_requests)]
    gw_q = _bench_gateway(n_entitlements)
    gw_q.handle_quantum(mkreqs("warm"), now=0.0)
    best = float("inf")
    for rep in range(3):
        reqs = mkreqs(f"q{rep}-")
        t0 = time.perf_counter()
        gw_q.handle_quantum(reqs, now=0.0)
        best = min(best, time.perf_counter() - t0)
    quantum = n_requests / best
    return scalar, quantum


def telemetry_overhead_rates(n_requests: int, n_entitlements: int = 512
                             ) -> tuple[float, float]:
    """(telemetry off, telemetry on) steady-state ``handle_quantum``
    decisions/s for one quantum — the observability tax.  The
    telemetry-on path adds exactly one flight-ring scatter plus one
    counter row-op per dispatched batch, so it must stay within a few
    percent of the bare gateway (gated at >=0.95x for 10k quanta)."""
    from repro.gateway import QuantumRequest

    mkreqs = lambda tag: [                                  # noqa: E731
        QuantumRequest(f"k{i % n_entitlements}", f"{tag}{i}", 64, 64)
        for i in range(n_requests)]
    # ONE gateway, telemetry toggled per quantum: comparing two
    # separate instances measures their memory-layout luck as much as
    # the telemetry branch, and on a cgroup-throttled single core the
    # run-to-run swing dwarfs a few-percent overhead.  Toggling the
    # attribute on physically identical state isolates exactly the
    # instrumented branch, and alternating which variant goes first in
    # each pair cancels the depleted-quota penalty the second quantum
    # of a pair systematically pays.  Throttle spikes (~2x, roughly
    # every third quantum on this host) still land on whichever
    # variant is unlucky, so instead of raw totals we drop the slowest
    # third of quanta from EACH variant symmetrically and compare the
    # trimmed totals — a spike inflates only the half that gets
    # trimmed away, never the estimate.
    gw = _bench_gateway(n_entitlements, telemetry=True)
    tel_obj = gw.telemetry
    gw.handle_quantum(mkreqs("warm"), now=0.0)
    reps = 12
    times = {False: [], True: []}
    for rep in range(reps):
        order = (False, True) if rep % 2 == 0 else (True, False)
        for tel in order:
            gw.telemetry = tel_obj if tel else None
            reqs = mkreqs(f"q{tel}-{rep}-")
            t0 = time.perf_counter()
            gw.handle_quantum(reqs, now=0.0)
            times[tel].append(time.perf_counter() - t0)
    gw.telemetry = tel_obj
    keep = reps - reps // 3
    trimmed = {tel: sum(sorted(ts)[:keep]) for tel, ts in times.items()}
    return (keep * n_requests / trimmed[False],
            keep * n_requests / trimmed[True])


def gateway_lifecycle_rates(n_requests: int, n_entitlements: int = 512
                            ) -> tuple[float, float]:
    """(scalar, batched) end-to-end request LIFECYCLES per second for
    one scheduling quantum: admit every request, then settle every
    admitted one — the full charge → settle → refund round trip, not
    just the admission decision.  The batched path is ONE
    ``handle_quantum`` plus ONE ``on_complete_batch`` (vectorized
    ``charge_rows`` / ``settle_rows`` row-ops on the request table);
    the scalar path is the per-request ``handle`` / ``on_complete``
    loop."""
    from repro.gateway import QuantumRequest

    gw = _bench_gateway(n_entitlements)
    t0 = time.perf_counter()
    admitted = []
    for i in range(n_requests):
        resp = gw.handle(f"k{i % n_entitlements}", f"r{i}", 64, 64,
                         now=0.0)
        if resp.status == 200:
            admitted.append(resp.request_id)
    for rid in admitted:
        gw.on_complete(rid, 64, latency_s=0.05, now=1.0)
    scalar = n_requests / (time.perf_counter() - t0)

    mkreqs = lambda tag: [                                  # noqa: E731
        QuantumRequest(f"k{i % n_entitlements}", f"{tag}{i}", 64, 64)
        for i in range(n_requests)]
    warm = _bench_gateway(n_entitlements)    # compile the padded size
    warm_resps = warm.handle_quantum(mkreqs("warm"), now=0.0)
    warm.on_complete_batch(
        [(r.request_id, 64, 0.05) for r in warm_resps
         if r.status == 200], now=1.0)
    gw_q = _bench_gateway(n_entitlements)
    reqs = mkreqs("q")
    t0 = time.perf_counter()
    resps = gw_q.handle_quantum(reqs, now=0.0)
    gw_q.on_complete_batch(
        [(r.request_id, 64, 0.05) for r in resps if r.status == 200],
        now=1.0)
    quantum = n_requests / (time.perf_counter() - t0)
    return scalar, quantum


def _resident_pool(n: int, seed: int = 0) -> TokenPool:
    """One pool with ``n`` resident mixed-class entitlements and a
    seeded demand signal — the end-to-end ``TokenPool.tick`` workload."""
    from repro.core.types import PoolSpec as PS
    pool = TokenPool(PS(
        name="p", model="m", scaling=ScalingBounds(1, 1),
        per_replica=Resources(100.0 * n, 1e18, 1e9),
        history_maxlen=8))
    rng = np.random.RandomState(seed)
    classes = list(ServiceClass)
    for i in range(n):
        klass = classes[rng.randint(0, 5)]
        base = (0.0 if klass in (ServiceClass.SPOT,
                                 ServiceClass.PREEMPTIBLE)
                else float(rng.uniform(10, 100)))
        pool.add_entitlement(EntitlementSpec(
            name=f"e{i}", tenant_id=f"t{i}", pool="p",
            qos=QoS(klass, float(rng.uniform(100, 30000))),
            baseline=Resources(base, 0.0, 8.0)))
    # seed a demand window directly in the resident columns (one
    # vectorized write — this is setup, not the measured path)
    alive = pool.store.col["alive"]
    pool.store.col["demand_window"][alive] = rng.uniform(
        0, 200, int(alive.sum()))
    return pool


def _gather_shell_tick(shell: dict, now: float) -> None:
    """The PRE-RESIDENT tick shell, kept here as the benchmark
    baseline: gather every row from plain-Python status dataclasses +
    demand dicts (O(n) attribute/dict work per tick), run the same
    fused kernel, scatter results back per name and re-rate each
    dict-backed ledger bucket per name.  ``shell`` holds exactly what
    the old ``TokenPool`` held — plain ``EntitlementStatus`` objects,
    a standalone dict-of-``TokenBucket`` ledger, and the spec-derived
    static row cache — so the baseline measures the historical
    dataclass/dict cost, not today's view-property overhead."""
    from repro.core import control_plane
    from repro.core.types import EntitlementState

    names = shell["names"]
    statuses = shell["statuses"]
    demand_tps = shell["demand_tps"]
    n = len(names)
    bound = np.zeros(n, bool)
    burst = np.zeros(n, np.float32)
    debt = np.zeros(n, np.float32)
    measured = np.zeros(n, np.float32)
    used_kv = np.zeros(n, np.float32)
    used_conc = np.zeros(n, np.float32)
    demand = np.zeros(n, np.float32)
    for i, name in enumerate(names):
        st = statuses[name]
        bound[i] = st.state == EntitlementState.BOUND
        burst[i] = st.burst
        debt[i] = st.debt
        measured[i] = st.measured_tps
        used_kv[i] = st.kv_bytes_in_use
        used_conc[i] = float(st.resident)
        demand[i] = demand_tps.get(name, 0.0)
    width = control_plane.bucket_width(n)
    pad = width - n

    def padvec(x):
        return (jnp.concatenate([jnp.asarray(x),
                                 jnp.zeros(pad, x.dtype)])
                if pad else jnp.asarray(x))

    state = control_plane.pad_state(PoolArrays(
        class_code=jnp.asarray(shell["class_code"]),
        bound=jnp.asarray(bound),
        baseline_tps=jnp.asarray(shell["baseline_tps"]),
        baseline_kv=jnp.asarray(shell["baseline_kv"]),
        baseline_conc=jnp.asarray(shell["baseline_conc"]),
        slo_ms=jnp.asarray(shell["slo_ms"]),
        burst=jnp.asarray(burst), debt=jnp.asarray(debt)), width)
    new_state, alloc, weights = control_tick(
        state, jnp.float32(shell["capacity_tps"]),
        padvec(measured), padvec(used_kv), padvec(used_conc),
        padvec(demand), jnp.float32(10_000.0),
        coeff=shell["coeff"])
    new_burst = np.asarray(new_state.burst)[:n]
    new_debt = np.asarray(new_state.debt)[:n]
    alloc_f = [float(a) for a in np.asarray(alloc)[:n]]
    ledger = shell["ledger"]
    for i, name in enumerate(names):
        st = statuses[name]
        st.burst = float(new_burst[i])
        st.debt = float(new_debt[i])
        ledger.set_rate(name, alloc_f[i], now)
    # the old TickRecord materialized every dict eagerly
    dict(zip(names, alloc_f))
    {nm: float(weights[i]) for i, nm in enumerate(names)}
    {nm: statuses[nm].debt for nm in names}


def _shell_state(pool: TokenPool) -> dict:
    """Detach a pool's state into the plain-Python form the
    pre-resident ``TokenPool`` kept: dataclass statuses, a standalone
    dict-backed ledger, demand dicts, cached static rows."""
    from repro.core import Ledger
    from repro.core.vectorized import CLASS_CODES as CC

    names = sorted(pool.entitlements)
    es = [pool.entitlements[n] for n in names]
    ledger = Ledger(burst_window_s=pool.spec.bucket_window_s)
    for n, e in zip(names, es):
        ledger.ensure(n, e.baseline.tokens_per_second, 0.0)
    return {
        "names": names,
        "statuses": {n: pool.store.snapshot_status(n) for n in names},
        "demand_tps": pool.demand_snapshot(),
        "ledger": ledger,
        "capacity_tps": pool.capacity().tokens_per_second,
        "coeff": pool.spec.coefficients,
        "class_code": np.array([CC[e.qos.service_class] for e in es],
                               np.int32),
        "baseline_tps": np.array(
            [e.baseline.tokens_per_second for e in es], np.float32),
        "baseline_kv": np.array([e.baseline.kv_bytes for e in es],
                                np.float32),
        "baseline_conc": np.array([e.baseline.concurrency for e in es],
                                  np.float32),
        "slo_ms": np.array([e.qos.slo_target_ms for e in es],
                           np.float32),
    }


def pool_tick_rates(sizes: list[int], shell_reps: int = 3,
                    resident_reps: int = 20) -> list[dict]:
    """End-to-end ``TokenPool.tick`` µs/tick trajectory: the resident
    path (arrays are truth, vectorized absorb) vs the gather/scatter
    shell baseline (per-name dict loops around the same kernel)."""
    rows = []
    for n in sizes:
        shell = _shell_state(_resident_pool(n))
        reps_s = max(1, shell_reps if n <= 10_000 else 1)
        t = 1.0
        _gather_shell_tick(shell, t)                   # warm the kernel
        t0 = time.perf_counter()
        for _ in range(reps_s):
            t += 1.0
            _gather_shell_tick(shell, t)
        shell_us = (time.perf_counter() - t0) / reps_s * 1e6

        pool = _resident_pool(n)
        reps_r = max(1, resident_reps if n <= 100_000 else 5)
        t = 1.0
        pool.tick(t)                                   # warm the kernel
        t0 = time.perf_counter()
        for _ in range(reps_r):
            t += 1.0
            pool.tick(t)
        resident_us = (time.perf_counter() - t0) / reps_r * 1e6
        rows.append({
            "rows": n,
            "gather_shell_us_per_tick": round(shell_us, 1),
            "resident_us_per_tick": round(resident_us, 1),
            "speedup": round(shell_us / resident_us, 2),
        })
    return rows


def _oracle_rows(n: int, seed: int = 0) -> list[OracleRow]:
    """A mixed-class fleet with random baselines, SLOs and demand."""
    rng = np.random.RandomState(seed)
    classes = list(ServiceClass)
    rows = []
    for i in range(n):
        klass = classes[rng.randint(0, 5)]
        base = (0.0 if klass in (ServiceClass.SPOT,
                                 ServiceClass.PREEMPTIBLE)
                else float(rng.uniform(10, 100)))
        rows.append(OracleRow(
            service_class=klass, bound=True,
            baseline_tps=base, baseline_kv=0.0, baseline_conc=8.0,
            slo_ms=float(rng.uniform(100, 30000)),
            burst=float(rng.uniform(0, 0.5)),
            debt=float(rng.uniform(-0.1, 0.5)),
            measured_tps=float(rng.uniform(0, 120)),
            used_conc=float(rng.randint(0, 8)),
            demand_tps=float(rng.uniform(0, 200))))
    return rows


def scalar_tick_us(n_entitlements: int, reps: int = 1) -> float:
    """The retained paper-style per-entitlement Python tick (oracle)."""
    rows = _oracle_rows(n_entitlements)
    cap = 25.0 * n_entitlements
    reference_tick(rows, cap, 10_000.0)          # warm caches
    t0 = time.perf_counter()
    for _ in range(reps):
        reference_tick(rows, cap, 10_000.0)
    return (time.perf_counter() - t0) / reps * 1e6


def unified_tick_us(n_entitlements: int, n_pools: int = 1,
                    reps: int = 20) -> float:
    """The unified control-plane tick (what TokenPool.tick executes),
    optionally batched across ``n_pools`` pools via the vmapped kernel."""
    rows = _oracle_rows(n_entitlements)
    state = state_from_rows(rows)
    rng = np.random.RandomState(1)
    measured = jnp.asarray(rng.uniform(0, 120, n_entitlements), jnp.float32)
    used_conc = jnp.asarray(rng.randint(0, 8, n_entitlements), jnp.float32)
    zero = jnp.zeros(n_entitlements, jnp.float32)
    demand = jnp.asarray(rng.uniform(0, 200, n_entitlements), jnp.float32)
    cap = jnp.float32(25.0 * n_entitlements)
    slo = jnp.float32(10_000.0)
    if n_pools == 1:
        fn = lambda: control_tick(state, cap, measured, zero,   # noqa: E731
                                  used_conc, demand, slo)
    else:
        stack = lambda x: jnp.broadcast_to(x, (n_pools,) + x.shape)  # noqa: E731
        states = jax.tree_util.tree_map(stack, state)
        caps = jnp.full((n_pools,), cap)
        slos = jnp.full((n_pools,), slo)
        fn = lambda: control_tick_pools(                        # noqa: E731
            states, caps, stack(measured), stack(zero),
            stack(used_conc), stack(demand), slos)
    fn()[1].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    out[1].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def main(quick: bool = False, out_json: str | None = None) -> None:
    n = 2_000 if quick else 100_000
    n_big = 10_000 if quick else 1_000_000
    s = scalar_admission_rate(200 if quick else 2000)
    if quick:
        v = vectorized_admission_rate(4096, 256)
    else:
        v = vectorized_admission_rate(65536, 4096)
    print(f"admission_scalar,{1e6 / s:.1f},decisions/s={s:.0f}")
    print(f"admission_vectorized,{1e6 / v:.3f},decisions/s={v:.0f}")

    # -- the gateway request path: per-request scalar loop vs ONE
    # handle_quantum call per batch (kernel + batched scatter)
    quantum_sizes = [256, 1024] if quick else [1_000, 10_000, 100_000]
    gw_ents = 64 if quick else 512
    trajectory = []
    for nq in quantum_sizes:
        gs, gq = gateway_admission_rates(nq, n_entitlements=gw_ents)
        speedup = gq / gs
        trajectory.append({
            "requests_per_quantum": nq,
            "entitlements": gw_ents,
            "scalar_gateway_dps": round(gs, 1),
            "quantum_gateway_dps": round(gq, 1),
            "speedup": round(speedup, 2),
        })
        note = ("smoke sizes; acceptance applies to the full run"
                if quick else "acceptance: >=5x at 10000")
        print(f"gateway_scalar_{nq},{1e6 / gs:.1f},decisions/s={gs:.0f}")
        print(f"gateway_quantum_{nq},{1e6 / gq:.2f},decisions/s={gq:.0f}")
        print(f"gateway_speedup_{nq},{speedup:.1f},x ({note})")

    # Re-measure the raw-kernel rate right next to the gateway
    # trajectory for the within-2x gate denominator: on a loaded
    # single-core host the kernel rate swings run to run, so a
    # denominator measured minutes before the numerator decorrelates
    # and the ratio gate flaps.  Adjacent measurements see the same
    # host conditions.
    if not quick:
        v = vectorized_admission_rate(65536, 4096)

    # -- the observability tax: telemetry-on vs telemetry-off
    # handle_quantum at each quantum size (flight ring + counter
    # row-ops ride the existing batch dispatch)
    telemetry_rows = []
    for nq in quantum_sizes:
        toff, ton = telemetry_overhead_rates(nq, n_entitlements=gw_ents)
        ratio = ton / toff
        telemetry_rows.append({
            "requests_per_quantum": nq,
            "entitlements": gw_ents,
            "telemetry_off_dps": round(toff, 1),
            "telemetry_on_dps": round(ton, 1),
            "on_over_off": round(ratio, 3),
        })
        print(f"telemetry_off_{nq},{1e6 / toff:.2f},decisions/s={toff:.0f}")
        print(f"telemetry_on_{nq},{1e6 / ton:.2f},decisions/s={ton:.0f}")
        print(f"telemetry_ratio_{nq},{ratio:.3f},on/off")

    # -- the full request lifecycle: admit + settle per quantum (the
    # batched charge_rows/settle_rows row-ops vs per-request loops)
    lifecycle = []
    for nq in quantum_sizes:
        ls, lq = gateway_lifecycle_rates(nq, n_entitlements=gw_ents)
        lifecycle.append({
            "requests_per_quantum": nq,
            "entitlements": gw_ents,
            "scalar_lifecycle_rps": round(ls, 1),
            "quantum_lifecycle_rps": round(lq, 1),
            "speedup": round(lq / ls, 2),
        })
        print(f"lifecycle_scalar_{nq},{1e6 / ls:.1f},lifecycles/s={ls:.0f}")
        print(f"lifecycle_quantum_{nq},{1e6 / lq:.2f},lifecycles/s={lq:.0f}")
        print(f"lifecycle_speedup_{nq},{lq / ls:.1f},x")

    # -- acceptance gates.  The 1024-quantum gate pins the PR-6 fix:
    # handle_quantum used to LOSE to the scalar loop at 1024
    # req/quantum (0.64x) because charges/settles scattered one
    # request at a time; with the request-table row-ops it must stay
    # >= 1x scalar even at this small-quantum crossover point.
    gates = {}
    by_n = {r["requests_per_quantum"]: r for r in trajectory}
    gate_n = 1024 if quick else 1_000
    if gate_n in by_n:
        ok = by_n[gate_n]["speedup"] >= 1.0
        gates[f"quantum_ge_1x_scalar_at_{gate_n}"] = bool(ok)
        print(f"gate_quantum_ge_1x_scalar_{gate_n},"
              f"{by_n[gate_n]['speedup']:.2f},x "
              f"({'PASS' if ok else 'FAIL'})")
    tel_by_n = {r["requests_per_quantum"]: r for r in telemetry_rows}
    if not quick and 10_000 in tel_by_n:
        ok = tel_by_n[10_000]["on_over_off"] >= 0.95
        gates["telemetry_within_5pct_at_10000"] = bool(ok)
        print(f"gate_telemetry_within_5pct_10000,"
              f"{tel_by_n[10_000]['on_over_off']:.3f},on/off "
              f"({'PASS' if ok else 'FAIL'})")
    if not quick and 10_000 in by_n:
        ok = by_n[10_000]["speedup"] >= 5.0
        gates["quantum_ge_5x_scalar_at_10000"] = bool(ok)
        print(f"gate_quantum_ge_5x_scalar_10000,"
              f"{by_n[10_000]['speedup']:.2f},x "
              f"({'PASS' if ok else 'FAIL'})")
        # within 2x of the raw admit_quantum kernel at 10k+ quanta
        for nq in (n for n in quantum_sizes if n >= 10_000):
            ratio = by_n[nq]["quantum_gateway_dps"] / v
            ok = ratio >= 0.5
            gates[f"quantum_within_2x_kernel_at_{nq}"] = bool(ok)
            print(f"gate_quantum_within_2x_kernel_{nq},{ratio:.2f},"
                  f"of kernel ({'PASS' if ok else 'FAIL'})")

    t_oracle = scalar_tick_us(n)
    t_unified = unified_tick_us(n, reps=5 if quick else 20)
    label = f"{n // 1000}k"
    note = ("smoke at 2k rows; acceptance applies to the full run"
            if quick else "acceptance: >=10x at 100k")
    print(f"tick_scalar_oracle_{label},{t_oracle:.0f},us_per_tick")
    print(f"tick_unified_{label},{t_unified:.0f},us_per_tick")
    print(f"tick_speedup_{label},{t_oracle / t_unified:.1f},x ({note})")

    t_1m = unified_tick_us(n_big, reps=3 if quick else 5)
    print(f"tick_unified_{n_big // 1000}k,{t_1m:.0f},us_per_tick")
    pools = 4 if quick else 8
    t_mp = unified_tick_us(n, n_pools=pools, reps=3 if quick else 10)
    print(f"tick_unified_{pools}pools_x_{label},{t_mp:.0f},"
          f"us_per_batched_tick ({t_mp / pools:.0f} us/pool)")

    # -- end-to-end TokenPool.tick: resident arrays vs the old
    # gather/scatter shell (per-name dict loops around the same kernel)
    tick_sizes = [1_000, 4_096] if quick \
        else [1_000, 10_000, 100_000, 1_000_000]
    tick_rows = pool_tick_rates(tick_sizes)
    note = ("smoke sizes; acceptance applies to the full run"
            if quick else "acceptance: >=5x at 100000")
    for row in tick_rows:
        nr = row["rows"]
        print(f"pool_tick_shell_{nr},{row['gather_shell_us_per_tick']:.0f},"
              "us_per_tick")
        print(f"pool_tick_resident_{nr},"
              f"{row['resident_us_per_tick']:.0f},us_per_tick")
        print(f"pool_tick_resident_speedup_{nr},{row['speedup']:.1f},"
              f"x ({note})")

    if out_json:
        os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
        with open(out_json, "w") as f:
            json.dump({
                "benchmark": "admission_throughput",
                "quick": quick,
                "admission_trajectory": trajectory,
                "lifecycle_trajectory": lifecycle,
                "telemetry_overhead": telemetry_rows,
                "gates": gates,
                "kernel": {
                    "scalar_decide_dps": round(s, 1),
                    "admit_quantum_dps": round(v, 1),
                },
                "tick": {
                    "rows": n,
                    "scalar_oracle_us": round(t_oracle, 1),
                    "unified_us": round(t_unified, 1),
                    "speedup": round(t_oracle / t_unified, 1),
                },
            }, f, indent=2)
        print(f"# wrote {out_json}")
        # BENCH_tick.json: the resident-vs-gather-shell TokenPool.tick
        # trajectory (CI artifact next to BENCH_admission/BENCH_autoscale)
        tick_json = os.path.join(os.path.dirname(out_json) or ".",
                                 "BENCH_tick.json")
        with open(tick_json, "w") as f:
            json.dump({
                "benchmark": "pool_tick_resident",
                "quick": quick,
                "acceptance": "resident >=5x gather shell at 100k rows",
                "tick_trajectory": tick_rows,
            }, f, indent=2)
        print(f"# wrote {tick_json}")
        # BENCH_shard.json: shard_map mesh trajectory (1M-16M rows x
        # 1/2/4/8 forced-host devices) + bitwise decision parity — the
        # worker needs XLA_FLAGS before jax import, so it runs in a
        # subprocess (see benchmarks/shard_scale.py)
        from benchmarks.shard_scale import main as shard_main
        shard_main(quick=quick, out_json=os.path.join(
            os.path.dirname(out_json) or ".", "BENCH_shard.json"))


if __name__ == "__main__":
    import sys
    args = [a for a in sys.argv[1:] if a != "--quick"]
    main(quick="--quick" in sys.argv,
         out_json=args[0] if args else None)
