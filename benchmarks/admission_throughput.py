"""Control-plane throughput: scalar (paper-style per-request Python)
vs the vectorized jit path (beyond-paper) — decisions/second and
tick latency at growing entitlement counts."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AdmissionController,
    AdmissionRequest,
    EntitlementSpec,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)
from repro.core.vectorized import (
    PoolArrays,
    admit_quantum,
    arrays_from_pool,
    tick_batch,
)


def scalar_admission_rate(n_requests: int = 2000) -> float:
    pool = TokenPool(PoolSpec(
        name="p", model="m", scaling=ScalingBounds(1, 1),
        per_replica=Resources(1e9, 1e12, 1e6)))
    for i in range(16):
        pool.add_entitlement(EntitlementSpec(
            name=f"e{i}", tenant_id=f"t{i}", pool="p",
            qos=QoS(ServiceClass.ELASTIC, 1000.0),
            baseline=Resources(1e6, 0.0, 1e4)))
    ctrl = AdmissionController(pool)
    t0 = time.perf_counter()
    for i in range(n_requests):
        ctrl.decide(AdmissionRequest(f"e{i % 16}", 64, 64,
                                     arrival_s=i * 1e-4,
                                     request_id=f"r{i}"))
    return n_requests / (time.perf_counter() - t0)


def vectorized_admission_rate(n_requests: int = 65536,
                              n_entitlements: int = 4096) -> float:
    rng = np.random.RandomState(0)
    arr = PoolArrays(
        class_code=jnp.asarray(rng.randint(0, 5, n_entitlements),
                               jnp.int32),
        bound=jnp.ones(n_entitlements, bool),
        baseline_tps=jnp.asarray(rng.uniform(10, 100, n_entitlements),
                                 jnp.float32),
        baseline_kv=jnp.zeros(n_entitlements, jnp.float32),
        baseline_conc=jnp.full(n_entitlements, 64.0, jnp.float32),
        slo_ms=jnp.asarray(rng.uniform(100, 30000, n_entitlements),
                           jnp.float32),
        burst=jnp.zeros(n_entitlements, jnp.float32),
        debt=jnp.zeros(n_entitlements, jnp.float32))
    req_ent = jnp.asarray(rng.randint(0, n_entitlements, n_requests),
                          jnp.int32)
    req_tok = jnp.full(n_requests, 128.0, jnp.float32)
    req_kv = jnp.zeros(n_requests, jnp.float32)
    args = dict(bucket_level=jnp.full(n_entitlements, 1e6, jnp.float32),
                in_flight=jnp.zeros(n_entitlements, jnp.int32),
                kv_in_use=jnp.zeros(n_entitlements, jnp.float32),
                pool_in_flight=jnp.int32(0),
                pool_conc_cap=jnp.float32(1e6),
                running_min_priority=jnp.float32(np.inf),
                pool_avg_slo=jnp.float32(1000.0))
    admit_quantum(arr, req_ent=req_ent, req_tokens=req_tok,
                  req_kv=req_kv, **args)[0].block_until_ready()
    t0 = time.perf_counter()
    out = admit_quantum(arr, req_ent=req_ent, req_tokens=req_tok,
                        req_kv=req_kv, **args)
    out[0].block_until_ready()
    return n_requests / (time.perf_counter() - t0)


def vectorized_tick_us(n_entitlements: int = 100_000) -> float:
    rng = np.random.RandomState(0)
    arr = PoolArrays(
        class_code=jnp.asarray(rng.randint(0, 5, n_entitlements),
                               jnp.int32),
        bound=jnp.ones(n_entitlements, bool),
        baseline_tps=jnp.asarray(rng.uniform(10, 100, n_entitlements),
                                 jnp.float32),
        baseline_kv=jnp.zeros(n_entitlements, jnp.float32),
        baseline_conc=jnp.full(n_entitlements, 8.0, jnp.float32),
        slo_ms=jnp.asarray(rng.uniform(100, 30000, n_entitlements),
                           jnp.float32),
        burst=jnp.zeros(n_entitlements, jnp.float32),
        debt=jnp.zeros(n_entitlements, jnp.float32))
    zero = jnp.zeros(n_entitlements, jnp.float32)
    demand = jnp.asarray(rng.uniform(0, 200, n_entitlements), jnp.float32)
    tick_batch(arr, jnp.float32(1e7), zero, zero, zero,
               demand)[1].block_until_ready()
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        out = tick_batch(arr, jnp.float32(1e7), zero, zero, zero, demand)
    out[1].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def main() -> None:
    s = scalar_admission_rate()
    v = vectorized_admission_rate()
    t = vectorized_tick_us()
    print(f"admission_scalar,{1e6 / s:.1f},decisions/s={s:.0f}")
    print(f"admission_vectorized,{1e6 / v:.3f},decisions/s={v:.0f}")
    print(f"tick_vectorized_100k_entitlements,{t:.0f},us_per_tick")


if __name__ == "__main__":
    main()
