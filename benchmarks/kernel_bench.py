"""Kernel micro-bench: interpret-mode correctness-rate + XLA reference
timings (wall-clock kernels need real TPU; CPU numbers are for the
oracle path and regression tracking)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import reference_attention
from repro.kernels.paged_attention import reference_paged_attention


def _time(fn, *args, reps=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def main() -> None:
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, H, S, dh = 1, 8, 1024, 64
    q = jax.random.normal(ks[0], (B, H, S, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, 2, S, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, 2, S, dh), jnp.float32)
    ref = jax.jit(lambda a, b, c: reference_attention(a, b, c,
                                                      causal=True))
    us = _time(ref, q, k, v)
    print(f"flash_attention_ref_xla_{B}x{H}x{S}x{dh},{us:.0f},us_per_call")

    qd = jax.random.normal(ks[0], (8, 8, 64), jnp.float32)
    kp = jax.random.normal(ks[1], (64, 16, 2, 64), jnp.float32)
    vp = jax.random.normal(ks[2], (64, 16, 2, 64), jnp.float32)
    bt = jnp.tile(jnp.arange(8, dtype=jnp.int32)[:, None], (1, 8))
    cl = jnp.full((8,), 100, jnp.int32)
    refp = jax.jit(lambda a, b, c, d, e: reference_paged_attention(
        a, b, c, d, e))
    us = _time(refp, qd, kp, vp, bt, cl)
    print(f"paged_attention_ref_xla_b8_p8x16,{us:.0f},us_per_call")


if __name__ == "__main__":
    main()
