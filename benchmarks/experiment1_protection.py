"""Experiment 1 — Cross-class protection (paper §5.2, Figs. 2–4).

Scenario: "Someone's batch job flooded the inference endpoint and our
production latency spiked."

Three entitlements share a 16-slot / 240 tok/s pool (the paper's single
vLLM replica serving Qwen3-8B): guaranteed-a (6 slots), spot-b
(10 slots), guaranteed-c (6 slots, joining t=30..60 s).  Phase 2 demand
is 22 slots vs 16 — the paper's 38% overload.  64-token inputs/outputs.

Claims validated against the paper:
  C1  token pools: guaranteed P99 TTFT stays bounded (paper: <1.2 s)
      through all phases;
  C2  baseline (no admission control): latency grows unboundedly
      (paper: 19+ s by the end of Phase 2) and the queue deepens
      (paper: ~34 requests) — ALL classes degrade;
  C3  with token pools the waiting queue stays ~empty — excess spot
      gets 429 + Retry-After instead of queueing;
  C4  spot's slot share is squeezed toward zero while guaranteed-c is
      present, and recovers immediately after it departs (Fig. 4);
  C5  a large fraction of spot traffic is throttled during overload
      (paper: 47% spot throttle rate).
"""
from __future__ import annotations

from repro.core import ServiceClass
from repro.serving import ServingSimulator, Workload
from repro.serving.request import RequestState, percentile


def build(admission: bool, duration: float = 90.0,
          telemetry=None) -> ServingSimulator:
    service_time = 64.0 / (240.0 / 16.0)      # ≈4.27 s per request
    rate_for = lambda slots: slots / service_time   # noqa: E731
    workloads = [
        Workload(name="guaranteed-a", service_class=ServiceClass.GUARANTEED,
                 slots=6, slo_ms=200.0, rate_rps=rate_for(6)),
        Workload(name="spot-b", service_class=ServiceClass.SPOT,
                 slots=10, slo_ms=30000.0, rate_rps=rate_for(10)),
        Workload(name="guaranteed-c", service_class=ServiceClass.GUARANTEED,
                 slots=6, slo_ms=200.0, rate_rps=rate_for(6),
                 start_s=30.0, end_s=60.0),
    ]
    return ServingSimulator(workloads, replica_slots=16,
                            replica_tps=240.0, n_replicas=1,
                            admission=admission, telemetry=telemetry)


def phase_ttft_p99(sim: ServingSimulator, ent: str, t0: float,
                   t1: float) -> float:
    vals = [r.ttft for r in sim.requests.values()
            if r.entitlement == ent and r.ttft is not None
            and t0 <= r.arrival_s < t1]
    return percentile(vals, 99)


def run(duration: float = 90.0) -> dict:
    pools = build(admission=True)
    pools.run(duration)
    base = build(admission=False)
    base.run(duration)

    out: dict = {"duration_s": duration}
    # C1/C2: guaranteed P99 TTFT per phase
    for name, sim in (("token_pools", pools), ("baseline", base)):
        out[name] = {
            "guaranteed_a_ttft_p99": {
                "phase1": phase_ttft_p99(sim, "guaranteed-a", 0, 30),
                "phase2": phase_ttft_p99(sim, "guaranteed-a", 30, 60),
                "phase3": phase_ttft_p99(sim, "guaranteed-a", 60, duration),
            },
            "max_waiting_queue": max(p.waiting for p in sim.timeline),
            "summary": sim.summary()["per_entitlement"],
        }
    # C4: spot slot share before/during/after guaranteed-c
    def spot_share(sim, t0, t1):
        pts = [p for p in sim.timeline if t0 <= p.t < t1 and p.running]
        if not pts:
            return 0.0
        return sum(p.per_ent_running.get("spot-b", 0) / max(p.running, 1)
                   for p in pts) / len(pts)
    out["spot_share"] = {
        "phase1": spot_share(pools, 10, 30),
        "phase2": spot_share(pools, 35, 60),
        "phase3": spot_share(pools, 65, duration),
    }
    # C5: spot throttle rate during overload
    spot = [r for r in pools.requests.values()
            if r.entitlement == "spot-b" and 30 <= r.arrival_s < 60]
    denied = sum(r.state == RequestState.DENIED for r in spot)
    out["spot_throttle_rate_phase2"] = denied / max(len(spot), 1)
    return out


def write_telemetry_artifacts(out_dir: str,
                              duration: float = 90.0) -> dict:
    """Re-run the token-pools arm with the telemetry plane attached and
    export what an operator would pull off the paper's platform during
    the §5.2 overload incident: ``TELEMETRY_snapshot.json`` (the full
    registry — admission verdict counters, bucket-level / debt gauges,
    per-tier SLO attainment) and ``TRACE_overload.json`` (a
    Chrome-trace / Perfetto timeline of control ticks, admission
    quanta and the overload incident markers)."""
    import json
    import os

    sim = build(admission=True, telemetry=True)
    sim.run(duration)
    tel = sim.telemetry
    os.makedirs(out_dir, exist_ok=True)
    snap_path = os.path.join(out_dir, "TELEMETRY_snapshot.json")
    with open(snap_path, "w") as f:
        json.dump(tel.snapshot(), f, indent=1, sort_keys=True)
    trace_path = os.path.join(out_dir, "TRACE_overload.json")
    with open(trace_path, "w") as f:
        f.write(tel.chrome_trace())
    return {"snapshot": snap_path, "trace": trace_path,
            "flight_rows": len(tel.flight)}


def main(duration: float = 90.0, artifacts_dir: str | None = None) -> None:
    res = run(duration)
    tp = res["token_pools"]["guaranteed_a_ttft_p99"]
    bl = res["baseline"]["guaranteed_a_ttft_p99"]
    print("experiment1,metric,token_pools,baseline,paper_claim")
    print(f"experiment1,guaranteed_p99_ttft_phase2_s,{tp['phase2']:.3f},"
          f"{bl['phase2']:.3f},<1.2 vs 19+")
    print(f"experiment1,max_waiting_queue,"
          f"{res['token_pools']['max_waiting_queue']},"
          f"{res['baseline']['max_waiting_queue']},~0 vs ~34")
    print(f"experiment1,spot_share_phase1,{res['spot_share']['phase1']:.2f},,"
          f"~10/16")
    print(f"experiment1,spot_share_phase2,{res['spot_share']['phase2']:.2f},,"
          f"near zero")
    print(f"experiment1,spot_share_phase3,{res['spot_share']['phase3']:.2f},,"
          f"recovers")
    print(f"experiment1,spot_throttle_rate_phase2,"
          f"{res['spot_throttle_rate_phase2']:.2f},,~0.47")
    if artifacts_dir:
        art = write_telemetry_artifacts(artifacts_dir, duration)
        print(f"experiment1,telemetry_flight_rows,{art['flight_rows']},"
              f"wrote {art['snapshot']} + {art['trace']}")


if __name__ == "__main__":
    import os
    main(artifacts_dir=os.path.join(os.path.dirname(__file__),
                                    "artifacts"))
