"""Experiment 2 — SLO-aware fair share (paper §5.3, Fig. 5/6, Table 2).

Scenario: "A GPU node fails during peak hours.  Two production services
share the surviving capacity: a latency-critical coding assistant and a
batch synthetic-data pipeline.  After recovery, an analytics report
generator joins to diagnose what occurred."

Three ELASTIC entitlements (5 slots each):
  elastic-copilot   500 ms SLO   (w ≈ 93.8 with ℓ̄* = 15 250 ms)
  elastic-synth     30 s SLO     (w ≈ 20.3)  →  4.6× priority gap
  elastic-reports   5 s SLO      (w ≈ 60), joins at t = 210 s
Pool: 2 replicas × 8 slots (= the paper's 16 slots / 240 tok/s); one
replica FAILS at t = 30 s (capacity halves to 8) and recovers at 120 s.
α_slo = 2.0, α_debt = 4.0, γ_d = 0.7 — the paper's coefficients.

Claims validated:
  C1  priority weights match the paper exactly (93.8 / 20.3 / ~60);
  C2  during the outage copilot keeps the larger share; synth absorbs
      the low-priority denials (paper: 0 vs 317);
  C3  both accumulate debt, synth faster (paper peaks 0.775 vs 0.607);
      the debt narrows the priority gap (4.6× → 3.9× in the paper);
  C4  after recovery debt decays to ~0 (paper: within ~50 s);
  C5  reports joins with zero debt and competes on its SLO term only.
"""
from __future__ import annotations

from repro.core import PriorityCoefficients, ServiceClass
from repro.serving import ServingSimulator, Workload


def build() -> ServingSimulator:
    workloads = [
        Workload(name="elastic-copilot",
                 service_class=ServiceClass.ELASTIC, slots=5,
                 slo_ms=500.0, rate_rps=2.33, in_tokens=32,
                 out_tokens=32, max_retries=2),
        Workload(name="elastic-synth",
                 service_class=ServiceClass.ELASTIC, slots=5,
                 slo_ms=30000.0, rate_rps=2.33, in_tokens=64,
                 out_tokens=64, max_retries=2),
        Workload(name="elastic-reports",
                 service_class=ServiceClass.ELASTIC, slots=5,
                 slo_ms=5000.0, rate_rps=0.67, in_tokens=80,
                 out_tokens=96, start_s=210.0, max_retries=2),
    ]
    sim = ServingSimulator(
        workloads, replica_slots=8, replica_tps=120.0, n_replicas=2,
        admission=True,
        coeff=PriorityCoefficients(alpha_slo=2.0, alpha_burst=1.0,
                                   alpha_debt=4.0, gamma_debt=0.7),
        fixed_avg_slo_ms=15250.0,
        # tokens-per-minute bucket semantics (paper cites TPM quotas):
        # the 90 s outage is gated by the priority threshold (check 5),
        # not by budget exhaustion
        bucket_window_s=60.0)
    sim.at(30.0, "fail_replica", idx=1)       # outage: 16 → 8 slots
    sim.at(120.0, "recover_replica", idx=1)   # recovery
    return sim


def run(duration: float = 300.0) -> dict:
    sim = build()
    sim.run(duration)
    res = sim.summary()
    hist = sim.pool.history

    # C1: no-debt/no-burst weights from the pool's own Eq. 1
    w0 = {}
    for n in ("elastic-copilot", "elastic-synth", "elastic-reports"):
        st = sim.pool.status[n]
        saved = (st.burst, st.debt)
        st.burst = st.debt = 0.0
        w0[n] = sim.pool.priority(n)
        st.burst, st.debt = saved

    # C3: peak debts + minimum priority gap during the outage
    def series(ent, field):
        return [(h.t, getattr(h, field).get(ent, 0.0)) for h in hist]

    debt_c = series("elastic-copilot", "debts")
    debt_s = series("elastic-synth", "debts")
    peak_c = max(v for _, v in debt_c)
    peak_s = max(v for _, v in debt_s)
    gaps = [(h.t, h.priorities["elastic-copilot"]
             / max(h.priorities["elastic-synth"], 1e-9))
            for h in hist if 30 <= h.t <= 120]
    min_gap = min(g for _, g in gaps)

    # C4: debt decay time after recovery
    decay_t = None
    for t, v in debt_s:
        if t > 125 and v < 0.05:
            decay_t = t - 120.0
            break

    # C2: in-flight shares during the outage
    def share(ent):
        pts = [p for p in sim.timeline if 40 <= p.t <= 120 and p.running]
        return (sum(p.per_ent_running.get(ent, 0) for p in pts)
                / max(sum(p.running for p in pts), 1))

    return {
        "weights_no_debt": w0,
        "denied_low_priority": {
            n: sim.pool.status[n].denied_low_priority
            for n in sim.workloads},
        "successful": {n: res["per_entitlement"][n]["finished"]
                       for n in sim.workloads},
        "peak_debt": {"copilot": peak_c, "synth": peak_s,
                      "reports": max(v for _, v in series(
                          "elastic-reports", "debts"))},
        "min_priority_gap_outage": min_gap,
        "initial_priority_gap": w0["elastic-copilot"]
        / w0["elastic-synth"],
        "debt_decay_s_after_recovery": decay_t,
        "outage_share": {"copilot": share("elastic-copilot"),
                         "synth": share("elastic-synth")},
        "per_entitlement": res["per_entitlement"],
    }


def main(duration: float = 300.0) -> None:
    r = run(duration)
    w = r["weights_no_debt"]
    print("experiment2,metric,value,paper_claim")
    print(f"experiment2,w_copilot,{w['elastic-copilot']:.1f},93.8")
    print(f"experiment2,w_synth,{w['elastic-synth']:.1f},20.3")
    print(f"experiment2,w_reports,{w['elastic-reports']:.1f},~60")
    print(f"experiment2,initial_gap,{r['initial_priority_gap']:.2f},4.6x")
    print(f"experiment2,min_gap_during_outage,"
          f"{r['min_priority_gap_outage']:.2f},3.9x")
    d = r["denied_low_priority"]
    print(f"experiment2,denials_copilot,{d['elastic-copilot']},0")
    print(f"experiment2,denials_synth,{d['elastic-synth']},317")
    print(f"experiment2,denials_reports,{d['elastic-reports']},22")
    s = r["successful"]
    print(f"experiment2,success_copilot,{s['elastic-copilot']},700")
    print(f"experiment2,success_synth,{s['elastic-synth']},381")
    print(f"experiment2,success_reports,{s['elastic-reports']},60")
    p = r["peak_debt"]
    print(f"experiment2,peak_debt_copilot,{p['copilot']:.3f},0.607")
    print(f"experiment2,peak_debt_synth,{p['synth']:.3f},0.775")
    print(f"experiment2,debt_decay_s,{r['debt_decay_s_after_recovery']},~50")
    o = r["outage_share"]
    print(f"experiment2,outage_share_copilot,{o['copilot']:.2f},5-7 of 8")
    print(f"experiment2,outage_share_synth,{o['synth']:.2f},2-3 of 8")


if __name__ == "__main__":
    main()
