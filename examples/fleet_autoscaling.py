"""Fleet autoscaling + cross-pool rebalancing — the closed loop in
~70 lines.

One gateway, two pools.  A guaranteed assistant and an elastic
analytics tenant live on ``east``; at t=15 s the analytics demand
surges 4×.  The fleet planner (``PoolManager.plan_quantum``, driven by
the simulator after every batched accounting tick) sees the surge in
the SAME demand signal admission uses and scales east toward its
ceiling — new replicas come live after a provisioning lag, and when
the surge ends, cooldown hysteresis drains them back down.

At t=25 s east also loses a replica: with the pool scarce (demand
needs more replicas than maxReplicas allows), the starved elastic
entitlement is MIGRATED to the slack pool ``west`` — its token-bucket
level, in-flight requests, demand signal and accumulated debt all
carry across, and the stored route follows the entitlement.

Run:  PYTHONPATH=src python examples/fleet_autoscaling.py
"""
from repro.core import FleetPlannerConfig, ServiceClass
from repro.serving import MultiPoolSimulator, PoolSite, Workload


def main() -> None:
    sim = MultiPoolSimulator(
        workloads=[
            Workload(name="assist", service_class=ServiceClass.GUARANTEED,
                     slots=4, slo_ms=500.0, rate_rps=1.0,
                     pools=("east", "west"), max_retries=2),
            Workload(name="analytics", service_class=ServiceClass.ELASTIC,
                     slots=8, slo_ms=2000.0, rate_rps=0.8,
                     pools=("east",), max_retries=2),
        ],
        sites=[
            PoolSite("east", n_replicas=2, replica_slots=8,
                     replica_tps=120.0, max_replicas=3),
            PoolSite("west", n_replicas=1, replica_slots=8,
                     replica_tps=120.0, max_replicas=3),
        ],
        autoscale=True, provision_lag_s=3.0, drain_s=2.0,
        # persistence > provisioning lag: starvation that in-flight
        # capacity will cure is ridden out; only the outage migrates
        planner_config=FleetPlannerConfig(starve_persistence_ticks=5))
    sim.at(15.0, "set_rate", workload="analytics", rate=3.2)  # 4× surge
    sim.at(25.0, "fail_replica", pool="east", idx=1)
    sim.at(25.0, "fail_replica", pool="east", idx=2)
    sim.at(45.0, "recover_replica", pool="east", idx=1)
    sim.at(50.0, "set_rate", workload="analytics", rate=0.8)
    res = sim.run(70.0)

    print("t(s)  east west   (planner-driven replica counts)")
    for (t, e), (_, w) in list(zip(sim.replica_timeline["east"],
                                   sim.replica_timeline["west"]))[::5]:
        print(f"{t:5.0f}  {e:>4} {w:>4}")
    print("\nworkload        finished denied admitted_by_pool")
    for name, s in res["per_workload"].items():
        print(f"{name:<15} {s['finished']:>8} {s['denied_total']:>6} "
              f"{s['admitted_by_pool']}")

    # the surge scaled east up BEFORE the failure hit
    assert any(n >= 3 for t, n in sim.replica_timeline["east"]
               if 15.0 <= t < 25.0), "surge should scale east up"
    # the scarce pool shed its starved elastic tenant to west
    assert res["migrations"], "expected a rebalance migration"
    m = res["migrations"][0]
    assert m.debt > 0, "the starved tenant should carry positive debt"
    print(f"\nOK: scaled on the surge, then migrated {m.entitlement} "
          f"{m.src}->{m.dst} (debt {m.debt:+.3f} carried) "
          "when the outage starved it.")


if __name__ == "__main__":
    main()
