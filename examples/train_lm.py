"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps on synthetic structured data; loss must fall substantially.

    PYTHONPATH=src python examples/train_lm.py --steps 300
(thin wrapper over repro.launch.train)
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "tinyllama-1.1b",
                "--reduce", "100m", "--steps", "300",
                "--seq-len", "256", "--batch", "8"] + sys.argv[1:]
    main()
