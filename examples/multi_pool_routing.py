"""Multi-pool routing with spill-over — dual-pool serving in ~60 lines.

Two pools (think: two regions, or a premium and an overflow fleet)
share one gateway.  A guaranteed production tenant prefers ``east`` but
is also entitled on ``west``; a spot batch tenant prefers ``west``.
At t=20 s the east fleet LOSES its only replica: the gateway routes
production traffic across the route to ``west`` (spill-over) instead of
returning 429s, and the batched ``PoolManager.tick`` keeps both pools'
entitlement accounting in one fused control-plane dispatch.  At t=40 s
east recovers and traffic drains back.

Admission itself runs on the BATCHED quantum path (the simulator's
default): each step's arrivals go through ``Gateway.handle_quantum`` —
one fused ``admit_quantum`` dispatch per (pool, leg round), spilled
requests re-entering the next leg's batch.  Each pool decides its
batch exactly as the scalar pipeline would; with the opposite-order
routes below (east-first vs west-first), cross-pool spills settle in
leg-round order rather than the sequential loop's interleaving
(``admission_mode="scalar"`` to compare).

Run:  PYTHONPATH=src python examples/multi_pool_routing.py
"""
from repro.core import ServiceClass
from repro.serving import MultiPoolSimulator, PoolSite, Workload


def main() -> None:
    sim = MultiPoolSimulator(
        workloads=[
            Workload(name="prod-chat", service_class=ServiceClass.GUARANTEED,
                     slots=6, slo_ms=500.0, rate_rps=1.4,
                     pools=("east", "west")),
            Workload(name="batch-eval", service_class=ServiceClass.SPOT,
                     slots=8, slo_ms=30000.0, rate_rps=3.0,
                     pools=("west", "east"), max_retries=1),
        ],
        sites=[
            PoolSite("east", n_replicas=1, replica_slots=8,
                     replica_tps=120.0),
            PoolSite("west", n_replicas=2, replica_slots=8,
                     replica_tps=120.0),
        ])
    sim.at(20.0, "fail_replica", pool="east", idx=0)   # regional outage
    sim.at(40.0, "recover_replica", pool="east", idx=0)
    res = sim.run(60.0)

    print("workload        finished denied spilled admitted_by_pool")
    for name, s in res["per_workload"].items():
        print(f"{name:<15} {s['finished']:>8} {s['denied_total']:>6} "
              f"{s['spilled']:>7} {s['admitted_by_pool']}")

    # during the outage, prod-chat is served by west via spill-over
    prod = res["per_workload"]["prod-chat"]
    assert prod["spilled"] > 0, "expected cross-pool spill during outage"
    assert prod["admitted_by_pool"].get("west", 0) > 0
    outage_429s = [r for r in sim.requests.values()
                   if r.entitlement == "prod-chat"
                   and r.deny_reason == "pool_unavailable"]
    assert not outage_429s, "spill-over should absorb the outage"
    print("\nOK: the outage was absorbed by cross-pool spill-over "
          f"({prod['spilled']} prod requests served on west).")


if __name__ == "__main__":
    main()
