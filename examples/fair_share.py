"""Paper Experiment 2 as a runnable example: SLO-aware fair share with
debt-based convergence during a capacity outage.

    PYTHONPATH=src python examples/fair_share.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.experiment2_fairshare import run  # noqa: E402

r = run(duration=300.0)
w = r["weights_no_debt"]
print("Priority weights (Eq. 1, ℓ̄*=15250ms)      paper")
print(f"  copilot (500ms SLO): {w['elastic-copilot']:6.1f}    93.8")
print(f"  synth    (30s SLO): {w['elastic-synth']:6.1f}    20.3")
print(f"  reports   (5s SLO): {w['elastic-reports']:6.1f}    ~60")
print(f"\ninitial priority gap: {r['initial_priority_gap']:.2f}x "
      f"(paper 4.6x)")
print(f"min gap during outage: {r['min_priority_gap_outage']:.2f}x "
      f"(debt narrowing; paper 3.9x)")
d = r["denied_low_priority"]
print(f"\nlow-priority denials: copilot={d['elastic-copilot']} "
      f"synth={d['elastic-synth']} reports={d['elastic-reports']}"
      f"   [paper: 0 / 317 / 22]")
print(f"peak debt: synth={r['peak_debt']['synth']:.3f} "
      f"copilot={r['peak_debt']['copilot']:.3f} [paper 0.775 / 0.607]")
print(f"debt decay after recovery: "
      f"{r['debt_decay_s_after_recovery']:.0f}s [paper ~50s]")
print(f"outage slot shares: copilot={r['outage_share']['copilot']:.2f} "
      f"synth={r['outage_share']['synth']:.2f} [paper ~5 vs 2-3 of 8]")
