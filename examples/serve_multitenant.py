"""End-to-end serving driver: a reduced qwen3-8b behind the gateway
with two tenants (guaranteed + spot), continuous batching engine.

    PYTHONPATH=src python examples/serve_multitenant.py
(thin wrapper over repro.launch.serve)
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0]] + sys.argv[1:]
    main()
