"""Quickstart: token pools in 60 lines.

Creates a pool with three service classes, floods it, and shows the
paper's core behaviours: work-conserving backfill, priority-ordered
admission under contention, debt-driven fair share.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    AdmissionController,
    AdmissionRequest,
    EntitlementSpec,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)

# a pool backed by one replica: 240 tok/s, 16 decode slots
pool = TokenPool(PoolSpec(
    name="qwen3-8b", model="Qwen/Qwen3-8B",
    scaling=ScalingBounds(min_replicas=1, max_replicas=4),
    per_replica=Resources(tokens_per_second=240.0,
                          kv_bytes=16 * (1 << 30), concurrency=16.0)))

# three tenants — the paper's §4.2 TokenEntitlement CRDs
pool.add_entitlement(EntitlementSpec(
    name="prod-api", tenant_id="3ed0feec", pool="qwen3-8b",
    qos=QoS(ServiceClass.GUARANTEED, slo_target_ms=200),
    baseline=Resources(100.0, 2 * (1 << 30), 4.0)))
pool.add_entitlement(EntitlementSpec(
    name="ml-team", tenant_id="a11ce", pool="qwen3-8b",
    qos=QoS(ServiceClass.ELASTIC, slo_target_ms=1000),
    baseline=Resources(80.0, 0.0, 6.0)))
pool.add_entitlement(EntitlementSpec(
    name="crawler", tenant_id="b0b", pool="qwen3-8b",
    qos=QoS(ServiceClass.SPOT, slo_target_ms=30000),
    baseline=Resources(0.0, 0.0, 0.0)))

ctrl = AdmissionController(pool)

print("== t=0: everyone idle; spot demand arrives ==")
pool.register_deny("crawler", 500.0, low_priority=False)  # demand signal
rec = pool.tick(1.0)
print("allocations:", {k: round(v) for k, v in rec.allocations.items()})
print("  → spot backfills ALL idle capacity (work conservation)\n")

print("== prod wakes up ==")
for t in range(2, 6):
    pool.register_deny("prod-api", 100.0, low_priority=False)
    pool.register_deny("crawler", 500.0, low_priority=False)
    rec = pool.tick(float(t))
print("allocations:", {k: round(v) for k, v in rec.allocations.items()})
print("  → guaranteed reclaims its reservation within one tick\n")

print("== admission under contention ==")
# deep-pocketed tenants flood the pool (budgets pre-funded so the
# CONTENTION check — not the token budget — is what decides here)
for name in ("prod-api", "ml-team", "crawler"):
    pool.ledger.set_rate(name, 2e4, 6.0)
    pool.ledger.bucket(name).level = 8e4
for i in range(4):
    d = ctrl.decide(AdmissionRequest("prod-api", 64, 64, 6.0, f"p{i}"))
    pool.on_start(f"p{i}")
for i in range(14):                       # overflow the pool
    d = ctrl.decide(AdmissionRequest("ml-team", 64, 64, 6.0, f"e{i}"))
    if d.admitted and i < 10:
        pool.on_start(f"e{i}")     # the rest stay queued → contention
d_spot = ctrl.decide(AdmissionRequest("crawler", 64, 64, 6.0, "s0"))
retry = (f"{d_spot.retry_after_s:.2f}s" if d_spot.retry_after_s
         else "n/a")
print(f"spot admitted? {d_spot.admitted}  reason="
      f"{d_spot.reason.value if d_spot.reason else None}"
      f"  retry_after={retry}")
print("priorities:", {n: round(pool.priority(n), 1)
                      for n in pool.entitlements})
print("  → 429 + Retry-After for the lowest-priority tenant")
