"""Telemetry plane tour: registry, flight recorder, SLO tracker and
the Perfetto timeline.

    PYTHONPATH=src python examples/observability.py

Builds a two-pool gateway with ``telemetry=True``, pushes a few
admission quanta of mixed guaranteed/spot traffic, then shows what an
operator gets for free:

* ``explain(request_id)`` — the flight recorder's multi-leg decision
  narrative (why was THIS request denied, at which spill hop, against
  what priority threshold and bucket level);
* live P50/P99 + SLO attainment per tier from completion batches;
* the Prometheus text exposition of the same registry arrays
  ``pool.stats()`` reads;
* ``TRACE_observability.json`` — a Chrome-trace timeline of control
  ticks and admission quanta, loadable at https://ui.perfetto.dev.
"""
import os
import random
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.core import (  # noqa: E402
    EntitlementSpec, PoolManager, PoolSpec, QoS, Resources,
    ScalingBounds, ServiceClass,
)
from repro.gateway import Gateway, QuantumRequest  # noqa: E402

rng = random.Random(0)


def pool(name, tps, slots):
    return PoolSpec(name=name, model="qwen3-8b",
                    scaling=ScalingBounds(1, 1),
                    per_replica=Resources(tps, float(1 << 30), slots),
                    default_max_tokens=64, bucket_window_s=1.0)


mgr = PoolManager()
prod = mgr.add_pool(pool("prod", tps=600.0, slots=4.0))
burst = mgr.add_pool(pool("burst", tps=1200.0, slots=8.0))
for pl, name, klass, tps, conc in [
    (prod, "web@prod", ServiceClass.GUARANTEED, 400.0, 3.0),
    (prod, "batch@prod", ServiceClass.SPOT, 60.0, 1.0),
    (burst, "web@burst", ServiceClass.ELASTIC, 300.0, 3.0),
    (burst, "batch@burst", ServiceClass.SPOT, 120.0, 2.0),
]:
    pl.add_entitlement(EntitlementSpec(
        name=name, tenant_id=name.split("@")[0], pool=pl.spec.name,
        qos=QoS(service_class=klass, slo_target_ms=500.0),
        baseline=Resources(tps, 0.0, conc)))

gw = Gateway(mgr, telemetry=True)          # <- the whole opt-in
tel = gw.telemetry
# web spills prod -> burst; batch spills the other way round
gw.register_route("web", [("prod", "web@prod"), ("burst", "web@burst")])
gw.register_route("batch", [("burst", "batch@burst"),
                            ("prod", "batch@prod")])

# -- drive a few admission quanta + completions + control ticks --------
responses = {}
for q in range(6):
    now = 0.25 * q
    reqs = [QuantumRequest(api_key=rng.choice(["web", "batch"]),
                           request_id=f"q{q}-r{i}",
                           input_tokens=rng.choice([16, 64]),
                           max_tokens=rng.choice([32, 64]))
            for i in range(40)]
    for req, resp in zip(reqs, gw.handle_quantum(reqs, now=now)):
        responses[req.request_id] = resp
    admitted = [r for r in reqs if responses[r.request_id].status == 200]
    gw.on_complete_batch(
        [(r.request_id, rng.choice([24, 48]),
          rng.uniform(0.1, 0.8)) for r in admitted[: len(admitted) // 2]],
        now=now + 0.1)
    for pl in (prod, burst):
        pl.tick(now=now + 0.2)

# -- 1. flight recorder: explain one admit and one deny ----------------
admit_rid = next(r for r, v in responses.items() if v.status == 200)
deny_rid = next(r for r, v in responses.items() if v.status != 200)
for rid in (admit_rid, deny_rid):
    tr = tel.flight.explain(rid)
    print(f"explain({rid}): status={tr.status} reason={tr.reason}")
    for leg in tr.legs:
        print(f"  leg {leg.leg} pool={leg.pool:<6} "
              f"verdict={leg.verdict_name:<6} "
              f"prio={leg.priority:7.3f} vs thr={leg.threshold:7.3f} "
              f"bucket={leg.bucket_level:8.1f} debt={leg.debt:6.1f}")

# -- 2. SLO attainment live view ---------------------------------------
print("\nSLO attainment by tier:")
for tier, stats in tel.slo.snapshot().items():
    if stats["completions"]:
        print(f"  {tier:<12} n={stats['completions']:<4.0f} "
              f"p50={stats['p50_s'] * 1e3:7.1f}ms "
              f"p99={stats['p99_s'] * 1e3:7.1f}ms "
              f"attainment={stats['attainment']:.0%}")

# -- 3. Prometheus exposition (excerpt) --------------------------------
print("\nPrometheus exposition (admission decision counters):")
for line in tel.prometheus().splitlines():
    if line.startswith("repro_admission_decisions_total{"):
        print(f"  {line}")

# -- 4. Perfetto timeline ----------------------------------------------
out = os.path.join(os.path.dirname(__file__),
                   "TRACE_observability.json")
with open(out, "w") as f:
    f.write(tel.chrome_trace())
print(f"\nwrote {out} — open it at https://ui.perfetto.dev")
