"""Paper Experiment 1 as a runnable example: cross-class protection.

    PYTHONPATH=src python examples/overload_protection.py

Prints the phase-by-phase comparison of token pools vs the
no-admission-control baseline (Figs. 2–4 of the paper).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.experiment1_protection import run  # noqa: E402

res = run(duration=90.0)
tp = res["token_pools"]
bl = res["baseline"]

print("Cross-class protection — guaranteed-a P99 TTFT (seconds)")
print(f"{'phase':<10}{'token pools':>14}{'baseline':>12}")
for phase in ("phase1", "phase2", "phase3"):
    print(f"{phase:<10}{tp['guaranteed_a_ttft_p99'][phase]:>14.3f}"
          f"{bl['guaranteed_a_ttft_p99'][phase]:>12.3f}")
print(f"\nmax waiting queue: {tp['max_waiting_queue']} (pools) vs "
      f"{bl['max_waiting_queue']} (baseline)   [paper: ~0 vs ~34]")
print(f"spot slot share:  {res['spot_share']['phase1']:.2f} → "
      f"{res['spot_share']['phase2']:.2f} → "
      f"{res['spot_share']['phase3']:.2f}   [squeeze + recovery]")
print(f"spot throttle rate during overload: "
      f"{res['spot_throttle_rate_phase2']:.0%}   [paper: 47%]")
