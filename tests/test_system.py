"""End-to-end behaviour: a real JAX model served through the gateway
with token-pool admission (continuous batching engine), plus shortened
versions of the paper's two experiments asserting their headline claims."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import (
    EntitlementSpec,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)
from repro.gateway import Gateway
from repro.models import build_model
from repro.serving import InferenceEngine, Request, RequestState
from repro.serving.request import latency_summary


@pytest.fixture(scope="module")
def served_model():
    cfg = get_config("tinyllama-1.1b").reduced(num_layers=2,
                                               vocab_size=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def mkgateway(slots=4, tps=1e4):
    spec = PoolSpec(name="p", model="m", scaling=ScalingBounds(1, 1),
                    per_replica=Resources(tps, float(1 << 30),
                                          float(slots)),
                    default_max_tokens=8)
    pool = TokenPool(spec)
    pool.add_entitlement(EntitlementSpec(
        name="prod", tenant_id="t1", pool="p",
        qos=QoS(service_class=ServiceClass.GUARANTEED, slo_target_ms=200),
        baseline=Resources(tps / 2, 0.0, float(slots))))
    pool.add_entitlement(EntitlementSpec(
        name="batch", tenant_id="t2", pool="p",
        qos=QoS(service_class=ServiceClass.SPOT, slo_target_ms=30000),
        baseline=Resources(0.0, 0.0, 0.0)))
    # fund the spot bucket as the first backfill tick would
    pool.ledger.set_rate("batch", tps, 0.0)
    pool.ledger.bucket("batch").level = tps
    gw = Gateway(pool)
    gw.register_key("key-prod", "prod")
    gw.register_key("key-batch", "batch")
    return gw


class TestEngineEndToEnd:
    def test_serves_batched_requests_through_gateway(self, served_model):
        cfg, model, params = served_model
        gw = mkgateway(slots=4)
        eng = InferenceEngine(model, params, slots=4, max_seq=64,
                              gateway=gw)
        reqs = [Request(request_id=f"r{i}", entitlement="prod",
                        prompt_tokens=[3 + i, 5, 7], max_tokens=6,
                        arrival_s=0.0, api_key="key-prod")
                for i in range(6)]
        for r in reqs:
            eng.submit(r, now=0.0)
        eng.run_until_drained()
        done = [r for r in reqs if r.state == RequestState.FINISHED]
        assert len(done) == 6
        for r in done:
            assert len(r.output_tokens) == 6
            assert all(0 <= t < cfg.padded_vocab for t in r.output_tokens)
        # completion callbacks settled all charges
        assert gw.pool.pool_in_flight() == 0
        assert gw.pool.status["prod"].completed_total == 6
        assert float(gw.store.get("tokens:prod")) > 0

    def test_unknown_key_rejected(self, served_model):
        cfg, model, params = served_model
        eng = InferenceEngine(model, params, slots=2, max_seq=64,
                              gateway=mkgateway())
        r = Request(request_id="x", entitlement="?", prompt_tokens=[1],
                    max_tokens=4, arrival_s=0.0, api_key="bogus")
        assert not eng.submit(r, now=0.0)
        assert r.state == RequestState.DENIED

    def test_engine_decode_is_teacher_consistent(self, served_model):
        """Engine lanes must produce the same continuation as a
        standalone greedy decode of the same prompt."""
        cfg, model, params = served_model
        eng = InferenceEngine(model, params, slots=2, max_seq=64)
        prompt = [3, 5, 7, 11]
        r = Request(request_id="a", entitlement="e",
                    prompt_tokens=list(prompt), max_tokens=5,
                    arrival_s=0.0)
        eng.submit(r, now=0.0)
        eng.run_until_drained()

        # reference: single-sequence greedy decode
        cache = model.init_cache(1, 64)
        logits, cache = model.prefill(
            params, jnp.asarray([prompt], jnp.int32), cache)
        toks = [int(jnp.argmax(logits[0, -1]))]
        for i in range(4):
            logits, cache = model.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
                jnp.int32(len(prompt) + i))
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert r.output_tokens == toks

    def test_spot_throttled_when_prod_floods(self, served_model):
        cfg, model, params = served_model
        gw = mkgateway(slots=2, tps=1e4)
        eng = InferenceEngine(model, params, slots=2, max_seq=64,
                              gateway=gw)
        # fill both slots + queue with guaranteed traffic
        for i in range(4):
            eng.submit(Request(request_id=f"p{i}", entitlement="prod",
                               prompt_tokens=[2, 3], max_tokens=6,
                               arrival_s=0.0, api_key="key-prod"),
                       now=0.0)
        eng.step(now=0.0)       # two become resident, two queue
        spot = Request(request_id="s0", entitlement="batch",
                       prompt_tokens=[2], max_tokens=4, arrival_s=0.0,
                       api_key="key-batch")
        assert not eng.submit(spot, now=0.0)
        assert spot.deny_reason == "low_priority"
        assert spot.retry_after_s > 0


class TestExperimentsShort:
    """Shortened paper experiments wired as regression tests."""

    def test_exp1_protection_claims(self):
        from benchmarks.experiment1_protection import run
        res = run(duration=90.0)
        tp = res["token_pools"]["guaranteed_a_ttft_p99"]
        bl = res["baseline"]["guaranteed_a_ttft_p99"]
        # C1/C2: bounded vs unbounded latency
        assert tp["phase2"] < 1.2
        assert bl["phase2"] > 5.0
        assert bl["phase2"] > 20 * tp["phase2"]
        # C3: queue empty vs deep
        assert res["token_pools"]["max_waiting_queue"] <= 3
        assert res["baseline"]["max_waiting_queue"] > 20
        # C4: spot squeezed then recovers
        assert res["spot_share"]["phase1"] > 0.45
        assert res["spot_share"]["phase2"] < 0.35
        assert res["spot_share"]["phase3"] > 0.45
        # C5: substantial spot throttling during overload (paper: 47%)
        assert 0.3 < res["spot_throttle_rate_phase2"] < 0.8
        # guaranteed never low-priority-denied
        per = res["token_pools"]["summary"]
        assert per["guaranteed-a"]["denied_low_priority"] == 0
        assert per["guaranteed-c"]["denied_low_priority"] == 0

    def test_exp2_fairshare_claims(self):
        from benchmarks.experiment2_fairshare import run
        r = run(duration=300.0)
        w = r["weights_no_debt"]
        # C1: exact paper weights
        assert w["elastic-copilot"] == pytest.approx(93.8, abs=0.1)
        assert w["elastic-synth"] == pytest.approx(20.3, abs=0.1)
        assert w["elastic-reports"] == pytest.approx(60.4, abs=0.5)
        assert r["initial_priority_gap"] == pytest.approx(4.6, abs=0.1)
        # C2: denials directed at the loose-SLO tenant
        d = r["denied_low_priority"]
        assert d["elastic-synth"] > 100
        assert d["elastic-copilot"] <= 0.1 * d["elastic-synth"]
        # C3: synth accumulates more debt; gap narrows during outage
        assert r["peak_debt"]["synth"] > 0.15
        assert r["peak_debt"]["synth"] >= r["peak_debt"]["copilot"]
        assert r["min_priority_gap_outage"] < r["initial_priority_gap"]
        # C4: debt decays after recovery
        assert r["debt_decay_s_after_recovery"] is not None
        assert r["debt_decay_s_after_recovery"] < 60.0
        # C2b: copilot keeps the larger share during the outage
        assert r["outage_share"]["copilot"] > r["outage_share"]["synth"]
        # throughput ordering matches the paper's Table 2
        s = r["successful"]
        assert s["elastic-copilot"] > s["elastic-synth"] > \
            s["elastic-reports"]


class TestReplicaFailureAndHedging:
    def test_replica_failure_requeues_and_recovers(self):
        from repro.serving import ServingSimulator, Workload
        sim = ServingSimulator(
            [Workload(name="e", service_class=ServiceClass.ELASTIC,
                      slots=8, slo_ms=1000.0, rate_rps=2.0)],
            replica_slots=8, replica_tps=120.0, n_replicas=2)
        sim.at(10.0, "fail_replica", idx=1)
        sim.run(40.0)
        reqs = list(sim.requests.values())
        # no request is lost to the failure — all eventually finish
        finished = [r for r in reqs if r.state == RequestState.FINISHED]
        assert len(finished) >= 0.8 * len(
            [r for r in reqs if r.arrival_s < 35])
        # capacity drop reflected in pool history
        caps = {h.capacity_tps for h in sim.pool.history}
        assert len(caps) >= 2
