"""Equivalence suite for the unified control plane.

Pins the jit-compiled tick (``control_tick`` — what ``TokenPool.tick``
executes — and the vmapped ``control_tick_pools`` behind
``PoolManager.tick``) against the retained scalar oracle
(``control_plane.reference_tick``: the paper-style per-entitlement
Python loop over ``core.priority`` + ``core.pool.waterfill``) across
service-class mixes, scarcity regimes, and multi-tick debt accrual.

Deterministic seeded sweeps — runs everywhere (the hypothesis property
tests in ``test_vectorized_equiv.py`` add randomized depth when
hypothesis is installed).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EntitlementSpec,
    OracleRow,
    PoolManager,
    PoolSpec,
    PriorityCoefficients,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
    control_tick,
    control_tick_pools,
    reference_tick,
)
from repro.core.control_plane import pad_state, stack_states, state_from_rows

CLASSES = list(ServiceClass)
REL = 2e-3
ABS = 1e-2


def random_rows(n: int, rng: np.random.RandomState,
                demand_scale: float = 200.0) -> list[OracleRow]:
    rows = []
    for _ in range(n):
        klass = CLASSES[rng.randint(0, 5)]
        base = (0.0 if klass in (ServiceClass.SPOT,
                                 ServiceClass.PREEMPTIBLE)
                else float(rng.uniform(5, 100)))
        rows.append(OracleRow(
            service_class=klass,
            bound=bool(rng.rand() > 0.1),
            baseline_tps=base,
            baseline_kv=float(rng.choice([0.0, 1 << 20])),
            baseline_conc=float(rng.choice([0.0, 4.0, 16.0])),
            slo_ms=float(rng.uniform(100, 30000)),
            burst=float(rng.uniform(0, 2.0)),
            debt=float(rng.uniform(-0.15, 1.0)),
            measured_tps=float(rng.uniform(0, 150)),
            used_kv=float(rng.uniform(0, 1 << 20)),
            used_conc=float(rng.randint(0, 8)),
            demand_tps=float(rng.uniform(0, demand_scale))))
    return rows


def run_kernel(rows, capacity, avg_slo,
               coeff=PriorityCoefficients()):
    state = state_from_rows(rows)
    new_state, alloc, weights = control_tick(
        state, jnp.float32(capacity),
        jnp.asarray([r.measured_tps for r in rows], jnp.float32),
        jnp.asarray([r.used_kv for r in rows], jnp.float32),
        jnp.asarray([r.used_conc for r in rows], jnp.float32),
        jnp.asarray([r.demand_tps for r in rows], jnp.float32),
        jnp.float32(avg_slo), coeff=coeff)
    return new_state, np.asarray(alloc), np.asarray(weights)


def assert_matches_oracle(rows, capacity, avg_slo,
                          coeff=PriorityCoefficients()):
    new_state, alloc, weights = run_kernel(rows, capacity, avg_slo, coeff)
    oracle_rows, o_alloc, o_weights = reference_tick(
        rows, capacity, avg_slo, coeff)
    burst = np.asarray(new_state.burst)
    debt = np.asarray(new_state.debt)
    for i, o in enumerate(oracle_rows):
        ctx = f"row {i} ({o.service_class.value})"
        assert weights[i] == pytest.approx(o_weights[i], rel=1e-4), ctx
        assert alloc[i] == pytest.approx(o_alloc[i], rel=REL,
                                         abs=ABS), ctx
        assert burst[i] == pytest.approx(o.burst, rel=1e-4,
                                         abs=1e-5), ctx
        assert debt[i] == pytest.approx(o.debt, rel=1e-4, abs=1e-5), ctx
    return oracle_rows, o_alloc


class TestSinglePoolEquivalence:
    @pytest.mark.parametrize("seed", range(6))
    @pytest.mark.parametrize("scarcity", [0.2, 1.0, 5.0])
    def test_mixed_fleet_matches_oracle(self, seed, scarcity):
        """Random mixed-class fleets across scarcity regimes: scarcity
        <1 starves protected baselines (emergency scaling), ~1 squeezes
        elastic, >1 exercises work-conserving backfill."""
        rng = np.random.RandomState(seed)
        n = int(rng.randint(3, 40))
        rows = random_rows(n, rng)
        demand = sum(min(r.baseline_tps, r.demand_tps)
                     for r in rows if r.bound)
        capacity = max(10.0, scarcity * demand)
        assert_matches_oracle(rows, capacity, avg_slo=10_000.0)

    def test_debt_accrual_over_many_ticks(self):
        """EWMA state threading: feed each tick's output state back in
        for 25 ticks under sustained scarcity and compare trajectories."""
        rng = np.random.RandomState(7)
        rows = random_rows(12, rng)
        capacity = 0.4 * sum(r.baseline_tps for r in rows if r.bound)
        coeff = PriorityCoefficients()
        state = state_from_rows(rows)
        for t in range(25):
            measured = jnp.asarray([r.measured_tps for r in rows],
                                   jnp.float32)
            new_state, alloc, _ = control_tick(
                state, jnp.float32(capacity), measured,
                jnp.asarray([r.used_kv for r in rows], jnp.float32),
                jnp.asarray([r.used_conc for r in rows], jnp.float32),
                jnp.asarray([r.demand_tps for r in rows], jnp.float32),
                jnp.float32(10_000.0), coeff=coeff)
            rows, o_alloc, _ = reference_tick(rows, capacity, 10_000.0,
                                              coeff)
            debt = np.asarray(new_state.debt)
            burst = np.asarray(new_state.burst)
            for i, o in enumerate(rows):
                assert debt[i] == pytest.approx(o.debt, rel=1e-3,
                                                abs=1e-4), (t, i)
                assert burst[i] == pytest.approx(o.burst, rel=1e-3,
                                                 abs=1e-4), (t, i)
            # thread BOTH trajectories forward from their own state
            state = dataclasses.replace(
                state, burst=new_state.burst, debt=new_state.debt)

    def test_zero_rows(self):
        new_state, alloc, weights = run_kernel([], 100.0, 1000.0)
        assert alloc.shape == (0,) and weights.shape == (0,)

    def test_nonstandard_coefficients(self):
        rng = np.random.RandomState(3)
        rows = random_rows(10, rng)
        coeff = PriorityCoefficients(alpha_slo=0.5, alpha_burst=3.0,
                                     alpha_debt=1.0, gamma_debt=0.9,
                                     gamma_burst=0.3, debt_max=5.0)
        assert_matches_oracle(rows, 500.0, 2000.0, coeff)


class TestTokenPoolOnControlPlane:
    """The live TokenPool must produce oracle-equal ticks: gather the
    pool's own tick inputs, run BOTH paths, and keep driving the pool
    with the kernel output (the production flow)."""

    def _mkpool(self, tps=160.0):
        spec = PoolSpec(name="p", model="m",
                        scaling=ScalingBounds(1, 2),
                        per_replica=Resources(tps, 64 * (1 << 20), 16.0))
        pool = TokenPool(spec)
        mix = [("d", ServiceClass.DEDICATED, 30.0, 200.0),
               ("g", ServiceClass.GUARANTEED, 50.0, 500.0),
               ("e1", ServiceClass.ELASTIC, 60.0, 1000.0),
               ("e2", ServiceClass.ELASTIC, 40.0, 30000.0),
               ("s", ServiceClass.SPOT, 0.0, 30000.0),
               ("pe", ServiceClass.PREEMPTIBLE, 0.0, 30000.0)]
        for name, klass, tps_e, slo in mix:
            pool.add_entitlement(EntitlementSpec(
                name=name, tenant_id=name, pool="p",
                qos=QoS(service_class=klass, slo_target_ms=slo),
                baseline=Resources(tps_e, 0.0, 4.0)))
        return pool

    def test_tick_record_matches_oracle(self):
        pool = self._mkpool()
        rng = np.random.RandomState(11)
        for t in range(1, 15):
            for name in pool.entitlements:
                pool.register_deny(name, float(rng.uniform(0, 120)),
                                   low_priority=False)
            inp = pool.begin_tick(float(t))
            rows = [OracleRow(
                service_class=pool.entitlements[n].qos.service_class,
                bound=bool(np.asarray(inp.state.bound)[i]),
                baseline_tps=float(np.asarray(inp.state.baseline_tps)[i]),
                baseline_kv=float(np.asarray(inp.state.baseline_kv)[i]),
                baseline_conc=float(
                    np.asarray(inp.state.baseline_conc)[i]),
                slo_ms=float(np.asarray(inp.state.slo_ms)[i]),
                burst=float(np.asarray(inp.state.burst)[i]),
                debt=float(np.asarray(inp.state.debt)[i]),
                measured_tps=float(np.asarray(inp.measured_tps)[i]),
                used_kv=float(np.asarray(inp.used_kv)[i]),
                used_conc=float(np.asarray(inp.used_conc)[i]),
                demand_tps=float(np.asarray(inp.demand_tps)[i]))
                for i, n in enumerate(inp.names)]
            o_rows, o_alloc, o_weights = reference_tick(
                rows, inp.capacity_tps, inp.avg_slo_ms,
                pool.spec.coefficients)
            # production path: kernel → apply
            from repro.core import control_plane
            new_state, alloc, weights = control_plane.control_tick(
                inp.state, jnp.float32(inp.capacity_tps),
                inp.measured_tps, inp.used_kv, inp.used_conc,
                inp.demand_tps, jnp.float32(inp.avg_slo_ms),
                coeff=pool.spec.coefficients)
            rec = pool.apply_tick(
                float(t), inp.names, np.asarray(new_state.burst),
                np.asarray(new_state.debt), np.asarray(alloc),
                np.asarray(weights))
            for i, n in enumerate(inp.names):
                assert rec.allocations[n] == pytest.approx(
                    o_alloc[i], rel=REL, abs=ABS), (t, n)
                assert rec.priorities[n] == pytest.approx(
                    o_weights[i], rel=1e-3), (t, n)
                assert pool.status[n].debt == pytest.approx(
                    o_rows[i].debt, rel=1e-3, abs=1e-4), (t, n)

    def test_pool_tick_is_kernel_tick(self):
        """pool.tick() must equal begin_tick + control_tick + apply_tick
        run on an identically-driven twin pool."""
        a, b = self._mkpool(), self._mkpool()
        for t in range(1, 8):
            for pool in (a, b):
                pool.register_deny("e1", 100.0, low_priority=False)
                pool.register_deny("s", 300.0, low_priority=False)
            rec_a = a.tick(float(t))
            inp = b.begin_tick(float(t))
            from repro.core import control_plane
            ns, alloc, w = control_plane.control_tick(
                inp.state, jnp.float32(inp.capacity_tps),
                inp.measured_tps, inp.used_kv, inp.used_conc,
                inp.demand_tps, jnp.float32(inp.avg_slo_ms),
                coeff=b.spec.coefficients)
            rec_b = b.apply_tick(float(t), inp.names,
                                 np.asarray(ns.burst),
                                 np.asarray(ns.debt), np.asarray(alloc),
                                 np.asarray(w))
            assert rec_a.allocations == rec_b.allocations
            assert rec_a.debts == rec_b.debts


class TestMultiPoolBatchedEquivalence:
    def _pool(self, name, n_ents, seed, tps=200.0,
              coeff=PriorityCoefficients()):
        spec = PoolSpec(name=name, model="m",
                        scaling=ScalingBounds(1, 1),
                        per_replica=Resources(tps, 1 << 30, 16.0),
                        coefficients=coeff)
        pool = TokenPool(spec)
        rng = np.random.RandomState(seed)
        for i in range(n_ents):
            klass = CLASSES[rng.randint(0, 5)]
            base = (0.0 if klass in (ServiceClass.SPOT,
                                     ServiceClass.PREEMPTIBLE)
                    else float(rng.uniform(5, 60)))
            pool.add_entitlement(EntitlementSpec(
                name=f"{name}-e{i}", tenant_id=f"t{i}", pool=name,
                qos=QoS(service_class=klass,
                        slo_target_ms=float(rng.uniform(100, 30000))),
                baseline=Resources(base, 0.0, 4.0)))
        return pool

    def test_batched_tick_equals_individual_ticks(self):
        """Ragged pool widths (3/7/5 rows) through ONE vmapped dispatch
        must equal each pool ticking alone — padding cannot leak."""
        mgr_pools = [self._pool("pa", 3, 1), self._pool("pb", 7, 2),
                     self._pool("pc", 5, 3)]
        solo_pools = [self._pool("pa", 3, 1), self._pool("pb", 7, 2),
                      self._pool("pc", 5, 3)]
        mgr = PoolManager(mgr_pools)
        rng = np.random.RandomState(9)
        for t in range(1, 10):
            demands = {}
            for p in mgr_pools:
                for n in p.entitlements:
                    demands[n] = float(rng.uniform(0, 150))
            for pools in (mgr_pools, solo_pools):
                for p in pools:
                    for n in p.entitlements:
                        p.register_deny(n, demands[n],
                                        low_priority=False)
            recs = mgr.tick(float(t))
            for solo in solo_pools:
                rec_solo = solo.tick(float(t))
                rec_mgr = recs[solo.spec.name]
                for n in rec_solo.allocations:
                    assert rec_mgr.allocations[n] == pytest.approx(
                        rec_solo.allocations[n], rel=1e-5,
                        abs=1e-4), (t, n)
                    assert rec_mgr.debts[n] == pytest.approx(
                        rec_solo.debts[n], rel=1e-5, abs=1e-6), (t, n)
                    assert rec_mgr.priorities[n] == pytest.approx(
                        rec_solo.priorities[n], rel=1e-5), (t, n)

    def test_mixed_coefficient_groups(self):
        """Pools with different (static-arg) coefficients tick in
        separate kernel groups but one manager call."""
        fast = PriorityCoefficients(gamma_debt=0.3)
        mgr = PoolManager([self._pool("pa", 4, 1),
                           self._pool("pb", 4, 2, coeff=fast)])
        for name, pool in mgr.pools.items():
            for n in pool.entitlements:
                pool.register_deny(n, 100.0, low_priority=False)
        recs = mgr.tick(1.0)
        assert set(recs) == {"pa", "pb"}
        assert all(len(r.allocations) == 4 for r in recs.values())

    def test_vmapped_kernel_matches_oracle_per_pool(self):
        """control_tick_pools vs reference_tick, pool by pool."""
        rng = np.random.RandomState(42)
        pools_rows = [random_rows(int(rng.randint(2, 12)), rng)
                      for _ in range(4)]
        caps = [float(rng.uniform(50, 800)) for _ in pools_rows]
        slos = [float(rng.uniform(500, 20000)) for _ in pools_rows]
        width = max(len(r) for r in pools_rows)

        def padded(vals):
            return jnp.stack([
                jnp.concatenate([jnp.asarray(v, jnp.float32),
                                 jnp.zeros(width - len(v), jnp.float32)])
                for v in vals])

        states = stack_states([state_from_rows(r) for r in pools_rows])
        ns, alloc, weights = control_tick_pools(
            states, jnp.asarray(caps, jnp.float32),
            padded([[r.measured_tps for r in rows]
                    for rows in pools_rows]),
            padded([[r.used_kv for r in rows] for rows in pools_rows]),
            padded([[r.used_conc for r in rows] for rows in pools_rows]),
            padded([[r.demand_tps for r in rows] for rows in pools_rows]),
            jnp.asarray(slos, jnp.float32))
        alloc = np.asarray(alloc)
        debt = np.asarray(ns.debt)
        for k, rows in enumerate(pools_rows):
            o_rows, o_alloc, _ = reference_tick(rows, caps[k], slos[k])
            for i in range(len(rows)):
                assert alloc[k, i] == pytest.approx(
                    o_alloc[i], rel=REL, abs=ABS), (k, i)
                assert debt[k, i] == pytest.approx(
                    o_rows[i].debt, rel=1e-3, abs=1e-4), (k, i)
            # padding rows stay inert
            assert (alloc[k, len(rows):] == 0.0).all()

    def test_pad_state_is_inert(self):
        rows = random_rows(5, np.random.RandomState(0))
        state = state_from_rows(rows)
        padded = pad_state(state, 9)
        assert padded.n_rows == 9
        assert not np.asarray(padded.bound)[5:].any()
