"""Fleet planner: plan_fleet == scalar Autoscaler parity (per pool,
across regimes), entitlement migration invariants (bucket level, debt,
in-flight records carried), virtual-node preemption on planned shrink,
and the closed plan_quantum loop with cross-pool rebalancing."""
import dataclasses

import pytest

from repro.core import (
    Autoscaler,
    AutoscalerConfig,
    EntitlementSpec,
    EntitlementState,
    FleetPlanner,
    FleetPlannerConfig,
    PoolManager,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TickRecord,
    TokenPool,
)
from repro.core.fleet import plan_fleet
from repro.core.markers import KERNELS
from repro.gateway import Gateway


def mkpool(name, lo=1, hi=4, per_tps=240.0, per_conc=8.0,
           bucket_window_s=4.0):
    return TokenPool(PoolSpec(
        name=name, model="m", scaling=ScalingBounds(lo, hi),
        per_replica=Resources(per_tps, 0.0, per_conc),
        default_max_tokens=64, bucket_window_s=bucket_window_s))


def ent(name, pool, klass=ServiceClass.ELASTIC, tps=240.0, conc=2.0,
        slo=1000.0):
    return EntitlementSpec(
        name=name, tenant_id="t", pool=pool,
        qos=QoS(service_class=klass, slo_target_ms=slo),
        baseline=Resources(tps, 0.0, conc))


def mkrecord(t, demand: dict) -> TickRecord:
    return TickRecord(t=t, capacity_tps=0.0, allocations={},
                      priorities={}, debts={}, bursts={}, in_flight={},
                      demand_tps=dict(demand))


# -- parity: plan_fleet == scalar Autoscaler ---------------------------------

CFG = dict(headroom=1.2, demand_ewma=0.5, cooldown_ticks=3)


def test_plan_fleet_registered_against_scalar_oracle():
    """The fused kernel driven throughout this module is the registered
    ``plan_fleet`` entry point, pinned to the scalar Autoscaler oracle —
    the oracle-parity analyzer pass keys off both symbols here."""
    spec = KERNELS["plan_fleet"]
    assert spec.oracle == "repro.core.autoscaler.Autoscaler.plan"
    assert callable(plan_fleet)


def run_parity(pool_params, demand_rounds, cfg=CFG):
    """Drive N pools through the fleet kernel and N scalar autoscalers
    through the same demand sequences; pin every decision equal and
    apply it, so hysteresis state evolves identically on both sides."""
    pools, scalars = {}, {}
    for name, kw, ents in pool_params:
        pool = mkpool(name, **kw)
        for e in ents:
            pool.add_entitlement(e)
        pools[name] = pool
        scalars[name] = Autoscaler(pool, AutoscalerConfig(**cfg))
    planner = FleetPlanner(FleetPlannerConfig(**cfg))

    for t, demands in enumerate(demand_rounds, start=1):
        records = {n: mkrecord(float(t), {"d": demands[n]})
                   for n in pools}
        plan = planner.plan(pools, records, float(t))
        for n, pool in pools.items():
            a = scalars[n]
            a.observe_demand(demands[n])
            sd = a.plan()
            fd = plan.decisions[n]
            assert (fd.desired, fd.reason) == (sd.desired, sd.reason), \
                (n, t, fd, sd)
            assert fd.demand_tps == pytest.approx(sd.demand_tps,
                                                  rel=1e-6)
            assert fd.current == sd.current
        for n, pool in pools.items():
            pool.set_replicas(plan.decisions[n].desired)
    return planner


class TestPlanFleetParity:
    def test_mixed_regimes_deterministic(self):
        """One sweep crossing every policy branch: reserved floor,
        demand scale-up, cooldown hold, scale-down, clamps."""
        params = [
            ("res", dict(hi=8), [ent("g", "res",
                                     ServiceClass.GUARANTEED, 480.0)]),
            ("dem", dict(hi=8), []),
            ("clamp", dict(hi=2), [ent("e", "clamp",
                                       ServiceClass.ELASTIC, 100.0)]),
            ("conc", dict(hi=8, per_conc=2.0),
             [ent("c", "conc", ServiceClass.GUARANTEED, 60.0,
                  conc=7.0)]),
            ("empty", dict(hi=8), []),
        ]
        demand_rounds = [
            {"res": 0.0, "dem": 1900.0, "clamp": 5000.0, "conc": 0.0,
             "empty": 0.0},
            {"res": 100.0, "dem": 1900.0, "clamp": 0.0, "conc": 333.3,
             "empty": 77.7},
            {"res": 0.0, "dem": 0.0, "clamp": 0.0, "conc": 0.0,
             "empty": 0.0},
            {"res": 0.0, "dem": 0.0, "clamp": 0.0, "conc": 0.0,
             "empty": 0.0},
            {"res": 0.0, "dem": 2500.0, "clamp": 0.0, "conc": 0.0,
             "empty": 0.0},
            {"res": 0.0, "dem": 0.0, "clamp": 0.0, "conc": 0.0,
             "empty": 0.0},
        ]
        run_parity(params, demand_rounds)

    def test_64_pools_one_dispatch(self):
        """ISSUE acceptance: ONE fused plan_fleet dispatch plans ≥64
        pools, each pinned to its scalar oracle."""
        classes = [ServiceClass.GUARANTEED, ServiceClass.ELASTIC,
                   ServiceClass.SPOT, ServiceClass.DEDICATED]
        params = []
        for i in range(64):
            name = f"p{i:02d}"
            ents = [ent(f"e{i}", name, classes[i % 4],
                        tps=40.0 * (i % 7), conc=float(i % 3))]
            params.append((name, dict(hi=2 + i % 7,
                                      per_tps=120.0 + 60.0 * (i % 3)),
                           ents))
        demand_rounds = [
            {f"p{i:02d}": (37.0 * ((i * r) % 11)) for i in range(64)}
            for r in range(4)]
        planner = run_parity(params, demand_rounds)
        # all 64 decided by the same planner state (one kernel call per
        # round — FleetPlanner.plan dispatches plan_fleet exactly once)
        assert len(planner._state) == 64

    def test_hypothesis_sweep(self):
        hypothesis = pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (pip install -r "
                   "requirements-dev.txt)")
        from hypothesis import given, settings, strategies as st

        demand = st.floats(0.0, 5000.0, allow_nan=False,
                           allow_infinity=False, width=32)

        @given(data=st.data())
        @settings(max_examples=40, deadline=None, derandomize=True)
        def sweep(data):
            n_pools = data.draw(st.integers(1, 5))
            params = []
            for i in range(n_pools):
                name = f"h{i}"
                klass = data.draw(st.sampled_from(list(ServiceClass)))
                params.append((
                    name,
                    dict(hi=data.draw(st.sampled_from([1, 2, 4, 8])),
                         per_tps=data.draw(
                             st.sampled_from([120.0, 240.0, 250.0])),
                         per_conc=data.draw(
                             st.sampled_from([2.0, 8.0]))),
                    [ent(f"he{i}", name, klass,
                         tps=data.draw(st.sampled_from(
                             [0.0, 60.0, 240.0, 333.0])),
                         conc=data.draw(st.sampled_from([0.0, 3.0])))]))
            rounds = [
                {f"h{i}": data.draw(demand, label=f"d{r}.{i}")
                 for i in range(n_pools)}
                for r in range(data.draw(st.integers(1, 6)))]
            run_parity(params, rounds)

        sweep()


# -- migration invariants -----------------------------------------------------

def two_pool_gateway(window=1.0):
    mgr = PoolManager([mkpool("a", bucket_window_s=window),
                       mkpool("b", bucket_window_s=window)])
    mgr.pool("a").add_entitlement(
        ent("e", "a", ServiceClass.ELASTIC, 500.0, conc=4.0))
    gw = Gateway(mgr)
    gw.register_route("key", [("a", "e")])
    return mgr, gw


class TestMigration:
    def test_bucket_level_debt_and_inflight_carried(self):
        mgr, gw = two_pool_gateway()
        r = gw.handle("key", "r1", 32, 32, now=0.0)
        assert r.status == 200 and r.pool == "a"
        a = mgr.pool("a")
        level_before = a.ledger.bucket("e").level
        st = a.status["e"]
        st.debt, st.burst = 0.6, 0.3
        admitted_before = st.admitted_total

        assert mgr.migrate_entitlement("e", "a", "b", now=0.0) \
            == EntitlementState.BOUND
        b = mgr.pool("b")
        assert "e" not in a.entitlements and "e" in b.entitlements
        # ledger: accrued level + outstanding charge moved, none minted
        assert b.ledger.bucket("e").level == pytest.approx(level_before)
        # status moved verbatim: debt/burst/counters carried
        assert b.status["e"].debt == pytest.approx(0.6)
        assert b.status["e"].burst == pytest.approx(0.3)
        assert b.status["e"].admitted_total == admitted_before
        # in-flight record follows: the completion settles on B,
        # refunding the unused charge into B's bucket
        assert "r1" in b.in_flight and "r1" not in a.in_flight
        level_pre_settle = b.ledger.bucket("e").level
        gw.on_complete("r1", 8, latency_s=0.1, now=0.5)
        assert b.status["e"].completed_total == 1
        assert b.ledger.bucket("e").level > level_pre_settle
        # the source pool is fully clean
        assert a.status == {} or "e" not in a.status
        assert not a.provider.is_bound("lease-e")

    def test_demand_signal_carried(self):
        mgr, _ = two_pool_gateway()
        a = mgr.pool("a")
        a.register_deny("e", 480.0, low_priority=False)
        a.tick(1.0)
        demand_before = a.demand_snapshot()["e"]
        assert demand_before > 0
        mgr.migrate_entitlement("e", "a", "b", now=1.0)
        assert mgr.pool("b").demand_snapshot()["e"] == pytest.approx(
            demand_before)

    def test_route_follows_migrated_entitlement(self):
        """A stored route leg naming the OLD pool keeps admitting: legs
        are remapped to the entitlement's current owner."""
        mgr, gw = two_pool_gateway()
        mgr.migrate_entitlement("e", "a", "b", now=0.0)
        r = gw.handle("key", "r1", 32, 32, now=0.0)
        assert r.status == 200
        assert r.pool == "b" and r.spill_hops == 0
        assert "r1" in mgr.pool("b").in_flight

    def test_detach_resyncs_rebound_leases(self):
        """Regression: detaching an entitlement frees its reservation,
        which can re-bind a previously preempted lease — the rebound
        tenant must recover to Bound immediately, not stay Degraded
        (and NOT_BOUND-denied) until the next authorize."""
        mgr = PoolManager([mkpool("a", hi=4), mkpool("b", hi=4)])
        a = mgr.pool("a")
        a.add_entitlement(ent("x", "a", ServiceClass.ELASTIC, 240.0))
        a.add_entitlement(ent("y", "a", ServiceClass.ELASTIC, 240.0))
        a.authorize_replicas(1)                    # preempts one of them
        degraded = [n for n in ("x", "y")
                    if a.status[n].state == EntitlementState.DEGRADED]
        assert len(degraded) == 1
        bound = "x" if degraded == ["y"] else "y"
        mgr.migrate_entitlement(bound, "a", "b")   # frees the reserve
        assert a.status[degraded[0]].state == EntitlementState.BOUND

    def test_detach_unknown_raises(self):
        mgr, _ = two_pool_gateway()
        with pytest.raises(KeyError):
            mgr.pool("a").detach_entitlement("nope")

    def test_attach_duplicate_raises(self):
        mgr, _ = two_pool_gateway()
        mig = mgr.pool("a").detach_entitlement("e")
        mgr.pool("b").attach_entitlement(mig)
        mig2 = dataclasses.replace(mig)
        with pytest.raises(ValueError):
            mgr.pool("b").attach_entitlement(mig2)


# -- planned shrink → virtual-node preemption --------------------------------

class TestAuthorizePreemption:
    def mkcommitted(self):
        pool = mkpool("p", hi=4, per_tps=240.0)
        pool.add_entitlement(ent("g", "p", ServiceClass.GUARANTEED,
                                 240.0, conc=2.0))
        pool.add_entitlement(ent("e", "p", ServiceClass.ELASTIC,
                                 240.0, conc=2.0))
        assert pool.status["g"].state == EntitlementState.BOUND
        assert pool.status["e"].state == EntitlementState.BOUND
        return pool

    def test_shrink_below_reservations_preempts_least_protected(self):
        pool = self.mkcommitted()
        preempted = pool.authorize_replicas(1)     # 240 < 480 committed
        assert preempted == ["e"]                  # elastic before guar
        assert pool.status["e"].state == EntitlementState.DEGRADED
        assert pool.status["g"].state == EntitlementState.BOUND

    def test_reauthorize_rebinds(self):
        pool = self.mkcommitted()
        pool.authorize_replicas(1)
        assert pool.authorize_replicas(2) == []
        assert pool.status["e"].state == EntitlementState.BOUND

    def test_unplanned_set_replicas_keeps_promises(self):
        """Failure injection must NOT unbind tenants (paper Exp. 2:
        an outage shows up as debt, not as Degraded entitlements)."""
        pool = self.mkcommitted()
        assert pool.set_replicas(0) == []
        assert pool.status["e"].state == EntitlementState.BOUND
        assert pool.status["g"].state == EntitlementState.BOUND

    def test_planned_set_replicas_flows_into_virtual_node(self):
        pool = self.mkcommitted()
        assert pool.set_replicas(1, planned=True) == ["e"]
        node = pool.provider.node("p")
        assert node.capacity.tokens_per_second == pytest.approx(240.0)

    def test_degraded_floor_heals_through_planner(self):
        """authorize-shrink must self-heal: a tenant degraded by a
        planner-shrunk ceiling still counts toward the reserved floor,
        so the next plan raises capacity and the lease re-binds."""
        pool = mkpool("p", hi=4, per_tps=240.0)
        a = Autoscaler(pool)
        pool.authorize_replicas(1)                 # planner idled it
        st = pool.add_entitlement(ent("big", "p",
                                      ServiceClass.GUARANTEED, 480.0,
                                      conc=0.0))
        assert st == EntitlementState.DEGRADED     # 480 > 240 ceiling
        a.observe_demand(0.0)
        d = a.plan()
        assert d.desired == 2                      # degraded counted
        pool.set_replicas(d.desired, planned=True)
        assert pool.status["big"].state == EntitlementState.BOUND


# -- the closed plan_quantum loop ---------------------------------------------

class TestPlanQuantum:
    def test_applies_scale_decision_and_authorizes(self):
        mgr = PoolManager([mkpool("p", hi=4)])
        mgr.pool("p").add_entitlement(
            ent("g", "p", ServiceClass.GUARANTEED, 480.0))
        plan = mgr.plan_quantum(1.0)
        assert plan.decisions["p"].desired == 2
        assert mgr.pool("p").replicas == 2
        assert mgr.pool("p")._authorized == 2
        node = mgr.pool("p").provider.node("p")
        assert node.capacity.tokens_per_second == pytest.approx(480.0)

    def test_provision_hook_defers_replica_changes(self):
        mgr = PoolManager([mkpool("p", hi=4)])
        mgr.pool("p").add_entitlement(
            ent("g", "p", ServiceClass.GUARANTEED, 480.0))
        seen = []
        mgr.provision_hook = lambda pool, d, now: seen.append(
            (pool.spec.name, d.desired))
        mgr.plan_quantum(1.0)
        assert seen == [("p", 2)]
        assert mgr.pool("p").replicas == 1      # hook owns liveness
        assert mgr.pool("p")._authorized == 2   # promises moved anyway

    def test_rebalance_migrates_starved_elastic_with_debt(self):
        """Scarce pool under outage sheds its indebted elastic tenant
        to the slack pool; the debt EWMA survives the move."""
        mgr = PoolManager([mkpool("a", hi=2), mkpool("b", hi=4)])
        a = mgr.pool("a")
        a.add_entitlement(ent("g", "a", ServiceClass.GUARANTEED, 240.0))
        a.add_entitlement(ent("el", "a", ServiceClass.ELASTIC, 240.0))
        mgr.planner = FleetPlanner(FleetPlannerConfig(
            debt_migrate_threshold=0.2, starve_persistence_ticks=2,
            migrate_cooldown_ticks=3))
        mgr.provision_hook = lambda *args: None   # replicas stay failed
        a.set_replicas(1)                         # outage: 240 tok/s

        moved = []
        for t in range(1, 8):
            # sustained demand: guaranteed fills its baseline, elastic
            # wants far more than the outage capacity leaves
            a.register_deny("g", 240.0, low_priority=False)
            a.register_deny("el", 480.0, low_priority=True)
            plan = mgr.plan_quantum(float(t))
            moved.extend(plan.applied)
            if moved:
                break
        assert moved, "no migration proposed under sustained starvation"
        prop = moved[0]
        assert (prop.entitlement, prop.src, prop.dst) == ("el", "a", "b")
        assert prop.reason == "debt"
        assert prop.debt > 0.2
        b = mgr.pool("b")
        assert b.status["el"].debt == pytest.approx(prop.debt)
        assert b.status["el"].state == EntitlementState.BOUND
        assert plan.unmet_replicas.get("a", 0.0) > 0
        # scarcity bookkeeping: 'a' was scarce, 'b' had the slack
        assert "el" not in a.entitlements

    def test_gateway_plan_quantum_surfaces_stats(self):
        mgr = PoolManager([mkpool("p", hi=4)])
        mgr.pool("p").add_entitlement(
            ent("g", "p", ServiceClass.GUARANTEED, 480.0))
        gw = Gateway(mgr)
        gw.plan_quantum(1.0)
        assert float(gw.store.get("replicas:p")) == 2.0
        assert float(gw.store.get("scale_ups:p")) == 1.0
