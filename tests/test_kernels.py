"""Pallas kernel validation (interpret mode = kernel body executed in
Python on CPU): shape/dtype sweeps vs the pure-jnp oracles, plus
integration against the model stack's attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (
    flash_attention,
    reference_attention,
)
from repro.kernels.paged_attention import (
    paged_attention,
    reference_paged_attention,
)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,Hkv,S,dh,bq,bk", [
        (1, 4, 4, 128, 64, 64, 64),      # MHA
        (2, 8, 2, 256, 64, 128, 128),    # GQA 4:1
        (1, 4, 1, 128, 128, 64, 64),     # MQA, MXU-width head
        (1, 2, 2, 192, 32, 64, 64),      # non-pow2 sequence
    ])
    def test_causal_sweep(self, B, H, Hkv, S, dh, bq, bk):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, S, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, S, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, S, dh), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=bq,
                              block_k=bk, interpret=True)
        ref = reference_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   **_tol(jnp.float32))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
        out = flash_attention(q, k, v, block_q=64, block_k=64,
                              interpret=True)
        ref = reference_attention(q, k, v)
        assert out.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **_tol(dtype))

    def test_sliding_window(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 256, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 256, 32), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=64,
                              block_q=64, block_k=64, interpret=True)
        ref = reference_attention(q, k, v, causal=True, window=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   **_tol(jnp.float32))

    def test_softcap_gemma2(self):
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = 4 * jax.random.normal(ks[0], (1, 2, 128, 32), jnp.float32)
        k = 4 * jax.random.normal(ks[1], (1, 2, 128, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.float32)
        out = flash_attention(q, k, v, causal=True, softcap=50.0,
                              block_q=64, block_k=64, interpret=True)
        ref = reference_attention(q, k, v, causal=True, softcap=50.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-5, atol=5e-5)

    def test_noncausal_encoder(self):
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 128, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.float32)
        out = flash_attention(q, k, v, causal=False, block_q=64,
                              block_k=64, interpret=True)
        ref = reference_attention(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   **_tol(jnp.float32))

    def test_matches_model_attention(self):
        """Kernel agrees with the model stack's XLA attention path."""
        from repro.configs import get_config
        from repro.models import attention as mattn
        cfg = get_config("tinyllama-1.1b").reduced(
            num_heads=4, num_kv_heads=2, head_dim=32, max_seq_len=128)
        params = mattn.init_attention(jax.random.PRNGKey(0), cfg,
                                      jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                              jnp.float32)
        positions = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
        ref_out = mattn.attention_block(params, x, cfg, "global",
                                        positions)
        # same computation via the kernel
        from repro.models.layers import apply_rope
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        out = flash_attention(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=True, block_q=32, block_k=32,
                              interpret=True).transpose(0, 2, 1, 3)
        out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   rtol=2e-4, atol=2e-4)


class TestPagedAttention:
    @pytest.mark.parametrize("B,H,Hkv,dh,P,T,mp", [
        (2, 4, 4, 64, 8, 16, 3),        # MHA
        (3, 8, 2, 64, 16, 16, 4),       # GQA
        (1, 8, 1, 128, 8, 32, 2),       # MQA, MXU head
        (4, 4, 2, 32, 32, 64, 5),       # larger pages
    ])
    def test_sweep(self, B, H, Hkv, dh, P, T, mp):
        rng = np.random.RandomState(0)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
        kp = jax.random.normal(ks[1], (P, T, Hkv, dh), jnp.float32)
        vp = jax.random.normal(ks[2], (P, T, Hkv, dh), jnp.float32)
        bt = np.full((B, mp), -1, np.int32)
        cl = np.zeros((B,), np.int32)
        for b in range(B):
            n = rng.randint(1, mp + 1)
            bt[b, :n] = rng.choice(P, size=n, replace=False)
            cl[b] = rng.randint(1, n * T + 1)
        out = paged_attention(q, kp, vp, jnp.asarray(bt),
                              jnp.asarray(cl), interpret=True)
        ref = reference_paged_attention(q, kp, vp, jnp.asarray(bt),
                                        jnp.asarray(cl))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        q = jax.random.normal(ks[0], (2, 4, 64)).astype(dtype)
        kp = jax.random.normal(ks[1], (8, 16, 2, 64)).astype(dtype)
        vp = jax.random.normal(ks[2], (8, 16, 2, 64)).astype(dtype)
        bt = jnp.asarray([[0, 1, -1], [2, -1, -1]], jnp.int32)
        cl = jnp.asarray([20, 10], jnp.int32)
        out = paged_attention(q, kp, vp, bt, cl, interpret=True)
        ref = reference_paged_attention(q, kp, vp, bt, cl)
        assert out.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **_tol(dtype))

    def test_softcap(self):
        ks = jax.random.split(jax.random.PRNGKey(6), 3)
        q = 4 * jax.random.normal(ks[0], (1, 4, 32), jnp.float32)
        kp = 4 * jax.random.normal(ks[1], (4, 16, 2, 32), jnp.float32)
        vp = jax.random.normal(ks[2], (4, 16, 2, 32), jnp.float32)
        bt = jnp.asarray([[1, 3]], jnp.int32)
        cl = jnp.asarray([30], jnp.int32)
        out = paged_attention(q, kp, vp, bt, cl, softcap=50.0,
                              interpret=True)
        ref = reference_paged_attention(q, kp, vp, bt, cl, softcap=50.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-5, atol=5e-5)

    def test_matches_dense_decode(self):
        """Paged kernel == dense-cache decode over the same history
        (block manager integration)."""
        from repro.serving.kv_manager import KVBlockManager
        B, H, Hkv, dh, T = 2, 4, 2, 32, 16
        S = 40
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
        k_hist = jax.random.normal(ks[1], (B, S, Hkv, dh), jnp.float32)
        v_hist = jax.random.normal(ks[2], (B, S, Hkv, dh), jnp.float32)

        mgr = KVBlockManager(total_pages=16, page_tokens=T)
        P = 16
        kp = np.zeros((P, T, Hkv, dh), np.float32)
        vp = np.zeros((P, T, Hkv, dh), np.float32)
        bt = np.full((B, 4), -1, np.int32)
        for b in range(B):
            alloc = mgr.allocate(f"s{b}", S)
            for i, page in enumerate(alloc.pages):
                lo = i * T
                hi = min(S, lo + T)
                kp[page, :hi - lo] = np.asarray(k_hist[b, lo:hi])
                vp[page, :hi - lo] = np.asarray(v_hist[b, lo:hi])
            bt[b] = mgr.block_table(f"s{b}", 4)
        cl = jnp.full((B,), S, jnp.int32)
        out = paged_attention(q, jnp.asarray(kp), jnp.asarray(vp),
                              jnp.asarray(bt), cl, interpret=True)

        # dense reference over the same history
        group = H // Hkv
        kf = jnp.repeat(k_hist, group, axis=2)
        vf = jnp.repeat(v_hist, group, axis=2)
        s = jnp.einsum("bhd,bkhd->bhk", q, kf) / (dh ** 0.5)
        p = jax.nn.softmax(s, axis=-1)
        ref = jnp.einsum("bhk,bkhd->bhd", p, vf)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
