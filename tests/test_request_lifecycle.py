"""Vectorized request lifecycle: batched row-ops == scalar oracles.

The request table (``core.request_table``) does for requests what the
ResidentStore did for entitlements: rows are the source of truth,
``InFlight`` is a view.  These tests pin the batched lifecycle entry
points to their retained scalar oracles:

- ``TokenPool.on_complete_batch`` / ``settle_rows`` == a loop of
  ``on_complete``; ``evict_rows`` == a loop of ``on_evict`` — exact
  bucket levels, status counters, and returned ``settled_tokens``
  through random admit / start / complete / evict / migrate / tick
  interleavings on mirrored universes (deterministic seeded driver
  everywhere, hypothesis shrinking where installed);
- ``Ledger.charge_batch`` == a loop of ``Ledger.charge`` (including
  mid-group budget failures, where affordability is greedy-with-skip,
  and unknown-entitlement ``KeyError`` at the same charge index);
- unknown settles/cancels count in ``Ledger.unknown_settles`` and
  surface through ``TokenPool.stats``;
- ``TokenPool.admission_threshold`` never raises on an empty owner
  set and equals the scalar ``min(priority(...))`` when contended;
- request churn within a capacity bucket never retraces the
  ``admit_quantum`` kernel (trace-counter pin).

Token values are integers so scalar and vectorized f64 accounting are
both exact (decision parity is bit-for-bit, not approximate).
"""
import numpy as np
import pytest

from repro.core import (
    AdmissionController,
    AdmissionRequest,
    Charge,
    EntitlementSpec,
    InFlight,
    PoolManager,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)
from repro.core.control_plane import TRACE_COUNTS


def mkpool(name="p", tps=100.0, conc=6.0):
    spec = PoolSpec(
        name=name, model="m",
        scaling=ScalingBounds(1, 4),
        per_replica=Resources(tps, 1 << 30, conc))
    return TokenPool(spec)


def ent(name, klass=ServiceClass.ELASTIC, tps=50.0, conc=4.0,
        slo=1000.0, kv=1e6):
    return EntitlementSpec(
        name=name, tenant_id=f"t-{name}", pool="p",
        qos=QoS(service_class=klass, slo_target_ms=slo),
        baseline=Resources(tps, kv, conc))


def mk_universe():
    """Two-pool manager: ``p`` holds the tenants, ``q`` is the
    migration / spill target."""
    p, q = mkpool("p"), mkpool("q")
    p.add_entitlement(ent("a", ServiceClass.GUARANTEED, 100.0,
                          slo=250.0))
    p.add_entitlement(ent("b", ServiceClass.ELASTIC, 50.0))
    p.add_entitlement(ent("c", ServiceClass.SPOT, 0.0, slo=8000.0))
    q.add_entitlement(ent("d", ServiceClass.ELASTIC, 50.0))
    return PoolManager([p, q])


def pool_of(manager, rid):
    for pool in manager.pools.values():
        if rid in pool.in_flight:
            return pool
    return None


def owner_pool(manager, name):
    for pool in manager.pools.values():
        if name in pool.entitlements:
            return pool
    raise KeyError(name)


def assert_mirror(mb, mo):
    """Batched universe == oracle universe, exactly: membership,
    status counters, bucket levels, record attributes, observability
    counters."""
    assert set(mb.pools) == set(mo.pools)
    for pname in mb.pools:
        pb, po = mb.pools[pname], mo.pools[pname]
        assert set(pb.entitlements) == set(po.entitlements), pname
        assert sorted(pb.in_flight) == sorted(po.in_flight), pname
        assert pb.ledger.unknown_settles == po.ledger.unknown_settles
        assert pb.stats() == po.stats(), pname
        for n in pb.entitlements:
            sb, so = pb.status[n], po.status[n]
            for attr in ("in_flight", "resident", "admitted_total",
                         "denied_total", "denied_low_priority",
                         "completed_total"):
                assert getattr(sb, attr) == getattr(so, attr), \
                    (pname, n, attr)
            for attr in ("kv_bytes_in_use", "window_tokens",
                         "tokens_total", "debt", "burst"):
                assert getattr(sb, attr) == getattr(so, attr), \
                    (pname, n, attr)
            assert pb.ledger.has_bucket(n) == po.ledger.has_bucket(n)
            if pb.ledger.has_bucket(n):
                assert (pb.ledger.bucket(n).level
                        == po.ledger.bucket(n).level), (pname, n)
        for rid in pb.in_flight:
            rb, ro = pb.in_flight[rid], po.in_flight[rid]
            assert rb.entitlement == ro.entitlement, rid
            assert rb.charged_tokens == ro.charged_tokens, rid
            assert rb.kv_bytes == ro.kv_bytes, rid
            assert bool(rb.resident) == bool(ro.resident), rid
            assert rb.spill_from == ro.spill_from, rid


def run_lifecycle(choose, n_ops):
    """One lifecycle scenario: the batched universe settles/evicts
    through the vectorized row-ops, the oracle universe through the
    scalar per-request loop; they must agree after EVERY op."""
    mb, mo = mk_universe(), mk_universe()
    live: dict[str, str] = {}            # rid → entitlement
    counter = [0]
    now = [0.0]

    def ent_names():
        return sorted(n for p in mb.pools.values()
                      for n in p.entitlements)

    def subset_of_live():
        """Deterministic contiguous slice of the live rid list."""
        rids = sorted(live)
        if not rids:
            return []
        k = min(len(rids), choose([1, 2, 3, 5]))
        i = choose(list(range(len(rids))))
        return [rids[(i + j) % len(rids)] for j in range(k)]

    def do_admit():
        name = choose(ent_names())
        kvpt = float(choose([0.0, 2.0]))
        for _ in range(choose([1, 2, 3])):
            counter[0] += 1
            rid = f"r{counter[0]}"
            decisions = []
            for m in (mb, mo):
                pool = owner_pool(m, name)
                decisions.append(AdmissionController(pool).decide(
                    AdmissionRequest(name, 16, 32, now[0],
                                     request_id=rid,
                                     kv_bytes_per_token=kvpt)))
            assert decisions[0].admitted == decisions[1].admitted
            if decisions[0].admitted:
                live[rid] = name

    def do_start():
        rids = sorted(live)
        if rids:
            rid = choose(rids)
            for m in (mb, mo):
                pool_of(m, rid).on_start(rid)

    def do_tag_spill():
        rids = sorted(live)
        if not rids:
            return
        rid = choose(rids)
        prefs = sorted(n for n in mb.pools["p"].entitlements
                       if n != live[rid])
        if not prefs:
            return
        leg = ("p", choose(prefs))
        for m in (mb, mo):
            pool_of(m, rid).in_flight[rid].spill_from = leg

    def do_complete():
        rids = subset_of_live()
        if not rids:
            return
        outs = [choose([0, 8, 16, 40]) for _ in rids]
        if choose([False, True]):        # an unknown id mid-batch
            rids = rids + [f"ghost{counter[0]}"]
            outs = outs + [7]
        batched = mb.on_complete_batch(list(zip(rids, outs)), now[0])
        for (rid, out), res in zip(zip(rids, outs), batched):
            oracle = mo.on_complete(rid, out, now[0])
            if oracle is None:
                assert res is None, rid
            else:
                pname, rec = oracle
                assert res == (pname, rec.entitlement,
                               rec.settled_tokens), rid
            live.pop(rid, None)

    def do_evict():
        rids = subset_of_live()
        if not rids:
            return
        groups: dict[str, list[str]] = {}
        for rid in rids:
            pool = pool_of(mb, rid)
            groups.setdefault(pool.spec.name, []).append(rid)
        for pname, group in groups.items():
            batch = mb.pools[pname].evict_rows(group, now[0])
            assert batch.known.all()
            assert not batch.settled_tokens.any()
        for rid in rids:
            assert mo.on_evict(rid, now[0]) is not None, rid
            del live[rid]

    def do_migrate():
        name = choose(ent_names())
        src = owner_pool(mb, name).spec.name
        dst = "q" if src == "p" else "p"
        for m in (mb, mo):
            m.migrate_entitlement(name, src, dst, now[0])

    def do_tick():
        now[0] += float(choose([0.5, 1.0]))
        mb.tick(now[0])
        mo.tick(now[0])

    ops = [do_admit, do_admit, do_start, do_tag_spill, do_complete,
           do_evict, do_migrate, do_tick]
    do_admit()
    assert_mirror(mb, mo)
    for _ in range(n_ops):
        choose(ops)()
        assert_mirror(mb, mo)


class TestLifecycleSeededSweep:
    """Always-run deterministic instantiation of the batched-vs-scalar
    lifecycle property (hypothesis adds shrinking depth below)."""

    @pytest.mark.parametrize("seed", range(8))
    def test_seeded_lifecycle_parity(self, seed):
        rng = np.random.RandomState(seed)
        run_lifecycle(
            lambda options: options[rng.randint(len(options))],
            n_ops=12)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    class TestLifecycleHypothesis:
        @given(data=st.data())
        @settings(max_examples=20, deadline=None, derandomize=True)
        def test_random_lifecycle_parity(self, data):
            run_lifecycle(
                lambda options: data.draw(st.sampled_from(options)),
                n_ops=data.draw(st.integers(6, 16), label="n_ops"))


# -- charge_batch == scalar charge loop ------------------------------------
def _charged_pool(tps_a=10.0, tps_b=50.0):
    pool = mkpool()
    pool.add_entitlement(ent("a", tps=tps_a))
    pool.add_entitlement(ent("b", tps=tps_b))
    pool.ledger.ensure("a", tps_a, 0.0)
    pool.ledger.ensure("b", tps_b, 0.0)
    return pool


def _charge(rid, name, tokens, now=0.0):
    return Charge(request_id=rid, entitlement=name,
                  charged_tokens=float(tokens), input_tokens=8,
                  max_tokens=int(tokens) - 8, admitted_at=now)


class TestChargeBatchParity:
    def test_batch_matches_scalar_loop_with_midgroup_failure(self):
        # bucket a holds 40 tokens (10 tps × 4 s burst window):
        # 16 ok, 16 ok, 16 FAILS, 8 ok — affordability must be
        # greedy-with-skip in arrival order, not prefix-cutoff
        pb, po = _charged_pool(), _charged_pool()
        charges = [_charge("r1", "a", 16), _charge("r2", "b", 64),
                   _charge("r3", "a", 16), _charge("r4", "a", 16),
                   _charge("r5", "a", 8), _charge("r6", "b", 200)]
        got = pb.ledger.charge_batch(charges, 0.0)
        want = [po.ledger.charge(c, 0.0) for c in charges]
        assert got == want == [True, True, True, False, True, False]
        for n in ("a", "b"):
            assert pb.ledger.bucket(n).level == po.ledger.bucket(n).level
        assert (pb.ledger.outstanding_charges()
                == po.ledger.outstanding_charges())

    def test_batch_refills_once_at_shared_now(self):
        pb, po = _charged_pool(), _charged_pool()
        for led in (pb.ledger, po.ledger):
            assert led.charge(_charge("warm", "a", 40), 0.0)
        charges = [_charge("r1", "a", 10), _charge("r2", "a", 10)]
        got = pb.ledger.charge_batch(charges, 1.5)   # 15 tokens refilled
        want = [po.ledger.charge(c, 1.5) for c in charges]
        assert got == want == [True, False]
        assert pb.ledger.bucket("a").level == po.ledger.bucket("a").level

    def test_unknown_entitlement_raises_at_same_index(self):
        pb, po = _charged_pool(), _charged_pool()
        charges = [_charge("r1", "a", 16), _charge("r2", "ghost", 16),
                   _charge("r3", "b", 16)]
        with pytest.raises(KeyError):
            pb.ledger.charge_batch(charges, 0.0)
        got = []
        with pytest.raises(KeyError):
            for c in charges:
                got.append(po.ledger.charge(c, 0.0))
        assert got == [True]                     # failed at index 1
        # both stopped with the same partial state
        for n in ("a", "b"):
            assert pb.ledger.bucket(n).level == po.ledger.bucket(n).level
        assert (pb.ledger.outstanding_charges()
                == po.ledger.outstanding_charges())


# -- unknown settles are counted, not silent --------------------------------
class TestUnknownSettleCounter:
    def test_scalar_settle_and_cancel_count(self):
        pool = mkpool()
        pool.add_entitlement(ent("a"))
        assert pool.ledger.settle("nope", 10, now=0.0) == 0.0
        assert pool.ledger.unknown_settles == 1
        pool.ledger.cancel("nope2", now=0.0)
        assert pool.ledger.unknown_settles == 2
        assert pool.stats()["unknown_settles"] == 2

    def test_record_without_charge_counts_in_batch(self):
        # the admission=False simulator path registers records without
        # a ledger charge — settling them must be visible
        pool = mkpool()
        pool.add_entitlement(ent("a"))
        pool.register_admit(InFlight("r1", "a", 0.5, 0.0, 48, 0.0),
                            48.0)
        batch = pool.on_complete_batch(["r1"], [16], now=1.0)
        assert batch.known.tolist() == [True]
        assert batch.settled_tokens.tolist() == [0.0]
        assert pool.ledger.unknown_settles == 1
        assert "r1" not in pool.in_flight

    def test_unknown_rid_is_not_an_unknown_settle(self):
        # a rid the pool never saw returns known=False and does NOT
        # bump the counter (matches scalar on_complete → None)
        pool = mkpool()
        pool.add_entitlement(ent("a"))
        batch = pool.on_complete_batch(["ghost"], [16], now=1.0)
        assert batch.known.tolist() == [False]
        assert pool.ledger.unknown_settles == 0
        assert pool.on_complete("ghost", 16, now=1.0) is None


# -- admission_threshold: vectorized Eq. 1, guarded ------------------------
class TestAdmissionThreshold:
    def _contended_pool(self):
        pool = mkpool(conc=2.0)                  # 2 decode slots
        pool.add_entitlement(ent("a", ServiceClass.GUARANTEED, 100.0,
                                 slo=250.0))
        pool.add_entitlement(ent("b", ServiceClass.ELASTIC, 50.0))
        pool.status["b"].debt = 0.25
        for i, name in enumerate(["a", "a", "b"]):
            pool.register_admit(
                InFlight(f"r{i}", name, 1.0, 0.0, 48, 0.0), 48.0)
        assert pool.contended()
        return pool

    def test_matches_scalar_priority_min(self):
        pool = self._contended_pool()
        expected = min(pool.priority(n) for n in ("a", "b"))
        assert pool.admission_threshold() == pytest.approx(
            expected, rel=1e-12)

    def test_empty_pool_is_zero(self):
        pool = mkpool()
        pool.add_entitlement(ent("a"))
        assert pool.admission_threshold() == 0.0

    def test_owner_removal_does_not_raise(self):
        # removing every in-flight owner used to leave stale records
        # behind and raise ValueError from an empty min(); removal now
        # evicts the rows and the threshold guard returns 0.0
        pool = self._contended_pool()
        pool.remove_entitlement("a", now=1.0)
        pool.remove_entitlement("b", now=1.0)
        assert len(pool.in_flight) == 0
        assert pool.admission_threshold() == 0.0


# -- no-retrace pin: request churn inside one capacity bucket --------------
class TestNoRetrace:
    def test_request_churn_does_not_retrace_admit_quantum(self):
        from repro.gateway import Gateway, QuantumRequest

        pool = mkpool(tps=100000.0, conc=1000.0)
        for n in ("a", "b"):
            pool.add_entitlement(ent(n, tps=50000.0))
        gw = Gateway(pool)
        gw.register_key("ka", "a")
        gw.register_key("kb", "b")
        rid = [0]

        def quantum(n_req, now):
            reqs = []
            for _ in range(n_req):
                rid[0] += 1
                reqs.append(QuantumRequest(
                    api_key="ka" if rid[0] % 2 else "kb",
                    request_id=f"r{rid[0]}", input_tokens=16,
                    max_tokens=32))
            return gw.handle_quantum(reqs, now)

        quantum(8, 0.0)                          # warm the trace
        before = TRACE_COUNTS["admit_quantum"]
        admitted = []
        # quantum sizes 5..8 share one pow2 pad bucket; completions
        # churn the request table between dispatches
        for step, size in enumerate([5, 8, 6, 7], start=1):
            for resp in quantum(size, float(step)):
                if resp.status == 200:
                    admitted.append(resp.request_id)
            drain, admitted = admitted[:4], admitted[4:]
            if drain:
                pool.on_complete_batch(drain, [16] * len(drain),
                                       float(step) + 0.5)
        assert TRACE_COUNTS["admit_quantum"] == before
