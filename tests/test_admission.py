"""The §4.3 admission pipeline: ordered checks, short-circuit, 429
semantics, contention thresholding, completion-callback accounting."""
import pytest

from repro.core import (
    AdmissionController,
    AdmissionRequest,
    DenyReason,
    EntitlementSpec,
    EntitlementState,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)


def mkpool(tps=240.0, conc=16.0, kv=float(1 << 30),
           max_r=1) -> TokenPool:
    spec = PoolSpec(
        name="qwen3-8b", model="Qwen/Qwen3-8B",
        scaling=ScalingBounds(1, max_r),
        per_replica=Resources(tps, kv, conc),
        default_max_tokens=64,
    )
    return TokenPool(spec)


def ent(name, klass, tps, conc=6.0, slo=200.0, kv=0.0):
    return EntitlementSpec(
        name=name, tenant_id=name, pool="qwen3-8b",
        qos=QoS(service_class=klass, slo_target_ms=slo),
        baseline=Resources(tps, kv, conc))


def req(entname, rid, t=0.0, n_in=64, n_out=64, kvpt=0.0):
    return AdmissionRequest(entitlement=entname, input_tokens=n_in,
                            max_tokens=n_out, arrival_s=t, request_id=rid,
                            kv_bytes_per_token=kvpt)


class TestCheckOrdering:
    """Checks evaluate in order and short-circuit (paper §4.3)."""

    def test_check1_not_bound_short_circuits(self):
        pool = mkpool()
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 100.0))
        pool.status["g"].state = EntitlementState.DEGRADED
        # even a trivially-affordable request is denied on state
        d = AdmissionController(pool).decide(req("g", "r1"))
        assert not d.admitted and d.reason == DenyReason.NOT_BOUND

    def test_unknown_entitlement(self):
        pool = mkpool()
        d = AdmissionController(pool).decide(req("nope", "r1"))
        assert not d.admitted and d.reason == DenyReason.NOT_BOUND

    def test_check2_default_max_tokens_applied(self):
        pool = mkpool(tps=2000.0)
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 1000.0))
        r = AdmissionRequest(entitlement="g", input_tokens=10,
                             max_tokens=None, arrival_s=0.0, request_id="r1")
        d = AdmissionController(pool).decide(r)
        assert d.admitted
        assert d.effective_max_tokens == 64          # pool default
        assert d.charged_tokens == 74

    def test_check3_concurrency_before_budget(self):
        pool = mkpool(tps=2e6)
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 1e6, conc=1.0))
        ac = AdmissionController(pool)
        assert ac.decide(req("g", "r1")).admitted
        pool.on_start("r1")           # r1's KV becomes resident
        d = ac.decide(req("g", "r2"))
        assert not d.admitted and d.reason == DenyReason.CONCURRENCY
        assert d.retry_after_s and d.retry_after_s > 0

    def test_check3_counts_resident_not_queued(self):
        """§3.1: concurrency r counts KV-resident sequences; an admitted
        request still waiting for a slot doesn't consume r_e."""
        pool = mkpool(tps=2e6)
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 1e6, conc=2.0))
        ac = AdmissionController(pool)
        assert ac.decide(req("g", "r1")).admitted   # queued, not started
        d = ac.decide(req("g", "r2"))
        assert d.admitted                            # resident still 0

    def test_check3_burst_above_limit_when_pool_free(self):
        """Table 1: burst classes may exceed r_e while the pool has idle
        slots (concurrency burst dimension); guaranteed may not."""
        pool = mkpool(tps=2e6, conc=16.0)
        pool.add_entitlement(ent("e", ServiceClass.ELASTIC, 100.0, conc=1))
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 100.0, conc=1))
        pool.ledger.bucket("e").level = 1e6
        pool.ledger.bucket("g").level = 1e6
        ac = AdmissionController(pool)
        assert ac.decide(req("e", "e1")).admitted
        pool.on_start("e1")
        d = ac.decide(req("e", "e2"))    # beyond r_e=1, pool has slots
        assert d.admitted
        assert ac.decide(req("g", "g1")).admitted
        pool.on_start("g1")
        d = ac.decide(req("g", "g2"))    # guaranteed cannot burst
        assert not d.admitted and d.reason == DenyReason.CONCURRENCY

    def test_check4_token_budget(self):
        pool = mkpool(conc=100.0)
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 10.0, conc=99))
        ac = AdmissionController(pool)
        # bucket starts at 4 s of 10 tok/s = 40 tokens; ask for 128
        d = ac.decide(req("g", "r1"))
        assert not d.admitted and d.reason == DenyReason.TOKEN_BUDGET
        # Retry-After reflects refill time of the deficit
        assert d.retry_after_s == pytest.approx((128 - 40) / 10.0, abs=0.2)

    def test_check4_kv_headroom(self):
        pool = mkpool(tps=2e6)
        # χ_e = 1 MiB; request needs 128 tokens × 16 KiB = 2 MiB
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 1e6,
                                 kv=1 << 20))
        d = AdmissionController(pool).decide(
            req("g", "r1", kvpt=16 * 1024.0))
        assert not d.admitted and d.reason == DenyReason.TOKEN_BUDGET

    def test_check5_only_when_contended(self):
        pool = mkpool(conc=2.0)
        pool.add_entitlement(ent("s", ServiceClass.SPOT, 0.0, conc=0.0))
        pool.ledger.set_rate("s", 1000.0, 0.0)
        pool.ledger.bucket("s").level = 1e6
        ac = AdmissionController(pool)
        assert ac.decide(req("s", "r1")).admitted      # pool empty
        assert ac.decide(req("s", "r2")).admitted      # fills pool (conc=2)


class TestContention:
    def test_spot_denied_below_threshold_guaranteed_admitted(self):
        pool = mkpool(tps=2e6, conc=4.0, max_r=2)
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 1e6, conc=3))
        pool.add_entitlement(ent("e", ServiceClass.ELASTIC, 100.0, conc=2,
                                 slo=500.0))
        pool.add_entitlement(ent("s", ServiceClass.SPOT, 0.0, conc=8,
                                 slo=30000.0))
        pool.ledger.set_rate("s", 1e6, 0.0)
        pool.ledger.bucket("s").level = 1e6
        pool.ledger.bucket("e").level = 1e6
        ac = AdmissionController(pool)
        # fill the pool with guaranteed + elastic traffic; e2 waits in
        # the queue → demand exceeds supply → contended
        for rid in ("g1", "g2", "g3"):
            assert ac.decide(req("g", rid)).admitted
            pool.on_start(rid)
        assert ac.decide(req("e", "e1")).admitted
        pool.on_start("e1")
        assert ac.decide(req("e", "e2")).admitted     # queued
        assert pool.contended()
        # spot arrives: priority ~1 < threshold (min live ≈ elastic) → 429
        d = ac.decide(req("s", "s1"))
        assert not d.admitted and d.reason == DenyReason.LOW_PRIORITY
        assert d.retry_after_s > 0
        assert pool.status["s"].denied_low_priority == 1
        # guaranteed is never rejected while within its r_e, even
        # under contention (check 5 shields protected classes)... its
        # concurrency is full here, so use completion + retry instead:
        pool.on_complete("e2", 64, now=1.0)
        assert not pool.contended()
        assert ac.decide(req("s", "s2", t=1.0)).admitted

    def test_guaranteed_shielded_from_check5(self):
        pool = mkpool(tps=2e6, conc=2.0, max_r=3)
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 1e6, conc=2))
        pool.add_entitlement(ent("e", ServiceClass.ELASTIC, 1e4, conc=3))
        ac = AdmissionController(pool)
        for rid in ("e1", "e2"):
            assert ac.decide(req("e", rid)).admitted
            pool.on_start(rid)
        assert ac.decide(req("e", "e3")).admitted     # queued
        assert pool.contended()
        # elastic self-competition under contention: equal live
        # priority fails the strict "must exceed" → denied
        d = ac.decide(req("e", "e4"))
        assert not d.admitted and d.reason == DenyReason.LOW_PRIORITY
        # guaranteed sails through (never rejected within r_e)
        assert ac.decide(req("g", "g1")).admitted

    def test_threshold_is_min_live_entitlement_priority(self):
        pool = mkpool(conc=2.0)
        pool.add_entitlement(ent("s", ServiceClass.SPOT, 0.0, conc=8))
        pool.ledger.set_rate("s", 1e6, 0.0)
        pool.ledger.bucket("s").level = 1e6
        ac = AdmissionController(pool)
        for rid in ("s1", "s2"):
            ac.decide(req("s", rid))
            pool.on_start(rid)
        ac.decide(req("s", "s3"))                     # queued
        assert pool.contended()
        assert pool.admission_threshold() == pytest.approx(
            pool.priority("s"))

    def test_completion_relieves_contention(self):
        pool = mkpool(tps=2e6, conc=1.0, max_r=2)
        pool.add_entitlement(ent("e", ServiceClass.ELASTIC, 100.0, conc=2))
        pool.ledger.bucket("e").level = 1e6
        ac = AdmissionController(pool)
        ac.decide(req("e", "r1"))
        pool.on_start("r1")
        ac.decide(req("e", "r2"))                     # queued
        assert pool.contended()
        pool.on_complete("r2", actual_output_tokens=64, now=1.0)
        assert not pool.contended()
        assert pool.admission_threshold() == 0.0


class TestAccountingLoop:
    """Completion callbacks close the admission↔execution gap."""

    def test_refund_of_unused_output(self):
        pool = mkpool()
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 100.0, conc=9))
        ac = AdmissionController(pool)
        b = pool.ledger.ensure("g", 100.0, 0.0)
        level0 = b.level
        d = ac.decide(req("g", "r1", n_in=64, n_out=64))
        assert d.admitted
        assert b.level == pytest.approx(level0 - 128)
        # model stopped after 10 output tokens → refund 54
        pool.on_complete("r1", actual_output_tokens=10, now=0.0)
        assert b.level == pytest.approx(level0 - 74)
        assert pool.status["g"].tokens_total == pytest.approx(74)

    def test_eviction_full_refund(self):
        pool = mkpool()
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 100.0, conc=9))
        ac = AdmissionController(pool)
        b = pool.ledger.ensure("g", 100.0, 0.0)
        level0 = b.level
        ac.decide(req("g", "r1"))
        pool.on_evict("r1", now=0.0)
        assert b.level == pytest.approx(level0)

    def test_denied_demand_counts_for_backfill(self):
        pool = mkpool()
        pool.add_entitlement(ent("s", ServiceClass.SPOT, 0.0, conc=1))
        ac = AdmissionController(pool)
        pool.ledger.set_rate("s", 10.0, 0.0)
        ac.decide(req("s", "r1"))       # admitted
        ac.decide(req("s", "r2"))       # concurrency-denied
        rec = pool.tick(1.0)
        # denied tokens still registered as demand
        assert rec.demand_tps["s"] > 0

    def test_burst_rises_on_overconsumption(self):
        pool = mkpool()
        pool.add_entitlement(ent("e", ServiceClass.ELASTIC, 10.0, conc=2))
        ac = AdmissionController(pool)
        pool.ledger.bucket("e").level = 1e6
        for t in range(8):
            d = ac.decide(req("e", f"r{t}", t=float(t)))
            if d.admitted:
                pool.on_complete(f"r{t}", 64, float(t))
            pool.tick(float(t + 1))
        assert pool.status["e"].burst > 0.5   # sustained λ overconsumption
