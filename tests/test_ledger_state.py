"""Token buckets (throughput entitlements), StateStore (Redis contract),
and the autoscaler policy."""
import pytest

from repro.core import (
    Autoscaler,
    AutoscalerConfig,
    Charge,
    EntitlementSpec,
    Ledger,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    StateStore,
    TokenBucket,
    TokenPool,
)
from repro.core.state import CASConflict


class TestTokenBucket:
    def test_refills_at_rate(self):
        b = TokenBucket(rate_tps=10.0, burst_window_s=4.0, level=0.0,
                        last_refill_s=0.0)
        b.refill(2.0)
        assert b.level == pytest.approx(20.0)

    def test_capacity_caps_accrual(self):
        b = TokenBucket(rate_tps=10.0, burst_window_s=4.0, level=0.0,
                        last_refill_s=0.0)
        b.refill(100.0)
        assert b.level == pytest.approx(40.0)   # 4s window cap

    def test_charge_and_insufficient(self):
        b = TokenBucket(rate_tps=10.0, level=15.0, last_refill_s=0.0)
        assert b.charge(10.0, now=0.0)
        assert not b.charge(10.0, now=0.0)      # only 5 left

    def test_rate_change_preserves_credit(self):
        b = TokenBucket(rate_tps=10.0, burst_window_s=4.0, level=0.0,
                        last_refill_s=0.0)
        b.set_rate(5.0, now=2.0)   # accrued 20 at old rate, cap now 20
        assert b.level == pytest.approx(20.0)
        b.set_rate(1.0, now=2.0)   # cap 4 clamps stored credit
        assert b.level == pytest.approx(4.0)

    def test_time_until_affordable(self):
        b = TokenBucket(rate_tps=10.0, level=5.0, last_refill_s=0.0)
        assert b.time_until_affordable(25.0, now=0.0) == pytest.approx(2.0)
        b2 = TokenBucket(rate_tps=0.0, level=0.0, last_refill_s=0.0)
        assert b2.time_until_affordable(1.0, now=0.0) == float("inf")


class TestLedger:
    def test_charge_settle_refund(self):
        led = Ledger()
        led.ensure("e", 100.0, now=0.0)
        assert led.charge(Charge("r1", "e", 128.0, 64, 64, 0.0), now=0.0)
        level_after = led.bucket("e").level
        actual = led.settle("r1", actual_output_tokens=20, now=0.0)
        assert actual == 84.0
        assert led.bucket("e").level == pytest.approx(level_after + 44.0)

    def test_cancel_refunds_everything(self):
        led = Ledger()
        led.ensure("e", 100.0, now=0.0)
        before = led.bucket("e").level
        led.charge(Charge("r1", "e", 128.0, 64, 64, 0.0), now=0.0)
        led.cancel("r1", now=0.0)
        assert led.bucket("e").level == pytest.approx(before)

    def test_settle_unknown_request_noop(self):
        led = Ledger()
        assert led.settle("nope", 10, now=0.0) == 0.0


class TestStateStore:
    def test_roundtrip_and_versions(self):
        s = StateStore()
        v1 = s.set("k", {"x": 1})
        v2 = s.set("k", {"x": 2})
        assert (v1, v2) == (1, 2)
        val, ver = s.get_versioned("k")
        assert val == {"x": 2} and ver == 2

    def test_cas_conflict(self):
        s = StateStore()
        s.set("k", 1)
        s.set("k", 2)
        with pytest.raises(CASConflict):
            s.compare_and_set("k", 3, expected_version=1)

    def test_update_read_modify_write(self):
        s = StateStore()
        s.set("ctr", 10)
        s.update("ctr", lambda v: (v or 0) + 5)
        assert s.get("ctr") == 15

    def test_ttl_expiry(self):
        s = StateStore()
        s.set("k", "v", now=0.0, ttl_s=10.0)
        assert s.get("k", now=5.0) == "v"
        assert s.get("k", now=10.0) is None

    def test_incr(self):
        s = StateStore()
        assert s.incr("c", 2.0) == 2.0
        assert s.incr("c", 3.0) == 5.0

    def test_keys_prefix(self):
        s = StateStore()
        s.set("ent:a", 1)
        s.set("ent:b", 2)
        s.set("pool:x", 3)
        assert s.keys("ent:") == ["ent:a", "ent:b"]


def _pool(min_r=1, max_r=10, per_tps=240.0):
    spec = PoolSpec(name="p", model="m",
                    scaling=ScalingBounds(min_r, max_r),
                    per_replica=Resources(per_tps, 1 << 30, 16.0))
    return TokenPool(spec)


def _ent(name, klass, tps):
    return EntitlementSpec(name=name, tenant_id=name, pool="p",
                           qos=QoS(service_class=klass),
                           baseline=Resources(tps, 0.0, 4.0))


class TestAutoscaler:
    def test_scales_up_for_reserved_baselines(self):
        pool = _pool()
        pool.add_entitlement(_ent("g", ServiceClass.GUARANTEED, 500.0))
        auto = Autoscaler(pool)
        d = auto.step()
        # 500 tok/s reserved needs ceil(500/240) = 3 replicas
        assert d.desired == 3
        assert pool.replicas == 3
        assert d.reason == "scale_up:reserved"

    def test_scales_up_on_demand_pressure(self):
        pool = _pool()
        pool.add_entitlement(_ent("s", ServiceClass.SPOT, 0.0))
        auto = Autoscaler(pool, AutoscalerConfig(demand_ewma=0.0))
        for t in range(1, 4):
            pool.register_deny("s", 1000.0, low_priority=True)
            pool.tick(float(t))
            d = auto.step()
        assert d.desired > 1

    def test_respects_max_replicas(self):
        pool = _pool(max_r=2)
        pool.add_entitlement(_ent("s", ServiceClass.SPOT, 0.0))
        auto = Autoscaler(pool, AutoscalerConfig(demand_ewma=0.0))
        for t in range(1, 4):
            pool.register_deny("s", 1e6, low_priority=True)
            pool.tick(float(t))
            d = auto.step()
        assert d.desired == 2

    def test_scale_down_needs_cooldown(self):
        pool = _pool()
        pool.add_entitlement(_ent("g", ServiceClass.GUARANTEED, 500.0))
        auto = Autoscaler(pool, AutoscalerConfig(cooldown_ticks=3))
        auto.step()
        assert pool.replicas == 3
        pool.remove_entitlement("g")     # demand vanishes
        held = [auto.step().desired for _ in range(2)]
        assert held == [3, 3]            # cooldown holds
        assert auto.step().desired == 1  # third low tick shrinks
        assert pool.replicas == 1

    def test_failure_then_recovery(self):
        """Replica failure drops runtime capacity; autoscaler restores it
        (paper Exp 2's outage/recovery, automated)."""
        pool = _pool()
        pool.add_entitlement(_ent("g", ServiceClass.GUARANTEED, 400.0))
        auto = Autoscaler(pool)
        auto.step()
        assert pool.replicas == 2
        pool.set_replicas(1)             # node failure
        d = auto.step()
        assert d.desired == 2            # plans recovery immediately
        assert pool.replicas == 2
