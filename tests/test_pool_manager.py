"""PoolManager routing semantics: ordered routes, spill-over, outages,
budget/latency-aware ordering, completion attribution — plus the
multi-pool simulation scenario end-to-end."""
import pytest

from repro.core import (
    EntitlementSpec,
    PoolManager,
    PoolSpec,
    QoS,
    Resources,
    RouteEntry,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)
from repro.gateway import Gateway


def mkpool(name, tps=1000.0, slots=4.0, bucket_window_s=1.0):
    return TokenPool(PoolSpec(
        name=name, model="m", scaling=ScalingBounds(1, 1),
        per_replica=Resources(tps, float(1 << 30), slots),
        default_max_tokens=64, bucket_window_s=bucket_window_s))


def ent(name, pool, klass=ServiceClass.GUARANTEED, tps=500.0, conc=4.0):
    return EntitlementSpec(
        name=name, tenant_id="t", pool=pool,
        qos=QoS(service_class=klass, slo_target_ms=500.0),
        baseline=Resources(tps, 0.0, conc))


def mkgateway(ent_tps_a=500.0, ent_tps_b=500.0, **gw_kwargs):
    """Two 1000-tps pools; the entitlement baselines control the token
    buckets (bucket window 1 s ⇒ initial budget == baseline tps)."""
    mgr = PoolManager([mkpool("a"), mkpool("b")])
    mgr.pool("a").add_entitlement(ent("prod@a", "a", tps=ent_tps_a))
    mgr.pool("b").add_entitlement(ent("prod@b", "b", tps=ent_tps_b))
    gw = Gateway(mgr, **gw_kwargs)
    gw.register_route("key", [("a", "prod@a"), ("b", "prod@b")])
    return gw


class TestRouting:
    def test_preferred_pool_admits(self):
        gw = mkgateway()
        r = gw.handle("key", "r1", 32, 32, now=0.0)
        assert r.status == 200
        assert r.pool == "a" and r.entitlement == "prod@a"
        assert r.spill_hops == 0
        assert "r1" in gw.manager.pool("a").in_flight

    def test_unknown_key_401(self):
        gw = mkgateway()
        assert gw.handle("nope", "r1", 32, 32, now=0.0).status == 401

    def test_spill_on_budget_exhaustion(self):
        # pool a's bucket only funds one request; the second spills to b
        gw = mkgateway(ent_tps_a=70.0)
        r1 = gw.handle("key", "r1", 32, 32, now=0.0)
        r2 = gw.handle("key", "r2", 32, 32, now=0.0)
        assert (r1.pool, r2.pool) == ("a", "b")
        assert r2.spill_hops == 1
        assert float(gw.store.get("spills:key")) == 1.0

    def test_spill_on_pool_outage(self):
        gw = mkgateway()
        gw.manager.pool("a").set_replicas(0)      # outage: a unavailable
        r = gw.handle("key", "r1", 32, 32, now=0.0)
        assert r.status == 200 and r.pool == "b"
        assert r.spill_hops == 1                  # past the dead leg

    def test_all_pools_deny_429_with_best_retry(self):
        gw = mkgateway(ent_tps_a=1.0, ent_tps_b=1.0)  # nobody affords 64
        r = gw.handle("key", "r1", 32, 32, now=0.0)
        assert r.status == 429
        assert r.reason == "token_budget"
        assert r.retry_after_s is not None and r.retry_after_s > 0
        assert float(gw.store.get("denials:prod@a")) == 1.0

    def test_no_live_pool_is_pool_unavailable(self):
        gw = mkgateway()
        gw.manager.pool("a").set_replicas(0)
        gw.manager.pool("b").set_replicas(0)
        r = gw.handle("key", "r1", 32, 32, now=0.0)
        assert r.status == 429
        assert r.reason == "pool_unavailable"

    def test_single_pool_legacy_api(self):
        pool = mkpool("only")
        pool.add_entitlement(ent("e", "only"))
        gw = Gateway(pool)                        # bare TokenPool
        gw.register_key("k", "e")
        assert gw.resolve("k") == "e"
        assert gw.pool is pool
        r = gw.handle("k", "r1", 16, 16, now=0.0)
        assert r.status == 200 and r.pool == "only"

    def test_headroom_policy_prefers_budget(self):
        """With spill_policy="headroom", the leg with the most remaining
        token-bucket budget wins even if it is not the declared first."""
        gw = mkgateway(ent_tps_a=70.0, ent_tps_b=500.0,
                       spill_policy="headroom")
        r0 = gw.handle("key", "r0", 32, 32, now=0.0)
        r1 = gw.handle("key", "r1", 32, 32, now=0.0)
        # b has 500 tokens of headroom vs a's 70 → both land on b,
        # and a (the declared preference) was never even tried
        assert (r0.pool, r1.pool) == ("b", "b")
        assert gw.manager.pool("a").status["prod@a"].denied_total == 0


class TestCompletionAttribution:
    def test_on_complete_settles_admitting_pool(self):
        gw = mkgateway(ent_tps_a=70.0)
        gw.handle("key", "r1", 32, 32, now=0.0)   # a
        gw.handle("key", "r2", 32, 32, now=0.0)   # spilled to b
        gw.on_complete("r2", 16, latency_s=0.5, now=1.0)
        a, b = gw.manager.pool("a"), gw.manager.pool("b")
        assert b.status["prod@b"].completed_total == 1
        assert a.status["prod@a"].completed_total == 0
        assert "r2" not in b.in_flight
        # token accounting attributed to the ADMITTING entitlement
        assert float(gw.store.get("tokens:prod@b")) == 16.0

    def test_pool_on_complete_returns_record(self):
        """Satellite: completion/eviction hand back the settled record
        instead of requiring a read-before-call on pool.in_flight."""
        pool = mkpool("p")
        pool.add_entitlement(ent("e", "p"))
        gw = Gateway(pool)
        gw.register_key("k", "e")
        gw.handle("k", "r1", 16, 16, now=0.0)
        rec = pool.on_complete("r1", 8, now=1.0)
        assert rec is not None and rec.entitlement == "e"
        assert pool.on_complete("r1", 8, now=1.0) is None  # idempotent

    def test_pool_on_evict_returns_record(self):
        pool = mkpool("p")
        pool.add_entitlement(ent("e", "p"))
        gw = Gateway(pool)
        gw.register_key("k", "e")
        gw.handle("k", "r1", 16, 16, now=0.0)
        rec = pool.on_evict("r1", now=1.0)
        assert rec is not None and rec.entitlement == "e"
        assert pool.status["e"].in_flight == 0
        assert pool.on_evict("r1", now=1.0) is None

    def test_gateway_on_failure_refunds(self):
        gw = mkgateway()
        gw.handle("key", "r1", 32, 32, now=0.0)
        level_after_admit = gw.manager.pool("a").ledger.bucket(
            "prod@a").level
        gw.on_failure("r1", now=0.0)
        level_after_evict = gw.manager.pool("a").ledger.bucket(
            "prod@a").level
        assert level_after_evict == pytest.approx(
            level_after_admit + 64.0)


class TestManagerLifecycle:
    def test_duplicate_pool_rejected(self):
        mgr = PoolManager([mkpool("a")])
        with pytest.raises(ValueError):
            mgr.adopt(mkpool("a"))

    def test_add_entitlement_routes_by_spec(self):
        mgr = PoolManager([mkpool("a"), mkpool("b")])
        mgr.add_entitlement(ent("e", "b"))
        assert "e" in mgr.pool("b").entitlements
        assert "e" not in mgr.pool("a").entitlements

    def test_route_requires_a_leg(self):
        gw = mkgateway()
        with pytest.raises(ValueError):
            gw.register_route("k2", [])

    def test_route_entries_accept_dataclass(self):
        gw = mkgateway()
        gw.register_route("k2", [RouteEntry("b", "prod@b")])
        assert gw.handle("k2", "r1", 16, 16, now=0.0).pool == "b"


class TestMultiPoolSimulation:
    def test_outage_spill_scenario_end_to_end(self):
        """ISSUE acceptance: 2+ pools, spill-over routing, one per-pool
        outage, running end-to-end via PoolManager's batched tick."""
        from repro.serving import (MultiPoolSimulator, PoolSite,
                                   RequestState, Workload)
        sim = MultiPoolSimulator(
            workloads=[
                Workload(name="prod",
                         service_class=ServiceClass.GUARANTEED,
                         slots=6, slo_ms=500.0, rate_rps=1.4,
                         pools=("east", "west")),
                Workload(name="batch", service_class=ServiceClass.SPOT,
                         slots=8, slo_ms=30000.0, rate_rps=3.0,
                         pools=("west", "east")),
            ],
            sites=[PoolSite("east", n_replicas=1, replica_slots=8,
                            replica_tps=120.0),
                   PoolSite("west", n_replicas=2, replica_slots=8,
                            replica_tps=120.0)])
        sim.at(15.0, "fail_replica", pool="east", idx=0)
        sim.at(30.0, "recover_replica", pool="east", idx=0)
        res = sim.run(45.0)

        prod = res["per_workload"]["prod"]
        # the guaranteed tenant rides out the outage via spill-over
        assert prod["spilled"] > 0
        assert prod["admitted_by_pool"].get("west", 0) > 0
        assert prod["admitted_by_pool"].get("east", 0) > 0
        unavailable = [r for r in sim.requests.values()
                       if r.entitlement == "prod"
                       and r.deny_reason == "pool_unavailable"]
        assert not unavailable
        # outage visible in east's capacity history, and both pools
        # ticked through the batched path
        east_caps = {h.capacity_tps
                     for h in res["per_pool_history"]["east"]}
        assert len(east_caps) >= 2
        assert len(res["per_pool_history"]["west"]) > 30
        # all admitted requests eventually completed or were in flight
        done = [r for r in sim.requests.values()
                if r.state == RequestState.FINISHED]
        assert len(done) > 0

    def test_failed_replica_requeues_on_same_pool(self):
        from repro.serving import MultiPoolSimulator, PoolSite, Workload
        sim = MultiPoolSimulator(
            workloads=[Workload(name="e",
                                service_class=ServiceClass.ELASTIC,
                                slots=8, slo_ms=1000.0, rate_rps=2.0,
                                pools=("p1", "p2"))],
            sites=[PoolSite("p1", n_replicas=2, replica_slots=8,
                            replica_tps=120.0),
                   PoolSite("p2", n_replicas=1, replica_slots=8,
                            replica_tps=120.0)])
        sim.at(10.0, "fail_replica", pool="p1", idx=1)
        res = sim.run(30.0)
        from repro.serving import RequestState
        reqs = [r for r in sim.requests.values() if r.arrival_s < 25]
        finished = [r for r in reqs
                    if r.state == RequestState.FINISHED]
        assert len(finished) >= 0.7 * max(len(reqs), 1)
