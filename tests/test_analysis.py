"""Static analyzer (``repro.analysis``): per-pass fixture tests (one
violating + one clean snippet each, exact rule-id and line pins),
waiver parsing/binding, manifest round-trip, oracle-parity failure
when a kernel's parity test is deleted, the "src is clean" self-test,
and the zero-overhead marker registries."""
import json
import shutil
import textwrap
from pathlib import Path

import pytest

from repro.analysis import Manifest, analyze, default_manifest
from repro.analysis.core import SourceFile

REPO = Path(__file__).resolve().parent.parent

#: synthetic column contract — one mirrored f32 column, one f64
#: accumulator, one sanctioned mutator.
SYNTH = Manifest.from_exports([{
    "store": "Store", "module": "fixture",
    "columns": {"burst": "float32", "window_tokens": "float64"},
    "mirrored": ["burst"],
    "kernel_f32": ["burst"],
    "sanctioned_mutators": ["Pool.adopt_device"],
}])


def line_of(src: str, needle: str) -> int:
    """1-based line of the first line containing ``needle``."""
    for i, ln in enumerate(src.splitlines(), start=1):
        if needle in ln:
            return i
    raise AssertionError(f"{needle!r} not in fixture")


def run(tmp_path, src: str, rules, *, name="repro/core/mod.py",
        tests_dir=None, manifest=SYNTH):
    src = textwrap.dedent(src)
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(src)
    report = analyze([str(p)], manifest=manifest, tests_dir=tests_dir,
                     rules=rules)
    return report, src


class TestMirrorInvalidation:
    VIOLATING = """
    import numpy as np

    class Pool:
        def bump(self, slot, v):
            c = self.store.col
            c["burst"][slot] = v

        def scatter(self, slots):
            c = self.store.col
            np.add.at(c["burst"], slots, 1.0)
    """

    CLEAN = """
    class Pool:
        def bump(self, slot, v):
            c = self.store.col
            c["burst"][slot] = v
            self.store.mark_dirty()

        def adopt_device(self, state):
            self.store.col["burst"][:] = 0.0

        def unmirrored(self, slot, v):
            self.store.col["window_tokens"][slot] = v
    """

    def test_violating(self, tmp_path):
        report, src = run(tmp_path, self.VIOLATING, ["mirror-invalidation"])
        lines = sorted(f.line for f in report.unwaived)
        assert [f.rule for f in report.unwaived] == ["mirror-invalidation"] * 2
        assert lines == [line_of(src, 'c["burst"][slot] = v'),
                         line_of(src, "np.add.at")]

    def test_clean(self, tmp_path):
        # invalidated write, sanctioned mutator, unmirrored column: 0
        report, _ = run(tmp_path, self.CLEAN, ["mirror-invalidation"])
        assert report.unwaived == []


class TestDtypeDiscipline:
    VIOLATING = """
    import numpy as np
    from repro.core.markers import kernel

    @kernel(oracle="fixture.oracle_fn")
    @jax.jit
    def k(x):
        return x

    class Pool:
        def call_uncast(self):
            c = self.store.col
            return k(c["window_tokens"])

        def call_f64(self, arr):
            return k(np.asarray(arr, np.float64))

        def truncate(self, slot, v):
            c = self.store.col
            c["window_tokens"][slot] = np.float32(v)
    """

    CLEAN = """
    import numpy as np
    from repro.core.markers import kernel

    @kernel(oracle="fixture.oracle_fn")
    @jax.jit
    def k(x):
        return x

    class Pool:
        def call_cast(self):
            c = self.store.col
            return k(c["window_tokens"].astype(np.float32))

        def accumulate(self, slot, v):
            c = self.store.col
            c["window_tokens"][slot] += float(v)
    """

    def test_violating(self, tmp_path):
        report, src = run(tmp_path, self.VIOLATING, ["dtype-discipline"])
        assert {f.rule for f in report.unwaived} == {"dtype-discipline"}
        lines = sorted(f.line for f in report.unwaived)
        assert lines == [line_of(src, 'k(c["window_tokens"])'),
                         line_of(src, "np.asarray(arr, np.float64)"),
                         line_of(src, "np.float32(v)")]

    def test_clean(self, tmp_path):
        report, _ = run(tmp_path, self.CLEAN, ["dtype-discipline"])
        assert report.unwaived == []


class TestRetraceHazard:
    VIOLATING = """
    from functools import partial
    from repro.core.markers import kernel

    COUNTS = {"k": 0}
    NAMES = ("coeff",)

    @kernel(oracle="fixture.oracle_fn")
    @partial(jax.jit, static_argnames=NAMES)
    def k(x, coeff=None):
        COUNTS["k"] += 1
        return x

    @kernel(oracle="fixture.oracle_fn")
    @partial(jax.jit, static_argnames=("coeff",))
    def k2(x, coeff=None):
        return x

    def unbucketed(arr):
        return k(arr)

    def unhashable(arr):
        return k2(pad_rows(arr), coeff=[1, 2])
    """

    CLEAN = """
    from functools import partial
    from repro.core.markers import kernel

    @kernel(oracle="fixture.oracle_fn")
    @partial(jax.jit, static_argnames=("coeff",))
    def k(x, coeff=None):
        return x

    def driver(arr, n):
        w = bucket_width(n)
        return k(pad_rows(arr, w), coeff=3)
    """

    def test_violating(self, tmp_path):
        report, src = run(tmp_path, self.VIOLATING, ["retrace-hazard"])
        msgs = {f.line: f.message for f in report.unwaived}
        assert {f.rule for f in report.unwaived} == {"retrace-hazard"}
        # non-literal static_argnames on the jit decoration
        assert "not a literal" in msgs[line_of(src, "static_argnames=NAMES")]
        # mutable host capture inside the kernel body
        assert "mutable host state 'COUNTS'" in \
            msgs[line_of(src, 'COUNTS["k"] += 1')]
        # call site with no shape-bucketing provider in sight
        assert "retraces the kernel" in msgs[line_of(src, "return k(arr)")]
        # unhashable literal for a declared static arg
        assert "unhashable literal" in msgs[line_of(src, "coeff=[1, 2]")]
        assert len(report.unwaived) == 4

    def test_clean(self, tmp_path):
        report, _ = run(tmp_path, self.CLEAN, ["retrace-hazard"])
        assert report.unwaived == []


class TestShardMapRetraceHazard:
    """``shard_map`` call-site awareness: ``mesh`` is a static jit
    argument, so an inline ``Mesh(...)`` at a kernel call site is a
    dispatch-cache leak; the cached ``row_mesh``/``pool_mesh``
    providers (and ``shard_width``) count as shape providers."""

    VIOLATING = """
    from functools import partial
    from repro.core.markers import kernel

    @kernel(oracle="fixture.oracle_fn")
    @partial(jax.jit, static_argnames=("mesh",))
    def sharded_k(x, *, mesh):
        return x

    def driver(arr, devices):
        w = bucket_width(arr.shape[0])
        return sharded_k(pad_rows(arr, w),
                         mesh=Mesh(devices, ("rows",)))
    """

    CLEAN = """
    from functools import partial
    from repro.core.markers import kernel

    @kernel(oracle="fixture.oracle_fn")
    @partial(jax.jit, static_argnames=("mesh",))
    def sharded_k(x, *, mesh):
        return x

    def driver(arr, n):
        mesh = row_mesh(4)
        w = shard_width(n, mesh)
        return sharded_k(pad_rows(arr, w), mesh=mesh)
    """

    def test_violating(self, tmp_path):
        report, src = run(tmp_path, self.VIOLATING, ["retrace-hazard"])
        [f] = report.unwaived
        assert f.rule == "retrace-hazard"
        assert f.line == line_of(src, "mesh=Mesh(devices")
        assert "inline Mesh" in f.message
        assert "row_mesh" in f.message

    def test_clean(self, tmp_path):
        # cached mesh provider + shard_width as the bucketing witness
        report, _ = run(tmp_path, self.CLEAN, ["retrace-hazard"])
        assert report.unwaived == []


class TestHotPathScalarLoop:
    VIOLATING = """
    from repro.core.markers import hot_path

    class Pool:
        @hot_path
        def bad(self):
            return [r for r in self.in_flight.values()]
    """

    CLEAN = """
    from repro.core.markers import hot_path

    class Pool:
        @hot_path
        def ok(self, batch):
            return [b for b in batch]

        def unmarked(self):
            return [r for r in self.in_flight.values()]
    """

    def test_violating(self, tmp_path):
        report, src = run(tmp_path, self.VIOLATING, ["hot-path-scalar-loop"])
        [f] = report.unwaived
        assert f.rule == "hot-path-scalar-loop"
        assert f.line == line_of(src, "self.in_flight.values()")

    def test_clean(self, tmp_path):
        # batch comprehension in a hot path is O(batch) — allowed; row
        # iteration outside @hot_path is not this pass's business.
        report, _ = run(tmp_path, self.CLEAN, ["hot-path-scalar-loop"])
        assert report.unwaived == []


class TestOracleParity:
    SRC = """
    from repro.core.markers import kernel

    @jax.jit
    def unregistered(x):
        return x

    @kernel(oracle="repro.core.scalar.Oracle.run")
    @jax.jit
    def fused_step(x):
        return x
    """

    def _tests_dir(self, tmp_path, covered=True):
        d = tmp_path / "tests"
        d.mkdir(exist_ok=True)
        if covered:
            (d / "test_parity.py").write_text(
                "from mod import fused_step\n"
                "from scalar import Oracle\n")
        return str(d)

    def test_unregistered_jit_flagged_and_covered_kernel_clean(
            self, tmp_path):
        report, src = run(tmp_path, self.SRC, ["oracle-parity"],
                          tests_dir=self._tests_dir(tmp_path))
        [f] = report.unwaived
        assert f.line == line_of(src, "def unregistered")
        assert "not registered" in f.message

    def test_deleting_parity_test_fails_the_pass(self, tmp_path):
        report, src = run(tmp_path, self.SRC, ["oracle-parity"],
                          tests_dir=self._tests_dir(tmp_path, covered=False))
        missing = [f for f in report.unwaived
                   if "parity coverage missing" in f.message]
        [f] = missing
        assert f.line == line_of(src, "def fused_step")
        assert "'fused_step'" in f.message

    def test_out_of_scope_jit_exempt(self, tmp_path):
        report, _ = run(tmp_path, self.SRC, ["oracle-parity"],
                        name="repro/kernels/mod.py",
                        tests_dir=self._tests_dir(tmp_path))
        # neither the unregistered jit nor coverage applies... except
        # the @kernel registration is global: coverage still checked.
        assert all("not registered" not in f.message
                   for f in report.unwaived)

    def test_out_of_scope_shard_map_jit_still_flagged(self, tmp_path):
        # a shard_map body makes a jit def a SHARDED kernel: it needs a
        # single-device oracle registration wherever it lives
        src = """
        from functools import partial
        from repro.core.markers import kernel

        @partial(jax.jit, static_argnames=("mesh",))
        def rogue_sharded(x, *, mesh):
            return shard_map(lambda b: b, mesh=mesh,
                             in_specs=P("rows"), out_specs=P("rows"))(x)

        @kernel(oracle="repro.core.scalar.Oracle.run")
        @partial(jax.jit, static_argnames=("mesh",))
        def fused_step(x, *, mesh):
            return shard_map(lambda b: b, mesh=mesh,
                             in_specs=P("rows"), out_specs=P("rows"))(x)
        """
        report, src = run(tmp_path, src, ["oracle-parity"],
                          name="repro/distributed/mod.py",
                          tests_dir=self._tests_dir(tmp_path))
        flagged = [f for f in report.unwaived
                   if "sharded jit kernel" in f.message]
        [f] = flagged
        assert f.line == line_of(src, "def rogue_sharded")
        assert "'rogue_sharded'" in f.message

    def test_non_literal_oracle_flagged(self, tmp_path):
        src = """
        from repro.core.markers import kernel

        PATH = "a.b"

        @kernel(oracle=PATH)
        @jax.jit
        def fused_step(x):
            return x
        """
        report, src = run(tmp_path, src, ["oracle-parity"],
                          tests_dir=self._tests_dir(tmp_path))
        assert any("no literal oracle" in f.message
                   for f in report.unwaived)


class TestWaivers:
    def test_same_line_waiver_with_reason(self, tmp_path):
        src = """
        class Pool:
            def bump(self, slot, v):
                c = self.store.col
                c["burst"][slot] = v  # repro: allow[mirror-invalidation] -- adopted wholesale below
        """
        report, _ = run(tmp_path, src, ["mirror-invalidation"])
        assert report.unwaived == []
        [f] = report.waived
        assert f.waive_reason == "adopted wholesale below"
        assert report.ok(strict=True)

    def test_comment_line_waiver_binds_to_next_code_line(self, tmp_path):
        src = """
        class Pool:
            def bump(self, slot, v):
                c = self.store.col
                # repro: allow[mirror-invalidation] -- statics; caller invalidates
                c["burst"][slot] = v
        """
        report, _ = run(tmp_path, src, ["mirror-invalidation"])
        assert report.unwaived == []
        assert len(report.waived) == 1

    def test_reasonless_waiver_fails_strict_only(self, tmp_path):
        src = """
        class Pool:
            def bump(self, slot, v):
                c = self.store.col
                c["burst"][slot] = v  # repro: allow[mirror-invalidation]
        """
        report, _ = run(tmp_path, src, ["mirror-invalidation"])
        assert report.unwaived == []
        assert report.ok(strict=False)
        assert not report.ok(strict=True)
        [(path, line, rules)] = report.reasonless_waivers
        assert rules == ("mirror-invalidation",)

    def test_file_scoped_waiver(self, tmp_path):
        src = """
        # repro: allow-file[mirror-invalidation] -- generated shim

        class Pool:
            def bump(self, slot, v):
                self.store.col["burst"][slot] = v
        """
        report, _ = run(tmp_path, src, ["mirror-invalidation"])
        assert report.unwaived == []
        assert len(report.waived) == 1

    def test_waiver_is_rule_scoped(self, tmp_path):
        # a hot-path waiver does not excuse a mirror violation
        src = """
        class Pool:
            def bump(self, slot, v):
                c = self.store.col
                c["burst"][slot] = v  # repro: allow[hot-path-scalar-loop] -- wrong rule
        """
        report, _ = run(tmp_path, src, ["mirror-invalidation"])
        assert len(report.unwaived) == 1

    def test_multi_rule_waiver_parsing(self):
        sf = SourceFile("x.py", textwrap.dedent("""
            a = 1  # repro: allow[rule-a, rule-b] -- both
        """))
        [w] = sf.waivers
        assert w.rules == ("rule-a", "rule-b")
        assert w.reason == "both"
        assert not w.file_scoped


class TestManifest:
    def test_json_round_trip(self):
        m = default_manifest()
        m2 = Manifest.from_json(m.to_json())
        assert m2.mirrored == m.mirrored
        assert m2.kernel_f32 == m.kernel_f32
        assert m2.f64_columns == m.f64_columns
        assert m2.sanctioned_mutators == m.sanctioned_mutators

    def test_live_contract_contents(self):
        m = default_manifest()
        assert "burst" in m.mirrored and "debt" in m.mirrored
        assert "class_code" in m.mirrored
        assert "window_tokens" in m.f64_columns
        assert "ResidentStore.adopt_device" in m.sanctioned_mutators
        # request-table columns merge in (priority is f64 there)
        assert "priority" in m.f64_columns


class TestRepoIsClean:
    """The adoption half of the tentpole: the analyzer runs over the
    real src/ tree with the live manifest and finds nothing unwaived,
    and every waiver carries a reason."""

    def test_src_clean_under_strict(self):
        report = analyze([str(REPO / "src")],
                         tests_dir=str(REPO / "tests"))
        assert [f.format() for f in report.unwaived] == []
        assert report.reasonless_waivers == []
        assert report.ok(strict=True)
        # all seven passes actually ran
        assert len(report.rules_run) == 7

    def test_deleting_a_parity_test_breaks_the_build(self, tmp_path):
        """ISSUE acceptance: remove a kernel's parity test from the
        cross-referenced tree and oracle-parity goes red."""
        pruned = tmp_path / "tests"
        shutil.copytree(REPO / "tests", pruned,
                        ignore=shutil.ignore_patterns("test_fleet.py",
                                                      "__pycache__"))
        report = analyze([str(REPO / "src")], tests_dir=str(pruned),
                         rules=["oracle-parity"])
        assert any("'plan_fleet'" in f.message for f in report.unwaived)

    def test_report_json_shape(self, tmp_path):
        report = analyze([str(REPO / "src")],
                         tests_dir=str(REPO / "tests"))
        blob = json.loads(json.dumps(report.to_json()))
        assert blob["unwaived_total"] == 0
        assert set(blob["rules"]) == {
            "mirror-invalidation", "dtype-discipline", "retrace-hazard",
            "hot-path-scalar-loop", "oracle-parity",
            "telemetry-hot-path", "chaos-public-api"}


class TestMarkers:
    def test_registries_populated(self):
        # importing the control plane registers the five fused kernels
        import repro.core.fleet       # noqa: F401
        import repro.core.vectorized  # noqa: F401
        from repro.core.markers import HOT_PATHS, KERNELS

        assert {"control_tick", "control_tick_pools", "tick_batch",
                "admit_quantum", "plan_fleet"} <= set(KERNELS)
        assert KERNELS["admit_quantum"].oracle == \
            "repro.core.admission.AdmissionController.decide"
        assert "repro.core.pool.TokenPool.reclaim_preemptible" in HOT_PATHS

    def test_decorators_are_zero_overhead(self):
        from repro.core.markers import hot_path, kernel

        def f():
            return 7

        assert hot_path(f) is f          # same object: no wrapper
        assert kernel(oracle="a.b")(f) is f
        assert f() == 7

    def test_assert_no_retrace_runtime_crosscheck(self):
        from repro.analysis.runtime import assert_no_retrace
        from repro.core.control_plane import TRACE_COUNTS

        with assert_no_retrace("control_tick"):
            pass                          # nothing compiled: fine
        before = TRACE_COUNTS["control_tick"]
        try:
            with pytest.raises(AssertionError, match="retraced"):
                with assert_no_retrace("control_tick"):
                    TRACE_COUNTS["control_tick"] += 1
        finally:
            TRACE_COUNTS["control_tick"] = before


class TestCLI:
    def test_strict_run_over_src_exits_zero_and_writes_report(
            self, tmp_path, capsys):
        from repro.analysis.__main__ import main

        out = tmp_path / "ANALYSIS_report.json"
        rc = main(["--strict", "--report", str(out),
                   "--tests-dir", str(REPO / "tests"), str(REPO / "src")])
        assert rc == 0
        blob = json.loads(out.read_text())
        assert blob["unwaived_total"] == 0

    def test_violation_exits_nonzero(self, tmp_path, capsys):
        from repro.analysis.__main__ import main

        bad = tmp_path / "bad.py"
        bad.write_text(textwrap.dedent("""
            from repro.core.markers import hot_path

            class Pool:
                @hot_path
                def bad(self):
                    return [r for r in self.in_flight.values()]
        """))
        rc = main(["--rules", "hot-path-scalar-loop",
                   "--tests-dir", str(tmp_path), str(bad)])
        assert rc == 1
        assert "hot-path-scalar-loop" in capsys.readouterr().out

    def test_list_rules(self, capsys):
        from repro.analysis.__main__ import main

        assert main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rule in ("mirror-invalidation", "dtype-discipline",
                     "retrace-hazard", "hot-path-scalar-loop",
                     "oracle-parity"):
            assert rule in out
