"""TokenPool controller: allocation ordering (Table 1), water-filling,
work-conserving backfill, debt dynamics, reclamation."""
import pytest

from repro.core import (
    EntitlementSpec,
    EntitlementState,
    PoolSpec,
    PriorityCoefficients,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
    waterfill,
)


def mkpool(tps=160.0, conc=16.0, replicas=1, max_replicas=1) -> TokenPool:
    spec = PoolSpec(
        name="p", model="m",
        scaling=ScalingBounds(min_replicas=replicas, max_replicas=max_replicas),
        per_replica=Resources(tps, 64 * (1 << 20), conc),
    )
    return TokenPool(spec)


def ent(name, klass, tps, conc=4.0, slo=1000.0, kv=0.0):
    return EntitlementSpec(
        name=name, tenant_id=f"t-{name}", pool="p",
        qos=QoS(service_class=klass, slo_target_ms=slo),
        baseline=Resources(tps, kv, conc),
    )


class TestWaterfill:
    def test_no_scarcity_everyone_gets_want(self):
        a = waterfill(100.0, {"x": 30.0, "y": 20.0}, {"x": 1.0, "y": 1.0})
        assert a == {"x": 30.0, "y": 20.0}

    def test_scarcity_weighted_shares(self):
        a = waterfill(30.0, {"x": 100.0, "y": 100.0}, {"x": 2.0, "y": 1.0})
        assert a["x"] == pytest.approx(20.0)
        assert a["y"] == pytest.approx(10.0)

    def test_cap_and_redistribute(self):
        # x caps at 5; its unused share flows to y
        a = waterfill(30.0, {"x": 5.0, "y": 100.0}, {"x": 10.0, "y": 1.0})
        assert a["x"] == pytest.approx(5.0)
        assert a["y"] == pytest.approx(25.0)

    def test_work_conserving(self):
        a = waterfill(50.0, {"x": 100.0, "y": 10.0}, {"x": 1.0, "y": 1.0})
        assert sum(a.values()) == pytest.approx(50.0)

    def test_zero_weights_equal_split(self):
        a = waterfill(10.0, {"x": 50.0, "y": 50.0}, {"x": 0.0, "y": 0.0})
        assert a["x"] == pytest.approx(5.0)
        assert a["y"] == pytest.approx(5.0)

    def test_never_exceeds_capacity(self):
        a = waterfill(10.0, {"x": 3.0, "y": 2.0}, {"x": 1.0, "y": 1.0})
        assert sum(a.values()) <= 10.0 + 1e-9


class TestAllocationOrdering:
    """Table 1 protection ordering end-to-end through a tick."""

    def test_guaranteed_funding_reserved_idle_capacity_borrowed(self):
        """Table 1: guaranteed funding is never reclaimed (alloc stays at
        baseline even when idle) — but the *idle* capacity itself is
        work-conservingly borrowed by spot until the tenant returns."""
        pool = mkpool(tps=100.0)
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 60.0))
        pool.add_entitlement(ent("s", ServiceClass.SPOT, 0.0))
        # spot demands everything, guaranteed idle
        pool.register_deny("s", 500.0, low_priority=False)
        rec = pool.tick(1.0)
        assert rec.allocations["g"] == pytest.approx(60.0)   # funding kept
        assert rec.allocations["s"] == pytest.approx(100.0)  # idle borrowed

    def test_spot_squeezed_when_guaranteed_returns(self):
        pool = mkpool(tps=100.0)
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 60.0))
        pool.add_entitlement(ent("s", ServiceClass.SPOT, 0.0))
        for t in range(1, 6):
            pool.register_deny("g", 60.0, low_priority=False)   # g active
            pool.register_deny("s", 500.0, low_priority=False)
            rec = pool.tick(float(t))
        # with g consuming its baseline, spot gets only the surplus
        assert rec.allocations["g"] == pytest.approx(60.0)
        assert rec.allocations["s"] == pytest.approx(40.0, abs=2.0)

    def test_elastic_shrunk_before_guaranteed(self):
        # entitleable capacity (2 replicas) covers both baselines;
        # runtime capacity (1 replica = 100 tps) creates the scarcity.
        pool = mkpool(tps=100.0, replicas=1, max_replicas=2)
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 80.0))
        pool.add_entitlement(ent("e", ServiceClass.ELASTIC, 50.0))
        rec = None
        for t in range(1, 8):
            pool.register_deny("g", 80.0, low_priority=False)
            pool.register_deny("e", 100.0, low_priority=False)
            rec = pool.tick(float(t))
        assert rec.allocations["e"] == pytest.approx(20.0, abs=3.0)

    def test_elastic_scarcity_split_by_priority(self):
        pool = mkpool(tps=80.0, replicas=1, max_replicas=2)
        pool.add_entitlement(ent("tight", ServiceClass.ELASTIC, 50.0, slo=500.0))
        pool.add_entitlement(ent("loose", ServiceClass.ELASTIC, 50.0, slo=30000.0))
        pool.register_deny("tight", 100.0, low_priority=False)
        pool.register_deny("loose", 100.0, low_priority=False)
        rec = pool.tick(1.0)
        assert rec.allocations["tight"] > rec.allocations["loose"]
        assert (rec.allocations["tight"] + rec.allocations["loose"]
                == pytest.approx(80.0))

    def test_dedicated_can_burst_guaranteed_cannot(self):
        pool = mkpool(tps=100.0)
        pool.add_entitlement(ent("d", ServiceClass.DEDICATED, 30.0))
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 30.0))
        # both demand far above baseline
        pool.register_deny("d", 200.0, low_priority=False)
        pool.register_deny("g", 200.0, low_priority=False)
        rec = pool.tick(1.0)
        assert rec.allocations["d"] > 30.0 + 1e-6      # bursts into surplus
        assert rec.allocations["g"] == pytest.approx(30.0)  # rate-limit semantics

    def test_runtime_capacity_dip_scales_protected(self):
        pool = mkpool(tps=100.0, replicas=1, max_replicas=2)
        pool.add_entitlement(ent("g1", ServiceClass.GUARANTEED, 80.0))
        pool.add_entitlement(ent("g2", ServiceClass.GUARANTEED, 80.0))
        # entitleable capacity 200 → both bind; runtime only 100;
        # both ACTIVE at full baseline → emergency proportional scaling
        rec = None
        for t in range(1, 8):
            pool.register_deny("g1", 80.0, low_priority=False)
            pool.register_deny("g2", 80.0, low_priority=False)
            rec = pool.tick(float(t))
        assert rec.allocations["g1"] == pytest.approx(50.0, abs=2.0)
        assert rec.allocations["g2"] == pytest.approx(50.0, abs=2.0)


class TestDebtDynamics:
    def test_underserved_elastic_accumulates_debt(self):
        # outage leaves capacity 40 < either baseline: both sub-baseline
        # (paper Fig. 5 panel 2: both debts positive, loose-SLO larger)
        pool = mkpool(tps=40.0, replicas=1, max_replicas=4)
        pool.add_entitlement(ent("a", ServiceClass.ELASTIC, 50.0, slo=500.0))
        pool.add_entitlement(ent("b", ServiceClass.ELASTIC, 50.0, slo=30000.0))
        for t in range(1, 20):
            pool.register_deny("a", 60.0, low_priority=False)
            pool.register_deny("b", 60.0, low_priority=False)
            pool.tick(float(t))
        # b (loose SLO) gets less capacity → more debt; both positive
        assert pool.status["b"].debt > pool.status["a"].debt > 0.0

    def test_fully_served_elastic_accrues_no_debt(self):
        # milder scarcity: tight-SLO tenant reaches baseline → no debt,
        # while the squeezed one converges to its steady-state gap
        pool = mkpool(tps=80.0, replicas=1, max_replicas=2)
        pool.add_entitlement(ent("a", ServiceClass.ELASTIC, 50.0, slo=500.0))
        pool.add_entitlement(ent("b", ServiceClass.ELASTIC, 50.0, slo=30000.0))
        for t in range(1, 20):
            pool.register_deny("a", 60.0, low_priority=False)
            pool.register_deny("b", 60.0, low_priority=False)
            rec = pool.tick(float(t))
        assert pool.status["a"].debt == pytest.approx(0.0, abs=1e-9)
        assert rec.allocations["a"] == pytest.approx(50.0)
        # b's steady-state debt equals its steady allocation gap (20/50)
        assert pool.status["b"].debt == pytest.approx(0.4, abs=0.01)

    def test_debt_raises_future_share_and_narrows_gap(self):
        """Paper §5.3: debt narrows the priority gap (4.6× → ~3.9× in
        their run) and the loose-SLO tenant's share grows, preventing
        starvation."""
        pool = mkpool(tps=40.0, replicas=1, max_replicas=4)
        pool.add_entitlement(ent("a", ServiceClass.ELASTIC, 50.0, slo=500.0))
        pool.add_entitlement(ent("b", ServiceClass.ELASTIC, 50.0, slo=30000.0))
        no_debt_gap = (pool.priority("a") / pool.priority("b"))
        assert no_debt_gap == pytest.approx(4.62, abs=0.05)
        for t in range(1, 30):
            pool.register_deny("a", 60.0, low_priority=False)
            pool.register_deny("b", 60.0, low_priority=False)
            rec = pool.tick(float(t))
        gap = rec.priorities["a"] / rec.priorities["b"]
        assert gap < 3.9                            # beats paper's 3.9×
        assert pool.status["b"].debt > pool.status["a"].debt > 0.0
        assert rec.allocations["b"] > 0.15 * 40.0   # no starvation

    def test_idle_entitlement_accrues_no_debt(self):
        pool = mkpool(tps=10.0)
        pool.add_entitlement(ent("idle", ServiceClass.ELASTIC, 50.0))
        for t in range(1, 10):
            pool.tick(float(t))
        assert pool.status["idle"].debt == pytest.approx(0.0)

    def test_spot_never_accrues_debt(self):
        pool = mkpool(tps=10.0)
        pool.add_entitlement(ent("s", ServiceClass.SPOT, 0.0))
        for t in range(1, 10):
            pool.register_deny("s", 100.0, low_priority=True)
            pool.tick(float(t))
        assert pool.status["s"].debt == 0.0

    def test_debt_decays_after_recovery(self):
        pool = mkpool(tps=20.0)
        pool.add_entitlement(ent("a", ServiceClass.ELASTIC, 50.0))
        for t in range(1, 10):
            pool.register_deny("a", 60.0, low_priority=False)
            pool.tick(float(t))
        peak = pool.status["a"].debt
        assert peak > 0.1
        # capacity recovers: demand served at baseline (no gap)
        pool.set_replicas(1)
        pool.spec.per_replica = Resources(200.0, 64 << 20, 16.0)
        for t in range(10, 40):
            pool.status["a"].window_tokens = 50.0  # served at baseline
            pool.register_deny("a", 0.0, low_priority=False)
            pool.tick(float(t))
        assert pool.status["a"].debt < 0.05


class TestVirtualNodeIntegration:
    def test_over_entitlement_degrades(self):
        pool = mkpool(tps=100.0, conc=16.0)
        s1 = pool.add_entitlement(ent("g1", ServiceClass.GUARANTEED, 80.0))
        s2 = pool.add_entitlement(ent("g2", ServiceClass.GUARANTEED, 80.0))
        assert s1 == EntitlementState.BOUND
        assert s2 == EntitlementState.DEGRADED     # 160 > 100 entitleable

    def test_spot_always_binds(self):
        pool = mkpool(tps=100.0)
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 100.0))
        s = pool.add_entitlement(ent("s", ServiceClass.SPOT, 0.0))
        assert s == EntitlementState.BOUND

    def test_removal_frees_capacity_for_pending(self):
        pool = mkpool(tps=100.0)
        pool.add_entitlement(ent("g1", ServiceClass.GUARANTEED, 80.0))
        pool.add_entitlement(ent("g2", ServiceClass.GUARANTEED, 80.0))
        pool.remove_entitlement("g1")
        # pending lease g2 reschedules on the freed node
        assert pool.provider.is_bound("lease-g2")


class TestReclamation:
    def test_preemptible_eviction_list(self):
        from repro.core.pool import InFlight
        pool = mkpool(tps=100.0)
        pool.add_entitlement(ent("p", ServiceClass.PREEMPTIBLE, 0.0))
        pool.add_entitlement(ent("s", ServiceClass.SPOT, 0.0))
        pool.register_admit(InFlight("r1", "p", 0.1, 0.0, 64, 0.0), 64.0)
        pool.register_admit(InFlight("r2", "s", 1.0, 0.0, 64, 0.0), 64.0)
        victims = pool.reclaim_preemptible()
        assert victims == ["r1"]          # preemptible evicted, spot not

    def test_preemptible_eviction_order_and_liveness(self):
        """Vectorized reclaim parity: victims come back in admission
        order (the old per-record scan's order) and completed records
        drop out of the victim set."""
        from repro.core.pool import InFlight
        pool = mkpool(tps=400.0, conc=32.0)
        pool.add_entitlement(ent("a", ServiceClass.PREEMPTIBLE, 0.0))
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 50.0))
        pool.add_entitlement(ent("b", ServiceClass.PREEMPTIBLE, 0.0))
        for rid, owner in [("r1", "a"), ("r2", "g"),
                           ("r3", "b"), ("r4", "a")]:
            pool.register_admit(InFlight(rid, owner, 0.1, 0.0, 64, 0.0),
                                64.0)
        assert pool.reclaim_preemptible() == ["r1", "r3", "r4"]
        pool.on_evict("r3", now=1.0)
        assert pool.reclaim_preemptible() == ["r1", "r4"]

    def test_preemptible_eviction_empty_table(self):
        pool = mkpool(tps=100.0)
        assert pool.reclaim_preemptible() == []


class TestMirrorContract:
    def test_write_statics_drops_device_mirror(self):
        """Regression (surfaced by the mirror-invalidation analyzer
        pass): ``_write_statics`` writes kernel-facing static columns,
        so it must drop the cached device mirror itself instead of
        relying on both callers writing ``st.state`` afterwards."""
        pool = mkpool(tps=100.0)
        pool.add_entitlement(ent("g", ServiceClass.GUARANTEED, 50.0))
        pool.store.device_state()            # build + cache the mirror
        assert pool.store._device is not None
        slot = pool.store.slot_of["g"]
        pool._write_statics(slot, ent("g", ServiceClass.GUARANTEED, 60.0))
        assert pool.store._device is None    # mirror dropped per-write

    def test_evict_releases_state(self):
        from repro.core.pool import InFlight
        pool = mkpool(tps=100.0)
        pool.add_entitlement(ent("p", ServiceClass.PREEMPTIBLE, 0.0))
        pool.register_admit(InFlight("r1", "p", 0.1, 1024.0, 64, 0.0), 64.0)
        assert pool.status["p"].in_flight == 1
        pool.on_evict("r1", now=1.0)
        assert pool.status["p"].in_flight == 0
        assert pool.status["p"].kv_bytes_in_use == 0.0
        assert "r1" not in pool.in_flight


class TestExpiry:
    def test_ttl_expiry(self):
        pool = mkpool()
        spec = ent("e", ServiceClass.ELASTIC, 10.0)
        spec.ttl_s = 5.0
        pool.add_entitlement(spec, now=0.0)
        pool.tick(1.0)
        assert pool.status["e"].state == EntitlementState.BOUND
        pool.tick(6.0)
        assert pool.status["e"].state == EntitlementState.EXPIRED


class TestRemoveEntitlement:
    """`remove_entitlement` must tear down EVERY piece of state keyed by
    the name — the seed leaked the ledger bucket, the demand-window
    keys, and any in-flight records (whose later completion callbacks
    then KeyError'd on the missing status row)."""

    def _pool_with_inflight(self):
        from repro.core import Charge
        from repro.core.pool import InFlight
        pool = mkpool(tps=200.0)
        pool.add_entitlement(ent("g1", ServiceClass.GUARANTEED, 80.0))
        pool.add_entitlement(ent("g2", ServiceClass.GUARANTEED, 80.0))
        # admit one request on g1 exactly as the §4.3 pipeline would
        pool.ledger.charge(Charge("r1", "g1", 64.0, 32, 32, 0.0), 0.0)
        pool.register_admit(InFlight("r1", "g1", 1.0, 128.0, 64, 0.0),
                            64.0)
        pool.on_start("r1")
        return pool

    def test_in_flight_records_settled(self):
        pool = self._pool_with_inflight()
        pool.remove_entitlement("g1", now=0.5)
        assert "r1" not in pool.in_flight
        # the old code left the record: on_complete then raised
        # KeyError on pool.status["g1"]; now it is a clean no-op
        assert pool.on_complete("r1", 16, now=1.0) is None
        assert pool.on_evict("r1", now=1.0) is None
        assert pool.pool_in_flight() == 0
        assert pool.total_resident() == 0

    def test_ledger_bucket_dropped(self):
        pool = self._pool_with_inflight()
        pool.remove_entitlement("g1", now=0.5)
        with pytest.raises(KeyError):
            pool.ledger.bucket("g1")     # no bucket left refilling

    def test_demand_keys_leave_future_tick_records(self):
        pool = self._pool_with_inflight()
        pool.tick(1.0)
        assert "g1" in pool.history[-1].demand_tps    # pre-removal
        pool.remove_entitlement("g1", now=1.5)
        rec = pool.tick(2.0)
        assert "g1" not in rec.demand_tps
        assert "g1" not in rec.allocations
        assert "g2" in rec.demand_tps

    def test_remove_without_inflight_still_clean(self):
        pool = mkpool(tps=200.0)
        pool.add_entitlement(ent("g1", ServiceClass.GUARANTEED, 80.0))
        pool.remove_entitlement("g1")
        assert pool.tick(1.0).demand_tps == {}
