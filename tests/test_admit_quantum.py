"""Decision parity of the fused ``admit_quantum`` kernel with the
scalar §4.3 ``AdmissionController`` pipeline — deterministic pins for
the regimes where the seed kernel DISAGREED with the oracle (burst
escape, live thresholds, snapshot mutation).  The hypothesis-randomized
sweep of the same property lives in ``test_vectorized_equiv.py``."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Resources, ServiceClass

#: scalar DenyReason → admit_quantum reason code (0 = admitted)
REASON_TO_CODE = {
    None: 0,
    "entitlement_not_bound": 1,
    "concurrency_limit": 2,
    "token_budget": 3,
    "low_priority": 4,
}


def mkpool_for_quantum(pool_conc=3.0, default_max_tokens=64,
                       slack=0.0, pool_tps=1000.0):
    from repro.core import PoolSpec, ScalingBounds, TokenPool
    spec = PoolSpec(name="p", model="m", scaling=ScalingBounds(1, 1),
                    per_replica=Resources(pool_tps, float(1 << 40),
                                          pool_conc),
                    default_max_tokens=default_max_tokens,
                    admission_slack=slack, bucket_window_s=1.0)
    return TokenPool(spec)


def qent(name, klass, tps, conc, slo, kv=0.0):
    from repro.core import EntitlementSpec, QoS
    return EntitlementSpec(
        name=name, tenant_id=name, pool="p",
        qos=QoS(service_class=klass, slo_target_ms=slo),
        baseline=Resources(tps, kv, conc))


def seed_inflight(pool, name, queued, resident, rid_prefix="bg"):
    """Place pre-existing requests on an entitlement: ``queued`` admitted
    but waiting + ``resident`` holding decode slots.  Record priorities
    are deliberately junk (0.0): the admission threshold must come from
    LIVE priorities, never the per-record snapshots."""
    from repro.core.pool import InFlight
    for k in range(queued + resident):
        rid = f"{rid_prefix}-{name}-{k}"
        pool.register_admit(InFlight(rid, name, 0.0, 0.0, 64, 0.0), 64.0)
        if k < resident:
            pool.on_start(rid)


def run_quantum_vs_scalar(pool, reqs, slack=0.0):
    """Kernel replay on a snapshot vs sequential scalar decides on the
    LIVE pool.  ``reqs``: list of (ent_name, input_tokens, max_tokens,
    kv_bytes_per_token).  Returns (kernel, scalar) decision lists of
    (admitted, reason_code)."""
    from repro.core import AdmissionController, AdmissionRequest
    from repro.core.vectorized import admit_quantum, quantum_snapshot

    snap = quantum_snapshot(pool, 0.0)
    rows, toks, kvs = [], [], []
    for name, n_in, n_out, kv_bpt in reqs:
        mt = (n_out if n_out is not None
              else pool.spec.default_max_tokens)
        rows.append(snap.row_of[name])
        toks.append(float(n_in + mt))
        kvs.append(float(n_in + mt) * kv_bpt)
    admitted, reason, _ = admit_quantum(
        snap.state, snap.bucket_level, snap.in_flight, snap.kv_in_use,
        pool_in_flight=jnp.int32(snap.pool_in_flight),
        pool_conc_cap=jnp.float32(snap.pool_conc_cap),
        running_min_priority=jnp.float32(snap.running_min_priority),
        pool_avg_slo=jnp.float32(snap.pool_avg_slo),
        req_ent=jnp.array(rows, jnp.int32),
        req_tokens=jnp.array(toks, jnp.float32),
        req_kv=jnp.array(kvs, jnp.float32),
        pool_resident=jnp.int32(snap.pool_resident),
        weights=snap.weights,          # what the gateway passes
        coeff=pool.spec.coefficients, slack=slack)
    kernel = list(zip((bool(a) for a in np.asarray(admitted)),
                      (int(r) for r in np.asarray(reason))))

    ac = AdmissionController(pool)
    scalar = []
    for i, (name, n_in, n_out, kv_bpt) in enumerate(reqs):
        d = ac.decide(AdmissionRequest(
            entitlement=name, input_tokens=n_in, max_tokens=n_out,
            arrival_s=0.0, request_id=f"r{i}",
            kv_bytes_per_token=kv_bpt))
        scalar.append((d.admitted, REASON_TO_CODE[
            d.reason.value if d.reason else None]))
    return kernel, scalar


class TestAdmitQuantum:
    def test_matches_scalar_controller(self):
        """Sequential fori_loop replay == scalar controller decisions on
        a frozen pool snapshot."""
        pool = mkpool_for_quantum(pool_conc=3.0)
        pool.add_entitlement(qent("a", ServiceClass.GUARANTEED,
                                  500.0, 2, 200.0))
        pool.add_entitlement(qent("b", ServiceClass.ELASTIC,
                                  300.0, 2, 1000.0))
        pool.add_entitlement(qent("c", ServiceClass.SPOT,
                                  0.0, 2, 30000.0))
        pool.ledger.set_rate("c", 100.0, 0.0)
        pool.ledger.bucket("c").level = 400.0

        names = sorted(pool.entitlements)
        reqs = [(names[i % 3], 64, 64, 0.0) for i in range(8)]
        kernel, scalar = run_quantum_vs_scalar(pool, reqs)
        assert kernel == scalar


class TestAdmitQuantumRegressions:
    """Deterministic pins for the scalar/kernel decision-parity bugs
    fixed in this PR — each would fail on the pre-fix kernel."""

    def test_burst_class_over_re_admitted_with_free_slots(self):
        """A burst-capable class over its r_e must be admitted while
        the pool has idle slots and nobody waits (scalar check 3's
        BURST_CLASSES escape; the old kernel always denied reason 2)."""
        pool = mkpool_for_quantum(pool_conc=8.0)
        pool.add_entitlement(qent("el", ServiceClass.ELASTIC,
                                  400.0, 2, 1000.0))
        seed_inflight(pool, "el", queued=0, resident=2)   # at r_e
        assert pool.has_free_slots() and not pool.contended()

        kernel, scalar = run_quantum_vs_scalar(
            pool, [("el", 32, 32, 0.0)])
        assert scalar == [(True, 0)]          # the oracle admits
        assert kernel == scalar               # old kernel: (False, 2)

    def test_guaranteed_over_re_still_denied(self):
        """GUARANTEED is not burst-capable (Table 1): over r_e it denies
        on concurrency even with free slots — the escape must not
        over-open."""
        pool = mkpool_for_quantum(pool_conc=8.0)
        pool.add_entitlement(qent("g", ServiceClass.GUARANTEED,
                                  400.0, 2, 200.0))
        seed_inflight(pool, "g", queued=0, resident=2)
        kernel, scalar = run_quantum_vs_scalar(pool, [("g", 32, 32, 0.0)])
        assert scalar == [(False, 2)]
        assert kernel == scalar

    def test_burst_escape_closed_when_contended(self):
        """The escape closes as soon as requests wait: burst classes
        over r_e deny on concurrency in a contended pool even though
        idle slots exist (they belong to the queue, not to bursts)."""
        pool = mkpool_for_quantum(pool_conc=4.0)
        pool.add_entitlement(qent("el", ServiceClass.ELASTIC,
                                  400.0, 1, 1000.0))
        pool.add_entitlement(qent("sp", ServiceClass.SPOT,
                                  0.0, 0.0, 30000.0))
        pool.ledger.set_rate("sp", 400.0, 0.0)
        seed_inflight(pool, "el", queued=0, resident=1)   # at r_e
        seed_inflight(pool, "sp", queued=3, resident=2)
        assert pool.has_free_slots()          # 3 resident < 4 slots
        assert pool.contended()               # 6 admitted > 4 slots
        kernel, scalar = run_quantum_vs_scalar(
            pool, [("el", 32, 32, 0.0)])
        assert scalar == [(False, 2)]
        assert kernel == scalar

    def test_running_min_seeded_from_live_priorities(self):
        """Check 5's threshold is the LIVE minimum priority among
        in-flight owners (``admission_threshold``), not the stale
        record snapshots and not +inf: a higher-priority burst request
        must clear it, an equal-priority one must not (strict >)."""
        from repro.core.vectorized import quantum_snapshot
        pool = mkpool_for_quantum(pool_conc=2.0)
        pool.add_entitlement(qent("el", ServiceClass.ELASTIC,
                                  0.0, 0.0, 1000.0))
        pool.add_entitlement(qent("sp", ServiceClass.SPOT,
                                  0.0, 0.0, 30000.0))
        pool.ledger.set_rate("el", 400.0, 0.0)
        pool.ledger.set_rate("sp", 400.0, 0.0)
        pool.ledger.bucket("el").level = 400.0
        pool.ledger.bucket("sp").level = 400.0
        seed_inflight(pool, "sp", queued=3, resident=0)
        assert pool.contended()

        snap = quantum_snapshot(pool, 0.0)
        assert snap.running_min_priority == pytest.approx(
            pool.priority("sp"))              # live seed, not inf/stale

        kernel, scalar = run_quantum_vs_scalar(
            pool, [("el", 32, 32, 0.0),       # elastic outranks spot
                   ("sp", 32, 32, 0.0)])      # spot == own threshold
        assert scalar == [(True, 0), (False, 4)]
        assert kernel == scalar

    def test_snapshot_does_not_mutate_pool(self):
        """arrays_from_pool was creating buckets with last_refill_s=0 —
        snapshotting must be a pure read that projects levels to
        ``now`` without touching the ledger."""
        from repro.core.vectorized import arrays_from_pool
        pool = mkpool_for_quantum()
        pool.add_entitlement(qent("a", ServiceClass.ELASTIC,
                                  100.0, 2, 1000.0), now=5.0)
        bucket = pool.ledger.bucket("a")
        bucket.level = 20.0
        _, levels, _, _ = arrays_from_pool(pool, now=5.5)
        # projected half a second of refill, without advancing the clock
        assert float(levels[0]) == pytest.approx(70.0)
        assert (bucket.level, bucket.last_refill_s) == (20.0, 5.0)
        # a missing bucket is reported at its would-be initial level but
        # NOT created (the seed bug left a last_refill_s=0 bucket behind)
        pool.ledger.drop("a")
        _, levels2, _, _ = arrays_from_pool(pool, now=5.5)
        assert float(levels2[0]) == pytest.approx(100.0)
        with pytest.raises(KeyError):
            pool.ledger.bucket("a")

    def test_admission_slack_threading(self):
        """slack > 0 softens the strict threshold exactly as the scalar
        controller's (1 − slack) multiplier does."""
        pool = mkpool_for_quantum(pool_conc=2.0, slack=0.5)
        pool.add_entitlement(qent("s1", ServiceClass.SPOT,
                                  0.0, 0.0, 30000.0))
        pool.add_entitlement(qent("s2", ServiceClass.SPOT,
                                  0.0, 0.0, 30000.0))
        pool.ledger.set_rate("s1", 400.0, 0.0)
        pool.ledger.set_rate("s2", 400.0, 0.0)
        pool.ledger.bucket("s1").level = 400.0
        pool.ledger.bucket("s2").level = 400.0
        seed_inflight(pool, "s1", queued=3, resident=0)
        assert pool.contended()
        # equal-priority spot is denied at slack=0 (strict >) but
        # admitted with slack (w > 0.5·w)
        kernel, scalar = run_quantum_vs_scalar(
            pool, [("s2", 32, 32, 0.0)], slack=0.5)
        assert scalar == [(True, 0)]
        assert kernel == scalar

    def test_padding_rows_are_inert(self):
        """req_live=False rows must not charge buckets, bump counts, or
        move the running threshold."""
        from repro.core.vectorized import admit_quantum, quantum_snapshot
        pool = mkpool_for_quantum(pool_conc=4.0)
        pool.add_entitlement(qent("a", ServiceClass.ELASTIC,
                                  100.0, 4, 1000.0))
        snap = quantum_snapshot(pool, 0.0)
        # 1 real request + 3 padding rows aimed at the same entitlement
        admitted, reason, _ = admit_quantum(
            snap.state, snap.bucket_level, snap.in_flight,
            snap.kv_in_use,
            pool_in_flight=jnp.int32(0),
            pool_conc_cap=jnp.float32(4.0),
            running_min_priority=jnp.float32(np.inf),
            pool_avg_slo=jnp.float32(snap.pool_avg_slo),
            req_ent=jnp.zeros(4, jnp.int32),
            req_tokens=jnp.full(4, 60.0, jnp.float32),
            req_kv=jnp.zeros(4, jnp.float32),
            pool_resident=jnp.int32(0),
            req_live=jnp.array([True, False, False, False]))
        assert list(np.asarray(admitted)) == [True, False, False, False]
        # bucket holds 100 tokens: had the padding charged 60 each, a
        # follow-up real request after the real charge would be denied
        admitted2, _, _ = admit_quantum(
            snap.state, snap.bucket_level - 60.0, snap.in_flight,
            snap.kv_in_use,
            pool_in_flight=jnp.int32(1),
            pool_conc_cap=jnp.float32(4.0),
            running_min_priority=jnp.float32(np.inf),
            pool_avg_slo=jnp.float32(snap.pool_avg_slo),
            req_ent=jnp.zeros(4, jnp.int32),
            req_tokens=jnp.full(4, 30.0, jnp.float32),
            req_kv=jnp.zeros(4, jnp.float32),
            pool_resident=jnp.int32(0),
            req_live=jnp.array([True, False, False, False]))
        assert bool(np.asarray(admitted2)[0])
