"""Paged KV block manager: allocation, extension, fragmentation-free
reuse, χ accounting — plus hypothesis invariants."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving.kv_manager import KVBlockManager, OutOfPages


class TestBasics:
    def test_allocate_rounds_up_to_pages(self):
        m = KVBlockManager(total_pages=10, page_tokens=16)
        a = m.allocate("s1", tokens=17)
        assert len(a.pages) == 2
        assert m.free_pages == 8

    def test_extend_allocates_on_boundary(self):
        m = KVBlockManager(total_pages=10, page_tokens=16)
        m.allocate("s1", tokens=16)
        m.extend("s1", 17)                 # crosses into page 2
        assert len(m._seqs["s1"].pages) == 2
        m.extend("s1", 30)                 # same page
        assert len(m._seqs["s1"].pages) == 2

    def test_free_returns_pages(self):
        m = KVBlockManager(total_pages=4, page_tokens=16)
        m.allocate("s1", 64)
        assert m.free_pages == 0
        with pytest.raises(OutOfPages):
            m.allocate("s2", 1)
        m.free("s1")
        assert m.free_pages == 4
        m.allocate("s2", 64)               # reuse without fragmentation

    def test_out_of_pages_on_extend(self):
        m = KVBlockManager(total_pages=2, page_tokens=16)
        m.allocate("s1", 32)
        with pytest.raises(OutOfPages):
            m.extend("s1", 33)

    def test_block_table_padding(self):
        m = KVBlockManager(total_pages=8, page_tokens=16)
        m.allocate("s1", 40)               # 3 pages
        row = m.block_table("s1", max_pages=6)
        assert (row[:3] >= 0).all()
        assert (row[3:] == -1).all()

    def test_kv_bytes_accounting(self):
        m = KVBlockManager(total_pages=8, page_tokens=16,
                           bytes_per_token=1024.0)
        m.allocate("s1", 32)
        assert m.kv_bytes_in_use() == 2 * 16 * 1024.0


class TestInvariants:
    @given(st.lists(st.tuples(st.sampled_from(["alloc", "extend", "free"]),
                              st.integers(0, 7),
                              st.integers(1, 200)),
                    min_size=1, max_size=60))
    @settings(max_examples=100, deadline=None)
    def test_no_page_leaks_or_double_allocation(self, ops):
        m = KVBlockManager(total_pages=16, page_tokens=16)
        live: dict[str, int] = {}
        for op, sid, tokens in ops:
            seq = f"s{sid}"
            try:
                if op == "alloc" and seq not in live:
                    m.allocate(seq, tokens)
                    live[seq] = tokens
                elif op == "extend" and seq in live:
                    new_total = live[seq] + tokens
                    m.extend(seq, new_total)
                    live[seq] = new_total
                elif op == "free" and seq in live:
                    m.free(seq)
                    del live[seq]
            except OutOfPages:
                pass
            # invariant 1: conservation
            assert m.used_pages + m.free_pages == m.total_pages
            # invariant 2: no page owned twice
            owned = [p for s in m._seqs.values() for p in s.pages]
            assert len(owned) == len(set(owned))
            # invariant 3: free list disjoint from owned
            assert not (set(owned) & set(m._free))
