"""Sharded control plane (``core.shard_plane``) — CPU-mesh parity.

The contract under test: ``shard_tick`` / ``shard_admit_quantum`` /
``shard_plan_fleet`` decisions are BIT-IDENTICAL to the single-device
kernels ``control_tick`` / ``admit_quantum`` / ``plan_fleet`` at every
power-of-two mesh size the backend offers, and (transitively, plus
directly for the tick) match the scalar oracles ``reference_tick`` /
``AdmissionController`` / ``Autoscaler.plan`` within the established
tolerances.  Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the CI ``shard`` job) this sweeps 1/2/4/8-way meshes; on a plain
single-device host it still drives the full shard_map path at mesh
size 1.

Also covered here: the ``ShardedResidentStore`` facade (per-shard free
lists, block-granular mirror uploads, slot stability across growth),
the ``PoolManager.tick`` stacked-state cache (no-retrace + no-recopy
counter pins), and a chaos-invariant churn+migration run over sharded
stores (token conservation, row leaks, mirror coherence).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import (
    EntitlementSpec,
    PoolSpec,
    PriorityCoefficients,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)
from repro.core import control_plane
from repro.core.control_plane import (
    TRACE_COUNTS,
    ControlState,
    control_tick,
    pad_rows,
    pad_state,
    reference_tick,
    state_from_rows,
    tree_any,
    tree_count,
    tree_sum,
)
from repro.core.fleet import FleetPlannerConfig, plan_fleet
from repro.core.pool_manager import PoolManager
from repro.core.resident import ResidentStore, ShardedResidentStore
from repro.core.shard_plane import (
    pool_mesh,
    row_mesh,
    shard_admit_quantum,
    shard_plan_fleet,
    shard_tick,
    shard_width,
)
from repro.core.vectorized import admit_quantum
from tests.test_control_plane import ABS, REL, random_rows

#: every power-of-two mesh the backend offers (1 on a plain host;
#: 1/2/4/8 under the forced-host CI mesh)
MESH_SIZES = [s for s in (1, 2, 4, 8) if s <= len(jax.devices())]
CLASSES = [ServiceClass.GUARANTEED, ServiceClass.DEDICATED,
           ServiceClass.ELASTIC, ServiceClass.SPOT]


def state_equal(a: ControlState, b: ControlState) -> bool:
    return all(
        bool(jnp.array_equal(getattr(a, f.name), getattr(b, f.name)))
        for f in dataclasses.fields(ControlState))


def padded_tick_inputs(rows, mesh):
    """(state, measured, kv, conc, demand) padded to the mesh-aligned
    width — padding rows are inert unbound zeros, exactly like free
    store slots."""
    w = shard_width(len(rows), mesh)
    state = pad_state(state_from_rows(rows), w)
    cols = [
        pad_rows(jnp.asarray([r.measured_tps for r in rows],
                             jnp.float32), w),
        pad_rows(jnp.asarray([r.used_kv for r in rows], jnp.float32), w),
        pad_rows(jnp.asarray([r.used_conc for r in rows],
                             jnp.float32), w),
        pad_rows(jnp.asarray([r.demand_tps for r in rows],
                             jnp.float32), w),
    ]
    return state, cols


class TestTreeReductions:
    """The shard-stable positional binary tree is blocking-invariant:
    any contiguous pow2 blocking (= any mesh size) reproduces the
    exact same f32 adds in the exact same order."""

    @pytest.mark.parametrize("n", [1, 3, 16, 37, 256])
    def test_tree_sum_matches_exact(self, n):
        rng = np.random.RandomState(n)
        x = (rng.rand(n) * 1000).astype(np.float32)
        got = float(tree_sum(jnp.asarray(x)))
        # n ≤ 256 f32 values sum exactly in f64 well under 2^53
        assert got == pytest.approx(float(np.sum(x.astype(np.float64))),
                                    rel=1e-6)

    def test_tree_sum_blocking_invariance(self):
        """Per-block subtrees + a top tree over the block roots must be
        bitwise the full tree — the property the mesh decomposition
        rides on."""
        rng = np.random.RandomState(7)
        x = jnp.asarray(rng.rand(64).astype(np.float32) * 997.0)
        full = float(tree_sum(x))
        for blocks in (2, 4, 8):
            roots = jnp.stack([
                tree_sum(x[k * (64 // blocks):(k + 1) * (64 // blocks)])
                for k in range(blocks)])
            assert float(tree_sum(roots)) == full, blocks

    def test_tree_any_and_count(self):
        m = jnp.asarray([True, False, True, False, False])
        assert bool(tree_any(m)) is True
        assert int(tree_count(m)) == 2
        assert bool(tree_any(jnp.zeros(5, bool))) is False


class TestShardTickParity:
    """shard_tick == control_tick bitwise at every mesh size, and both
    match the scalar reference_tick within the pinned tolerances."""

    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("scarcity", [0.2, 1.0, 5.0])
    def test_mesh_vs_single_device_bitwise(self, seed, scarcity):
        rng = np.random.RandomState(seed)
        rows = random_rows(int(rng.randint(3, 60)), rng)
        demand = sum(min(r.baseline_tps, r.demand_tps)
                     for r in rows if r.bound)
        cap = jnp.float32(max(10.0, scarcity * demand))
        slo = jnp.float32(10_000.0)
        coeff = PriorityCoefficients()
        mesh0 = row_mesh(MESH_SIZES[-1])
        state, cols = padded_tick_inputs(rows, mesh0)
        ref = control_tick(state, cap, *cols, slo, coeff=coeff)
        for size in MESH_SIZES:
            got = shard_tick(state, cap, *cols, slo, coeff=coeff,
                             mesh=row_mesh(size))
            assert state_equal(ref[0], got[0]), size
            assert jnp.array_equal(ref[1], got[1]), size
            assert jnp.array_equal(ref[2], got[2]), size

    @pytest.mark.parametrize("seed", range(3))
    def test_mesh_vs_scalar_oracle(self, seed):
        rng = np.random.RandomState(100 + seed)
        rows = random_rows(24, rng)
        cap = 800.0
        coeff = PriorityCoefficients()
        mesh = row_mesh(MESH_SIZES[-1])
        state, cols = padded_tick_inputs(rows, mesh)
        new_state, alloc, weights = shard_tick(
            state, jnp.float32(cap), *cols, jnp.float32(10_000.0),
            coeff=coeff, mesh=mesh)
        o_rows, o_alloc, o_weights = reference_tick(
            rows, cap, 10_000.0, coeff)
        alloc = np.asarray(alloc)
        weights = np.asarray(weights)
        burst = np.asarray(new_state.burst)
        debt = np.asarray(new_state.debt)
        for i, o in enumerate(o_rows):
            ctx = f"row {i} ({o.service_class.value})"
            assert weights[i] == pytest.approx(o_weights[i],
                                               rel=1e-4), ctx
            assert alloc[i] == pytest.approx(o_alloc[i], rel=REL,
                                             abs=ABS), ctx
            assert burst[i] == pytest.approx(o.burst, rel=1e-4,
                                             abs=1e-5), ctx
            assert debt[i] == pytest.approx(o.debt, rel=1e-4,
                                            abs=1e-5), ctx

    @pytest.mark.parametrize("seed", range(200, 212))
    def test_seeded_sweep(self, seed):
        rng = np.random.RandomState(seed)
        check_tick_parity(int(rng.randint(0, 2**31 - 1)),
                          int(rng.randint(2, 49)),
                          float(rng.uniform(0.1, 6.0)))


def check_tick_parity(seed, n, scarcity):
    rng = np.random.RandomState(seed)
    rows = random_rows(n, rng)
    demand = sum(r.demand_tps for r in rows if r.bound)
    cap = jnp.float32(max(10.0, scarcity * max(demand, 1.0)))
    slo = jnp.float32(float(rng.uniform(200, 20000)))
    coeff = PriorityCoefficients()
    mesh = row_mesh(MESH_SIZES[-1])
    state, cols = padded_tick_inputs(rows, mesh)
    ref = control_tick(state, cap, *cols, slo, coeff=coeff)
    got = shard_tick(state, cap, *cols, slo, coeff=coeff, mesh=mesh)
    assert state_equal(ref[0], got[0])
    assert jnp.array_equal(ref[1], got[1])
    assert jnp.array_equal(ref[2], got[2])


def random_admit_case(rng, n, m):
    """Random (state, rows arrays, request arrays) for an admission
    quantum at mesh-aligned width."""
    mesh = row_mesh(MESH_SIZES[-1])
    w = shard_width(n, mesh)
    state = pad_state(state_from_rows(random_rows(n, rng)), w)
    kw = dict(
        bucket_level=pad_rows(jnp.asarray(
            rng.rand(n).astype(np.float32) * 120), w),
        in_flight=pad_rows(jnp.asarray(
            rng.randint(0, 5, n), jnp.int32), w),
        kv_in_use=pad_rows(jnp.asarray(
            rng.rand(n).astype(np.float32) * 50), w),
        pool_in_flight=jnp.int32(rng.randint(0, 12)),
        pool_conc_cap=jnp.float32(rng.choice([8.0, 64.0, 1e9])),
        running_min_priority=jnp.float32(
            np.inf if rng.rand() < 0.5 else rng.rand() * 4),
        pool_avg_slo=jnp.float32(rng.uniform(200, 20000)),
        req_ent=jnp.asarray(rng.randint(0, n, m), jnp.int32),
        req_tokens=jnp.asarray(rng.rand(m).astype(np.float32) * 40 + 1),
        req_kv=jnp.asarray(rng.rand(m).astype(np.float32) * 20),
        pool_resident=jnp.int32(rng.randint(0, 40)),
        req_live=jnp.asarray(rng.rand(m) < 0.9),
    )
    return state, kw, mesh


class TestShardAdmitParity:
    """shard_admit_quantum == admit_quantum bitwise: the sharded gather
    + compact replicated replay must reproduce the sequential decision
    stream decision for decision (admit_quantum itself is pinned
    against the scalar AdmissionController in test_admit_quantum)."""

    @pytest.mark.parametrize("seed", range(6))
    def test_mesh_vs_kernel_bitwise(self, seed):
        rng = np.random.RandomState(seed)
        n, m = int(rng.randint(2, 50)), int(rng.randint(1, 33))
        state, kw, _ = random_admit_case(rng, n, m)
        coeff = PriorityCoefficients()
        slack = float(rng.choice([0.0, 0.1]))
        ref = admit_quantum(state, **kw, coeff=coeff, slack=slack)
        for size in MESH_SIZES:
            got = shard_admit_quantum(state, **kw, coeff=coeff,
                                      slack=slack, mesh=row_mesh(size))
            for r, g in zip(ref, got):
                assert jnp.array_equal(r, g), (size, seed)

    def test_explicit_weights_bitwise(self):
        rng = np.random.RandomState(99)
        state, kw, mesh = random_admit_case(rng, 21, 16)
        w = pad_rows(jnp.asarray(rng.rand(21).astype(np.float32) * 3),
                     state.class_code.shape[0])
        ref = admit_quantum(state, **kw, weights=w)
        got = shard_admit_quantum(state, **kw, weights=w, mesh=mesh)
        for r, g in zip(ref, got):
            assert jnp.array_equal(r, g)
        # the returned priorities are the gathered row weights, bitwise
        assert jnp.array_equal(got[2], w[kw["req_ent"]])

    @pytest.mark.parametrize("seed", range(300, 312))
    def test_seeded_sweep(self, seed):
        check_admit_parity(seed)


def check_admit_parity(seed, n=None, m=None):
    rng = np.random.RandomState(seed)
    n = n if n is not None else int(rng.randint(2, 41))
    m = m if m is not None else int(rng.randint(1, 25))
    state, kw, mesh = random_admit_case(rng, n, m)
    ref = admit_quantum(state, **kw)
    got = shard_admit_quantum(state, **kw, mesh=mesh)
    for r, g in zip(ref, got):
        assert jnp.array_equal(r, g)


if HAVE_HYPOTHESIS:
    class TestShardHypothesis:
        """Hypothesis adds shrinking depth to the seeded sweeps where
        installed (the container runs the seeded forms regardless)."""

        @settings(max_examples=25, deadline=None, derandomize=True)
        @given(seed=st.integers(0, 2**31 - 1),
               n=st.integers(2, 48), scarcity=st.floats(0.1, 6.0))
        def test_tick_parity(self, seed, n, scarcity):
            check_tick_parity(seed, n, scarcity)

        @settings(max_examples=25, deadline=None, derandomize=True)
        @given(seed=st.integers(0, 2**31 - 1),
               n=st.integers(2, 40), m=st.integers(1, 24))
        def test_admit_parity(self, seed, n, m):
            check_admit_parity(seed, n, m)


class TestShardPlanFleetParity:
    """shard_plan_fleet == plan_fleet bitwise over the pool axis (the
    scale policy is per-pool elementwise; plan_fleet itself is pinned
    against the scalar Autoscaler.plan in test_fleet)."""

    @pytest.mark.parametrize("seed", range(4))
    def test_mesh_vs_kernel_bitwise(self, seed):
        rng = np.random.RandomState(seed)
        p = 16
        args = (
            jnp.asarray(rng.randint(1, 5, p), jnp.int32),      # current
            jnp.ones(p, jnp.int32),                            # lo
            jnp.full((p,), 8, jnp.int32),                      # hi
            jnp.asarray(rng.rand(p).astype(np.float32) * 100 + 10),
            jnp.asarray(rng.rand(p).astype(np.float32) * 200 + 20),
            jnp.asarray(rng.rand(p).astype(np.float32) * 8 + 1),
            jnp.asarray(rng.rand(p).astype(np.float32) * 80),
            jnp.asarray(rng.rand(p).astype(np.float32) * 100),
            jnp.asarray(rng.rand(p).astype(np.float32) * 4),
            jnp.asarray(rng.rand(p).astype(np.float32) * 150),
            jnp.asarray(rng.rand(p).astype(np.float32) * 100),
            jnp.asarray(rng.rand(p) < 0.7),
            jnp.asarray(rng.randint(0, 4, p), jnp.int32),
        )
        cfg = FleetPlannerConfig()
        ref = plan_fleet(*args, config=cfg)
        for size in MESH_SIZES:
            got = shard_plan_fleet(*args, config=cfg,
                                   mesh=row_mesh(size))
            for r, g in zip(ref, got):
                assert jnp.array_equal(r, g), (size, seed)


class TestShardedResidentStore:
    def mkstore(self, capacity=64, n_shards=4, live=40):
        st_ = ShardedResidentStore(capacity=capacity, n_shards=n_shards)
        for i in range(live):
            st_.allocate(f"e{i}")
        return st_

    def test_pow2_shards_enforced(self):
        with pytest.raises(ValueError):
            ShardedResidentStore(n_shards=3)

    def test_row_accounting_closure(self):
        st_ = self.mkstore()
        acct = st_.row_accounting()
        assert acct["live"] + acct["free"] == acct["capacity"]
        assert acct["alive_rows"] == acct["live"]
        assert sum(acct["shard_free"]) == acct["free"]

    def test_allocation_balances_shards(self):
        st_ = self.mkstore(capacity=64, n_shards=4, live=40)
        per_shard = [st_.shard_rows - f
                     for f in st_.row_accounting()["shard_free"]]
        assert max(per_shard) - min(per_shard) <= 1

    def test_churn_is_block_local(self):
        """release / allocate / view-write re-upload exactly one shard
        block, never the pool."""
        st_ = self.mkstore()
        st_.device_state()
        for mutate in (lambda: st_.release("e3"),
                       lambda: st_.allocate("e3b"),
                       lambda: setattr(st_.view("e10"), "burst", 3.0)):
            b0, f0, r0 = (st_.block_uploads, st_.full_uploads,
                          st_.uploaded_rows)
            mutate()
            st_.device_state()
            assert st_.block_uploads - b0 == 1
            assert st_.full_uploads == f0
            assert st_.uploaded_rows - r0 == st_.shard_rows

    def test_block_rebuild_is_coherent(self):
        """After block-granular rebuilds the mirror must agree with the
        host columns exactly (the chaos MirrorCoherence invariant)."""
        st_ = self.mkstore()
        st_.device_state()
        st_.view("e7").debt = 1.25
        st_.release("e20")
        st_.view("e30").state = st_.view("e30").state  # state_code path
        st_.device_state()
        drift = st_.mirror_drift()
        assert drift and max(drift.values()) == 0.0

    def test_growth_keeps_slots_stable(self):
        st_ = self.mkstore(capacity=16, n_shards=4, live=16)
        before = dict(st_.slot_of)
        views = {n: st_.view(n) for n in list(before)[:5]}
        for i in range(20):
            st_.allocate(f"g{i}")
        assert st_.capacity == 64
        assert all(st_.slot_of[n] == s for n, s in before.items())
        for n, v in views.items():          # persistent views stay valid
            assert v.slot == before[n]
        acct = st_.row_accounting()
        assert acct["live"] + acct["free"] == 64

    def test_shard_of_name_routes(self):
        st_ = self.mkstore()
        for name, slot in st_.slot_of.items():
            assert st_.shard_of_name(name) == slot // st_.shard_rows

    def test_adopt_device_resyncs(self):
        st_ = self.mkstore()
        state = st_.device_state()
        bumped = dataclasses.replace(
            state, burst=state.burst + 1.0, debt=state.debt + 0.5)
        st_.adopt_device(bumped)
        assert st_.device_state() is bumped
        assert np.allclose(st_.col["burst"], np.asarray(bumped.burst))
        drift = st_.mirror_drift()
        assert max(drift.values()) == 0.0


def mkpool(shards, n_ents=37, tps=2000.0, slots=64.0, name="p"):
    spec = PoolSpec(name=name, model="m", shards=shards,
                    scaling=ScalingBounds(1, 1),
                    per_replica=Resources(tps, float(1 << 40), slots))
    pool = TokenPool(spec)
    for i in range(n_ents):
        pool.add_entitlement(EntitlementSpec(
            name=f"e{i}", tenant_id=f"t{i}", pool=name,
            qos=QoS(service_class=CLASSES[i % 4],
                    slo_target_ms=100.0 + 10 * i),
            baseline=Resources(20.0 + i, float(1 << 20), 4.0)))
    return pool


class TestPoolIntegration:
    """A sharded pool (PoolSpec.shards) must tick and admit exactly
    like a flat pool, name for name, through the public surfaces."""

    def test_spec_selects_store(self):
        assert isinstance(mkpool(None).store, ResidentStore)
        assert not isinstance(mkpool(None).store, ShardedResidentStore)
        assert isinstance(mkpool(4).store, ShardedResidentStore)

    def test_tick_parity_namewise(self):
        flat, shard = mkpool(None), mkpool(4)
        for t in (1.0, 2.0, 3.0):
            flat.tick(t)
            shard.tick(t)
        cf, cs = flat.store.col, shard.store.col
        for name in flat.store.slot_of:
            sf, ss = flat.store.slot_of[name], shard.store.slot_of[name]
            for col in ("burst", "debt", "eff_tps", "eff_kv",
                        "eff_conc"):
                assert cf[col][sf] == cs[col][ss], (name, col)

    def test_gateway_quantum_parity(self):
        from repro.gateway.gateway import Gateway, QuantumRequest
        flat, shard = mkpool(None), mkpool(4)
        outs = []
        for pool in (flat, shard):
            pool.tick(1.0)
            gw = Gateway(pool)
            for i in range(37):
                gw.register_route(f"k{i}", [("p", f"e{i}")])
            reqs = [QuantumRequest(api_key=f"k{i % 37}",
                                   request_id=f"r{i}",
                                   input_tokens=50, max_tokens=64)
                    for i in range(100)]
            outs.append(gw.handle_quantum(reqs, now=1.5))
        for a, b in zip(*outs):
            assert (a.status, a.reason) == (b.status, b.reason), \
                a.request_id

    def test_pool_mesh_gate(self):
        """pool_mesh: flat store never meshes; sharded store meshes
        only when ≥2 devices are visible, never wider than the shard
        count."""
        assert pool_mesh(mkpool(None)) is None
        mesh = pool_mesh(mkpool(4))
        if len(jax.devices()) < 2:
            assert mesh is None
        else:
            assert 2 <= mesh.size <= 4

    def test_churn_does_not_retrace(self):
        """Entitlement churn within a capacity bucket must not retrace
        any tick kernel (sharded or not)."""
        pool = mkpool(4, n_ents=20)
        pool.tick(1.0)
        pool.tick(2.0)
        before = dict(TRACE_COUNTS)
        pool.remove_entitlement("e7", now=2.5)
        pool.add_entitlement(EntitlementSpec(
            name="e7b", tenant_id="t7b", pool="p",
            qos=QoS(service_class=ServiceClass.ELASTIC,
                    slo_target_ms=500.0),
            baseline=Resources(25.0, float(1 << 20), 4.0)))
        pool.tick(3.0)
        assert dict(TRACE_COUNTS) == before


class TestStackCache:
    """PoolManager.tick stacked-state cache: steady-state fleet ticks
    reuse the kernel's own output stack (no re-stack, no re-upload, no
    retrace) and stay bitwise identical to uncached stacking; churn
    re-splices only the changed pool's row."""

    def mkmanager(self):
        mgr = PoolManager()
        for pname, n in (("a", 5), ("b", 13), ("c", 37)):
            spec = PoolSpec(name=pname, model="m",
                            scaling=ScalingBounds(1, 1),
                            per_replica=Resources(900.0, float(1 << 40),
                                                  32.0))
            pool = mgr.add_pool(spec)
            for i in range(n):
                pool.add_entitlement(EntitlementSpec(
                    name=f"{pname}{i}", tenant_id=f"t{i}", pool=pname,
                    qos=QoS(service_class=CLASSES[i % 4],
                            slo_target_ms=100.0 + 7 * i),
                    baseline=Resources(10.0 + i, float(1 << 18), 2.0)))
        return mgr

    def test_steady_state_reuses_no_retrace(self):
        mgr = self.mkmanager()
        mgr.tick(1.0)
        assert mgr.stack_restacks == 3      # first tick stacks 3 pools
        trace_before = dict(TRACE_COUNTS)
        restacks = mgr.stack_restacks
        for t in (2.0, 3.0, 4.0):
            mgr.tick(t)
        assert mgr.stack_reuses == 3
        assert mgr.stack_restacks == restacks          # no re-copy
        assert dict(TRACE_COUNTS) == trace_before      # no re-trace

    def test_cached_equals_fresh_bitwise(self):
        cached, fresh = self.mkmanager(), self.mkmanager()
        for t in (1.0, 2.0, 3.0, 4.0):
            cached.tick(t)
        for t in (1.0, 2.0, 3.0, 4.0):
            fresh._stack_cache.clear()      # defeat the cache
            fresh.tick(t)
        for pname in ("a", "b", "c"):
            cc = cached.pool(pname).store.col
            cf = fresh.pool(pname).store.col
            for col in ("burst", "debt", "eff_tps"):
                assert np.array_equal(cc[col], cf[col]), (pname, col)

    def test_churn_splices_one_row(self):
        mgr = self.mkmanager()
        mgr.tick(1.0)
        mgr.tick(2.0)
        r0 = mgr.stack_restacks
        mgr.pool("b").remove_entitlement("b3", now=2.5)
        mgr.tick(3.0)
        assert mgr.stack_restacks - r0 == 1
        # and the spliced row is decision-correct vs uncached stacking
        fresh = self.mkmanager()
        fresh._stack_cache.clear()
        fresh.tick(1.0)
        fresh._stack_cache.clear()
        fresh.tick(2.0)
        fresh.pool("b").remove_entitlement("b3", now=2.5)
        fresh._stack_cache.clear()
        fresh.tick(3.0)
        for pname in ("a", "b", "c"):
            cc = mgr.pool(pname).store.col
            cf = fresh.pool(pname).store.col
            for col in ("burst", "debt", "eff_tps"):
                assert np.array_equal(cc[col], cf[col]), (pname, col)


class TestChaosShardedChurn:
    """The churn+migration incident scenario over SHARDED stores must
    hold every global invariant — token conservation, row-leak
    closure, debt bounds, capacity, device-mirror coherence — while
    entitlements join, migrate across pools (and shard boundaries)
    and leave under live traffic."""

    def sharded_scenario(self):
        from repro.chaos.scenarios import CHURN_MIGRATION
        return dataclasses.replace(
            CHURN_MIGRATION,
            sites=tuple({**dict(s), "shards": 4}
                        for s in CHURN_MIGRATION.sites))

    def test_stores_are_sharded(self):
        from repro.chaos.scenario import build_sim
        sim = build_sim(self.sharded_scenario())
        for pool in sim.manager.pools.values():
            assert isinstance(pool.store, ShardedResidentStore)

    def test_invariants_hold(self):
        from repro.chaos.runner import run_scenario
        rep = run_scenario(self.sharded_scenario())
        assert rep["passed"], rep["violations"]

    def test_migration_across_shard_boundaries(self):
        mgr = PoolManager()
        for pname in ("src", "dst"):
            spec = PoolSpec(name=pname, model="m", shards=4,
                            scaling=ScalingBounds(1, 2),
                            per_replica=Resources(900.0, float(1 << 40),
                                                  32.0))
            pool = mgr.add_pool(spec)
            for i in range(11):
                pool.add_entitlement(EntitlementSpec(
                    name=f"{pname}{i}", tenant_id=f"t{i}", pool=pname,
                    qos=QoS(service_class=ServiceClass.ELASTIC,
                            slo_target_ms=500.0),
                    baseline=Resources(15.0, float(1 << 18), 2.0)))
        mgr.tick(1.0)
        src, dst = mgr.pool("src"), mgr.pool("dst")
        src.ledger.set_rate("src3", 50.0, 1.0)
        src.ledger.bucket("src3").level = 33.0
        src.status["src3"].debt = 0.75
        mgr.migrate_entitlement("src3", "src", "dst", now=1.5)
        assert "src3" not in src.store
        assert "src3" in dst.store
        assert dst.status["src3"].debt == pytest.approx(0.75)
        # carried bucket is refilled to `now`: 33 + 50 tps * 0.5 s
        assert dst.ledger.bucket("src3").level == pytest.approx(58.0)
        for pool in (src, dst):
            acct = pool.store.row_accounting()
            assert acct["live"] + acct["free"] == acct["capacity"]
            assert acct["alive_rows"] == acct["live"]
        mgr.tick(2.0)           # and the fleet still ticks cleanly
        drift = dst.store.mirror_drift()
        assert not drift or max(drift.values()) == 0.0
