"""Scalar Autoscaler coverage: reserved-floor dominance, headroom
scale-up, cooldown hysteresis, min/max clamping, demand seeding, and
the per-instance-config regression.  The scalar planner is the parity
oracle for the fleet kernel (``tests/test_fleet.py``)."""
import pytest

from repro.core import (
    Autoscaler,
    AutoscalerConfig,
    EntitlementSpec,
    EntitlementState,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)


def mkpool(name="p", lo=1, hi=10, per_tps=240.0, per_conc=16.0):
    return TokenPool(PoolSpec(
        name=name, model="m", scaling=ScalingBounds(lo, hi),
        per_replica=Resources(per_tps, 0.0, per_conc)))


def ent(name, klass=ServiceClass.GUARANTEED, tps=240.0, conc=2.0,
        pool="p"):
    return EntitlementSpec(
        name=name, tenant_id="t", pool=pool,
        qos=QoS(service_class=klass, slo_target_ms=500.0),
        baseline=Resources(tps, 0.0, conc))


class TestReservedFloor:
    def test_reserved_dominates_idle_demand(self):
        """Zero demand: the pool still provisions every promised
        baseline (paper: entitlements authorize autoscaling)."""
        pool = mkpool()
        pool.add_entitlement(ent("a", ServiceClass.GUARANTEED, 480.0))
        pool.add_entitlement(ent("b", ServiceClass.ELASTIC, 240.0))
        a = Autoscaler(pool)
        a.observe_demand(0.0)
        d = a.plan()
        assert d.desired == 3                # ceil(720 / 240)
        assert d.reason == "scale_up:reserved"
        assert d.reserved_tps == pytest.approx(720.0)

    def test_spot_reserves_nothing(self):
        pool = mkpool()
        pool.add_entitlement(ent("s", ServiceClass.SPOT, 0.0, conc=8.0))
        pool.add_entitlement(ent("pre", ServiceClass.PREEMPTIBLE, 0.0))
        a = Autoscaler(pool)
        a.observe_demand(0.0)
        assert a.reserved_tps() == 0.0
        assert a.plan().desired == 1

    def test_degraded_counts_toward_floor(self):
        """A Degraded entitlement is an accepted promise the pool
        cannot currently honor — exactly what must raise capacity
        (otherwise a planner-shrunk pool could never grow back)."""
        pool = mkpool()
        pool.add_entitlement(ent("a", ServiceClass.GUARANTEED, 480.0))
        pool.status["a"].state = EntitlementState.DEGRADED
        a = Autoscaler(pool)
        a.observe_demand(0.0)
        assert a.plan().desired == 2

    def test_expired_does_not_count(self):
        pool = mkpool()
        pool.add_entitlement(ent("a", ServiceClass.GUARANTEED, 480.0))
        pool.status["a"].state = EntitlementState.EXPIRED
        a = Autoscaler(pool)
        assert a.reserved_tps() == 0.0

    def test_concurrency_dimension_floors_too(self):
        """The reserved floor is three-dimensional: a pool whose
        concurrency promises exceed what the tps floor would provision
        must scale for the slots."""
        pool = mkpool(per_tps=240.0, per_conc=4.0)
        pool.add_entitlement(ent("a", ServiceClass.GUARANTEED,
                                 tps=240.0, conc=12.0))
        a = Autoscaler(pool)
        a.observe_demand(0.0)
        assert a.plan().desired == 3         # ceil(12 / 4), not 240/240


class TestHeadroomScaleUp:
    def test_demand_above_reserved_scales_up(self):
        pool = mkpool()
        pool.add_entitlement(ent("a", ServiceClass.GUARANTEED, 240.0))
        a = Autoscaler(pool)
        a.observe_demand(790.0)              # seeds the EWMA
        d = a.plan()
        assert d.desired == 4                # ceil(790·1.2 / 240) = ⌈3.95⌉
        assert d.reason == "scale_up:demand"

    def test_demand_seeded_with_first_observation(self):
        """Cold start must NOT decay up from 0.0 — the first
        observation IS the estimate (an empty-history EWMA of 0 would
        under-provision the first minutes of a launch)."""
        pool = mkpool()
        a = Autoscaler(pool)
        a.observe_demand(960.0)
        assert a.demand_tps == pytest.approx(960.0)
        d = a.plan()
        assert d.desired == 5                # not ceil(480·1.2/240)

    def test_ewma_smooths_after_seed(self):
        a = Autoscaler(mkpool())
        a.observe_demand(1000.0)
        a.observe_demand(0.0)
        assert a.demand_tps == pytest.approx(500.0)   # γ = 0.5

    def test_step_reads_tick_record_demand(self):
        """Satellite: step() feeds on the TickRecord the control plane
        emits — not the pool's private accounting dicts."""
        pool = mkpool()
        pool.add_entitlement(ent("a", ServiceClass.GUARANTEED, 240.0))
        pool.register_deny("a", 960.0, low_priority=False)
        rec = pool.tick(1.0)                 # demand EWMA ≈ 480
        a = Autoscaler(pool)
        d = a.step(rec)
        assert d.demand_tps == pytest.approx(
            sum(rec.demand_tps.values()))
        assert pool.replicas == d.desired    # applied

    def test_step_without_record_uses_public_snapshot(self):
        pool = mkpool()
        pool.add_entitlement(ent("a", ServiceClass.GUARANTEED, 240.0))
        pool.register_deny("a", 960.0, low_priority=False)
        pool.tick(1.0)
        a = Autoscaler(pool)
        d = a.step()
        assert d.demand_tps == pytest.approx(
            sum(pool.demand_snapshot().values()))


class TestHysteresis:
    def mkscaled(self, cooldown=3):
        pool = mkpool()
        pool.add_entitlement(ent("a", ServiceClass.GUARANTEED, 240.0))
        pool.set_replicas(6)
        return pool, Autoscaler(
            pool, AutoscalerConfig(cooldown_ticks=cooldown))

    def test_scale_down_held_during_cooldown(self):
        pool, a = self.mkscaled(cooldown=3)
        for _ in range(2):
            a.observe_demand(0.0)
            d = a.plan()
            assert (d.desired, d.reason) == (6, "hold:cooldown")
        a.observe_demand(0.0)
        d = a.plan()
        assert d.reason == "scale_down"
        assert d.desired == 1

    def test_flap_resets_cooldown(self):
        """A demand spike mid-cooldown resets the low-tick counter:
        scale-down needs CONSECUTIVE low ticks."""
        pool, a = self.mkscaled(cooldown=3)
        a.observe_demand(0.0)
        assert a.plan().reason == "hold:cooldown"
        a.observe_demand(8000.0)             # spike: scale-up resets
        assert a.plan().reason.startswith("scale_up")
        for _ in range(2):
            a.observe_demand(0.0)
            d = a.plan()
        assert d.reason == "hold:cooldown"   # counter restarted

    def test_scale_up_is_immediate(self):
        pool, a = self.mkscaled()
        pool.set_replicas(1)
        a.observe_demand(2000.0)
        d = a.plan()
        assert d.desired == 10 and d.reason == "scale_up:demand"

    def test_steady_resets_counter(self):
        pool, a = self.mkscaled(cooldown=2)
        a.observe_demand(0.0)
        assert a.plan().reason == "hold:cooldown"
        pool.set_replicas(1)                 # external change → steady
        a.observe_demand(0.0)
        assert a.plan().reason == "steady"
        pool.set_replicas(6)
        a.observe_demand(0.0)
        assert a.plan().reason == "hold:cooldown"   # count restarted


class TestClamping:
    def test_max_clamp(self):
        pool = mkpool(hi=3)
        a = Autoscaler(pool)
        a.observe_demand(1e6)
        assert a.plan().desired == 3

    def test_min_clamp(self):
        pool = mkpool(lo=2)
        pool.set_replicas(2)
        a = Autoscaler(pool)
        a.observe_demand(0.0)
        assert a.plan().desired == 2

    def test_unsatisfiable_dimension_clamps_to_max(self):
        """per-replica KV of 0 with a KV baseline: need is infinite —
        clamp to maxReplicas instead of overflowing the ceil."""
        pool = mkpool(hi=4)
        pool.add_entitlement(EntitlementSpec(
            name="kv", tenant_id="t", pool="p",
            qos=QoS(service_class=ServiceClass.GUARANTEED),
            baseline=Resources(10.0, 1 << 30, 1.0)))
        a = Autoscaler(pool)
        a.observe_demand(0.0)
        assert a.plan().desired == 4


class TestConfigIsolation:
    def test_config_not_shared_between_instances(self):
        """Regression (satellite): the old ``config: AutoscalerConfig
        = AutoscalerConfig()`` default was ONE instance shared by every
        autoscaler — tuning one retuned all.  Defaults must be
        per-instance (and frozen)."""
        import dataclasses
        a1, a2 = Autoscaler(mkpool("p1")), Autoscaler(mkpool("p2"))
        assert a1.config is not a2.config
        with pytest.raises(dataclasses.FrozenInstanceError):
            a1.config.headroom = 9.9
