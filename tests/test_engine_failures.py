"""Engine + KV manager under failure: page reclamation after a
mid-stream eviction, double-free rejection, and steps with zero live
requests (the chaos PR's serving-layer satellite)."""
import jax
import pytest

from repro.configs import get_config
from repro.core import (
    EntitlementSpec,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)
from repro.gateway import Gateway
from repro.models import build_model
from repro.serving import InferenceEngine, Request, RequestState
from repro.serving.kv_manager import DoubleFree, KVBlockManager


@pytest.fixture(scope="module")
def served_model():
    cfg = get_config("tinyllama-1.1b").reduced(num_layers=2,
                                               vocab_size=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def mkgateway(slots=4, tps=1e4):
    spec = PoolSpec(name="p", model="m", scaling=ScalingBounds(1, 1),
                    per_replica=Resources(tps, float(1 << 30),
                                          float(slots)),
                    default_max_tokens=8)
    pool = TokenPool(spec)
    pool.add_entitlement(EntitlementSpec(
        name="prod", tenant_id="t1", pool="p",
        qos=QoS(service_class=ServiceClass.GUARANTEED,
                slo_target_ms=200),
        baseline=Resources(tps / 2, 0.0, float(slots))))
    gw = Gateway(pool)
    gw.register_key("key-prod", "prod")
    return gw


def mkreq(rid: str, max_tokens: int = 6) -> Request:
    return Request(request_id=rid, entitlement="prod",
                   prompt_tokens=[3, 5, 7], max_tokens=max_tokens,
                   arrival_s=0.0, api_key="key-prod")


class TestKVBlockManagerFailurePaths:
    def test_double_free_rejected_and_counted(self):
        kv = KVBlockManager(total_pages=8, page_tokens=16)
        kv.allocate("s1", 40)                 # 3 pages
        assert kv.used_pages == 3
        assert kv.free("s1") == 3
        assert kv.used_pages == 0
        # second free: counted no-op (pages must NOT return twice)
        assert kv.free("s1") == 0
        assert kv.double_free_rejections == 1
        assert kv.used_pages == 0
        with pytest.raises(DoubleFree):
            kv.free("s1", strict=True)
        assert kv.double_free_rejections == 2
        assert kv.free_pages == kv.total_pages

    def test_unknown_free_is_counted_noop(self):
        kv = KVBlockManager(total_pages=4, page_tokens=16)
        assert kv.free("never-seen") == 0
        assert kv.unknown_frees == 1
        assert kv.double_free_rejections == 0
        assert kv.free_pages == 4

    def test_reallocate_clears_double_free_state(self):
        kv = KVBlockManager(total_pages=4, page_tokens=16)
        kv.allocate("s1", 16)
        kv.free("s1")
        kv.allocate("s1", 16)                 # legitimate reuse
        assert kv.free("s1", strict=True) == 1   # not a double free
        assert kv.double_free_rejections == 0

    def test_leak_invariant_closed_under_churn(self):
        kv = KVBlockManager(total_pages=16, page_tokens=16)
        for i in range(5):
            kv.allocate(f"s{i}", 16 * (i + 1))
        for i in (1, 3):
            kv.free(f"s{i}")
        kv.extend("s4", 16 * 5 + 1)
        assert kv.used_pages + kv.free_pages == kv.total_pages


class TestEngineFailurePaths:
    def test_step_with_zero_live_requests(self, served_model):
        cfg, model, params = served_model
        eng = InferenceEngine(model, params, slots=2, max_seq=64)
        assert eng.step(now=0.0) == 0
        assert eng.kv_pages.used_pages == 0

    def test_mid_stream_eviction_reclaims_kv(self, served_model):
        cfg, model, params = served_model
        gw = mkgateway(slots=2)
        eng = InferenceEngine(model, params, slots=2, max_seq=64,
                              gateway=gw)
        a, b = mkreq("a"), mkreq("b")
        assert eng.submit(a, now=0.0) and eng.submit(b, now=0.0)
        eng.step(now=0.0)                     # both decoding
        assert eng.kv_pages.used_pages > 0
        assert gw.pool.pool_in_flight() == 2

        assert eng.evict("a", now=0.1)
        assert a.state == RequestState.EVICTED
        assert a in eng.finished
        # the lane's pages went back and the admission charge was
        # cancelled through the gateway failure path
        assert "a" not in eng.kv_pages.sequences()
        assert gw.pool.pool_in_flight() == 1
        # freeing the evicted lane again is a rejected double free
        assert eng.kv_pages.free("a") == 0
        assert eng.kv_pages.double_free_rejections == 1

        # the survivor drains normally and every page comes home
        eng.run_until_drained(now=0.2)
        assert b.state == RequestState.FINISHED
        assert eng.kv_pages.used_pages == 0
        assert gw.pool.pool_in_flight() == 0

    def test_evict_queued_unstarted_request(self, served_model):
        cfg, model, params = served_model
        gw = mkgateway(slots=4)
        eng = InferenceEngine(model, params, slots=1, max_seq=64,
                              gateway=gw)
        first, queued = mkreq("first"), mkreq("queued")
        eng.submit(first, now=0.0)
        eng.submit(queued, now=0.0)
        eng.step(now=0.0)                     # only "first" gets a lane
        used = eng.kv_pages.used_pages
        assert eng.evict("queued", now=0.1)
        assert queued.state == RequestState.EVICTED
        # no KV was resident for the queued request — nothing freed
        assert eng.kv_pages.used_pages == used
        assert gw.pool.pool_in_flight() == 1
        eng.run_until_drained(now=0.2)
        assert eng.kv_pages.used_pages == 0

    def test_evict_unknown_id_returns_false(self, served_model):
        cfg, model, params = served_model
        eng = InferenceEngine(model, params, slots=1, max_seq=64)
        assert not eng.evict("ghost", now=0.0)
        r = mkreq("r")
        eng.submit(r, now=0.0)
        eng.run_until_drained()
        # already-terminal ids are not re-evicted (nothing freed twice)
        assert not eng.evict("r", now=1.0)
        assert eng.kv_pages.double_free_rejections == 0
