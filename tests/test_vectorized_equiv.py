"""Property tests: the jit-compiled vectorized control plane must agree
with the scalar reference implementation (hypothesis-driven).

Deterministic (no-hypothesis) equivalence coverage for the SAME kernel
— including the multi-pool batched tick — lives in
``tests/test_control_plane.py`` and always runs."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    PriorityCoefficients,
    Resources,
    ServiceClass,
    priority_weight,
    burst_overconsumption,
    waterfill,
)
from repro.core.vectorized import (
    CLASS_CODES,
    PoolArrays,
    burst_delta_batch,
    priority_batch,
    tick_batch,
    waterfill_batch,
)

COEFF = PriorityCoefficients()
CLASSES = list(ServiceClass)

# zero or a meaningfully-sized value: denormal baselines (1e-38) are
# degenerate configs the scalar/vector paths may legitimately clamp
# differently, and no real entitlement is entitled to 1e-38 tok/s.
finite = st.one_of(st.just(0.0),
                   st.floats(min_value=0.0009765625, max_value=1e6,
                             allow_nan=False, allow_infinity=False,
                             width=32))
pos = st.floats(min_value=1.0, max_value=1e5, allow_nan=False,
                allow_infinity=False, width=32)
small = st.floats(min_value=-0.875, max_value=5.0, allow_nan=False,
                  allow_infinity=False, width=32)


def mkarrays(classes, baselines, slos, bursts, debts, bound=None):
    n = len(classes)
    return PoolArrays(
        class_code=jnp.array([CLASS_CODES[c] for c in classes], jnp.int32),
        bound=jnp.array(bound if bound is not None else [True] * n),
        baseline_tps=jnp.array(baselines, jnp.float32),
        baseline_kv=jnp.zeros(n, jnp.float32),
        baseline_conc=jnp.zeros(n, jnp.float32),
        slo_ms=jnp.array(slos, jnp.float32),
        burst=jnp.array(bursts, jnp.float32),
        debt=jnp.array(debts, jnp.float32),
    )


class TestPriorityEquivalence:
    @given(
        klass=st.sampled_from(CLASSES),
        slo=pos, avg=pos,
        burst=st.floats(0.0, 10.0, width=32),
        debt=small,
    )
    @settings(max_examples=200, deadline=None)
    def test_matches_scalar(self, klass, slo, avg, burst, debt):
        arr = mkarrays([klass], [1.0], [slo], [burst], [debt])
        w_vec = float(priority_batch(arr, jnp.float32(avg), COEFF)[0])
        w_ref = priority_weight(klass, float(np.float32(slo)),
                                float(np.float32(avg)),
                                float(np.float32(burst)),
                                float(np.float32(debt)), COEFF)
        assert w_vec == pytest.approx(w_ref, rel=1e-4)


class TestBurstEquivalence:
    @given(
        used=st.tuples(finite, finite, finite),
        base=st.tuples(finite, finite, finite),
    )
    @settings(max_examples=200, deadline=None)
    def test_matches_scalar(self, used, base):
        arr = mkarrays([ServiceClass.ELASTIC], [base[0]], [1000.0],
                       [0.0], [0.0])
        arr = dataclasses.replace(
            arr,
            baseline_tps=jnp.array([base[0]], jnp.float32),
            baseline_kv=jnp.array([base[1]], jnp.float32),
            baseline_conc=jnp.array([base[2]], jnp.float32))
        d_vec = float(burst_delta_batch(
            jnp.array([used[0]], jnp.float32),
            jnp.array([used[1]], jnp.float32),
            jnp.array([used[2]], jnp.float32), arr)[0])
        d_ref = burst_overconsumption(
            Resources(*[float(np.float32(u)) for u in used]),
            Resources(*[float(np.float32(b)) for b in base]))
        assert d_vec == pytest.approx(d_ref, rel=1e-4, abs=1e-5)


class TestWaterfillEquivalence:
    @given(
        capacity=st.floats(0.0, 1000.0, width=32),
        wants=st.lists(st.floats(0.0, 200.0, width=32),
                       min_size=1, max_size=12),
        data=st.data(),
    )
    @settings(max_examples=150, deadline=None)
    def test_matches_scalar(self, capacity, wants, data):
        # realistic weights: exactly zero, or within the range Eq. 1 can
        # produce (class 0.1 × factors ≳ 1e-3 … class 1000 × debt ≲ 5)
        weights = data.draw(st.lists(
            st.one_of(st.just(0.0),
                      st.floats(0.0078125, 5000.0, width=32)),
            min_size=len(wants), max_size=len(wants)))
        keys = [f"k{i}" for i in range(len(wants))]
        ref = waterfill(float(np.float32(capacity)),
                        dict(zip(keys, [float(np.float32(w)) for w in wants])),
                        dict(zip(keys, [float(np.float32(w)) for w in weights])))
        vec = waterfill_batch(jnp.float32(capacity),
                              jnp.array(wants, jnp.float32),
                              jnp.array(weights, jnp.float32))
        vec = np.asarray(vec)
        for i, k in enumerate(keys):
            assert vec[i] == pytest.approx(ref[k], rel=2e-3, abs=1e-2)

    @given(
        capacity=st.floats(0.0, 1000.0, width=32),
        wants=st.lists(st.floats(0.0, 200.0, width=32),
                       min_size=1, max_size=12),
    )
    @settings(max_examples=100, deadline=None)
    def test_invariants(self, capacity, wants):
        """Work conservation + cap respect, regardless of weights."""
        alloc = np.asarray(waterfill_batch(
            jnp.float32(capacity), jnp.array(wants, jnp.float32),
            jnp.ones(len(wants), jnp.float32)))
        wants_arr = np.asarray(wants, np.float32)
        assert (alloc <= wants_arr + 1e-3).all()
        assert alloc.sum() <= capacity + 1e-2
        # work conserving: all wants met or all capacity used
        assert (np.isclose(alloc, wants_arr, atol=1e-2).all()
                or alloc.sum() >= capacity - max(1e-2, 1e-4 * capacity))


class TestTickBatch:
    def test_full_tick_against_scalar_pool(self):
        """End-to-end tick on a mixed-class pool must reproduce the
        scalar TokenPool allocation + debt update."""
        from repro.core import (EntitlementSpec, PoolSpec, QoS,
                                ScalingBounds, TokenPool)

        spec = PoolSpec(name="p", model="m",
                        scaling=ScalingBounds(1, 2),
                        per_replica=Resources(100.0, 1 << 30, 16.0))
        pool = TokenPool(spec)

        def ent(name, klass, tps, slo):
            return EntitlementSpec(
                name=name, tenant_id=name, pool="p",
                qos=QoS(service_class=klass, slo_target_ms=slo),
                baseline=Resources(tps, 0.0, 4.0))

        pool.add_entitlement(ent("a_guar", ServiceClass.GUARANTEED, 40.0, 200.0))
        pool.add_entitlement(ent("b_el", ServiceClass.ELASTIC, 50.0, 500.0))
        pool.add_entitlement(ent("c_el", ServiceClass.ELASTIC, 50.0, 30000.0))
        pool.add_entitlement(ent("d_spot", ServiceClass.SPOT, 0.0, 30000.0))
        for n in ["a_guar", "b_el", "c_el", "d_spot"]:
            pool.register_deny(n, 80.0, low_priority=False)
        rec = pool.tick(1.0)

        names = sorted(pool.entitlements)      # matches arrays_from_pool
        arr = mkarrays(
            [pool.entitlements[n].qos.service_class for n in names],
            [pool.entitlements[n].baseline.tokens_per_second for n in names],
            [pool.entitlements[n].qos.slo_target_ms for n in names],
            [0.0] * 4, [0.0] * 4)
        demand = jnp.array([pool._demand_tps[n] for n in names], jnp.float32)
        arr2, alloc, weights = tick_batch(
            arr, jnp.float32(100.0),
            measured_tps=jnp.zeros(4), used_kv=jnp.zeros(4),
            used_conc=jnp.zeros(4), demand_tps=demand,
            coeff=pool.spec.coefficients)
        alloc = np.asarray(alloc)
        debts = np.asarray(arr2.debt)
        for i, n in enumerate(names):
            assert alloc[i] == pytest.approx(rec.allocations[n], rel=1e-4,
                                             abs=1e-3), n
            assert debts[i] == pytest.approx(pool.status[n].debt,
                                             rel=1e-4, abs=1e-5), n
            assert float(weights[i]) == pytest.approx(
                rec.priorities[n], rel=1e-4), n

    def test_scales_to_many_entitlements(self):
        """100k entitlements tick in one fused call (beyond-paper)."""
        n = 100_000
        rng = np.random.RandomState(0)
        arr = PoolArrays(
            class_code=jnp.array(rng.randint(0, 5, n), jnp.int32),
            bound=jnp.ones(n, bool),
            baseline_tps=jnp.array(rng.uniform(0, 100, n), jnp.float32),
            baseline_kv=jnp.zeros(n, jnp.float32),
            baseline_conc=jnp.array(rng.uniform(1, 8, n), jnp.float32),
            slo_ms=jnp.array(rng.uniform(100, 30000, n), jnp.float32),
            burst=jnp.zeros(n, jnp.float32),
            debt=jnp.zeros(n, jnp.float32),
        )
        demand = jnp.array(rng.uniform(0, 200, n), jnp.float32)
        protected = np.isin(np.asarray(arr.class_code), [0, 1])
        active_p = np.minimum(np.asarray(arr.baseline_tps),
                              np.asarray(demand))[protected].sum()

        # (a) scarcity regime: protected active use alone exceeds this
        # capacity → emergency scaling, nothing for other classes
        _, alloc_s, _ = tick_batch(
            arr, jnp.float32(1e6),
            measured_tps=jnp.zeros(n), used_kv=jnp.zeros(n),
            used_conc=jnp.zeros(n), demand_tps=demand)
        alloc_s = np.asarray(alloc_s)
        assert np.isfinite(alloc_s).all() and (alloc_s >= -1e-3).all()
        assert active_p > 1e6                    # premise
        assert alloc_s[~protected].sum() == pytest.approx(0.0, abs=1.0)

        # (b) normal regime: protected funding may overcommit (idle
        # reservations are borrowed) but active protected use + all
        # other allocations fit capacity
        cap = np.float32(active_p * 3.0)
        _, alloc_n, _ = tick_batch(
            arr, jnp.asarray(cap),
            measured_tps=jnp.zeros(n), used_kv=jnp.zeros(n),
            used_conc=jnp.zeros(n), demand_tps=demand)
        alloc_n = np.asarray(alloc_n)
        assert np.isfinite(alloc_n).all() and (alloc_n >= -1e-3).all()
        assert (active_p + alloc_n[~protected].sum()
                <= float(cap) * 1.01)


# Deterministic (always-run) parity coverage for the same kernel lives
# in ``tests/test_admit_quantum.py`` — including the regression pins
# for the burst-escape / live-threshold / snapshot-mutation fixes.
from test_admit_quantum import (  # noqa: E402
    mkpool_for_quantum as _mkpool_for_quantum,
    qent as _qent,
    run_quantum_vs_scalar as _run_quantum_vs_scalar,
    seed_inflight as _seed_inflight,
)

# value grids exactly representable in float32 so scalar (f64) and
# kernel (f32) comparisons can only tie when the operands are identical
_SLO_GRID = [125.0, 1000.0, 32000.0]
_BURST_GRID = [0.0, 0.5, 1.5]
_DEBT_GRID = [-0.125, 0.0, 0.5]
_TPS_GRID = [0.0, 64.0, 256.0]
_LEVEL_GRID = [0.0, 64.0, 192.0, 1024.0]
_CHI_GRID = [0.0, 2048.0, 8192.0]


class TestAdmitQuantumParityRandomized:
    """Hypothesis sweep of the regimes the deterministic test misses:
    burst-over-r_e with free slots, contended pools with live
    thresholds, KV exhaustion, admission slack — the kernel must make
    the scalar §4.3 pipeline's decisions request for request."""

    @given(data=st.data())
    @settings(max_examples=60, deadline=None, derandomize=True)
    def test_decision_parity(self, data):
        from repro.core import EntitlementState

        pool_conc = data.draw(st.sampled_from([2.0, 4.0, 8.0]),
                              label="pool_conc")
        slack = data.draw(st.sampled_from([0.0, 0.25]), label="slack")
        pool = _mkpool_for_quantum(pool_conc=pool_conc, slack=slack,
                                   pool_tps=4096.0)

        classes = data.draw(st.lists(st.sampled_from(CLASSES),
                                     min_size=3, max_size=3),
                            label="classes")
        names = [f"e{i}" for i in range(3)]
        for i, (name, klass) in enumerate(zip(names, classes)):
            pool.add_entitlement(_qent(
                name, klass,
                tps=data.draw(st.sampled_from(_TPS_GRID)),
                conc=data.draw(st.sampled_from([0.0, 1.0, 2.0])),
                slo=data.draw(st.sampled_from(_SLO_GRID)),
                kv=data.draw(st.sampled_from(_CHI_GRID))))
            st_ = pool.status[name]
            st_.burst = data.draw(st.sampled_from(_BURST_GRID))
            st_.debt = data.draw(st.sampled_from(_DEBT_GRID))
            if data.draw(st.booleans(), label=f"degraded{i}"):
                st_.state = EntitlementState.DEGRADED
            bucket = pool.ledger.bucket(name)
            bucket.level = data.draw(st.sampled_from(_LEVEL_GRID))
            st_.kv_bytes_in_use = data.draw(
                st.sampled_from([0.0, 1024.0]))
            _seed_inflight(
                pool, name,
                queued=data.draw(st.integers(0, 3)),
                resident=data.draw(st.integers(0, 2)))

        reqs = [(data.draw(st.sampled_from(names)),
                 data.draw(st.sampled_from([8, 32])),
                 data.draw(st.sampled_from([None, 16, 64])),
                 data.draw(st.sampled_from([0.0, 16.0])))
                for _ in range(8)]

        kernel, scalar = _run_quantum_vs_scalar(pool, reqs, slack=slack)
        assert kernel == scalar
