"""Virtual-node scheduler semantics (paper §4.1): transactional binds,
pending→Degraded, FIFO reschedule, preemption on capacity shrink."""
import pytest

from repro.core import LeasePod, Resources, VirtualNodeProvider


def lease(name, tps, conc=0.0, kv=0.0, weight=100.0):
    return LeasePod(name=name, entitlement=name,
                    request=Resources(tps, kv, conc),
                    protection_weight=weight)


class TestBinding:
    def test_bind_within_capacity(self):
        p = VirtualNodeProvider()
        p.create_node("pool", Resources(100.0, 0.0, 16.0))
        assert p.submit("pool", lease("a", 60.0))
        assert p.node("pool").allocatable().tokens_per_second == pytest.approx(40.0)

    def test_bind_is_all_or_nothing(self):
        p = VirtualNodeProvider()
        p.create_node("pool", Resources(100.0, 0.0, 4.0))
        # tps fits but concurrency doesn't → nothing committed
        assert not p.submit("pool", lease("a", 50.0, conc=8.0))
        assert p.node("pool").allocated.tokens_per_second == 0.0

    def test_insufficient_capacity_pending(self):
        p = VirtualNodeProvider()
        p.create_node("pool", Resources(100.0, 0.0, 16.0))
        assert p.submit("pool", lease("a", 80.0))
        assert not p.submit("pool", lease("b", 40.0))
        assert p.pending() == ["b"]

    def test_no_oversubscription_ever(self):
        p = VirtualNodeProvider()
        p.create_node("pool", Resources(100.0, 0.0, 16.0))
        for i in range(10):
            p.submit("pool", lease(f"l{i}", 30.0))
        node = p.node("pool")
        assert node.allocated.fits_within(node.capacity)

    def test_zero_request_always_binds(self):
        p = VirtualNodeProvider()
        p.create_node("pool", Resources(0.0, 0.0, 0.0))
        assert p.submit("pool", lease("spot", 0.0))


class TestRescheduling:
    def test_delete_unblocks_pending_fifo(self):
        p = VirtualNodeProvider()
        p.create_node("pool", Resources(100.0, 0.0, 16.0))
        p.submit("pool", lease("a", 80.0))
        p.submit("pool", lease("b", 60.0))   # pending
        p.submit("pool", lease("c", 30.0))   # pending
        p.delete("a")
        assert p.is_bound("b")
        assert p.is_bound("c")    # 60 + 30 ≤ 100

    def test_fifo_order_respected(self):
        p = VirtualNodeProvider()
        p.create_node("pool", Resources(100.0, 0.0, 16.0))
        p.submit("pool", lease("a", 100.0))
        p.submit("pool", lease("b", 90.0))   # pending first
        p.submit("pool", lease("c", 20.0))   # pending second
        p.delete("a")
        assert p.is_bound("b")
        assert not p.is_bound("c")           # b consumed the capacity first
        assert p.pending() == ["c"]

    def test_capacity_grow_reschedules(self):
        p = VirtualNodeProvider()
        p.create_node("pool", Resources(50.0, 0.0, 16.0))
        p.submit("pool", lease("a", 40.0))
        p.submit("pool", lease("b", 40.0))   # pending
        p.set_capacity("pool", Resources(100.0, 0.0, 16.0))
        assert p.is_bound("b")


class TestPreemption:
    def test_capacity_shrink_evicts_least_protected(self):
        p = VirtualNodeProvider()
        p.create_node("pool", Resources(100.0, 0.0, 16.0))
        p.submit("pool", lease("guar", 60.0, weight=1000.0))
        p.submit("pool", lease("elastic", 40.0, weight=100.0))
        preempted = p.set_capacity("pool", Resources(70.0, 0.0, 16.0))
        assert preempted == ["elastic"]
        assert p.is_bound("guar")
        assert not p.is_bound("elastic")
        # elastic waits in pending; capacity restore re-binds it
        p.set_capacity("pool", Resources(100.0, 0.0, 16.0))
        assert p.is_bound("elastic")


class TestResize:
    def test_grow_within_capacity(self):
        p = VirtualNodeProvider()
        p.create_node("pool", Resources(100.0, 0.0, 16.0))
        p.submit("pool", lease("a", 40.0))
        assert p.resize("a", Resources(70.0, 0.0, 0.0))
        assert p.node("pool").allocated.tokens_per_second == pytest.approx(70.0)

    def test_failed_grow_keeps_old_reservation(self):
        p = VirtualNodeProvider()
        p.create_node("pool", Resources(100.0, 0.0, 16.0))
        p.submit("pool", lease("a", 40.0))
        p.submit("pool", lease("b", 50.0))
        assert not p.resize("a", Resources(80.0, 0.0, 0.0))
        # a's original 40 still bound — no lost reservation
        assert p.node("pool").allocated.tokens_per_second == pytest.approx(90.0)
