"""Chaos harness: scenario DSL, global invariant checkers (each with a
deliberately-broken fixture proving it fires), the scripted scenario
library, differential replay, settle-order determinism, engine/KV
failure paths, and migration rollback under same-quantum pool failure.

Fast structural tests run in tier-1; full scenario soaks, the replay
sweep and the random-scenario sweep carry ``@pytest.mark.chaos`` and
run in the CI chaos job (``pytest -m chaos``).

NOTE: the broken fixtures poke private columns ON PURPOSE — that is
how each checker is proven live.  The ``chaos-public-api`` analysis
pass bans such reach-ins from ``src/repro/chaos/`` itself, not from
tests.
"""
import dataclasses
import json
import random

import numpy as np
import pytest

from repro.chaos import (
    SCENARIOS,
    Scenario,
    ScenarioEvent,
    build_sim,
    by_name,
    checker_catalog,
    default_checkers,
    install_checkers,
    run_replay,
    run_scenario,
    seeded_backoff,
)
from repro.chaos.invariants import (
    Capacity,
    DebtBounds,
    GuaranteedP99,
    MirrorCoherence,
    RowLeaks,
    TokenConservation,
)
from repro.core import (
    AdmissionController,
    AdmissionRequest,
    EntitlementSpec,
    PoolManager,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
)
from repro.core.fleet import FleetPlan, RebalanceProposal
from repro.serving.request import Request, RequestState
from repro.serving.simulation import Workload

# -- shared fixtures ---------------------------------------------------------

MINI = Scenario(
    name="mini", seed=5, duration_s=3.0, p99_bound_s=6.0,
    sites=(
        dict(name="east", n_replicas=1, replica_slots=8,
             replica_tps=160.0),
        dict(name="west", n_replicas=1, replica_slots=8,
             replica_tps=160.0),
    ),
    workloads=(
        dict(name="gold", service_class=ServiceClass.GUARANTEED,
             slots=4, slo_ms=800.0, rate_rps=2.0, in_tokens=32,
             out_tokens=32, max_retries=1, pools=("east", "west")),
        dict(name="flex", service_class=ServiceClass.ELASTIC,
             slots=3, slo_ms=2000.0, rate_rps=6.0, in_tokens=32,
             out_tokens=32, max_retries=1, pools=("east", "west")),
    ),
)

MINI_SINGLE = dataclasses.replace(
    MINI, name="mini_single",
    sites=(dict(name="core", n_replicas=1, replica_slots=8,
                replica_tps=160.0),),
    workloads=tuple(dict(w, pools=("core",)) for w in MINI.workloads))


def run_with_sabotage(checker, sabotage, scenario=MINI,
                      sabotage_at=None):
    """Run ``scenario`` under one checker, corrupting state through
    ``sabotage(sim)`` near the end of the run (so later sanctioned
    row-ops cannot launder the damage before the checker sees it)."""
    sim = build_sim(scenario)
    for pool in sim.manager.pools.values():
        pool.ledger.enable_level_audit()
    t_sab = (scenario.duration_s - 3 * scenario.dt
             if sabotage_at is None else sabotage_at)
    done = []

    def sab(sim, now):
        if now >= t_sab and not done:
            sabotage(sim)
            done.append(now)

    violations = []
    sim.step_hooks.append(sab)          # runs before the checker hook
    install_checkers(sim, [checker], violations, scenario)
    sim.run(scenario.duration_s)
    assert done, "sabotage never fired"
    return violations


# -- scenario DSL ------------------------------------------------------------

class TestScenarioDSL:
    def test_build_sim_isolates_workload_state(self):
        """set_rate mutates Workload objects in place; scenarios store
        kwargs so each build starts pristine."""
        sc = dataclasses.replace(MINI_SINGLE, events=(
            ScenarioEvent(0.5, "set_rate",
                          dict(workload="flex", rate=50.0)),))
        sim1 = build_sim(sc)
        sim1.run(1.0)
        assert sim1.workloads["flex"].rate_rps == 50.0
        sim2 = build_sim(sc)
        assert sim2.workloads["flex"].rate_rps == 6.0
        assert sc.workloads[1]["rate_rps"] == 6.0

    def test_unknown_event_kind_rejected(self):
        sc = dataclasses.replace(
            MINI_SINGLE, events=(ScenarioEvent(0.1, "meteor", {}),))
        with pytest.raises(ValueError, match="meteor"):
            build_sim(sc)

    def test_library_lookup(self):
        assert by_name("retry_storm").name == "retry_storm"
        with pytest.raises(KeyError):
            by_name("nope")
        assert len(SCENARIOS) >= 5

    def test_seeded_backoff_is_deterministic(self):
        fn = seeded_backoff(MINI)
        w = Workload(name="gold",
                     service_class=ServiceClass.GUARANTEED, slots=4,
                     slo_ms=800.0, rate_rps=1.0)
        vals = [fn(w, None, a, None) for a in range(4)]
        assert vals == [fn(w, None, a, None) for a in range(4)]
        for v in vals:
            assert MINI.retry_base_s <= v \
                <= MINI.retry_base_s + MINI.retry_jitter_s
        # attempts draw different jitter (crc32, not a constant)
        assert len(set(vals)) > 1

    def test_churn_events_use_public_entry_points(self):
        """add/remove/migrate events round-trip an entitlement through
        the public pool surface while the sim runs."""
        sc = dataclasses.replace(MINI, name="churn", events=(
            ScenarioEvent(0.5, "add_entitlement", dict(
                pool="east", name="standby",
                service_class=ServiceClass.GUARANTEED,
                slo_ms=1000.0, tokens_per_second=20.0, slots=1.0)),
            ScenarioEvent(1.0, "migrate", dict(
                entitlement="standby", src="east", dst="west")),
            ScenarioEvent(1.5, "remove_entitlement", dict(
                pool="west", name="standby")),
        ))
        sim = build_sim(sc)
        sim.run(2.0)
        assert "standby" not in sim.manager.pool("east").entitlements
        assert "standby" not in sim.manager.pool("west").entitlements


# -- every checker fires on a deliberately-broken fixture --------------------

class TestCheckersFire:
    def test_registry_has_at_least_six(self):
        checkers = default_checkers()
        assert len(checkers) >= 6
        names = {c.name for c in checkers}
        assert {"token-conservation", "row-leaks", "debt-bounds",
                "capacity", "mirror-coherence",
                "guaranteed-p99"} <= names
        assert len(checker_catalog()) == len(checkers)

    def test_clean_run_is_quiet(self):
        rep = run_scenario(MINI)
        assert rep["passed"], rep["violations"]

    def test_token_conservation_fires_on_level_poke(self):
        def sabotage(sim):
            pool = sim.manager.pool("east")
            slot = pool.store.slot_of["gold@east"]
            pool.store.col["bucket_level"][slot] += 123.0
        vs = run_with_sabotage(TokenConservation(), sabotage)
        assert any(v.checker == "token-conservation" for v in vs), vs

    def test_row_leaks_fires_on_free_list_corruption(self):
        def sabotage(sim):
            store = sim.manager.pool("east").store
            store._free.append(store.slot_of["gold@east"])
        vs = run_with_sabotage(RowLeaks(), sabotage)
        assert any("row leak" in v.message for v in vs), vs

    def test_row_leaks_fires_on_unknown_settle(self):
        def sabotage(sim):
            # a settle with no outstanding charge is a counted no-op
            sim.manager.pool("east").ledger.settle(
                "never-admitted", 1, 1.0)
        vs = run_with_sabotage(RowLeaks(), sabotage)
        assert any("no outstanding charge" in v.message for v in vs), vs

    def test_debt_bounds_fires_on_out_of_range_debt(self):
        def sabotage(sim):
            pool = sim.manager.pool("east")
            coeff = pool.spec.coefficients
            pool.status["flex@east"].debt = coeff.debt_max + 1.0
        vs = run_with_sabotage(DebtBounds(), sabotage)
        assert any("outside" in v.message for v in vs), vs

    def test_debt_bounds_fires_on_guaranteed_debt_growth(self):
        """Debt-free classes must only drain: raising a guaranteed
        tenant's debt (in range!) trips drain-monotonicity."""
        def sabotage(sim):
            sim.manager.pool("east").status["gold@east"].debt = 0.5
        vs = run_with_sabotage(DebtBounds(), sabotage)
        assert any("debt-free class" in v.message for v in vs), vs

    def test_capacity_fires_on_in_flight_poke(self):
        def sabotage(sim):
            pool = sim.manager.pool("east")
            slot = pool.store.slot_of["gold@east"]
            pool.store.col["in_flight"][slot] += 3
        vs = run_with_sabotage(Capacity(), sabotage)
        assert any("table recount" in v.message for v in vs), vs

    def test_capacity_fires_on_overloaded_backend_lane(self):
        def sabotage(sim):
            replica = sim.replicas["east"][0]
            for i in range(replica.slots + 2):
                rid = f"ghost-{i}"
                sim.requests[rid] = Request(
                    request_id=rid, entitlement="gold",
                    prompt_tokens=[1], max_tokens=1, arrival_s=0.0)
                replica.active.setdefault(rid, [1e9, 0.0])
        vs = run_with_sabotage(Capacity(), sabotage)
        assert any("over its" in v.message for v in vs), vs

    def test_mirror_coherence_fires_on_dirty_host_write(self):
        def sabotage(sim):
            pool = sim.manager.pool("east")
            pool.store.device_state()      # build + cache the mirror
            slot = pool.store.slot_of["gold@east"]
            # host write WITHOUT mark_dirty: the cached mirror goes
            # stale, which is exactly what the checker must observe
            pool.store.col["burst"][slot] += 1.0
        vs = run_with_sabotage(MirrorCoherence(), sabotage)
        assert any("mark_dirty" in v.message for v in vs), vs

    def test_guaranteed_p99_fires_on_absurd_bound(self):
        sc = dataclasses.replace(MINI, p99_bound_s=1e-6)
        rep = run_scenario(sc, checkers=[GuaranteedP99()])
        assert any(v["checker"] == "guaranteed-p99"
                   for v in rep["violations"]), rep


# -- scripted scenario library ----------------------------------------------

class TestScenarioLibrary:
    @pytest.mark.parametrize("scenario", SCENARIOS,
                             ids=[s.name for s in SCENARIOS])
    def test_scenario_passes_all_invariants(self, scenario):
        rep = run_scenario(scenario)
        assert rep["passed"], rep["violations"]
        tier = rep["slo"].get("guaranteed") or {}
        assert tier.get("completions", 0) > 0
        assert tier["p99_s"] <= scenario.p99_bound_s

    def test_failure_scenarios_record_incident_windows(self):
        rep = run_scenario(by_name("correlated_failure"))
        windows = rep["incident_windows"]
        assert len(windows) >= 2
        for key, start, end in windows:
            assert key.startswith("east/")
            assert end is not None and end > start

    def test_report_is_json_serializable(self):
        rep = run_scenario(MINI)
        text = json.dumps(rep, default=str)
        back = json.loads(text)
        assert back["scenario"] == "mini"
        assert len(back["checkers"]) >= 6
        assert back["requests_total"] > 0


# -- differential replay -----------------------------------------------------

class TestDifferentialReplay:
    def test_mini_replay_identical(self):
        res = run_replay(MINI)
        assert res.identical, res.mismatches[:10]
        assert set(res.traces) == {"scalar", "quantum", "quantum_fast"}
        # the run produced real decisions, not an empty diff
        assert len(res.traces["scalar"].outcomes) > 10
        assert res.traces["scalar"].flight_legs

    @pytest.mark.chaos
    @pytest.mark.parametrize("scenario", SCENARIOS,
                             ids=[s.name for s in SCENARIOS])
    def test_library_replay_identical(self, scenario):
        res = run_replay(scenario)
        assert res.identical, res.mismatches[:10]

    def test_replay_detects_divergence(self):
        """The diff engine itself must fire when decisions differ —
        compare two different seeds of the same scenario."""
        from repro.chaos.replay import capture_trace, diff_traces
        sim_a = build_sim(MINI_SINGLE)
        sim_a.run(2.0)
        sim_b = build_sim(dataclasses.replace(MINI_SINGLE, seed=99))
        sim_b.run(2.0)
        diffs = diff_traces(capture_trace(sim_a, "a"),
                            capture_trace(sim_b, "b"))
        assert diffs


# -- satellite 1: settle-order determinism ----------------------------------

class TestSettleDeterminism:
    @pytest.mark.parametrize("order", [
        ["gold-3", "gold-1", "gold-2"],
        ["gold-2", "gold-3", "gold-1"],
    ])
    def test_same_step_completions_settle_in_rid_order(self, order):
        """Completions landing on one dt step must settle sorted by
        (finished_s, rid), not by ``replica.active`` dict insertion
        order — the insertion permutation simulates what
        PYTHONHASHSEED/dispatch history variation used to leak into
        the settle (and retry re-submission) sequence."""
        sim = build_sim(MINI_SINGLE, telemetry=False)
        captured = []
        sim.gateway.on_complete_batch = \
            lambda completions, now: captured.extend(
                rid for rid, _, _ in completions)
        replica = sim.replicas["core"][0]
        for rid in order:
            req = Request(request_id=rid, entitlement="gold",
                          prompt_tokens=[1], max_tokens=1,
                          arrival_s=0.0)
            req.state = RequestState.DECODING
            sim.requests[rid] = req
            replica.active[rid] = [1e-6, 0.0]   # finishes this step
        sim._advance_replicas(0.0)
        assert captured == sorted(order)


# -- satellite 3: migration rollback & same-quantum pool failure -------------

def _two_pools():
    manager = PoolManager()
    for name in ("src", "dst"):
        pool = manager.add_pool(PoolSpec(
            name=name, model="m", scaling=ScalingBounds(1, 2),
            per_replica=Resources(1000.0, 0.0, 8.0)))
        pool.set_replicas(1)
    manager.pool("src").add_entitlement(EntitlementSpec(
        name="ent", tenant_id="t", pool="src",
        qos=QoS(service_class=ServiceClass.ELASTIC,
                slo_target_ms=1000),
        baseline=Resources(200.0, 0.0, 4.0)))
    return manager


class TestMigrationRollback:
    def test_attach_failure_rolls_back_to_source(self):
        manager = _two_pools()
        src = manager.pool("src")
        # live traffic: one outstanding charge + in-flight record
        dec = AdmissionController(src).decide(AdmissionRequest(
            entitlement="ent", input_tokens=10, max_tokens=10,
            arrival_s=0.0, request_id="r1"))
        assert dec.admitted
        src.status["ent"].debt = 0.25
        level_before = src.ledger.bucket("ent").level
        # destination already owns the name → attach raises
        manager.pool("dst").add_entitlement(EntitlementSpec(
            name="ent", tenant_id="other", pool="dst",
            qos=QoS(service_class=ServiceClass.ELASTIC,
                    slo_target_ms=1000),
            baseline=Resources(100.0, 0.0, 2.0)))
        # now=0.0 so bucket refill can't mask the level comparison
        with pytest.raises(ValueError):
            manager.migrate_entitlement("ent", "src", "dst", now=0.0)
        # everything restored on the source: spec, bucket level, debt,
        # in-flight record (settling it still works)
        assert "ent" in src.entitlements
        assert src.ledger.bucket("ent").level \
            == pytest.approx(level_before)
        assert src.status["ent"].debt == pytest.approx(0.25)
        assert src.pool_in_flight() == 1
        assert src.on_complete("r1", 10, now=1.0) is not None
        assert src.pool_in_flight() == 0
        assert src.ledger.unknown_settles == 0

    def test_plan_quantum_skips_migration_into_failed_pool(self):
        """A rebalance proposed before an outage must not execute into
        the dead pool in the same quantum — it lands in
        ``plan.skipped`` and the entitlement stays put."""
        manager = _two_pools()
        prop = RebalanceProposal(entitlement="ent", src="src",
                                 dst="dst", debt=0.5,
                                 baseline_tps=200.0, reason="debt")

        class StubPlanner:
            def plan(self, pools, records, now):
                return FleetPlan(decisions={}, migrations=[prop],
                                 unmet_replicas={})

        manager.planner = StubPlanner()
        manager.pool("dst").set_replicas(0)      # fails this quantum
        plan = manager.plan_quantum(now=1.0)
        assert plan.skipped == [prop]
        assert plan.applied == []
        assert "ent" in manager.pool("src").entitlements
        assert "ent" not in manager.pool("dst").entitlements
        # destination recovers → the same proposal applies next round
        manager.pool("dst").set_replicas(1)
        plan2 = manager.plan_quantum(now=2.0)
        assert [p.entitlement for p in plan2.applied] == ["ent"]
        assert "ent" in manager.pool("dst").entitlements

    def test_rollback_under_seeded_chaos_scenario(self):
        """Pin the rollback with a live scenario: a migrate event whose
        destination already owns the name fails mid-run; the control
        plane must carry on with every invariant intact."""
        sc = dataclasses.replace(MINI, name="clash", events=(
            ScenarioEvent(0.5, "add_entitlement", dict(
                pool="east", name="clash",
                service_class=ServiceClass.GUARANTEED,
                slo_ms=1000.0, tokens_per_second=20.0, slots=1.0)),
            ScenarioEvent(0.6, "add_entitlement", dict(
                pool="west", name="clash",
                service_class=ServiceClass.GUARANTEED,
                slo_ms=1000.0, tokens_per_second=20.0, slots=1.0)),
        ))
        sim = build_sim(sc)
        for pool in sim.manager.pools.values():
            pool.ledger.enable_level_audit()
        errors = []

        def attempt(sim, now):
            try:
                sim.manager.migrate_entitlement(
                    "clash", "east", "west", now)
            except ValueError as e:
                errors.append(e)

        sim.at(1.5, "call", fn=attempt)
        violations = []
        install_checkers(sim, default_checkers(), violations, sc)
        sim.run(sc.duration_s)
        assert errors, "migration clash never raised"
        assert "clash" in sim.manager.pool("east").entitlements
        assert not violations, violations[:5]


# -- satellite 2 lives in test_engine_failures.py ----------------------------
# (KV reclamation after mid-stream eviction, double-free rejection,
#  zero-live engine steps — needs the real-model fixture)


# -- random scenario sweep ---------------------------------------------------

def random_scenario(seed: int) -> Scenario:
    """Property-style scenario generator (stdlib ``random`` — the
    container has no hypothesis; the sweep is seeded instead).  All
    workloads share one pool order so the replay-parity contract
    holds by construction."""
    rng = random.Random(seed)
    n_pools = rng.randint(1, 2)
    pools = tuple(f"p{i}" for i in range(n_pools))
    sites = tuple(
        dict(name=p, n_replicas=rng.randint(1, 2), replica_slots=8,
             replica_tps=160.0)
        for p in pools)
    workloads = [dict(
        name="gold", service_class=ServiceClass.GUARANTEED,
        slots=4, slo_ms=800.0, rate_rps=rng.uniform(1.0, 3.0),
        in_tokens=32, out_tokens=32, max_retries=rng.randint(0, 2),
        pools=pools)]
    for i in range(rng.randint(1, 2)):
        workloads.append(dict(
            name=f"fl{i}",
            service_class=rng.choice(
                [ServiceClass.ELASTIC, ServiceClass.DEDICATED]),
            slots=rng.randint(2, 4), slo_ms=2000.0,
            rate_rps=rng.uniform(2.0, 10.0), in_tokens=32,
            out_tokens=32, max_retries=rng.randint(0, 3),
            pools=pools))
    duration = rng.uniform(4.0, 6.0)
    events = []
    if rng.random() < 0.8:       # one failure/recovery window
        p = rng.choice(pools)
        idx = rng.randrange(
            next(s["n_replicas"] for s in sites if s["name"] == p))
        t = rng.uniform(1.0, duration / 2)
        events.append(ScenarioEvent(
            t, "fail_replica", dict(pool=p, idx=idx)))
        events.append(ScenarioEvent(
            t + rng.uniform(0.5, 2.0), "recover_replica",
            dict(pool=p, idx=idx)))
    if rng.random() < 0.6:       # one demand step
        w = rng.choice(workloads[1:])["name"] if len(workloads) > 1 \
            else "gold"
        events.append(ScenarioEvent(
            rng.uniform(1.0, duration - 1.0), "set_rate",
            dict(workload=w, rate=rng.uniform(0.5, 20.0))))
    return Scenario(
        name=f"random_{seed}", seed=seed, duration_s=duration,
        sites=sites, workloads=tuple(workloads),
        events=tuple(sorted(events, key=lambda e: e.t)))


@pytest.mark.chaos
class TestRandomScenarioSweep:
    @pytest.mark.parametrize("seed", [101, 202, 303, 404, 505, 606])
    def test_random_scenario_holds_all_invariants(self, seed):
        rep = run_scenario(random_scenario(seed))
        assert rep["passed"], rep["violations"][:5]

    @pytest.mark.parametrize("seed", [101, 404])
    def test_random_scenario_replays_identically(self, seed):
        res = run_replay(random_scenario(seed))
        assert res.identical, res.mismatches[:10]
