"""Resident control-plane state: the arrays are the source of truth,
dicts are views (``core.resident``).

Covers the ownership inversion invariants:

- ``pool.status[name]`` views write through to the resident columns
  and never diverge from them;
- the resident arrays always equal the arrays a per-name dict walk
  (the OLD ``arrays_from_pool`` gather) would build — pinned through
  arbitrary churn (add / remove / expire / attach / detach interleaved
  with ticks and admissions, deterministic + hypothesis);
- free-slot recycling never aliases live rows, freed rows are zeroed
  (inert under every kernel mask), capacity grows by pow2 doubling;
- entitlement churn WITHIN a pow2 capacity bucket never retraces the
  jitted kernels (trace-counter pins);
- ``TokenPool.history`` is bounded by ``PoolSpec.history_maxlen``;
- the demand EWMA is dt-aware (α = 1 − exp(−dt/τ)) and the fleet
  planner/scalar-autoscaler pair stays decision-identical on it.
"""
import math

import numpy as np
import pytest

from repro.core import (
    AdmissionController,
    AdmissionRequest,
    EntitlementSpec,
    EntitlementState,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)
from repro.core.control_plane import CLASS_CODES, TRACE_COUNTS
from repro.core.resident import STATE_CODES


def mkpool(name="p", tps=1000.0, conc=64.0, maxlen=None, tau=None,
           max_replicas=4):
    spec = PoolSpec(
        name=name, model="m",
        scaling=ScalingBounds(1, max_replicas),
        per_replica=Resources(tps, 1 << 30, conc),
        history_maxlen=maxlen, demand_tau_s=tau)
    return TokenPool(spec)


def ent(name, klass=ServiceClass.ELASTIC, tps=50.0, conc=4.0,
        slo=1000.0, kv=0.0, ttl=None):
    return EntitlementSpec(
        name=name, tenant_id=f"t-{name}", pool="p",
        qos=QoS(service_class=klass, slo_target_ms=slo),
        baseline=Resources(tps, kv, conc), ttl_s=ttl)


def oracle_arrays(pool):
    """The OLD dict-walk gather: per-name rows built from the spec /
    status dicts and the per-bucket ledger API, in sorted-name order.
    The resident arrays must agree with this row for row."""
    names = sorted(pool.entitlements)
    rows = {}
    for n in names:
        e, s = pool.entitlements[n], pool.status[n]
        rows[n] = dict(
            class_code=CLASS_CODES[e.qos.service_class],
            bound=s.state == EntitlementState.BOUND,
            baseline_tps=np.float32(e.baseline.tokens_per_second),
            baseline_kv=np.float32(e.baseline.kv_bytes),
            baseline_conc=np.float32(e.baseline.concurrency),
            slo_ms=np.float32(e.qos.slo_target_ms),
            burst=np.float32(s.burst),
            debt=np.float32(s.debt),
            resident=s.resident,
            kv_in_use=s.kv_bytes_in_use,
            bucket_level=(pool.ledger.bucket(n).level
                          if pool.ledger.has_bucket(n) else None),
        )
    return rows


def assert_store_matches_dicts(pool):
    """Resident columns == dict-built oracle rows, plus the structural
    free-slot / aliasing invariants."""
    store = pool.store
    c = store.col
    # no aliasing: every live name has its own slot, maps both ways
    slots = list(store.slot_of.values())
    assert len(set(slots)) == len(slots)
    assert set(store.slot_of) == set(pool.entitlements) \
        == set(pool.status)
    for name, slot in store.slot_of.items():
        assert store.name_of[slot] == name
        assert c["alive"][slot]
    # free slots: not mapped, zeroed on every column (inert padding)
    live = set(slots)
    for slot in range(store.capacity):
        if slot in live:
            continue
        assert store.name_of[slot] is None
        assert not c["alive"][slot]
        for col_name, arr in c.items():
            assert arr[slot] == 0, (slot, col_name)
    # row-for-row equality with the dict walk
    for name, row in oracle_arrays(pool).items():
        slot = store.slot_of[name]
        for key in ("class_code", "baseline_tps", "baseline_kv",
                    "baseline_conc", "slo_ms", "burst", "debt",
                    "resident"):
            assert c[key][slot] == row[key], (name, key)
        assert bool(c["bound"][slot]) == row["bound"], name
        assert c["kv_in_use"][slot] == row["kv_in_use"], name
        if row["bucket_level"] is not None:
            assert c["has_bucket"][slot]
            assert c["bucket_level"][slot] == row["bucket_level"], name
    # the cached device mirror agrees with the columns
    dev = store.device_state()
    for key in ("class_code", "bound", "baseline_tps", "baseline_kv",
                "baseline_conc", "slo_ms", "burst", "debt"):
        np.testing.assert_array_equal(np.asarray(getattr(dev, key)),
                                      c[key], err_msg=key)


class TestViewsWriteThrough:
    def test_status_view_is_the_row(self):
        pool = mkpool()
        pool.add_entitlement(ent("a"))
        slot = pool.store.slot_of["a"]
        st = pool.status["a"]
        st.debt = 0.5
        st.burst = 0.25
        st.in_flight = 3
        assert pool.store.col["debt"][slot] == np.float32(0.5)
        assert pool.store.col["burst"][slot] == np.float32(0.25)
        assert pool.store.col["in_flight"][slot] == 3
        # and the other way: column writes are visible through the view
        pool.store.col["debt"][slot] = np.float32(0.75)
        assert st.debt == 0.75

    def test_state_setter_maintains_bound_mask(self):
        pool = mkpool()
        pool.add_entitlement(ent("a"))
        slot = pool.store.slot_of["a"]
        assert pool.store.col["bound"][slot]
        pool.status["a"].state = EntitlementState.DEGRADED
        assert not pool.store.col["bound"][slot]
        assert (pool.store.col["state_code"][slot]
                == STATE_CODES[EntitlementState.DEGRADED])

    def test_device_mirror_invalidated_by_view_writes(self):
        pool = mkpool()
        pool.add_entitlement(ent("a"))
        dev0 = pool.store.device_state()
        pool.status["a"].debt = 0.5
        dev1 = pool.store.device_state()
        assert dev1 is not dev0
        assert float(dev1.debt[pool.store.slot_of["a"]]) == \
            pytest.approx(0.5)

    def test_bucket_view_is_the_row(self):
        pool = mkpool()
        pool.add_entitlement(ent("a", tps=100.0))
        b = pool.ledger.bucket("a")
        b.level = 123.0
        slot = pool.store.slot_of["a"]
        assert pool.store.col["bucket_level"][slot] == 123.0
        # two views of the same row can never diverge
        assert pool.ledger.bucket("a").level == 123.0


class TestChurnDeterministic:
    def test_scripted_churn_matches_dict_oracle(self):
        pool = mkpool()
        ctrl = AdmissionController(pool)
        now = 0.0
        pool.add_entitlement(ent("a", ServiceClass.GUARANTEED, 100.0))
        pool.add_entitlement(ent("b", ServiceClass.ELASTIC, 50.0))
        pool.add_entitlement(ent("c", ServiceClass.SPOT, 0.0))
        assert_store_matches_dicts(pool)
        for step in range(1, 6):
            now = float(step)
            for n in list(pool.entitlements):
                pool.register_deny(n, 60.0, low_priority=False)
            ctrl.decide(AdmissionRequest("a", 16, 16, now,
                                         request_id=f"r{step}"))
            pool.tick(now)
            assert_store_matches_dicts(pool)
        # churn: remove, re-add (slot recycled), expire a TTL tenant
        pool.remove_entitlement("b", now)
        assert_store_matches_dicts(pool)
        pool.add_entitlement(ent("d", ServiceClass.ELASTIC, 25.0,
                                 ttl=2.0), now=now)
        assert_store_matches_dicts(pool)
        pool.tick(now + 1.0)
        assert pool.status["d"].state == EntitlementState.BOUND
        pool.tick(now + 3.0)                       # past the TTL
        assert pool.status["d"].state == EntitlementState.EXPIRED
        assert_store_matches_dicts(pool)

    def test_detach_attach_roundtrip_between_stores(self):
        a, b = mkpool("a"), mkpool("b")
        a.add_entitlement(ent("x", ServiceClass.ELASTIC, 50.0))
        a.add_entitlement(ent("y", ServiceClass.ELASTIC, 40.0))
        a.register_deny("x", 300.0, low_priority=False)
        a.tick(1.0)
        a.status["x"].debt = 0.375
        level = a.ledger.bucket("x").level
        demand = a.demand_snapshot()["x"]
        mig = a.detach_entitlement("x", now=1.0)
        assert "x" not in a.store
        assert_store_matches_dicts(a)
        b.attach_entitlement(mig, now=1.0)
        assert b.status["x"].debt == pytest.approx(0.375)
        assert b.ledger.bucket("x").level == pytest.approx(level)
        assert b.demand_snapshot()["x"] == pytest.approx(demand)
        assert_store_matches_dicts(b)
        # the freed slot in A is recycled by the next add without
        # touching the surviving row
        y_slot = a.store.slot_of["y"]
        y_debt = a.status["y"].debt
        a.add_entitlement(ent("z", ServiceClass.SPOT, 0.0))
        assert a.store.slot_of["y"] == y_slot
        assert a.status["y"].debt == y_debt
        assert_store_matches_dicts(a)

    def test_capacity_growth_preserves_rows(self):
        pool = mkpool()
        for i in range(20):                       # forces pow2 growth
            pool.add_entitlement(ent(f"e{i}", tps=float(10 + i)))
        assert pool.store.capacity == 32
        assert_store_matches_dicts(pool)
        pool.tick(1.0)
        assert_store_matches_dicts(pool)


class TestNoRetraceWithinBucket:
    def test_tick_add_remove_within_bucket_no_retrace(self):
        pool = mkpool()
        for i in range(5):
            pool.add_entitlement(ent(f"e{i}"))
        assert pool.store.capacity == 8
        pool.tick(1.0)
        pool.tick(2.0)
        base = TRACE_COUNTS["control_tick"]
        pool.add_entitlement(ent("late"))          # 6 rows, still cap 8
        pool.tick(3.0)
        pool.remove_entitlement("e0")
        pool.tick(4.0)
        pool.add_entitlement(ent("recycled"))      # reuses e0's slot
        pool.tick(5.0)
        assert TRACE_COUNTS["control_tick"] == base
        assert pool.store.capacity == 8

    def test_quantum_add_remove_within_bucket_no_retrace(self):
        from repro.gateway import Gateway, QuantumRequest
        pool = mkpool()
        gw = Gateway(pool)
        for i in range(5):
            pool.add_entitlement(ent(f"e{i}", conc=8.0))
            gw.register_key(f"k{i}", f"e{i}", pool="p")

        def quantum(tag):
            return [QuantumRequest(f"k{i % 4}", f"{tag}-{i}", 16, 16)
                    for i in range(8)]

        gw.handle_quantum(quantum("warm"), now=0.0)
        base = TRACE_COUNTS["admit_quantum"]
        pool.add_entitlement(ent("late", conc=8.0))
        gw.handle_quantum(quantum("a"), now=0.1)
        pool.remove_entitlement("late")
        gw.handle_quantum(quantum("b"), now=0.2)
        assert TRACE_COUNTS["admit_quantum"] == base


class TestHistoryBound:
    def test_history_is_bounded(self):
        pool = mkpool(maxlen=5)
        pool.add_entitlement(ent("a"))
        for t in range(1, 12):
            pool.tick(float(t))
        assert len(pool.history) == 5
        assert pool.history[-1].t == 11.0
        assert pool.history[0].t == 7.0

    def test_default_is_bounded_none_is_unbounded(self):
        assert TokenPool(PoolSpec(name="p", model="m")
                         ).history.maxlen == 4096
        assert mkpool(maxlen=None).history.maxlen is None


class TestDtAwareDemandEWMA:
    def test_nominal_interval_keeps_half_blend(self):
        """At dt == accounting_interval_s the default τ retains exactly
        ½ — bit-identical to the historical fixed blend."""
        pool = mkpool()
        pool.add_entitlement(ent("a", tps=100.0))
        pool.register_deny("a", 100.0, low_priority=False)
        pool.tick(1.0)
        assert pool.demand_snapshot()["a"] == 50.0     # exactly

    def test_decay_is_tick_rate_independent(self):
        """With τ fixed, the same elapsed time decays the estimate the
        same amount no matter how many ticks it is split into."""
        tau = 2.0
        coarse, fine = mkpool(tau=tau), mkpool(tau=tau)
        for pool in (coarse, fine):
            pool.add_entitlement(ent("a", tps=100.0))
            pool.register_deny("a", 100.0, low_priority=False)
            pool.tick(1.0)                              # seed the EWMA
        seed = coarse.demand_snapshot()["a"]
        assert seed == fine.demand_snapshot()["a"]
        coarse.tick(5.0)                                # one dt=4 tick
        for t in (2.0, 3.0, 4.0, 5.0):                  # four dt=1 ticks
            fine.tick(t)
        expected = seed * math.exp(-4.0 / tau)
        assert coarse.demand_snapshot()["a"] == pytest.approx(expected)
        assert fine.demand_snapshot()["a"] == pytest.approx(expected)

    def test_legacy_fixed_blend_depended_on_tick_rate(self):
        """The default τ (interval/ln2) is still dt-aware: splitting an
        interval into two half-ticks decays by ~the same factor as one
        full tick — the old fixed 0.5/0.5 blend would have squared it."""
        a, b = mkpool(), mkpool()
        for pool in (a, b):
            pool.add_entitlement(ent("a", tps=100.0))
            pool.register_deny("a", 100.0, low_priority=False)
            pool.tick(1.0)
        a.tick(2.0)                                     # dt = 1
        b.tick(1.5)                                     # dt = ½ twice
        b.tick(2.0)
        assert a.demand_snapshot()["a"] == pytest.approx(
            b.demand_snapshot()["a"], rel=1e-9)

    def test_autoscaler_and_fleet_kernel_agree_on_new_signal(self):
        """The scalar Autoscaler oracle and the fused plan_fleet kernel
        stay decision-identical when fed the dt-aware demand signal."""
        from repro.core import Autoscaler, AutoscalerConfig, FleetPlanner

        pool = mkpool(tau=1.5, tps=240.0, conc=16.0, max_replicas=8)
        pool.add_entitlement(ent("a", ServiceClass.GUARANTEED, 200.0))
        pool.add_entitlement(ent("b", ServiceClass.ELASTIC, 100.0))
        planner = FleetPlanner()
        scalar = Autoscaler(pool, AutoscalerConfig())
        rec = None
        for t, burst in ((1.0, 900.0), (1.7, 1500.0), (3.2, 400.0),
                         (4.0, 0.0), (5.5, 0.0)):
            for n in pool.entitlements:
                pool.register_deny(n, burst, low_priority=False)
            rec = pool.tick(t)                          # irregular dt
            fleet_d = planner.plan({"p": pool}, {"p": rec},
                                   now=t).decisions["p"]
            scalar_d = scalar.step(rec)
            assert fleet_d.desired == scalar_d.desired, t
            assert fleet_d.demand_tps == pytest.approx(
                scalar_d.demand_tps, rel=1e-5, abs=1e-3), t


# -- churn sweep: resident arrays == dict-built oracle through random
# add/remove/expire/detach/attach/tick/admission interleavings.  The
# procedure is written against a generic ``choose(options)`` so the
# SAME code runs under a seeded deterministic driver everywhere and
# under hypothesis (which shrinks failures) where it is installed.

CLASSES = list(ServiceClass)


def run_churn(choose, n_ops: int) -> None:
    """One churn scenario: every ``choose(list)`` picks the next
    branch; the store must match the dict oracle after EVERY op and
    recycling must never alias live rows."""
    pool = mkpool()
    ctrl = AdmissionController(pool)
    detached = {}                    # name → EntitlementMigration
    counter = [0]
    now = [0.0]

    def do_add():
        counter[0] += 1
        name = f"e{counter[0]}"
        klass = choose(CLASSES)
        tps = (0.0 if klass in (ServiceClass.SPOT,
                                ServiceClass.PREEMPTIBLE)
               else float(choose([10.0, 50.0, 100.0])))
        pool.add_entitlement(
            ent(name, klass, tps,
                slo=float(choose([250.0, 1000.0, 8000.0])),
                ttl=choose([None, None, 3.0])),
            now=now[0])

    def do_remove():
        names = sorted(pool.entitlements)
        if names:
            pool.remove_entitlement(choose(names), now=now[0])

    def do_detach():
        names = sorted(set(pool.entitlements) - set(detached))
        if names:
            name = choose(names)
            detached[name] = pool.detach_entitlement(name, now=now[0])

    def do_attach():
        if detached:
            name = choose(sorted(detached))
            pool.attach_entitlement(detached.pop(name), now=now[0])

    def do_tick():
        now[0] += float(choose([0.5, 1.0, 2.0]))
        for n in pool.entitlements:
            pool.register_deny(n, 40.0, low_priority=False)
        pool.tick(now[0])

    def do_admit():
        names = sorted(pool.entitlements)
        if names:
            counter[0] += 1
            ctrl.decide(AdmissionRequest(
                choose(names), 16, 16, now[0],
                request_id=f"r{counter[0]}"))

    ops = [do_add, do_add, do_remove, do_detach, do_attach,
           do_tick, do_admit]
    do_add()
    assert_store_matches_dicts(pool)
    for _ in range(n_ops):
        choose(ops)()
        assert_store_matches_dicts(pool)


class TestChurnSeededSweep:
    """Always-run deterministic instantiation of the churn property
    (hypothesis adds shrinking randomized depth where installed)."""

    @pytest.mark.parametrize("seed", range(8))
    def test_seeded_churn_stays_coherent(self, seed):
        rng = np.random.RandomState(seed)
        run_churn(lambda options: options[rng.randint(len(options))],
                  n_ops=14)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    class TestChurnHypothesis:
        @given(data=st.data())
        @settings(max_examples=25, deadline=None, derandomize=True)
        def test_random_churn_stays_coherent(self, data):
            run_churn(
                lambda options: data.draw(st.sampled_from(options)),
                n_ops=data.draw(st.integers(6, 18), label="n_ops"))
