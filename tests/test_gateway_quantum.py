"""``Gateway.handle_quantum`` — the batched admission path must be
decision-identical to the per-request scalar pipeline, and the denial
attribution / spill-hop fixes must hold on both paths."""
import random

import pytest

from repro.core import (
    EntitlementSpec,
    PoolManager,
    PoolSpec,
    QoS,
    Resources,
    RouteEntry,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)
from repro.gateway import Gateway, QuantumRequest


def mkpool(name, tps=1000.0, slots=4.0, default_max_tokens=64,
           window=1.0):
    return TokenPool(PoolSpec(
        name=name, model="m", scaling=ScalingBounds(1, 1),
        per_replica=Resources(tps, float(1 << 30), slots),
        default_max_tokens=default_max_tokens, bucket_window_s=window))


def ent(name, pool, klass=ServiceClass.GUARANTEED, tps=500.0, conc=4.0,
        slo=500.0):
    return EntitlementSpec(
        name=name, tenant_id="t", pool=pool,
        qos=QoS(service_class=klass, slo_target_ms=slo),
        baseline=Resources(tps, 0.0, conc))


def _resp_key(r):
    return (r.status, r.pool, r.entitlement, r.spill_hops, r.reason)


class TestQuantumScalarParity:
    """Randomized multi-pool workloads: ``handle_quantum`` must make
    the decisions the sequential ``handle`` loop makes, request for
    request.  Routes are drawn as prefixes of one pool order, the
    regime where leg-round batching provably replays the sequential
    interleaving."""

    def _build(self, seed):
        rng = random.Random(seed)
        mgr = PoolManager([
            mkpool("a", tps=rng.choice([300.0, 600.0]),
                   slots=rng.choice([2.0, 4.0])),
            mkpool("b", tps=600.0, slots=4.0),
            mkpool("c", tps=1000.0, slots=8.0),
        ])
        classes = [ServiceClass.GUARANTEED, ServiceClass.ELASTIC,
                   ServiceClass.SPOT]
        gw = Gateway(mgr)
        for k in range(4):
            klass = classes[k % 3]
            depth = rng.randint(1, 3)
            route = []
            for pname in ["a", "b", "c"][:depth]:
                ename = f"t{k}@{pname}"
                mgr.pool(pname).add_entitlement(ent(
                    ename, pname, klass=klass,
                    tps=rng.choice([80.0, 200.0]),
                    conc=rng.choice([1.0, 2.0]),
                    slo=rng.choice([250.0, 1000.0, 30000.0])))
                if klass is ServiceClass.SPOT:
                    mgr.pool(pname).ledger.set_rate(ename, 200.0, 0.0)
                    mgr.pool(pname).ledger.bucket(ename).level = 200.0
                route.append((pname, ename))
            gw.register_route(f"k{k}", route)
        reqs = [QuantumRequest(api_key=f"k{rng.randint(0, 4)}"
                               if rng.random() < 0.9 else "nokey",
                               request_id=f"r{i}",
                               input_tokens=rng.choice([16, 48]),
                               max_tokens=rng.choice([None, 32, 96]))
                for i in range(24)]
        return gw, reqs

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
    def test_decision_identical(self, seed):
        gw_q, reqs = self._build(seed)
        gw_s, _ = self._build(seed)            # identical fresh state

        quantum = gw_q.handle_quantum(reqs, now=0.0)
        scalar = [gw_s.handle(q.api_key, q.request_id, q.input_tokens,
                              q.max_tokens, now=0.0) for q in reqs]
        assert [_resp_key(r) for r in quantum] == \
            [_resp_key(r) for r in scalar]
        for rq, rs in zip(quantum, scalar):
            assert rq.priority == pytest.approx(rs.priority, rel=1e-5)
        # bookkeeping converges too: same in-flight sets per pool
        for pname in ["a", "b", "c"]:
            assert (sorted(gw_q.manager.pool(pname).in_flight)
                    == sorted(gw_s.manager.pool(pname).in_flight))
            # and the same bucket levels (charges identical)
            pq, ps = gw_q.manager.pool(pname), gw_s.manager.pool(pname)
            for ename, bucket in pq.ledger._buckets.items():
                assert bucket.level == pytest.approx(
                    ps.ledger.bucket(ename).level)

    def test_counters_match_scalar(self, ):
        gw_q, reqs = self._build(7)
        gw_s, _ = self._build(7)
        gw_q.handle_quantum(reqs, now=0.0)
        for q in reqs:
            gw_s.handle(q.api_key, q.request_id, q.input_tokens,
                        q.max_tokens, now=0.0)
        keys = set(gw_q.store.keys()) | set(gw_s.store.keys())
        for key in keys:
            if key.startswith(("admits:", "denials:", "spills:",
                               "unroutable:")):
                assert gw_q.store.get(key) == gw_s.store.get(key), key


class TestQuantumPath:
    def test_empty_quantum(self):
        mgr = PoolManager([mkpool("a")])
        assert Gateway(mgr).handle_quantum([], now=0.0) == []

    def test_unknown_key_401(self):
        mgr = PoolManager([mkpool("a")])
        gw = Gateway(mgr)
        [r] = gw.handle_quantum(
            [QuantumRequest("nope", "r1", 16, 16)], now=0.0)
        assert r.status == 401 and r.reason == "unknown_key"

    def test_per_leg_default_max_tokens(self):
        """A request omitting max_tokens must be charged each LEG'S own
        pool default — pool a's large default exhausts its budget, pool
        b's small default fits."""
        mgr = PoolManager([
            mkpool("a", default_max_tokens=512, tps=100.0),
            mkpool("b", default_max_tokens=32, tps=100.0),
        ])
        mgr.pool("a").add_entitlement(ent("e@a", "a", tps=100.0))
        mgr.pool("b").add_entitlement(ent("e@b", "b", tps=100.0))
        gw = Gateway(mgr)
        gw.register_route("k", [("a", "e@a"), ("b", "e@b")])
        [r] = gw.handle_quantum(
            [QuantumRequest("k", "r1", 16, None)], now=0.0)
        assert (r.status, r.pool, r.spill_hops) == (200, "b", 1)
        # charged 16 + 32 on b (not 16 + 512, not a's default)
        assert mgr.pool("b").ledger.bucket("e@b").level == \
            pytest.approx(100.0 - 48.0)

    def test_spill_reenters_next_leg_in_order(self):
        """Requests denied on the preferred pool re-enter the next
        leg's batch ahead of nothing — arrival order is preserved
        within the spill batch."""
        mgr = PoolManager([mkpool("a", tps=100.0), mkpool("b", tps=150.0)])
        mgr.pool("a").add_entitlement(ent("e@a", "a", tps=100.0))
        mgr.pool("b").add_entitlement(ent("e@b", "b", tps=150.0))
        gw = Gateway(mgr)
        gw.register_route("k", [("a", "e@a"), ("b", "e@b")])
        # each request charges 96; a affords one, b affords one more
        resps = gw.handle_quantum(
            [QuantumRequest("k", f"r{i}", 32, 64) for i in range(3)],
            now=0.0)
        assert [(r.status, r.pool) for r in resps] == \
            [(200, "a"), (200, "b"), (429, None)]
        assert resps[1].spill_hops == 1
        assert resps[2].reason == "token_budget"
        assert resps[2].retry_after_s > 0


class TestSpillOrdering:
    def test_mixed_skip_and_deny_spills_keep_arrival_order(self):
        """A leg naming a missing entitlement (espec-miss skip) and a
        kernel denial spill out of round 0 by different code paths —
        the next round's batch must still replay in ARRIVAL order, or
        pool b would give r2's budget to r1."""
        mgr = PoolManager([mkpool("a", tps=1000.0), mkpool("b", tps=150.0)])
        mgr.pool("a").add_entitlement(ent("e1@a", "a", tps=30.0))
        # e2@a is routed but never created on pool a → espec-miss skip
        mgr.pool("b").add_entitlement(ent("e@b", "b", tps=150.0))
        gw = Gateway(mgr)
        gw.register_route("k1", [("a", "e1@a"), ("b", "e@b")])
        gw.register_route("k2", [("a", "e2@a"), ("b", "e@b")])
        # r1 (kernel budget denial on a) arrives BEFORE r2 (skip on a);
        # b's bucket affords exactly one 96-token charge
        resps = gw.handle_quantum(
            [QuantumRequest("k1", "r1", 32, 64),
             QuantumRequest("k2", "r2", 32, 64)], now=0.0)
        assert [(r.status, r.pool) for r in resps] == \
            [(200, "b"), (429, None)]

        # and the scalar loop agrees
        gw2 = Gateway(PoolManager([mkpool("a", tps=1000.0),
                                   mkpool("b", tps=150.0)]))
        gw2.manager.pool("a").add_entitlement(ent("e1@a", "a", tps=30.0))
        gw2.manager.pool("b").add_entitlement(ent("e@b", "b", tps=150.0))
        gw2.register_route("k1", [("a", "e1@a"), ("b", "e@b")])
        gw2.register_route("k2", [("a", "e2@a"), ("b", "e@b")])
        scalar = [gw2.handle("k1", "r1", 32, 64, now=0.0),
                  gw2.handle("k2", "r2", 32, 64, now=0.0)]
        assert [_resp_key(r) for r in resps] == \
            [_resp_key(r) for r in scalar]


class TestQuantumHeadroomPolicy:
    def test_headroom_reorder_reports_declared_position(self):
        """Under the budget-aware policy the quantum path follows the
        reordered legs but still reports declared-route positions."""
        mgr = PoolManager([mkpool("a", tps=50.0), mkpool("b", tps=1000.0)])
        mgr.pool("a").add_entitlement(ent("e@a", "a", tps=50.0))
        mgr.pool("b").add_entitlement(ent("e@b", "b", tps=500.0))
        gw = Gateway(mgr, spill_policy="headroom")
        gw.register_route("k", [("a", "e@a"), ("b", "e@b")])
        # a's bucket (50) cannot afford 96; headroom ranks b first
        [r] = gw.handle_quantum(
            [QuantumRequest("k", "r1", 32, 64)], now=0.0)
        assert (r.status, r.pool, r.spill_hops) == (200, "b", 1)
        # and a was never charged
        assert mgr.pool("a").ledger.bucket("e@a").level == \
            pytest.approx(50.0)


class TestDenialAttribution:
    """Satellite fix: the denial counter goes to the first leg actually
    TRIED, and spill_hops carries the declared-route position through
    ``route_order`` instead of re-searching."""

    def _gw(self, a_up=True):
        mgr = PoolManager([mkpool("a", tps=100.0), mkpool("b", tps=10.0)])
        mgr.pool("a").add_entitlement(ent("e@a", "a", tps=100.0))
        mgr.pool("b").add_entitlement(ent("e@b", "b", tps=10.0))
        if not a_up:
            mgr.pool("a").set_replicas(0)
        gw = Gateway(mgr)
        gw.register_route("k", [("a", "e@a"), ("b", "e@b")])
        return gw

    @pytest.mark.parametrize("batched", [False, True])
    def test_denial_attributed_to_first_tried_leg(self, batched):
        """With the preferred leg UNAVAILABLE, a denial on the spill
        target must be charged to the spill target — the old code
        charged route[0], a pool that never saw the request."""
        gw = self._gw(a_up=False)
        if batched:
            [r] = gw.handle_quantum(
                [QuantumRequest("k", "r1", 32, 64)], now=0.0)
        else:
            r = gw.handle("k", "r1", 32, 64, now=0.0)
        assert r.status == 429 and r.reason == "token_budget"
        assert gw.store.get("denials:e@b") == 1.0
        assert gw.store.get("denials:e@a") is None     # never tried

    @pytest.mark.parametrize("batched", [False, True])
    def test_unroutable_key_not_charged_to_any_leg(self, batched):
        gw = self._gw()
        gw.manager.pool("a").set_replicas(0)
        gw.manager.pool("b").set_replicas(0)
        if batched:
            [r] = gw.handle_quantum(
                [QuantumRequest("k", "r1", 32, 64)], now=0.0)
        else:
            r = gw.handle("k", "r1", 32, 64, now=0.0)
        assert r.status == 429 and r.reason == "pool_unavailable"
        assert gw.store.get("unroutable:k") == 1.0
        assert gw.store.keys("denials:") == []

    @pytest.mark.parametrize("batched", [False, True])
    def test_spill_hops_is_declared_position(self, batched):
        """spill_hops must report the admitting leg's position in the
        DECLARED route even when a route repeats a leg before it."""
        mgr = PoolManager([mkpool("a", tps=10.0), mkpool("b", tps=150.0)])
        mgr.pool("a").add_entitlement(ent("e@a", "a", tps=10.0))
        mgr.pool("b").add_entitlement(ent("e@b", "b", tps=150.0))
        gw = Gateway(mgr)
        # leg (a, e@a) is declared twice ahead of the admitting leg
        gw.register_route("k", [RouteEntry("a", "e@a"),
                                RouteEntry("a", "e@a"),
                                RouteEntry("b", "e@b")])
        if batched:
            [r] = gw.handle_quantum(
                [QuantumRequest("k", "r1", 32, 64)], now=0.0)
        else:
            r = gw.handle("k", "r1", 32, 64, now=0.0)
        assert r.status == 200 and r.pool == "b"
        assert r.spill_hops == 2


class TestFastPathParity:
    """All-single-leg route sets take ``Gateway._quantum_fast``; its
    decisions, counters, bucket levels, and in-flight sets must match
    the generic leg-round loop exactly (integer token values keep the
    f64 bookkeeping bit-exact)."""

    def _build(self, seed, fast):
        rng = random.Random(seed)
        mgr = PoolManager([
            mkpool("a", tps=rng.choice([300.0, 600.0]),
                   slots=rng.choice([2.0, 4.0])),
            mkpool("b", tps=600.0, slots=4.0),
        ])
        classes = [ServiceClass.GUARANTEED, ServiceClass.ELASTIC,
                   ServiceClass.SPOT]
        gw = Gateway(mgr)
        if not fast:
            # force the generic leg-round loop
            gw._quantum_fast = lambda requests, now: None
        for k in range(5):
            klass = classes[k % 3]
            pname = rng.choice(["a", "b"])
            ename = f"t{k}@{pname}"
            mgr.pool(pname).add_entitlement(ent(
                ename, pname, klass=klass,
                tps=rng.choice([80.0, 200.0]),
                conc=rng.choice([1.0, 2.0]),
                slo=rng.choice([250.0, 1000.0, 30000.0])))
            if klass is ServiceClass.SPOT:
                mgr.pool(pname).ledger.set_rate(ename, 200.0, 0.0)
                mgr.pool(pname).ledger.bucket(ename).level = 200.0
            gw.register_route(f"k{k}", [(pname, ename)])
        # a leg naming an entitlement the pool never heard of
        # (espec-miss → terminal NOT_BOUND), and a route whose only
        # pool does not exist (→ POOL_UNAVAILABLE + unroutable)
        gw.register_route("kmiss", [("a", "ghost")])
        gw.register_route("kdead", [("zpool", "ez")])
        keys = [f"k{i}" for i in range(5)] + ["kmiss", "kdead", "nokey"]
        reqs = [QuantumRequest(api_key=rng.choice(keys),
                               request_id=f"r{i}",
                               input_tokens=rng.choice([16, 48]),
                               max_tokens=rng.choice([None, 32, 96]))
                for i in range(32)]
        return gw, reqs

    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6])
    def test_fast_matches_generic(self, seed):
        gw_f, reqs = self._build(seed, fast=True)
        gw_g, _ = self._build(seed, fast=False)

        fast = gw_f.handle_quantum(reqs, now=0.0)
        generic = gw_g.handle_quantum(reqs, now=0.0)
        for rf, rg in zip(fast, generic):
            assert _resp_key(rf) == _resp_key(rg)
            assert rf.request_id == rg.request_id
            assert rf.retry_after_s == rg.retry_after_s
            assert rf.priority == rg.priority
        for pname in ["a", "b"]:
            pf, pg = gw_f.manager.pool(pname), gw_g.manager.pool(pname)
            assert sorted(pf.in_flight) == sorted(pg.in_flight)
            assert set(pf.ledger._buckets) == set(pg.ledger._buckets)
            for ename, bucket in pf.ledger._buckets.items():
                assert bucket.level == pg.ledger.bucket(ename).level
            assert list(pf.store.col["demand_window"][
                pf.store.live_slots()]) == \
                list(pg.store.col["demand_window"][
                    pg.store.live_slots()])
        keys = set(gw_f.store.keys()) | set(gw_g.store.keys())
        for key in keys:
            if key.startswith(("admits:", "denials:", "spills:",
                               "unroutable:")):
                assert gw_f.store.get(key) == gw_g.store.get(key), key

    def test_multi_leg_routes_bail_to_generic(self):
        """A single multi-leg key must disable the fast path for the
        whole quantum — and leave no partial state behind."""
        mgr = PoolManager([mkpool("a", tps=10.0), mkpool("b")])
        mgr.pool("a").add_entitlement(ent("e@a", "a", tps=10.0))
        mgr.pool("b").add_entitlement(ent("e@b", "b"))
        gw = Gateway(mgr)
        gw.register_route("k", [("a", "e@a"), ("b", "e@b")])
        assert gw._quantum_fast(
            [QuantumRequest("k", "r1", 32, 64),
             QuantumRequest("k", "r2", 32, 64)], 0.0) is None
        # nothing admitted / counted by the aborted fast attempt
        assert gw.store.keys("admits:") == []
        assert not mgr.pool("a").in_flight and not mgr.pool("b").in_flight
        # the full quantum still works end to end (generic path)
        resps = gw.handle_quantum(
            [QuantumRequest("k", "r1", 32, 64),
             QuantumRequest("k", "r2", 32, 64)], now=0.0)
        assert [r.status for r in resps] == [200, 200]
