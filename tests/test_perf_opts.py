"""§Perf optimizations are exact rewrites — pinned against the
reference paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    _mask_bias,
    attend,
    attend_blocked,
    decode_attention,
    init_attention,
    init_kv_cache,
)
from repro.configs import get_config


class TestBlockedAttention:
    @pytest.mark.parametrize("causal,window,cap", [
        (True, None, None), (True, 64, None), (True, None, 50.0),
        (False, None, None)])
    def test_matches_full(self, causal, window, cap):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        B, H, S, dh = 2, 4, 300, 32
        q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, H, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, H, dh), jnp.float32)
        pos = jnp.arange(S)
        blk = attend_blocked(q, k, v, pos, causal, window, cap,
                             block_k=128)
        full = attend(q, k, v, _mask_bias(pos, pos, causal, window), cap)
        np.testing.assert_allclose(np.asarray(blk), np.asarray(full),
                                   rtol=2e-5, atol=2e-5)

    def test_nondivisible_block(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 100, 2, 16), jnp.float32)
        k = jax.random.normal(ks[1], (1, 100, 2, 16), jnp.float32)
        v = jax.random.normal(ks[2], (1, 100, 2, 16), jnp.float32)
        pos = jnp.arange(100)
        blk = attend_blocked(q, k, v, pos, True, None, None, block_k=64)
        full = attend(q, k, v, _mask_bias(pos, pos, True, None), None)
        np.testing.assert_allclose(np.asarray(blk), np.asarray(full),
                                   rtol=2e-5, atol=2e-5)


class TestOneHotCacheUpdate:
    @pytest.mark.parametrize("kind", ["global", "local"])
    def test_matches_scatter_update(self, kind):
        cfg = get_config("gemma2-2b").reduced(window_size=16)
        params = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
        B, S = 3, 32
        cache = init_kv_cache(B, S, cfg, jnp.float32, kind)
        # pre-populate with history
        cache = jax.tree.map(
            lambda a: jax.random.normal(jax.random.PRNGKey(9), a.shape),
            cache)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model),
                              jnp.float32)
        cur = jnp.asarray([5, 9, 13], jnp.int32)
        out_ref, cache_ref = decode_attention(params, x, cfg, kind,
                                              cache, cur,
                                              onehot_update=False)
        out_oh, cache_oh = decode_attention(params, x, cfg, kind,
                                            cache, cur,
                                            onehot_update=True)
        np.testing.assert_allclose(np.asarray(out_oh),
                                   np.asarray(out_ref),
                                   rtol=1e-5, atol=1e-5)
        for key in ("k", "v"):
            np.testing.assert_allclose(np.asarray(cache_oh[key]),
                                       np.asarray(cache_ref[key]),
                                       rtol=1e-6, atol=1e-6)
