"""Telemetry plane (``repro.telemetry``): batch-recorder == scalar-
oracle parity for histograms/counters, flight-recorder wraparound and
``explain()`` == ``GatewayResponse`` parity sweeps on the scalar AND
quantum gateway paths, a no-retrace pin with telemetry on, the
StateStore TTL regression, ``pool.stats()``-as-registry-view, SLO
attainment math, exporter well-formedness (Prometheus text + Chrome
trace JSON), and the ``telemetry-hot-path`` sanitizer pass."""
import json
import random
import re
import textwrap

import numpy as np
import pytest

from repro.analysis import analyze
from repro.core import (
    EntitlementSpec,
    PoolManager,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    StateStore,
    TokenPool,
)
from repro.core.control_plane import TRACE_COUNTS
from repro.gateway import Gateway, QuantumRequest
from repro.telemetry import (
    FlightRecorder,
    MetricsRegistry,
    Telemetry,
    prometheus_text,
)
from repro.telemetry import flight as fl

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# registry: batch row-ops == scalar oracles
# ---------------------------------------------------------------------------

def _hist_pair(n_series=5, lo=1e-3, hi=1e3, buckets=24):
    a = MetricsRegistry().histogram("h", labels=("s",), lo=lo, hi=hi,
                                    buckets=buckets)
    b = MetricsRegistry().histogram("h", labels=("s",), lo=lo, hi=hi,
                                    buckets=buckets)
    for i in range(n_series):
        assert a.series((f"s{i}",)) == b.series((f"s{i}",))
    return a, b


def _assert_hist_equal(a, b):
    np.testing.assert_array_equal(a.counts, b.counts)
    np.testing.assert_allclose(a.sums, b.sums, rtol=1e-12)
    np.testing.assert_array_equal(a.totals, b.totals)


class TestHistogramParity:
    def test_random_batches_match_scalar_oracle(self):
        rng = np.random.RandomState(7)
        batched, oracle = _hist_pair()
        for _ in range(50):
            m = rng.randint(0, 40)
            # span under-range, in-range, over-range and exact edges
            vals = rng.choice(
                [1e-5, 1e-3, 0.37, 42.0, 999.0, 1e3, 5e6],
                size=m) * rng.uniform(0.5, 2.0, size=m)
            sids = rng.randint(0, 5, size=m)
            batched.observe_rows(vals, sids)
            for v, s in zip(vals, sids):
                oracle.observe(int(s), float(v))
            _assert_hist_equal(batched, oracle)

    def test_edge_values_land_consistently(self):
        batched, oracle = _hist_pair()
        edges = batched.edges
        vals = np.concatenate([edges, edges * (1 + 1e-12), [0.0]])
        sids = np.zeros(len(vals), np.int64)
        batched.observe_rows(vals, sids)
        for v in vals:
            oracle.observe(0, float(v))
        _assert_hist_equal(batched, oracle)

    def test_quantile_bounds(self):
        h = MetricsRegistry().histogram("h", lo=0.01, hi=10.0)
        sid = h.series(())
        assert h.quantile(sid, 0.99) == 0.0           # empty
        h.observe_rows(np.full(100, 0.5), np.full(100, sid))
        q = h.quantile(sid, 0.5)
        # bucket-interpolated: within the bucket containing 0.5
        b = int(np.searchsorted(h.edges, 0.5))
        lo_edge = h.edges[b - 1] if b else 0.0
        assert lo_edge <= q <= h.edges[b]
        h.observe(sid, 1e9)                            # overflow clamps
        assert h.quantile(sid, 1.0) == pytest.approx(float(h.edges[-1]))


class TestCounterGauge:
    def test_inc_rows_matches_scalar(self):
        rng = np.random.RandomState(3)
        a = MetricsRegistry().counter("c", labels=("s",))
        b = MetricsRegistry().counter("c", labels=("s",))
        for i in range(4):
            a.series((f"s{i}",)), b.series((f"s{i}",))
        for _ in range(30):
            m = rng.randint(0, 20)
            sids = rng.randint(0, 4, size=m)
            by = rng.uniform(0, 5, size=m)
            a.inc_rows(sids, by)
            for s, v in zip(sids, by):
                b.inc(int(s), float(v))
        np.testing.assert_allclose(a.values, b.values, rtol=1e-12)

    def test_counters_reject_negative(self):
        c = MetricsRegistry().counter("c")
        sid = c.series(())
        with pytest.raises(ValueError):
            c.inc(sid, -1.0)
        with pytest.raises(ValueError):
            c.inc_rows(np.array([sid]), np.array([-0.5]))
        c.inc_rows(np.array([], np.int64), np.array([]))  # empty ok

    def test_gauge_callback_binding(self):
        g = MetricsRegistry().gauge("g", labels=("p",))
        state = {"v": 1.0}
        sid = g.bind(("x",), lambda: state["v"])
        assert g.read(sid) == 1.0
        state["v"] = 7.5
        assert g.read(sid) == 7.5                     # live view

    def test_kind_conflict(self):
        r = MetricsRegistry()
        r.counter("m")
        with pytest.raises(TypeError):
            r.gauge("m")


if HAVE_HYPOTHESIS:

    class TestHistogramParityHypothesis:
        @given(data=st.data())
        @settings(max_examples=25, deadline=None, derandomize=True)
        def test_observe_rows_matches_oracle(self, data):
            batched, oracle = _hist_pair(n_series=3)
            batches = data.draw(st.lists(
                st.lists(st.tuples(
                    st.floats(min_value=0.0, max_value=1e6,
                              allow_nan=False),
                    st.integers(min_value=0, max_value=2)),
                    max_size=20),
                max_size=8))
            for batch in batches:
                if batch:
                    vals = np.array([v for v, _ in batch])
                    sids = np.array([s for _, s in batch])
                    batched.observe_rows(vals, sids)
                    for v, s in batch:
                        oracle.observe(s, v)
            _assert_hist_equal(batched, oracle)


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

class TestFlightRecorder:
    def _record_n(self, rec, n, start=0):
        for k in range(start, start + n):
            rec.record(f"r{k}", float(k), "p", 0, k % 4,
                       fl.VERDICT_ADMIT if k % 2 else fl.VERDICT_DENY,
                       0 if k % 2 else 3, 1.0, 0.5, 10.0, 0.1, 0.2,
                       100.0)

    def test_wraparound(self):
        rec = FlightRecorder(capacity=8)
        self._record_n(rec, 20)
        assert rec.head == 20
        assert len(rec) == 8
        # only the 8 newest survive; older rids are evicted
        assert rec.explain("r5") is None
        tr = rec.explain("r19")
        assert tr is not None and tr.legs[0].seq == 20
        recent = rec.recent(n=100)
        assert [r.seq for r in recent] == list(range(20, 12, -1))

    def test_batch_matches_scalar_rings(self):
        rng = np.random.RandomState(11)
        a = FlightRecorder(capacity=16)
        b = FlightRecorder(capacity=16)
        assert a.pool_id("p") == b.pool_id("p")
        total = 0
        for _ in range(10):
            m = int(rng.randint(0, 12))
            rids = [f"q{total + k}" for k in range(m)]
            rows = rng.randint(-1, 6, size=m)
            verd = rng.randint(0, 2, size=m).astype(np.int16)
            reas = rng.randint(0, 5, size=m).astype(np.int16)
            prio = rng.uniform(0, 5, size=m)
            a.record_batch(rids, 1.5, 0, 0, rows, verd,
                           reas, prio, 0.9, 3.0, 0.1, 0.2, 64.0)
            for k in range(m):
                b.record(rids[k], 1.5, "p", 0, int(rows[k]),
                         int(verd[k]), int(reas[k]), float(prio[k]),
                         0.9, 3.0, 0.1, 0.2, 64.0)
            total += m
        assert a.head == b.head
        a._materialize(), b._materialize()   # rid hashes are lazy
        for name in a.col:
            np.testing.assert_array_equal(a.col[name], b.col[name],
                                          err_msg=name)

    def test_oversize_batch_keeps_tail(self):
        rec = FlightRecorder(capacity=4)
        rids = [f"r{k}" for k in range(10)]
        rec.record_batch(rids, 0.0, -1,
                         np.arange(10), -1,
                         np.zeros(10, np.int16), np.zeros(10, np.int16),
                         0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
        assert rec.head == 10
        assert rec.explain("r0") is None
        assert rec.explain("r9").legs[0].leg == 9

    def test_filters(self):
        rec = FlightRecorder(capacity=32)
        self._record_n(rec, 10)
        denies = rec.recent(verdict=fl.VERDICT_DENY)
        assert denies and all(
            r.verdict == fl.VERDICT_DENY for r in denies)
        assert rec.recent(pool="nope") == []


# ---------------------------------------------------------------------------
# explain() == GatewayResponse parity (scalar + quantum paths)
# ---------------------------------------------------------------------------

def mkpool(name, tps=1000.0, slots=4.0, default_max_tokens=64):
    return TokenPool(PoolSpec(
        name=name, model="m", scaling=ScalingBounds(1, 1),
        per_replica=Resources(tps, float(1 << 30), slots),
        default_max_tokens=default_max_tokens, bucket_window_s=1.0))


def ent(name, pool, klass=ServiceClass.GUARANTEED, tps=500.0,
        conc=4.0):
    return EntitlementSpec(
        name=name, tenant_id="t", pool=pool,
        qos=QoS(service_class=klass, slo_target_ms=500.0),
        baseline=Resources(tps, 0.0, conc))


def _build_gateway(seed):
    """Multi-pool gateway with prefix routes (the regime where the
    quantum path replays the scalar interleaving exactly)."""
    rng = random.Random(seed)
    mgr = PoolManager([
        mkpool("a", tps=rng.choice([300.0, 600.0]),
               slots=rng.choice([2.0, 4.0])),
        mkpool("b", tps=600.0, slots=4.0),
        mkpool("c", tps=1000.0, slots=8.0),
    ])
    classes = [ServiceClass.GUARANTEED, ServiceClass.ELASTIC,
               ServiceClass.SPOT]
    gw = Gateway(mgr, telemetry=True)
    order = ["a", "b", "c"]
    routes = {}
    for k in range(6):
        depth = rng.randint(1, 3)
        legs = []
        for pname in order[:depth]:
            ename = f"e{k}@{pname}"
            mgr.pool(pname).add_entitlement(
                ent(ename, pname, klass=rng.choice(classes),
                    tps=rng.choice([120.0, 400.0]),
                    conc=rng.choice([1.0, 3.0])))
            legs.append((pname, ename))
        gw.register_route(f"k{k}", legs)
        routes[f"k{k}"] = legs
    return gw, routes, rng


def _requests(rng, n, prefix):
    reqs = []
    for i in range(n):
        key = (f"k{rng.randrange(6)}" if rng.random() > 0.1
               else "unknown")
        reqs.append(QuantumRequest(
            api_key=key, request_id=f"{prefix}{i}",
            input_tokens=rng.choice([16, 64]),
            max_tokens=rng.choice([None, 32])))
    return reqs


def _assert_trace_matches(tel, resp, routes, key):
    tr = tel.flight.explain(resp.request_id)
    assert tr is not None, resp.request_id
    assert tr.status == resp.status
    assert tr.reason == resp.reason
    assert tr.pool == resp.pool
    assert tr.spill_hops == resp.spill_hops
    assert tr.priority == pytest.approx(resp.priority, abs=1e-9)
    # leg order: rows walk the DECLARED route positions in order
    hops = [r.leg for r in tr.legs]
    assert hops == sorted(hops)
    for row in tr.legs:
        if row.pool is not None:
            assert routes[key][row.leg][0] == row.pool


class TestExplainParity:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_quantum_path(self, seed):
        gw, routes, rng = _build_gateway(seed)
        for rep in range(3):
            reqs = _requests(rng, 40, f"q{rep}-")
            resps = gw.handle_quantum(reqs, now=float(rep))
            for q, resp in zip(reqs, resps):
                if q.api_key == "unknown":
                    tr = gw.telemetry.flight.explain(q.request_id)
                    assert tr.status == 401
                    assert tr.reason == "unknown_key"
                else:
                    _assert_trace_matches(gw.telemetry, resp, routes,
                                          q.api_key)

    @pytest.mark.parametrize("seed", [10, 11])
    def test_scalar_path(self, seed):
        gw, routes, rng = _build_gateway(seed)
        for rep in range(2):
            for q in _requests(rng, 30, f"s{rep}-"):
                resp = gw.handle(q.api_key, q.request_id,
                                 q.input_tokens, q.max_tokens,
                                 now=float(rep))
                if q.api_key == "unknown":
                    tr = gw.telemetry.flight.explain(q.request_id)
                    assert tr.status == 401
                else:
                    _assert_trace_matches(gw.telemetry, resp, routes,
                                          q.api_key)

    def test_pool_unavailable_terminal(self):
        pool = mkpool("a")
        gw = Gateway(pool, telemetry=True)
        pool.add_entitlement(ent("e", "a"))
        gw.register_route("k", [("ghost", "e@ghost")])
        # route names only a pool the manager doesn't have → no live
        # leg → POOL_UNAVAILABLE; verify on both paths
        r1 = gw.handle("k", "r1", 8, 8, now=0.0)
        resp = gw.handle_quantum(
            [QuantumRequest("k", "r2", 8, 8),
             QuantumRequest("k", "r3", 8, 8)], now=0.0)
        for r in [r1] + list(resp):
            assert r.status == 429
            assert r.reason == "pool_unavailable"
            tr = gw.telemetry.flight.explain(r.request_id)
            assert tr.status == 429
            assert tr.reason == "pool_unavailable"


class TestNoRetrace:
    def test_telemetry_on_does_not_retrace_admit_quantum(self):
        # fixed batch shape (sizes 5..8 share one pow2 pad bucket);
        # the flight scatter + counter row-ops must stay host-side
        pool = mkpool("p", tps=10_000.0, slots=64.0)
        gw = Gateway(pool, telemetry=True)
        for i in range(3):
            pool.add_entitlement(ent(f"e{i}", "p", conc=16.0))
            gw.register_key(f"k{i}", f"e{i}", pool="p")

        def quantum(n, tag, now):
            return gw.handle_quantum(
                [QuantumRequest(f"k{i % 3}", f"{tag}-{i}", 16, 16)
                 for i in range(n)], now=now)

        quantum(8, "warm", 0.0)                   # warm-up compiles
        before = TRACE_COUNTS["admit_quantum"]
        for step, size in enumerate([5, 8, 6, 7], start=1):
            quantum(size, f"n{step}", float(step))
        assert TRACE_COUNTS["admit_quantum"] == before
        assert len(gw.telemetry.flight) > 0       # telemetry did record


# ---------------------------------------------------------------------------
# StateStore: INCRBY preserves TTL (Redis contract)
# ---------------------------------------------------------------------------

class TestStateStoreIncrTTL:
    def test_incr_preserves_ttl(self):
        s = StateStore()
        s.set("hits", 1.0, now=0.0, ttl_s=10.0)
        assert s.incr("hits", 2.0, now=5.0) == 3.0
        assert s.get("hits", now=9.9) == 3.0
        assert s.get("hits", now=10.0) is None    # TTL still enforced

    def test_incr_on_expired_key_restarts(self):
        s = StateStore()
        s.set("hits", 5.0, now=0.0, ttl_s=1.0)
        assert s.incr("hits", 1.0, now=2.0) == 1.0
        assert s.get("hits", now=100.0) == 1.0    # fresh key: no TTL

    def test_incr_bumps_version(self):
        s = StateStore()
        s.set("k", 1.0, now=0.0)
        _, v1 = s.get_versioned("k")
        s.incr("k", 1.0, now=0.0)
        _, v2 = s.get_versioned("k")
        assert v2 == v1 + 1

    def test_incr_many(self):
        s = StateStore()
        s.set("a", 1.0, now=0.0, ttl_s=50.0)
        s.incr_many({"a": 2.0, "b": 3.0}, now=0.0)
        assert s.get("a", now=49.0) == 3.0
        assert s.get("a", now=50.0) is None
        assert s.get("b", now=1e9) == 3.0


# ---------------------------------------------------------------------------
# stats()-as-view + SLO tracking
# ---------------------------------------------------------------------------

class TestRegistryViews:
    def test_pool_stats_is_registry_view(self):
        pool = mkpool("a")
        pool.add_entitlement(ent("e", "a"))
        gw = Gateway(pool, telemetry=True)
        gw.register_key("k", "e")
        gw.handle_quantum(
            [QuantumRequest("k", f"r{i}", 8, 8) for i in range(4)],
            now=0.0)
        g = gw.telemetry.registry.get("repro_pool_in_flight")
        sid = g.series(("a",))
        assert g.read(sid) == pool.stats()["in_flight"] > 0
        g2 = gw.telemetry.registry.get("repro_pool_unknown_settles")
        assert g2.read(g2.series(("a",))) == 0

    def test_slo_attainment(self):
        tel = Telemetry()
        tr = tel.slo
        lats = np.array([0.1, 0.2, 0.4, 2.0])
        tr.observe_rows(lats, np.full(4, 1, np.int64),
                        np.full(4, 0.5))          # guaranteed, 500 ms
        assert tr.attainment("guaranteed") == pytest.approx(0.75)
        assert tr.attainment("spot") == 1.0       # idle tier
        assert 0.05 < tr.p50("guaranteed") < 0.5
        assert tr.p99("guaranteed") > 0.5
        # scalar oracle agrees
        tel2 = Telemetry()
        for v in lats:
            tel2.slo.observe(float(v), 1, 0.5)
        assert tel2.slo.attainment("guaranteed") == pytest.approx(0.75)
        assert tel2.slo.snapshot() == tr.snapshot()


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

_PROM_LINE = re.compile(
    r"^(# (HELP|TYPE) [a-zA-Z_:][a-zA-Z0-9_:]* .*"
    r"|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? -?[0-9.e+inf-]+)$")


class TestExporters:
    def _telemetry_with_traffic(self):
        gw, routes, rng = _build_gateway(5)
        resps = gw.handle_quantum(_requests(rng, 40, "t"), now=0.0)
        gw.on_complete_batch(
            [(r.request_id, 16, 0.05) for r in resps
             if r.status == 200], now=1.0)
        for p in gw.manager.pools.values():
            p.tick(2.0)
        return gw.telemetry

    def test_prometheus_text_parses(self):
        tel = self._telemetry_with_traffic()
        text = tel.prometheus()
        assert text.endswith("\n")
        for line in text.splitlines():
            assert _PROM_LINE.match(line), line

    def test_prometheus_histogram_shape(self):
        tel = self._telemetry_with_traffic()
        text = tel.prometheus()
        # cumulative buckets are monotone and close at +Inf == _count
        buckets = {}
        counts = {}
        for line in text.splitlines():
            m = re.match(
                r'repro_request_latency_seconds_bucket'
                r'\{tier="([^"]+)",le="([^"]+)"\} (\d+)', line)
            if m:
                buckets.setdefault(m.group(1), []).append(
                    int(m.group(3)))
            m = re.match(
                r'repro_request_latency_seconds_count'
                r'\{tier="([^"]+)"\} (\d+)', line)
            if m:
                counts[m.group(1)] = int(m.group(2))
        assert buckets
        for tier, cum in buckets.items():
            assert cum == sorted(cum)
            assert cum[-1] == counts[tier]

    def test_chrome_trace_round_trips(self):
        tel = self._telemetry_with_traffic()
        doc = json.loads(tel.chrome_trace())
        assert doc["displayTimeUnit"] == "ms"
        events = doc["traceEvents"]
        assert events
        names = set()
        for ev in events:
            assert {"name", "ph", "pid", "tid"} <= set(ev)
            if ev["ph"] != "M":
                assert ev["ts"] >= 0.0
            if ev["ph"] == "X":
                assert ev["dur"] >= 0.0
            names.add(ev["name"])
        assert "control_tick" in names
        assert "admit_quantum" in names

    def test_json_snapshot(self):
        tel = self._telemetry_with_traffic()
        snap = tel.snapshot()
        json.dumps(snap)                           # serializable
        assert snap["flight_rows"] > 0
        dec = snap["metrics"]["repro_admission_decisions_total"]
        assert dec["kind"] == "counter"
        assert sum(dec["series"].values()) > 0


# ---------------------------------------------------------------------------
# sanitizer pass: telemetry-hot-path
# ---------------------------------------------------------------------------

def _run_pass(tmp_path, src):
    from repro.analysis import Manifest
    src = textwrap.dedent(src)
    p = tmp_path / "repro" / "core" / "mod.py"
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(src)
    report = analyze([str(p)], manifest=Manifest.from_exports([]),
                     rules=["telemetry-hot-path"])
    return report, src


class TestTelemetryHotPathPass:
    VIOLATING = """
    from repro.core.markers import hot_path

    class Gw:
        @hot_path
        def admit(self, batch, now):
            for ent in batch:
                self.store.incr(f"admits:{ent}", 1.0, now)
            self.hist.observe(0, 0.5)

        def cold(self, now):
            self.store.incr("fine-here", 1.0, now)
    """

    CLEAN = """
    from repro.core.markers import hot_path

    class Gw:
        @hot_path
        def admit(self, sids, vals, now):
            self.hist.observe_rows(vals, sids)
            self.count.inc_rows(sids, 1.0)
            self.flight.record_batch(sids, now)
            self.store.incr_many({"admits:a": 2.0}, now)

        def oracle(self, now):
            self.hist.observe(0, 0.5)
            self.store.incr("admits:a", 1.0, now)
    """

    def test_violating(self, tmp_path):
        report, src = _run_pass(tmp_path, self.VIOLATING)
        assert [f.rule for f in report.unwaived] \
            == ["telemetry-hot-path"] * 2
        lines = sorted(f.line for f in report.unwaived)
        exp = sorted([
            next(i for i, ln in enumerate(src.splitlines(), 1)
                 if "store.incr(f" in ln),
            next(i for i, ln in enumerate(src.splitlines(), 1)
                 if "hist.observe(0" in ln)])
        assert lines == exp

    def test_clean(self, tmp_path):
        report, _ = _run_pass(tmp_path, self.CLEAN)
        assert report.unwaived == []

    def test_src_tree_is_clean(self):
        """The shipped tree itself holds the invariant."""
        from pathlib import Path
        from repro.analysis import default_manifest
        repo = Path(__file__).resolve().parent.parent
        files = [str(p) for p in
                 (repo / "src" / "repro").rglob("*.py")]
        report = analyze(files, manifest=default_manifest(),
                         rules=["telemetry-hot-path"])
        assert report.unwaived == []

    def test_flight_columns_in_manifest(self):
        from repro.analysis import default_manifest
        man = default_manifest()
        assert "level_at" in man.f64_columns
        assert "rid_hash" not in man.f64_columns
        stores = {s["store"] for s in man.stores}
        assert "FlightRecorder" in stores
