"""Per-architecture smoke tests: a REDUCED config of each assigned
family runs forward_train / prefill / decode on CPU; output shapes and
finiteness asserted.  Also: prefill→decode consistency against a pure
forward pass (the KV-cache path must reproduce the no-cache path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_model

ARCHS = list(ASSIGNED) + ["qwen3-8b"]


def _batch_inputs(cfg, rng, B=2, S=16):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    extra = None
    if cfg.num_vision_tokens:
        extra = jax.random.normal(
            rng, (B, cfg.num_vision_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        extra = jax.random.normal(rng, (B, 24, cfg.d_model), jnp.float32)
    return tokens, extra


@pytest.fixture(scope="module")
def built():
    """Build each reduced model + params once per module."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_train_shapes_and_finite(arch, built):
    cfg, model, params = built(arch)
    tokens, extra = _batch_inputs(cfg, jax.random.PRNGKey(1))
    logits = model.forward_train(params, tokens, extra_embed=extra)
    B, S = tokens.shape
    S_out = S + (cfg.num_vision_tokens if cfg.num_vision_tokens else 0)
    assert logits.shape == (B, S_out, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), \
        f"{arch}: non-finite logits"
    # padded vocab ids masked to -inf-ish
    if cfg.padded_vocab > cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e8


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch, built):
    cfg, model, params = built(arch)
    tokens, extra = _batch_inputs(cfg, jax.random.PRNGKey(2))
    B, S = tokens.shape
    cache = model.init_cache(B, max_seq=S + 8)
    logits, cache = model.prefill(params, tokens, cache,
                                  extra_embed=extra)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    # decode positions continue after the (possibly prefixed) prompt
    pos0 = S + (cfg.num_vision_tokens or 0) if not cfg.is_encoder_decoder \
        else S
    for step in range(2):
        logits, cache = model.decode_step(
            params, nxt[:, None], cache, jnp.int32(pos0 + step))
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), \
            f"{arch}: non-finite decode logits at step {step}"
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-2b",
                                  "recurrentgemma-2b", "xlstm-350m",
                                  "qwen3-moe-30b-a3b"])
def test_decode_matches_forward(arch, built):
    """Teacher-forced decode must reproduce the no-cache forward logits
    (the KV cache/recurrent-state path is exact, not approximate).

    MoE needs ample expert capacity here: capacity dropping depends on
    batch composition, so prefill(6 tokens) and forward(12 tokens) only
    agree when nothing is dropped."""
    if arch == "qwen3-moe-30b-a3b":
        cfg = get_config(arch).reduced(moe_capacity_factor=16.0)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
    else:
        cfg, model, params = built(arch)
    rng = jax.random.PRNGKey(3)
    B, S = 2, 12
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full = model.forward_train(params, tokens)          # (B,S,V)

    Sp = S // 2
    cache = model.init_cache(B, max_seq=S + 4)
    logits_p, cache = model.prefill(params, tokens[:, :Sp], cache)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32),
        np.asarray(full[:, Sp - 1], np.float32), rtol=2e-2, atol=2e-2)
    for i in range(Sp, S):
        logits_d, cache = model.decode_step(
            params, tokens[:, i:i + 1], cache, jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full[:, i], np.float32), rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode diverges at position {i}")


@pytest.mark.parametrize("arch", ["gemma2-2b"])
def test_ring_buffer_matches_full_window(arch, built):
    """Sliding-window ring cache must agree with the dense path when the
    context exceeds the window."""
    cfg0 = get_config(arch)
    cfg = cfg0.reduced(window_size=8, max_seq_len=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(4)
    B, S = 1, 24                     # 3× the window
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full = model.forward_train(params, tokens)
    cache = model.init_cache(B, max_seq=S + 4)
    Sp = 16
    logits_p, cache = model.prefill(params, tokens[:, :Sp], cache)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32),
        np.asarray(full[:, Sp - 1], np.float32), rtol=2e-2, atol=2e-2)
    for i in range(Sp, S):
        logits_d, cache = model.decode_step(
            params, tokens[:, i:i + 1], cache, jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full[:, i], np.float32), rtol=2e-2, atol=2e-2,
            err_msg=f"ring cache diverges at position {i}")


def test_moe_sort_dispatch_matches_dense_reference():
    """With ample capacity, sort-based dispatch == dense oracle."""
    from repro.models import moe as moe_lib
    cfg = get_config("qwen3-moe-30b-a3b").reduced(
        moe_capacity_factor=8.0)     # no drops
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg,
                              jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model),
                          jnp.float32)
    fast = moe_lib.moe_mlp(params, x, cfg)
    ref = moe_lib.moe_dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_are_partial_not_nan():
    from repro.models import moe as moe_lib
    cfg = get_config("qwen3-moe-30b-a3b").reduced(
        moe_capacity_factor=0.25)    # heavy dropping
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    out = moe_lib.moe_mlp(params, x, cfg)
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch, built):
    """One loss+grad step per arch (the training path must differentiate
    through scans, MoE dispatch, associative scans, etc.)."""
    cfg, model, params = built(arch)
    tokens, extra = _batch_inputs(cfg, jax.random.PRNGKey(5), B=2, S=8)

    def loss_fn(p):
        logits = model.forward_train(p, tokens, extra_embed=extra)
        tgt_len = tokens.shape[1]
        logits = logits[:, -tgt_len:, :].astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tokens[..., None],
                                   axis=-1).mean()
        return nll

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all())
               for g in flat), f"{arch}: non-finite grads"
