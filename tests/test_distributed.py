"""Distribution plumbing, run in subprocesses with forced host-device
counts (the main test process must keep seeing ONE device):

- mini dry-run: lower+compile train/prefill/decode on a 2×4 mesh for a
  reduced config of each family (the same code path as the production
  512-chip dry-run);
- sharded train step == single-device train step (numerics);
- elastic checkpoint: save on 8 devices, restore on 4.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-2b",
                                  "qwen3-moe-30b-a3b",
                                  "recurrentgemma-2b", "xlstm-350m",
                                  "whisper-small"])
def test_mini_dryrun_all_kinds(arch):
    """Reduced config × (train, prefill, decode) lowers AND compiles on
    a real 2×4 device mesh with the production sharding rules."""
    out = run_py(f"""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import build_model
        from repro.distributed.sharding import make_plan, param_pspecs, cache_pspecs
        from repro.launch.mesh import make_test_mesh
        from repro.launch.dryrun import build_step, input_specs
        from repro.models.config import ShapeSpec

        cfg = get_config("{arch}").reduced(
            d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
            vocab_size=512, d_ff=0 if get_config("{arch}").d_ff == 0 else 256)
        model = build_model(cfg)
        mesh = make_test_mesh(2, 4)
        for kind, S, B in (("train", 32, 8), ("prefill", 64, 8),
                           ("decode", 64, 8)):
            shape = ShapeSpec("t", S, B, kind)
            plan = make_plan(cfg, mesh, "train" if kind == "train" else "serve")
            specs = input_specs(cfg, shape)
            fn, args, shardings, donate, out_sh = build_step(model, plan, shape, specs)
            with mesh:
                compiled = jax.jit(fn, in_shardings=shardings,
                                   out_shardings=out_sh,
                                   donate_argnums=donate).lower(*args).compile()
            assert compiled.cost_analysis() is not None
            print(kind, "ok")
        print("ALL-OK")
    """)
    assert "ALL-OK" in out


def test_sharded_train_matches_single_device():
    """One train step on the 2×4 mesh must match the unsharded step."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.distributed.sharding import make_plan, param_pspecs
        from repro.launch.mesh import make_test_mesh
        from repro.training.loss import lm_loss
        from repro.training.optimizer import OptimizerConfig, adamw_init, adamw_update

        cfg = get_config("tinyllama-1.1b").reduced(
            d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
            vocab_size=512, d_ff=256)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 512)
        targets = jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, 512)
        ocfg = OptimizerConfig()

        def step(p, o, tok, tgt, rt):
            def loss_fn(pp):
                logits = model.forward_train(pp, tok, rt=rt)
                return lm_loss(logits, tgt)[0]
            loss, grads = jax.value_and_grad(loss_fn)(p)
            p2, o2, _ = adamw_update(p, grads, o, ocfg)
            return loss, p2

        from repro.models import Runtime
        loss_ref, params_ref = jax.jit(
            lambda p, o, a, b: step(p, o, a, b, Runtime()))(
            params, adamw_init(params), tokens, targets)

        mesh = make_test_mesh(2, 4)
        plan = make_plan(cfg, mesh, "train")
        rt = plan.runtime()
        p_spec = param_pspecs(plan, params)
        named = lambda s: jax.sharding.NamedSharding(mesh, s)
        P = jax.sharding.PartitionSpec
        with mesh:
            sharded = jax.jit(
                lambda p, o, a, b: step(p, o, a, b, rt),
                in_shardings=(jax.tree.map(named, p_spec,
                    is_leaf=lambda x: isinstance(x, P)),
                    None, named(P("data", None)), named(P("data", None))))
            loss_sh, params_sh = sharded(params, adamw_init(params),
                                         tokens, targets)
        np.testing.assert_allclose(float(loss_sh), float(loss_ref),
                                   rtol=2e-3)
        flat_r = jax.tree.leaves(params_ref)
        flat_s = jax.tree.leaves(params_sh)
        for a, b in zip(flat_r, flat_s):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=5e-2, atol=5e-3)
        print("MATCH-OK")
    """)
    assert "MATCH-OK" in out


def test_elastic_checkpoint_reshard(tmp_path):
    """Save sharded on 8 devices → restore sharded on 4 (elastic)."""
    ckpt = str(tmp_path)
    run_py(f"""
        import jax, jax.numpy as jnp
        from repro.checkpointing import save
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((8,), ("data",))
        x = jax.device_put(
            jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            NamedSharding(mesh, P("data", None)))
        save({ckpt!r}, 3, {{"w": x}})
        print("SAVED")
    """, devices=8)
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpointing import restore, latest_step
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((4,), ("data",))
        step = latest_step({ckpt!r})
        target = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
        shardings = {{"w": NamedSharding(mesh, P("data", None))}}
        out = restore({ckpt!r}, step, target, shardings)
        assert out["w"].sharding.num_devices == 4
        np.testing.assert_array_equal(
            np.asarray(out["w"]),
            np.arange(64, dtype=np.float32).reshape(8, 8))
        print("RESHARD-OK")
    """, devices=4)
    assert "RESHARD-OK" in out
