"""Eq. (1)-(3) math, pinned to the paper's own §5.3 numbers."""
import math

import pytest

from repro.core import (
    PriorityCoefficients,
    Resources,
    ServiceClass,
    burst_overconsumption,
    burst_update,
    debt_update,
    pool_average_slo,
    priority_breakdown,
    priority_weight,
    service_gap,
)

COEFF = PriorityCoefficients(alpha_slo=2.0, alpha_burst=1.0, alpha_debt=4.0,
                             gamma_debt=0.7)


class TestPaperNumbers:
    """§5.3: with α_slo=2.0 and ℓ̄*=15250 ms, w_copilot≈93.8 and
    w_synth≈20.3 (4.6× gap); reports enters at w≈60 for its 5 s target."""

    AVG = 15250.0

    def test_copilot_weight(self):
        w = priority_weight(ServiceClass.ELASTIC, 500.0, self.AVG,
                            burst=0.0, debt=0.0, coeff=COEFF)
        assert w == pytest.approx(93.8, abs=0.1)

    def test_synth_weight(self):
        w = priority_weight(ServiceClass.ELASTIC, 30000.0, self.AVG,
                            burst=0.0, debt=0.0, coeff=COEFF)
        assert w == pytest.approx(20.3, abs=0.1)

    def test_reports_weight(self):
        w = priority_weight(ServiceClass.ELASTIC, 5000.0, self.AVG,
                            burst=0.0, debt=0.0, coeff=COEFF)
        assert w == pytest.approx(60.0, abs=0.5)

    def test_pool_average_is_paper_value(self):
        # (500 + 30000) / 2 = 15250 — paper's quoted ℓ̄*
        assert pool_average_slo([500.0, 30000.0]) == 15250.0

    def test_priority_gap_is_4_6x(self):
        wc = priority_weight(ServiceClass.ELASTIC, 500.0, self.AVG, 0, 0, COEFF)
        ws = priority_weight(ServiceClass.ELASTIC, 30000.0, self.AVG, 0, 0, COEFF)
        assert wc / ws == pytest.approx(4.6, abs=0.05)

    def test_peak_debt_amplification(self):
        """Paper: at peak debt 0.775, synth's priority rises
        20.3 × (1 + 4.0·0.775) = 83.2, narrowing the gap to 3.9×."""
        ws = priority_weight(ServiceClass.ELASTIC, 30000.0, self.AVG,
                             burst=0.0, debt=0.775, coeff=COEFF)
        assert ws == pytest.approx(83.2, abs=0.5)
        wc = priority_weight(ServiceClass.ELASTIC, 500.0, self.AVG,
                             burst=0.0, debt=0.607, coeff=COEFF)
        assert wc / ws == pytest.approx(3.9, abs=0.2)


class TestEq1Properties:
    def test_class_dominates(self):
        """Multi-order-of-magnitude class gaps dominate other factors
        under normal conditions (paper §3.3): a spot entitlement at its
        best realistic priority (no debt — spot accrues none) never
        outranks a guaranteed one at its worst realistic priority
        (loose SLO 4× pool average, sustained burst b=1)."""
        w_spot_best = priority_weight(ServiceClass.SPOT, 1.0, 1000.0,
                                      0.0, 0.0, COEFF)
        w_guar_worst = priority_weight(ServiceClass.GUARANTEED, 4000.0,
                                       1000.0, 1.0, 0.0, COEFF)
        assert w_guar_worst > w_spot_best

    def test_tighter_slo_higher_priority(self):
        w_tight = priority_weight(ServiceClass.ELASTIC, 100.0, 1000.0, 0, 0, COEFF)
        w_loose = priority_weight(ServiceClass.ELASTIC, 10000.0, 1000.0, 0, 0, COEFF)
        assert w_tight > w_loose

    def test_burst_lowers_priority(self):
        w0 = priority_weight(ServiceClass.SPOT, 1000.0, 1000.0, 0.0, 0, COEFF)
        w1 = priority_weight(ServiceClass.SPOT, 1000.0, 1000.0, 2.0, 0, COEFF)
        assert w1 < w0
        assert w1 == pytest.approx(w0 / 3.0)

    def test_debt_raises_credit_lowers(self):
        w0 = priority_weight(ServiceClass.ELASTIC, 1000.0, 1000.0, 0, 0.0, COEFF)
        w_debt = priority_weight(ServiceClass.ELASTIC, 1000.0, 1000.0, 0, 0.5, COEFF)
        w_cred = priority_weight(ServiceClass.ELASTIC, 1000.0, 1000.0, 0, -0.1, COEFF)
        assert w_debt > w0 > w_cred

    def test_priority_stays_positive(self):
        w = priority_weight(ServiceClass.ELASTIC, 1000.0, 1000.0, 0.0,
                            -10.0, COEFF)
        assert w > 0.0

    def test_breakdown_product(self):
        b = priority_breakdown(ServiceClass.ELASTIC, 500.0, 15250.0,
                               0.3, 0.2, COEFF)
        assert b.weight == pytest.approx(
            b.w_class * b.slo_factor * b.burst_factor * b.debt_factor)


class TestDebtEq2:
    def test_ewma_form(self):
        assert debt_update(0.5, 1.0, 0.7) == pytest.approx(0.65)

    def test_converges_to_constant_gap(self):
        d = 0.0
        for _ in range(60):
            d = debt_update(d, 0.4, 0.7)
        assert d == pytest.approx(0.4, abs=1e-6)

    def test_decay_time_matches_paper(self):
        """Paper: after recovery debt returns near zero 'within
        approximately 50 seconds' with γ_d=0.7 — that's per-tick decay;
        0.7^k < 2% needs k≈11 ticks; with the experiment's ~4–5 s
        effective accounting cadence that's ~50 s.  We check the decay
        constant itself."""
        d = 0.775
        ticks = 0
        while d > 0.02 and ticks < 100:
            d = debt_update(d, 0.0, 0.7)
            ticks += 1
        assert 8 <= ticks <= 14

    def test_gap_sign_conventions(self):
        assert service_gap(5.0, 3.0) > 0          # underserved
        assert service_gap(5.0, 7.0) < 0          # overserved (burst)
        assert service_gap(5.0, 5.0) == 0.0
        assert service_gap(0.0, 3.0) == 0.0       # no baseline → no gap


class TestBurstEq3:
    def test_zero_when_within_baseline(self):
        base = Resources(100.0, 1000.0, 4.0)
        used = Resources(80.0, 900.0, 4.0)
        assert burst_overconsumption(used, base) == 0.0

    def test_additive_across_dimensions(self):
        base = Resources(100.0, 1000.0, 4.0)
        used = Resources(150.0, 2000.0, 6.0)
        # 0.5 + 1.0 + 0.5
        assert burst_overconsumption(used, base) == pytest.approx(2.0)

    def test_zero_baseline_dimension(self):
        base = Resources(0.0, 0.0, 0.0)    # spot
        assert burst_overconsumption(Resources(10.0, 0.0, 0.0), base) == 1.0
        assert burst_overconsumption(Resources.zero(), base) == 0.0

    def test_brief_burst_small_penalty(self):
        b = 0.0
        b = burst_update(b, 3.0, 0.7)      # one bursty tick
        assert b == pytest.approx(0.9)
        for _ in range(10):                # then idle
            b = burst_update(b, 0.0, 0.7)
        assert b < 0.03

    def test_sustained_burst_accumulates(self):
        b = 0.0
        for _ in range(50):
            b = burst_update(b, 1.5, 0.7)
        assert b == pytest.approx(1.5, abs=1e-4)
