"""Serving-simulator invariants + straggler hedging + accounting
conservation (the control plane must never leak tokens or counters)."""
import pytest

from repro.core import ServiceClass
from repro.serving import ServingSimulator, Workload
from repro.serving.request import RequestState


def simple_sim(**kw):
    defaults = dict(replica_slots=8, replica_tps=120.0, n_replicas=1)
    defaults.update(kw)
    return ServingSimulator(
        [Workload(name="g", service_class=ServiceClass.GUARANTEED,
                  slots=4, slo_ms=200.0, rate_rps=1.0),
         Workload(name="s", service_class=ServiceClass.SPOT,
                  slots=4, slo_ms=30000.0, rate_rps=2.0)],
        **defaults)


class TestInvariants:
    def test_counters_never_negative_and_conserved(self):
        sim = simple_sim()
        sim.run(30.0)
        for name, st in sim.pool.status.items():
            assert st.in_flight >= 0
            assert st.resident >= 0
            assert st.denied_total >= st.denied_low_priority >= 0
            reqs = [r for r in sim.requests.values()
                    if r.entitlement == name]
            finished = sum(r.state == RequestState.FINISHED
                           for r in reqs)
            denied = sum(r.state == RequestState.DENIED for r in reqs)
            # conservation: every request is finished, denied, or
            # still in the system
            in_system = len(reqs) - finished - denied
            assert in_system >= 0
            assert st.completed_total == finished
            assert st.denied_total == denied

    def test_resident_bounded_by_slots(self):
        sim = simple_sim()
        sim.run(30.0)
        for p in sim.timeline:
            assert p.running <= p.capacity_slots

    def test_tokens_accounting_matches_completions(self):
        sim = simple_sim()
        sim.run(30.0)
        for name, st in sim.pool.status.items():
            reqs = [r for r in sim.requests.values()
                    if r.entitlement == name
                    and r.state == RequestState.FINISHED]
            expected = sum(r.input_len + r.max_tokens for r in reqs)
            assert st.tokens_total == pytest.approx(expected)

    def test_all_ledger_charges_settled_after_drain(self):
        sim = simple_sim()
        sim.run(60.0)
        # after the arrival window, let the system drain
        for w in sim.workloads.values():
            w.end_s = 0.0
        sim.run(20.0)
        assert sim.pool.pool_in_flight() == len(
            [r for r in sim.requests.values()
             if r.state in (RequestState.QUEUED, RequestState.DECODING,
                            RequestState.PREFILLING)])


class TestHedging:
    def test_hedged_requests_jump_the_queue(self):
        """Straggler mitigation: requests stranded by a replica failure
        (requeued, waiting while the survivor is full) get hedged and
        are served ahead of later arrivals.  Note: under normal load
        admission control itself keeps the queue near-empty — hedging
        only matters in failure transients, which is exactly this test."""
        sim = ServingSimulator(
            [Workload(name="e", service_class=ServiceClass.ELASTIC,
                      slots=16, slo_ms=1000.0, rate_rps=3.0,
                      in_tokens=64, out_tokens=128)],
            replica_slots=8, replica_tps=60.0, n_replicas=2,
            hedge_after_s=1.0)
        sim.at(6.0, "fail_replica", idx=1)     # strand ~8 in-flight
        sim.at(20.0, "recover_replica", idx=1)
        sim.run(45.0)
        hedged = [r for r in sim.requests.values()
                  if getattr(r, "_hedged", False)]
        assert hedged, "hedging never triggered"
        served_hedged = [r for r in hedged if r.first_token_s is not None]
        assert served_hedged, "no hedged request ever served"

    def test_failure_mid_flight_requeues_not_loses(self):
        sim = ServingSimulator(
            [Workload(name="e", service_class=ServiceClass.ELASTIC,
                      slots=8, slo_ms=1000.0, rate_rps=2.0)],
            replica_slots=4, replica_tps=60.0, n_replicas=2)
        sim.at(5.0, "fail_replica", idx=0)
        sim.at(15.0, "recover_replica", idx=0)
        sim.run(40.0)
        lost = [r for r in sim.requests.values()
                if r.state == RequestState.FAILED]
        assert not lost
        # requests that were on the failed replica finished elsewhere
        finished = [r for r in sim.requests.values()
                    if r.state == RequestState.FINISHED]
        assert len(finished) > 0
