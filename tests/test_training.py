"""Training substrate: optimizer math, loss, grad compression (error
feedback), checkpoint save/restore (+elastic reshard), fault-tolerant
train loop with injected crash + bit-exact resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import AsyncCheckpointer, latest_step, restore, save
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.models import build_model
from repro.training import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    lm_loss,
    lr_schedule,
)
from repro.training.grad_compress import (
    CompressorConfig,
    compress_grads,
    compressed_bytes,
    init_error_state,
)
from repro.training.train_loop import TrainConfig, TrainLoop


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
        assert float(lr_schedule(jnp.asarray(0), cfg)) == pytest.approx(0.1)
        assert float(lr_schedule(jnp.asarray(9), cfg)) == pytest.approx(1.0)
        end = float(lr_schedule(jnp.asarray(99), cfg))
        assert end == pytest.approx(0.1, abs=0.02)

    def test_clip(self):
        g = {"a": jnp.full((4,), 3.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(6.0)
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)

    def test_adamw_descends_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                              weight_decay=0.0, grad_clip=100.0)
        st = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, st, _ = adamw_update(params, grads, st, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_no_decay_on_norm_scales(self):
        params = {"layers": {"scale": jnp.ones((4,)),
                             "w_up": jnp.ones((4, 4))}}
        cfg = OptimizerConfig(lr=0.0, weight_decay=1.0, warmup_steps=0)
        st = adamw_init(params)
        zero_g = jax.tree.map(jnp.zeros_like, params)
        new, _, _ = adamw_update(params, zero_g, st, cfg)
        # lr=0 → nothing changes regardless; use lr>0 to see decay applied
        cfg2 = OptimizerConfig(lr=0.1, weight_decay=1.0, warmup_steps=0)
        new2, _, _ = adamw_update(params, zero_g, adamw_init(params), cfg2)
        assert float(new2["layers"]["scale"][0]) == pytest.approx(1.0)
        assert float(new2["layers"]["w_up"][0, 0]) < 1.0


class TestLoss:
    def test_perfect_prediction_low_loss(self):
        V = 16
        targets = jnp.asarray([[1, 2, 3]])
        logits = jax.nn.one_hot(targets, V) * 100.0
        loss, m = lm_loss(logits, targets)
        assert float(loss) < 1e-3
        assert float(m["accuracy"]) == 1.0

    def test_mask_excludes_positions(self):
        V = 16
        targets = jnp.asarray([[1, 2]])
        logits = jnp.zeros((1, 2, V))
        logits = logits.at[0, 0, 1].set(100.0)   # right at pos 0
        logits = logits.at[0, 1, 0].set(100.0)   # wrong at pos 1
        loss_full, _ = lm_loss(logits, targets)
        loss_masked, _ = lm_loss(logits, targets,
                                 mask=jnp.asarray([[1.0, 0.0]]))
        assert float(loss_masked) < float(loss_full)


class TestGradCompression:
    def test_int8_roundtrip_close(self):
        g = {"w": jnp.asarray(np.random.RandomState(0)
                              .randn(256).astype(np.float32))}
        e = init_error_state(g)
        out, e2 = compress_grads(g, e, CompressorConfig(kind="int8"))
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(g["w"]), atol=0.05)

    def test_topk_keeps_largest(self):
        g = {"w": jnp.asarray([0.1, -5.0, 0.2, 4.0])}
        e = init_error_state(g)
        out, _ = compress_grads(g, e, CompressorConfig(kind="topk",
                                                       topk_ratio=0.5))
        w = np.asarray(out["w"])
        assert w[1] == pytest.approx(-5.0) and w[3] == pytest.approx(4.0)
        assert w[0] == 0.0 and w[2] == 0.0

    def test_error_feedback_conservation(self):
        """Error feedback conserves signal: over many steps the
        transmitted total tracks the injected total for EVERY entry
        (including the small one that loses top-k most steps), and the
        residual error stays bounded by the competing magnitude."""
        g = {"w": jnp.asarray([0.1, 1.0])}
        cfg = CompressorConfig(kind="topk", topk_ratio=0.5)   # k=1
        e = init_error_state(g)
        sent = np.zeros(2)
        steps = 200
        for _ in range(steps):
            out, e = compress_grads(g, e, cfg)
            sent += np.asarray(out["w"])
        assert sent[0] == pytest.approx(steps * 0.1, rel=0.25)
        assert sent[1] == pytest.approx(steps * 1.0, rel=0.25)
        assert float(jnp.abs(e["w"]).max()) < 3.0   # bounded residual

    def test_wire_bytes_accounting(self):
        params = {"w": jnp.zeros((1000,))}
        dense = compressed_bytes(params, CompressorConfig("none"))
        topk = compressed_bytes(params, CompressorConfig("topk", 0.01))
        int8 = compressed_bytes(params, CompressorConfig("int8"))
        assert dense == 4000.0
        assert topk == pytest.approx(80.0)
        assert int8 == pytest.approx(1004.0)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.asarray([1, 2], jnp.int32)}}
        save(str(tmp_path), 7, tree)
        assert latest_step(str(tmp_path)) == 7
        out = restore(str(tmp_path), 7, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                      np.asarray(tree["b"]["c"]))

    def test_torn_save_invisible(self, tmp_path):
        tree = {"a": jnp.ones((2,))}
        save(str(tmp_path), 1, tree)
        # simulate a torn save at step 2: directory without COMMIT
        os.makedirs(tmp_path / "step_00000002")
        assert latest_step(str(tmp_path)) == 1

    def test_async_checkpointer(self, tmp_path):
        ck = AsyncCheckpointer(str(tmp_path), keep=2)
        for s in [1, 2, 3]:
            ck.save(s, {"x": jnp.full((4,), float(s))})
        ck.wait()
        assert latest_step(str(tmp_path)) == 3
        # gc keeps only 2
        kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
        assert len(kept) == 2

    def test_shape_mismatch_raises(self, tmp_path):
        save(str(tmp_path), 1, {"a": jnp.ones((2,))})
        with pytest.raises(ValueError):
            restore(str(tmp_path), 1,
                    {"a": jax.ShapeDtypeStruct((3,), jnp.float32)})


class TestTrainLoop:
    def _setup(self, tmp_path=None, compressor="none"):
        cfg = get_config("tinyllama-1.1b").reduced(num_layers=2,
                                                   vocab_size=256)
        model = build_model(cfg)
        data = SyntheticLMData(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
        tcfg = TrainConfig(
            steps=8, checkpoint_every=4,
            checkpoint_dir=str(tmp_path) if tmp_path else None,
            optimizer=OptimizerConfig(lr=1e-2, warmup_steps=2,
                                      total_steps=8),
            compressor=CompressorConfig(kind=compressor, topk_ratio=0.1),
            log_every=1)
        return model, data, tcfg

    def test_loss_decreases(self, tmp_path):
        model, data, tcfg = self._setup()
        loop = TrainLoop(model, data, tcfg)
        logs = loop.run(steps=8)
        assert logs[-1]["loss"] < logs[0]["loss"]
        assert all(l["skipped"] == 0.0 for l in logs)

    def test_crash_and_bitexact_resume(self, tmp_path):
        model, data, tcfg = self._setup(tmp_path)
        # uninterrupted reference run
        ref = TrainLoop(model, data, TrainConfig(
            steps=8, checkpoint_every=100, checkpoint_dir=None,
            optimizer=tcfg.optimizer, log_every=1))
        ref_logs = ref.run(steps=8)

        loop = TrainLoop(model, data, tcfg)
        with pytest.raises(RuntimeError, match="injected crash"):
            loop.run(steps=8, crash_after_step=4)
        assert latest_step(str(tmp_path)) == 4

        # a NEW loop (fresh process semantics) resumes from step 4
        loop2 = TrainLoop(model, data, tcfg)
        assert loop2.start_step == 4
        logs2 = loop2.run(steps=8)
        assert logs2[-1]["step"] == 7
        assert logs2[-1]["loss"] == pytest.approx(
            ref_logs[-1]["loss"], rel=1e-5)

    def test_compressed_training_still_learns(self):
        model, data, tcfg = self._setup(compressor="int8")
        tcfg.steps = 24
        tcfg.optimizer = OptimizerConfig(lr=2e-2, warmup_steps=2,
                                         total_steps=24)
        loop = TrainLoop(model, data, tcfg)
        logs = loop.run(steps=24)
        assert logs[-1]["loss"] < logs[0]["loss"]

    def test_data_shards_partition_global_batch(self):
        data = SyntheticLMData(DataConfig(vocab_size=64, seq_len=8,
                                          global_batch=8))
        full = data.global_batch_at(3)
        parts = [data.shard_at(3, i, 4) for i in range(4)]
        stacked = np.concatenate([p["tokens"] for p in parts])
        np.testing.assert_array_equal(stacked, full["tokens"])
