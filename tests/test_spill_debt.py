"""Per-request cross-pool spill debt (ROADMAP item 4, per-request
half): a request denied on its preferred leg but served by a spill leg
transfers the service-equivalent debt credit from the preferred
entitlement to the serving one on completion
(``PoolManager.transfer_spill_debt``).
"""
import pytest

from repro.core import (
    EntitlementSpec,
    PoolManager,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
)
from repro.gateway import Gateway, QuantumRequest


def mkpool_spec(name, tps=1000.0):
    return PoolSpec(name=name, model="m", scaling=ScalingBounds(1, 1),
                    per_replica=Resources(tps, 1 << 30, 64.0),
                    bucket_window_s=1.0)


def ent(name, pool, klass=ServiceClass.ELASTIC, tps=100.0):
    return EntitlementSpec(
        name=name, tenant_id="tenant", pool=pool,
        qos=QoS(service_class=klass, slo_target_ms=1000.0),
        baseline=Resources(tps, 0.0, 16.0))


def spill_gateway(serving_class=ServiceClass.ELASTIC):
    """Two-pool route for one tenant: preferred leg e@a on pool a
    (bucket drained → TOKEN_BUDGET denial), spill leg e@b on pool b."""
    mgr = PoolManager()
    a = mgr.add_pool(mkpool_spec("a"))
    b = mgr.add_pool(mkpool_spec("b"))
    a.add_entitlement(ent("e@a", "a"))
    b.add_entitlement(ent("e@b", "b", klass=serving_class,
                          tps=(0.0 if serving_class is ServiceClass.SPOT
                               else 100.0)))
    # fund the spill leg generously so leg b always admits
    spill_bucket = b.ledger.ensure("e@b", 1000.0, 0.0)
    spill_bucket.rate_tps = 1000.0
    spill_bucket.level = 1e4
    gw = Gateway(mgr)
    gw.register_route("key", [("a", "e@a"), ("b", "e@b")])
    # drain the preferred bucket so leg a denies on token budget
    bucket = a.ledger.bucket("e@a")
    bucket.level = 0.0
    bucket.rate_tps = 0.0
    # the preferred entitlement has accrued debt (starved tenant)
    a.status["e@a"].debt = 0.5
    return mgr, gw, a, b


class TestSpillDebtTransfer:
    def _expected_delta(self, pool_a, settled, window=1.0, debt=0.5):
        coeff = pool_a.spec.coefficients
        base = 100.0
        gap = min(coeff.gap_clip, settled / (base * window))
        return min((1.0 - coeff.gamma_debt) * gap,
                   debt - coeff.debt_min)

    def test_scalar_path_transfers_debt_on_complete(self):
        mgr, gw, a, b = spill_gateway()
        r = gw.handle("key", "r1", 64, 64, now=0.0)
        assert r.status == 200 and r.pool == "b" and r.spill_hops == 1
        rec = b.in_flight["r1"]
        assert rec.spill_from == ("a", "e@a")
        debt_a0, debt_b0 = a.status["e@a"].debt, b.status["e@b"].debt
        gw.on_complete("r1", 64, latency_s=0.2, now=0.5)
        # settled = 64 input + 64 actual output = 128 tokens over the
        # accounting-interval floor (1 s) against a 100 tok/s baseline:
        # gap clipped to 1.0 → delta = (1 − γ_d)·1.0 = 0.3
        delta = self._expected_delta(a, 128.0)
        assert delta == pytest.approx(0.3, abs=1e-9)
        assert a.status["e@a"].debt == pytest.approx(debt_a0 - delta,
                                                     rel=1e-5)
        assert b.status["e@b"].debt == pytest.approx(debt_b0 + delta,
                                                     rel=1e-5)

    def test_quantum_path_matches_scalar_path(self):
        mgr_s, gw_s, a_s, b_s = spill_gateway()
        mgr_q, gw_q, a_q, b_q = spill_gateway()
        r_s = gw_s.handle("key", "r1", 64, 64, now=0.0)
        [r_q, r_q2] = gw_q.handle_quantum(
            [QuantumRequest("key", "r1", 64, 64),
             QuantumRequest("key", "r2", 64, 64)], now=0.0)
        assert (r_s.status, r_s.pool, r_s.spill_hops) == \
            (r_q.status, r_q.pool, r_q.spill_hops) == (200, "b", 1)
        assert b_q.in_flight["r1"].spill_from == \
            b_s.in_flight["r1"].spill_from == ("a", "e@a")
        gw_s.on_complete("r1", 64, latency_s=0.2, now=0.5)
        gw_q.on_complete("r1", 64, latency_s=0.2, now=0.5)
        assert a_q.status["e@a"].debt == a_s.status["e@a"].debt
        assert b_q.status["e@b"].debt == b_s.status["e@b"].debt

    def test_starved_tenant_debt_drains_over_spilled_stream(self):
        """The headline scenario: a starved tenant whose traffic keeps
        spilling sees its preferred-leg debt DRAIN with every spilled
        completion, while the serving entitlement inherits the boost."""
        mgr, gw, a, b = spill_gateway()
        a.status["e@a"].debt = 1.0
        debts = [a.status["e@a"].debt]
        for i in range(6):
            r = gw.handle("key", f"r{i}", 32, 32, now=float(i))
            assert r.status == 200 and r.spill_hops == 1
            gw.on_complete(f"r{i}", 32, latency_s=0.1, now=float(i) + 0.5)
            debts.append(a.status["e@a"].debt)
        assert all(d1 < d0 for d0, d1 in zip(debts, debts[1:]))
        assert debts[-1] < 0.3                       # drained, not stuck
        assert b.status["e@b"].debt > 0.5            # boost carried over

    def test_no_transfer_when_served_by_preferred_leg(self):
        mgr, gw, a, b = spill_gateway()
        a.ledger.set_rate("e@a", 1000.0, 0.0)        # refund the budget
        a.ledger.bucket("e@a").level = 1000.0
        r = gw.handle("key", "r1", 16, 16, now=0.0)
        assert r.status == 200 and r.pool == "a" and r.spill_hops == 0
        assert a.in_flight["r1"].spill_from is None
        debt0 = a.status["e@a"].debt
        gw.on_complete("r1", 16, latency_s=0.1, now=0.5)
        assert a.status["e@a"].debt == debt0

    def test_spot_serving_leg_drains_source_without_inheriting(self):
        """A spot serving entitlement carries no debt (Table 1): the
        preferred entitlement still drains — it WAS served — but
        nothing is credited to the non-debt-bearing class."""
        mgr, gw, a, b = spill_gateway(serving_class=ServiceClass.SPOT)
        r = gw.handle("key", "r1", 64, 64, now=0.0)
        assert r.status == 200 and r.pool == "b"
        debt_a0 = a.status["e@a"].debt
        gw.on_complete("r1", 64, latency_s=0.2, now=0.5)
        assert a.status["e@a"].debt < debt_a0
        assert b.status["e@b"].debt == 0.0

    def test_transfer_clamped_at_target_debt_max(self):
        mgr, gw, a, b = spill_gateway()
        a.status["e@a"].debt = 1.0
        b.status["e@b"].debt = b.spec.coefficients.debt_max
        debt_a0 = a.status["e@a"].debt
        r = gw.handle("key", "r1", 64, 64, now=0.0)
        assert r.status == 200 and r.pool == "b"
        gw.on_complete("r1", 64, latency_s=0.2, now=0.5)
        # target saturated → nothing moves (conservation, no minting)
        assert a.status["e@a"].debt == debt_a0
        assert b.status["e@b"].debt == b.spec.coefficients.debt_max

    def test_transfer_follows_migrated_preferred_entitlement(self):
        """The preferred leg may have been rebalanced to another pool
        between admission and completion: the drain follows the
        entitlement, not the stale leg."""
        mgr, gw, a, b = spill_gateway()
        c = mgr.add_pool(mkpool_spec("c"))
        r = gw.handle("key", "r1", 64, 64, now=0.0)
        assert r.status == 200 and r.pool == "b"
        mgr.migrate_entitlement("e@a", "a", "c", now=0.1)
        debt0 = c.status["e@a"].debt
        gw.on_complete("r1", 64, latency_s=0.2, now=0.5)
        assert c.status["e@a"].debt < debt0
