"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        softcap: float | None = None) -> jax.Array:
    """q (B,H,S,dh) · k,v (B,H_kv,Sk,dh) → (B,H,S,dh), fp32 softmax."""
    B, H, S, dh = q.shape
    H_kv, Sk = k.shape[1], k.shape[2]
    group = H // H_kv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((S, Sk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask[None, None], s, -2.38e38)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows → zero output (kernel convention)
    any_valid = mask.any(axis=-1)[None, None, :, None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    out = jnp.where(any_valid, out, 0.0)
    return out.astype(q.dtype)
