"""Jitted public wrapper: picks the Pallas kernel on TPU, interpret
mode elsewhere (CPU validation), with layout adaptation from the model
stack's (B, S, H, dh) to the kernel's (B, H, S, dh)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k"))
def flash_attention_bshd(q, k, v, *, causal=True, window=None,
                         softcap=None, block_q=128, block_k=128):
    """Model-layout entry point: q (B,S,H,dh), k/v (B,Sk,H_kv,dh)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          softcap=softcap, block_q=block_q,
                          block_k=block_k, interpret=not _on_tpu())
    return out.transpose(0, 2, 1, 3)
