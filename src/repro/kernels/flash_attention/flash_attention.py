"""Flash attention (prefill) — Pallas TPU kernel.

TPU adaptation of the FlashAttention tiling: the grid's last dimension
iterates K/V blocks *sequentially* while VMEM scratch carries the
running (m, l, acc) online-softmax state — the TPU idiom for the CUDA
kernel's shared-memory loop.  Block shapes are MXU-aligned (q/k blocks
multiples of the 128-lane tile; dh is the contraction minor dim).

Supports: causal masking, sliding windows (gemma2 local layers), logit
soft-capping (gemma2), and GQA via the q-head → kv-head index map
(kv blocks are fetched once per q-head group position — no repeated-KV
materialisation in HBM).

Layouts: q (B, H, S, dh) · k/v (B, H_kv, S, dh) → out (B, H, S, dh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.38e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scratch, l_scratch, acc_scratch,
                  *, block_q: int, block_k: int, n_k: int,
                  causal: bool, window: int | None,
                  softcap: float | None, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scratch[...]                        # (bq, 1)
    l_prev = l_scratch[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (exp(NEG_INF - NEG_INF) would be 1)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2,
                      jnp.exp(m_prev - m_new), 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scratch[...] = (acc_scratch[...] * alpha
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
    m_scratch[...] = m_new
    l_scratch[...] = l_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scratch[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scratch[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q (B,H,S,dh) · k,v (B,H_kv,S,dh) → (B,H,S,dh)."""
    B, H, S, dh = q.shape
    _, H_kv, Sk, _ = k.shape
    assert H % H_kv == 0
    group = H // H_kv
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    assert S % block_q == 0 and Sk % block_k == 0
    n_q, n_k = S // block_q, Sk // block_k
    scale = 1.0 / (dh ** 0.5)

    grid = (B, H, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, n_k=n_k,
        causal=causal, window=window, softcap=softcap, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
