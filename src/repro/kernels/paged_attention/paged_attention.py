"""Paged attention (single-token decode) — Pallas TPU kernel.

vLLM's PagedAttention re-thought for TPU: instead of warp-level pointer
chasing through a block table in L2, the block table rides in scalar
memory (``PrefetchScalarGridSpec``) and *drives the BlockSpec index
maps* — each grid step DMAs exactly one KV page HBM→VMEM while the MXU
consumes the previous one (the pipelined-prefetch TPU idiom).  Pages
are token-major and lane-aligned (page_tokens × dh tiles).

Inputs:
  q            (B, H, dh)           one decode token per sequence
  k_pages      (P, T, H_kv, dh)     the physical page pool
  v_pages      (P, T, H_kv, dh)
  block_tables (B, max_pages) int32 page ids, -1 padded
  context_lens (B,) int32           valid tokens per sequence
Output: (B, H, dh).

Grid (B, H_kv, max_pages): the page dimension iterates sequentially
with (m, l, acc) online-softmax scratch carried in VMEM; the whole
q-head GROUP for one kv head (G = H/H_kv rows) is processed per step so
GQA costs one page fetch for all its q heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.38e38


def _paged_kernel(block_tables_ref, context_lens_ref,   # scalar prefetch
                  q_ref, k_ref, v_ref, o_ref,
                  m_scratch, l_scratch, acc_scratch,
                  *, page_tokens: int, n_pages: int, scale: float,
                  softcap: float | None):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    ctx = context_lens_ref[b]
    page_id = block_tables_ref[b, ip]
    valid_page = page_id >= 0

    q = q_ref[0, 0].astype(jnp.float32)             # (G, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)       # (T, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    tok_pos = ip * page_tokens + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    mask = (tok_pos < ctx) & valid_page
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scratch[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.where(m_prev > NEG_INF / 2,
                      jnp.exp(m_prev - m_new), 0.0)
    l_scratch[...] = alpha * l_scratch[...] + jnp.sum(
        p, axis=1, keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scratch[...] = m_new

    @pl.when(ip == n_pages - 1)
    def _finalize():
        l = jnp.maximum(l_scratch[...], 1e-30)
        o_ref[0, 0] = (acc_scratch[...] / l).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, context_lens: jax.Array, *,
                    softcap: float | None = None,
                    interpret: bool = False) -> jax.Array:
    B, H, dh = q.shape
    P, T, H_kv, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    assert H % H_kv == 0
    G = H // H_kv
    scale = 1.0 / (dh ** 0.5)
    q_grouped = q.reshape(B, H_kv, G, dh)

    grid = (B, H_kv, max_pages)
    kernel = functools.partial(
        _paged_kernel, page_tokens=T, n_pages=max_pages, scale=scale,
        softcap=softcap)

    def q_map(b, h, ip, bt, cl):
        return (b, h, 0, 0)

    def kv_map(b, h, ip, bt, cl):
        # the scalar-prefetched block table drives the page DMA; padded
        # (-1) entries clamp to page 0 and are masked in the kernel
        return (jnp.maximum(bt[b, ip], 0), 0, h, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, dh), q_map),
                pl.BlockSpec((1, T, 1, dh), kv_map),
                pl.BlockSpec((1, T, 1, dh), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, dh), q_map),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H_kv, G, dh), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, q_grouped, k_pages, v_pages)
    return out.reshape(B, H, dh)
