"""Pure-jnp oracle for paged decode attention: gathers pages into a
dense KV per sequence and runs masked softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_paged_attention(q, k_pages, v_pages, block_tables,
                              context_lens, *, softcap=None):
    """q (B,H,dh); pages (P,T,H_kv,dh); tables (B,max_pages);
    lens (B,) → (B,H,dh)."""
    B, H, dh = q.shape
    P, T, H_kv, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    group = H // H_kv

    safe = jnp.maximum(block_tables, 0)              # (B, max_pages)
    k = k_pages[safe]                                # (B,mp,T,H_kv,dh)
    v = v_pages[safe]
    k = k.reshape(B, max_pages * T, H_kv, dh)
    v = v.reshape(B, max_pages * T, H_kv, dh)
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)

    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(max_pages * T)[None, :]
    page_ok = (block_tables >= 0)[:, :, None]        # (B,mp,1)
    page_ok = jnp.broadcast_to(page_ok, (B, max_pages, T)) \
        .reshape(B, max_pages * T)
    mask = (pos < context_lens[:, None]) & page_ok   # (B, K)
    s = jnp.where(mask[:, None, :], s, -2.38e38)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
