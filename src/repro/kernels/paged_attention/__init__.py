from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.paged_attention.paged_attention import paged_attention
from repro.kernels.paged_attention.ref import reference_paged_attention

__all__ = ["paged_attention", "paged_decode_attention",
           "reference_paged_attention"]
