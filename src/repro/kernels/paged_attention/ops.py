"""Jitted wrapper for paged decode attention (TPU kernel / interpret)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.paged_attention import paged_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("softcap",))
def paged_decode_attention(q, k_pages, v_pages, block_tables,
                           context_lens, *, softcap=None):
    return paged_attention(q, k_pages, v_pages, block_tables,
                           context_lens, softcap=softcap,
                           interpret=not _on_tpu())
