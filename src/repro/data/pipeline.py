"""Deterministic synthetic LM data pipeline, shard-aware.

Produces structured pseudo-text (Markov-chain token streams with
repeated n-gram motifs) rather than uniform noise, so a ~100M model
trained for a few hundred steps shows a clearly falling loss — the
end-to-end example's acceptance signal.

Sharding model: the pipeline is *host-local* like a real multi-host
loader — ``shard(host_index, host_count)`` yields only this host's rows
of the global batch, derived from a counter-based PRNG so any host can
deterministically regenerate any step (elastic restart: a resumed job
re-derives batch ``k`` without replaying the stream; straggler
mitigation: a backup host can generate another host's shard).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_motifs: int = 64
    motif_len: int = 12
    branch: int = 4          # Markov branching factor


class SyntheticLMData:
    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        root = np.random.RandomState(cfg.seed)
        # fixed Markov table: each token has `branch` likely successors
        self._next = root.randint(
            0, cfg.vocab_size, size=(cfg.vocab_size, cfg.branch))
        # n-gram motifs injected at random offsets
        self._motifs = root.randint(
            0, cfg.vocab_size, size=(cfg.n_motifs, cfg.motif_len))

    def _gen_row(self, rng: np.random.RandomState) -> np.ndarray:
        cfg = self.cfg
        seq = np.empty(cfg.seq_len + 1, np.int32)
        tok = rng.randint(cfg.vocab_size)
        i = 0
        while i < cfg.seq_len + 1:
            if rng.rand() < 0.1:               # drop in a motif
                m = self._motifs[rng.randint(cfg.n_motifs)]
                take = min(len(m), cfg.seq_len + 1 - i)
                seq[i:i + take] = m[:take]
                i += take
                tok = int(seq[i - 1])
            else:
                tok = int(self._next[tok, rng.randint(cfg.branch)])
                seq[i] = tok
                i += 1
        return seq

    def global_batch_at(self, step: int) -> dict:
        """The full global batch for ``step`` (counter-based, stateless)."""
        cfg = self.cfg
        rows = []
        for b in range(cfg.global_batch):
            rng = np.random.RandomState(
                (cfg.seed * 1_000_003 + step) * 65_537 + b)
            rows.append(self._gen_row(rng))
        arr = np.stack(rows)                   # (B, S+1)
        return {"tokens": arr[:, :-1], "targets": arr[:, 1:]}

    def shard_at(self, step: int, host_index: int, host_count: int) -> dict:
        """This host's rows of the global batch (contiguous row split)."""
        cfg = self.cfg
        assert cfg.global_batch % host_count == 0
        per = cfg.global_batch // host_count
        lo = host_index * per
        rows = []
        for b in range(lo, lo + per):
            rng = np.random.RandomState(
                (cfg.seed * 1_000_003 + step) * 65_537 + b)
            rows.append(self._gen_row(rng))
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "targets": arr[:, 1:]}
