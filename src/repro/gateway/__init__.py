from repro.gateway.gateway import Gateway, GatewayResponse, QuantumRequest

__all__ = ["Gateway", "GatewayResponse", "QuantumRequest"]
