from repro.gateway.gateway import Gateway, GatewayResponse

__all__ = ["Gateway", "GatewayResponse"]
