"""AI Gateway: the admission boundary (paper Fig. 1, LiteLLM role).

Responsibilities (paper §4.3):
  - resolve the inference key to its route (auth): an ordered list of
    (pool, entitlement) legs — one leg is the classic single-pool
    deployment, several legs give dual-pool-style spill-over routing;
  - run the admission pipeline BEFORE the request reaches a backend,
    walking the route until a pool admits (spill-over) or every leg
    has denied;
  - on rejection return 429 + Retry-After (the most optimistic hint
    across the legs that were actually tried);
  - on completion, post actual token consumption back to the auth
    service (the callback that closes admission ↔ execution
    accounting), attributed to whichever pool admitted the request.

State lives in the StateStore (Redis contract): key → route mapping and
per-entitlement counters, so a real deployment can point this class at
an actual Redis.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence, Union

from repro.core import (
    AdmissionController,
    AdmissionRequest,
    DenyReason,
    RouteEntry,
    StateStore,
    TokenPool,
)
from repro.core.pool_manager import PoolOrManager, as_manager


@dataclasses.dataclass(frozen=True)
class GatewayResponse:
    status: int                      # 200 admitted / 401 / 429
    request_id: str
    retry_after_s: Optional[float] = None
    reason: Optional[str] = None
    priority: float = 0.0
    #: pool + entitlement that admitted the request (multi-pool routing)
    pool: Optional[str] = None
    entitlement: Optional[str] = None
    #: position of the admitting leg in the client's declared route
    #: (0 = preferred pool; >0 = request spilled past denied or
    #: unavailable higher-preference legs)
    spill_hops: int = 0


class Gateway:
    def __init__(self, pools: PoolOrManager,
                 store: Optional[StateStore] = None,
                 spill_policy: str = "static") -> None:
        from repro.core.pool_manager import SPILL_POLICIES
        if spill_policy not in SPILL_POLICIES:
            raise ValueError(f"unknown spill policy {spill_policy!r}; "
                             f"expected one of {SPILL_POLICIES}")
        self.manager = as_manager(pools)
        self.store = store or StateStore()
        self.spill_policy = spill_policy
        self.controllers: dict[str, AdmissionController] = {
            name: AdmissionController(pool)
            for name, pool in self.manager.pools.items()}

    # -- back-compat accessors -------------------------------------------------
    @property
    def pool(self) -> TokenPool:
        """The default (first) pool — single-pool callers' view."""
        return self.manager.default_pool()

    @property
    def controller(self) -> AdmissionController:
        return self.controllers[self.pool.spec.name]

    def _controller(self, pool_name: str) -> AdmissionController:
        ctrl = self.controllers.get(pool_name)
        if ctrl is None:
            ctrl = AdmissionController(self.manager.pool(pool_name))
            self.controllers[pool_name] = ctrl
        return ctrl

    # -- key management ---------------------------------------------------------
    def register_key(self, api_key: str, entitlement: str,
                     pool: Optional[str] = None) -> None:
        """Single-leg route (legacy API): key → entitlement on one pool.

        When ``pool`` is omitted the entitlement's OWNING pool is
        looked up, and a miss is an error — silently defaulting to the
        first pool would leave the key permanently 429-ing NOT_BOUND
        on a multi-pool gateway."""
        if pool is None:
            owners = [name for name, p in self.manager.pools.items()
                      if entitlement in p.entitlements]
            if not owners:
                raise ValueError(
                    f"entitlement {entitlement!r} exists in no pool; "
                    "add it before registering a key")
            if len(owners) > 1:
                raise ValueError(
                    f"entitlement {entitlement!r} exists in pools "
                    f"{owners}; pass pool= (or use register_route for "
                    "a multi-pool route)")
            pool = owners[0]
        self.register_route(api_key, [RouteEntry(pool, entitlement)])

    def register_route(self, api_key: str,
                       entries: Sequence[Union[RouteEntry,
                                               tuple[str, str]]]) -> None:
        """Ordered multi-pool route: first leg is the preferred pool,
        later legs are spill-over targets.

        Stored in the StateStore as a JSON string — the store keeps the
        Redis contract (string values), so a real Redis can be swapped
        in behind it."""
        route = tuple(e if isinstance(e, RouteEntry) else RouteEntry(*e)
                      for e in entries)
        if not route:
            raise ValueError("route must have at least one leg")
        self.store.set(f"route:{api_key}", json.dumps(
            [[e.pool, e.entitlement] for e in route]))

    def resolve(self, api_key: str, now: float = 0.0) -> Optional[str]:
        """Entitlement of the preferred leg (legacy single-pool view)."""
        route = self.route(api_key, now)
        return route[0].entitlement if route else None

    def route(self, api_key: str, now: float = 0.0
              ) -> Optional[tuple[RouteEntry, ...]]:
        raw = self.store.get(f"route:{api_key}", now)
        if raw is None:
            return None
        return tuple(RouteEntry(p, e) for p, e in json.loads(raw))

    # -- request path --------------------------------------------------------------
    def handle(self, api_key: str, request_id: str, input_tokens: int,
               max_tokens: Optional[int], now: float,
               kv_bytes_per_token: float = 0.0) -> GatewayResponse:
        route = self.route(api_key, now)
        if not route:
            return GatewayResponse(status=401, request_id=request_id,
                                   reason="unknown_key")
        legs = self.manager.route_order(list(route), input_tokens,
                                        max_tokens, now,
                                        policy=self.spill_policy)
        first_denial = None
        best_retry: Optional[float] = None
        for leg in legs:
            decision = self._controller(leg.pool).decide(AdmissionRequest(
                entitlement=leg.entitlement, input_tokens=input_tokens,
                max_tokens=max_tokens, arrival_s=now,
                request_id=request_id,
                kv_bytes_per_token=kv_bytes_per_token))
            if decision.admitted:
                hop = route.index(leg)
                self.store.incr(f"admits:{leg.entitlement}", 1.0, now)
                if hop > 0:
                    self.store.incr(f"spills:{api_key}", 1.0, now)
                return GatewayResponse(
                    status=200, request_id=request_id,
                    priority=decision.priority, pool=leg.pool,
                    entitlement=leg.entitlement, spill_hops=hop)
            if first_denial is None:
                first_denial = decision
            if decision.retry_after_s is not None:
                best_retry = (decision.retry_after_s if best_retry is None
                              else min(best_retry, decision.retry_after_s))

        # every leg denied (or none was available)
        ent0 = route[0].entitlement
        self.store.incr(f"denials:{ent0}", 1.0, now)
        if first_denial is None:           # no live pool on the route
            return GatewayResponse(
                status=429, request_id=request_id, retry_after_s=5.0,
                reason=DenyReason.POOL_UNAVAILABLE.value)
        return GatewayResponse(
            status=429, request_id=request_id,
            retry_after_s=best_retry,
            reason=(first_denial.reason.value
                    if first_denial.reason else None),
            priority=first_denial.priority)

    # -- completion callback ----------------------------------------------------------
    def on_complete(self, request_id: str, actual_output_tokens: int,
                    latency_s: float, now: float) -> None:
        settled = self.manager.on_complete(request_id,
                                           actual_output_tokens, now)
        if settled is not None:
            _, rec = settled
            self.store.incr(f"tokens:{rec.entitlement}",
                            float(actual_output_tokens), now)
            self.store.set(f"last_latency:{rec.entitlement}", latency_s,
                           now)

    def on_failure(self, request_id: str, now: float) -> None:
        self.manager.on_evict(request_id, now)
