"""AI Gateway: the admission boundary (paper Fig. 1, LiteLLM role).

Responsibilities (paper §4.3):
  - resolve the inference key to its route (auth): an ordered list of
    (pool, entitlement) legs — one leg is the classic single-pool
    deployment, several legs give dual-pool-style spill-over routing;
  - run the admission pipeline BEFORE the request reaches a backend,
    walking the route until a pool admits (spill-over) or every leg
    has denied;
  - on rejection return 429 + Retry-After (the most optimistic hint
    across the legs that were actually tried);
  - on completion, post actual token consumption back to the auth
    service (the callback that closes admission ↔ execution
    accounting), attributed to whichever pool admitted the request.

Two request paths share these semantics:

- :meth:`Gateway.handle` — one request through the scalar §4.3
  pipeline (``AdmissionController.decide``); the per-request fallback
  and the parity oracle for the batched path;
- :meth:`Gateway.handle_quantum` — the DEFAULT hot path at scale: all
  requests of one scheduling quantum are grouped per (pool, leg), each
  pool is snapshotted once, and ONE fused ``admit_quantum`` dispatch
  replays the §4.3 pipeline for the whole group; denials spill into
  the next leg's batch, so routes keep their ``route_order``
  semantics.  Requests are padded to a power-of-two per dispatch so
  quantum-size churn does not retrace the kernel.

State lives in the StateStore (Redis contract): key → route mapping and
per-entitlement counters, so a real deployment can point this class at
an actual Redis.
"""
from __future__ import annotations

import dataclasses
import json
import time
from itertools import chain
from operator import attrgetter
from typing import NamedTuple, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core import (
    AdmissionController,
    AdmissionRequest,
    DenyReason,
    RouteEntry,
    StateStore,
    TokenPool,
)
from repro.core.control_plane import (
    bucket_width,
    pad_rows,
    pad_state,
    quantum_width,
)
from repro.core.markers import hot_path
from repro.core import shard_plane
from repro.core.pool_manager import PoolOrManager, as_manager
from repro.core.vectorized import admit_quantum, quantum_snapshot
from repro.telemetry import flight as flightrec

#: C-speed attribute extractors for the quantum fast path.
_Q_RID = attrgetter("request_id")
_Q_KV = attrgetter("kv_bytes_per_token")

#: ``admit_quantum`` deny-reason codes → gateway deny reasons.
_REASON_CODES = {
    1: DenyReason.NOT_BOUND,
    2: DenyReason.CONCURRENCY,
    3: DenyReason.TOKEN_BUDGET,
    4: DenyReason.LOW_PRIORITY,
}


class GatewayResponse(NamedTuple):
    """Immutable per-request verdict.  A NamedTuple, not a dataclass:
    the quantum hot path constructs one per request, and tuple
    construction is ~3x cheaper than a frozen dataclass's
    ``object.__setattr__`` per field."""

    status: int                      # 200 admitted / 401 / 429
    request_id: str
    retry_after_s: Optional[float] = None
    reason: Optional[str] = None
    priority: float = 0.0
    #: pool + entitlement that admitted the request (multi-pool routing)
    pool: Optional[str] = None
    entitlement: Optional[str] = None
    #: position of the admitting leg in the client's declared route
    #: (0 = preferred pool; >0 = request spilled past denied or
    #: unavailable higher-preference legs)
    spill_hops: int = 0


@dataclasses.dataclass(frozen=True)
class QuantumRequest:
    """One request of a scheduling quantum (``Gateway.handle_quantum``)."""

    api_key: str
    request_id: str
    input_tokens: int
    max_tokens: Optional[int] = None     # None → each leg's pool default
    kv_bytes_per_token: float = 0.0


@dataclasses.dataclass(slots=True)
class _Pending:
    """Per-request routing state while a quantum is in flight."""

    idx: int                             # position in the input quantum
    req: QuantumRequest
    legs: list[tuple[int, RouteEntry]]   # (declared position, leg)
    leg_ptr: int = 0
    first_reason: Optional[DenyReason] = None
    first_priority: float = 0.0
    best_retry: Optional[float] = None

    def current(self) -> tuple[int, RouteEntry]:
        return self.legs[self.leg_ptr]

    def note_denial(self, reason: Optional[DenyReason], priority: float,
                    retry: Optional[float]) -> None:
        if self.first_reason is None:
            self.first_reason = reason
            self.first_priority = priority
        if retry is not None:
            self.best_retry = (retry if self.best_retry is None
                               else min(self.best_retry, retry))


class Gateway:
    def __init__(self, pools: PoolOrManager,
                 store: Optional[StateStore] = None,
                 spill_policy: str = "static",
                 telemetry=None) -> None:
        from repro.core.pool_manager import SPILL_POLICIES
        if spill_policy not in SPILL_POLICIES:
            raise ValueError(f"unknown spill policy {spill_policy!r}; "
                             f"expected one of {SPILL_POLICIES}")
        self.manager = as_manager(pools)
        self.store = store or StateStore()
        self.spill_policy = spill_policy
        self.controllers: dict[str, AdmissionController] = {
            name: AdmissionController(pool)
            for name, pool in self.manager.pools.items()}
        # ``telemetry=True`` builds a fresh ``repro.telemetry.Telemetry``;
        # passing an instance shares one plane across gateways.  Off by
        # default: the overhead gate in BENCH_admission.json pins the
        # telemetry-on quantum path within 5% of this zero-cost default.
        if telemetry is True:
            from repro.telemetry import Telemetry
            telemetry = Telemetry()
        self.telemetry = telemetry or None
        if self.telemetry is not None:
            for pool in self.manager.pools.values():
                self.telemetry.attach_pool(pool)
        #: public knob: False forces ``handle_quantum`` through the
        #: generic leg-round loop even when the single-leg fast path
        #: would apply — the chaos differential-replay harness runs the
        #: same seeded scenario with this on/off (and against the
        #: scalar ``handle``) to pin all three decision traces equal
        self.quantum_fast_enabled: bool = True

    # -- back-compat accessors -------------------------------------------------
    @property
    def pool(self) -> TokenPool:
        """The default (first) pool — single-pool callers' view."""
        return self.manager.default_pool()

    @property
    def controller(self) -> AdmissionController:
        return self.controllers[self.pool.spec.name]

    def _controller(self, pool_name: str) -> AdmissionController:
        ctrl = self.controllers.get(pool_name)
        if ctrl is None:
            ctrl = AdmissionController(self.manager.pool(pool_name))
            self.controllers[pool_name] = ctrl
        return ctrl

    # -- key management ---------------------------------------------------------
    def register_key(self, api_key: str, entitlement: str,
                     pool: Optional[str] = None) -> None:
        """Single-leg route (legacy API): key → entitlement on one pool.

        When ``pool`` is omitted the entitlement's OWNING pool is
        looked up, and a miss is an error — silently defaulting to the
        first pool would leave the key permanently 429-ing NOT_BOUND
        on a multi-pool gateway."""
        if pool is None:
            owners = [name for name, p in self.manager.pools.items()
                      if entitlement in p.entitlements]
            if not owners:
                raise ValueError(
                    f"entitlement {entitlement!r} exists in no pool; "
                    "add it before registering a key")
            if len(owners) > 1:
                raise ValueError(
                    f"entitlement {entitlement!r} exists in pools "
                    f"{owners}; pass pool= (or use register_route for "
                    "a multi-pool route)")
            pool = owners[0]
        self.register_route(api_key, [RouteEntry(pool, entitlement)])

    def register_route(self, api_key: str,
                       entries: Sequence[Union[RouteEntry,
                                               tuple[str, str]]]) -> None:
        """Ordered multi-pool route: first leg is the preferred pool,
        later legs are spill-over targets.

        Stored in the StateStore as a JSON string — the store keeps the
        Redis contract (string values), so a real Redis can be swapped
        in behind it."""
        route = tuple(e if isinstance(e, RouteEntry) else RouteEntry(*e)
                      for e in entries)
        if not route:
            raise ValueError("route must have at least one leg")
        self.store.set(f"route:{api_key}", json.dumps(
            [[e.pool, e.entitlement] for e in route]))

    def resolve(self, api_key: str, now: float = 0.0) -> Optional[str]:
        """Entitlement of the preferred leg (legacy single-pool view)."""
        route = self.route(api_key, now)
        return route[0].entitlement if route else None

    def route(self, api_key: str, now: float = 0.0
              ) -> Optional[tuple[RouteEntry, ...]]:
        raw = self.store.get(f"route:{api_key}", now)
        if raw is None:
            return None
        return tuple(RouteEntry(p, e) for p, e in json.loads(raw))

    # -- request path --------------------------------------------------------------
    def handle(self, api_key: str, request_id: str, input_tokens: int,
               max_tokens: Optional[int], now: float,
               kv_bytes_per_token: float = 0.0) -> GatewayResponse:
        tel = self.telemetry
        route = self.route(api_key, now)
        if not route:
            if tel is not None:
                tel.record_terminal_one(
                    now, request_id, flightrec.VERDICT_UNKNOWN_KEY,
                    flightrec.REASON_NONE)
            return GatewayResponse(status=401, request_id=request_id,
                                   reason="unknown_key")
        legs = self.manager.route_order_indexed(
            list(route), input_tokens, max_tokens, now,
            policy=self.spill_policy)
        first_denial = None
        best_retry: Optional[float] = None
        for i_leg, (hop, leg) in enumerate(legs):
            decision = self._controller(leg.pool).decide(AdmissionRequest(
                entitlement=leg.entitlement, input_tokens=input_tokens,
                max_tokens=max_tokens, arrival_s=now,
                request_id=request_id,
                kv_bytes_per_token=kv_bytes_per_token))
            if tel is not None:
                pool = self.manager.pool(leg.pool)
                tel.attach_pool(pool)
                mt = (max_tokens if max_tokens is not None
                      else pool.spec.default_max_tokens)
                tel.record_decision(
                    leg.pool, now, request_id, hop, leg.entitlement,
                    decision.admitted,
                    flightrec.REASON_NONE if decision.reason is None
                    else flightrec.REASON_CODES[decision.reason.value],
                    decision.priority, float(input_tokens + mt))
            if decision.admitted:
                self.store.incr(f"admits:{leg.entitlement}", 1.0, now)
                if hop > 0:
                    self.store.incr(f"spills:{api_key}", 1.0, now)
                if i_leg > 0:
                    # served by a spill leg: remember the PREFERRED leg
                    # so completion can transfer the debt credit
                    # (PoolManager.transfer_spill_debt)
                    rec = self.manager.pool(leg.pool).in_flight.get(
                        request_id)
                    if rec is not None:
                        first = legs[0][1]
                        rec.spill_from = (first.pool, first.entitlement)
                return GatewayResponse(
                    status=200, request_id=request_id,
                    priority=decision.priority, pool=leg.pool,
                    entitlement=leg.entitlement, spill_hops=hop)
            if first_denial is None:
                first_denial = decision
            if decision.retry_after_s is not None:
                best_retry = (decision.retry_after_s if best_retry is None
                              else min(best_retry, decision.retry_after_s))

        # Every leg denied, or none was available.  The denial is
        # attributed to the first leg actually TRIED — when the whole
        # route is down nothing denied it, so the unroutable counter
        # takes it instead of charging a pool that never saw the
        # request.
        if legs:
            self.store.incr(f"denials:{legs[0][1].entitlement}", 1.0, now)
        else:
            self.store.incr(f"unroutable:{api_key}", 1.0, now)
        if first_denial is None:           # no live pool on the route
            if tel is not None:
                tel.record_terminal_one(
                    now, request_id, flightrec.VERDICT_DENY,
                    flightrec.REASON_POOL_UNAVAILABLE)
            return GatewayResponse(
                status=429, request_id=request_id, retry_after_s=5.0,
                reason=DenyReason.POOL_UNAVAILABLE.value)
        return GatewayResponse(
            status=429, request_id=request_id,
            retry_after_s=best_retry,
            reason=(first_denial.reason.value
                    if first_denial.reason else None),
            priority=first_denial.priority)

    # -- batched request path (the scheduling-quantum hot path) -----------------
    @hot_path
    def handle_quantum(self, requests: Sequence[QuantumRequest],
                       now: float) -> list[GatewayResponse]:
        """Admit one scheduling quantum of requests through the fused
        kernel — ONE ``admit_quantum`` dispatch per (pool, leg-round)
        instead of five Python checks per request.

        Round ``k`` groups every still-undecided request by the pool of
        the ``k``-th leg of its ``route_order``; each pool is
        snapshotted once (a pure read), its group replayed through the
        kernel in arrival order, and the resulting charges/denials are
        scattered back through the real ledger + pool bookkeeping.
        Requests denied at round ``k`` re-enter round ``k+1`` with
        their next leg.  Responses come back in input order.

        Parity contract (pinned by ``tests/test_gateway_quantum.py``):
        each pool decides its batch exactly as the scalar
        :meth:`handle` pipeline would decide that arrival sequence, so
        end-to-end decisions are identical to the sequential handle
        loop whenever routes are single-leg or share one pool order
        (prefixes of a common route — the typical deployment, where a
        pool is only ever reached at one leg depth).  Route sets that
        interleave pools in DIFFERENT orders are still served
        deterministically, but leg-round batching admits a pool's
        round-``k`` arrivals before another request's round-``k+1``
        spill reaches it — where the sequential loop may interleave
        the other way.  Likewise ``headroom`` spill rankings are
        evaluated once at quantum start (per key + token shape), not
        re-ranked between requests mid-quantum.
        """
        if len(requests) == 1:
            # A one-request quantum replays the sequential walk exactly
            # (per-pool batches of size one) — skip the snapshot +
            # kernel dispatch and use the scalar pipeline directly.
            q = requests[0]
            return [self.handle(q.api_key, q.request_id, q.input_tokens,
                                q.max_tokens, now,
                                kv_bytes_per_token=q.kv_bytes_per_token)]
        tel = self.telemetry
        t0 = time.perf_counter() if tel is not None else 0.0
        fast = (self._quantum_fast(requests, now)
                if self.quantum_fast_enabled else None)
        if fast is not None:
            if tel is not None:
                tel.on_quantum(now, len(requests),
                               time.perf_counter() - t0)
            return fast
        responses: list[Optional[GatewayResponse]] = [None] * len(requests)
        # Routes are resolved once per distinct (key, token shape) at
        # quantum start — within a quantum `now` is fixed, so a key's
        # route (and its headroom ordering) is a constant.
        route_cache: dict[tuple, Optional[list]] = {}
        pending: list[_Pending] = []
        unknown_ids: list[str] = []
        for i, q in enumerate(requests):
            ck = (q.api_key, q.input_tokens, q.max_tokens)
            legs = route_cache.get(ck, False)
            if legs is False:
                route = self.route(q.api_key, now)
                legs = None if route is None else \
                    self.manager.route_order_indexed(
                        list(route), q.input_tokens, q.max_tokens, now,
                        policy=self.spill_policy)
                route_cache[ck] = legs
            if legs is None:
                responses[i] = GatewayResponse(
                    status=401, request_id=q.request_id,
                    reason="unknown_key")
                unknown_ids.append(q.request_id)
                continue
            pending.append(_Pending(idx=i, req=q, legs=list(legs)))
        if tel is not None and unknown_ids:
            tel.record_terminal(now, unknown_ids,
                                flightrec.VERDICT_UNKNOWN_KEY,
                                flightrec.REASON_NONE)

        while pending:
            # spills from different pools (and espec-miss skips) land in
            # group order — restore arrival order so every pool batch
            # replays its requests exactly as the scalar loop would
            pending.sort(key=lambda p: p.idx)
            groups: dict[str, list[_Pending]] = {}
            for p in pending:
                if p.leg_ptr >= len(p.legs):
                    responses[p.idx] = self._finish_denied(p, now)
                else:
                    groups.setdefault(p.current()[1].pool, []).append(p)
            pending = []
            for pool_name, batch in groups.items():
                pending.extend(self._admit_batch(pool_name, batch,
                                                 responses, now))
        if tel is not None:
            tel.on_quantum(now, len(requests), time.perf_counter() - t0)
        return responses

    def _finish_denied(self, p: _Pending, now: float) -> GatewayResponse:
        """Route exhausted: the 429 (same attribution as ``handle``)."""
        if p.legs:
            self.store.incr(f"denials:{p.legs[0][1].entitlement}",
                            1.0, now)
        else:
            self.store.incr(f"unroutable:{p.req.api_key}", 1.0, now)
        if p.first_reason is None:         # no live pool on the route
            if self.telemetry is not None:
                self.telemetry.record_terminal_one(
                    now, p.req.request_id, flightrec.VERDICT_DENY,
                    flightrec.REASON_POOL_UNAVAILABLE)
            return GatewayResponse(
                status=429, request_id=p.req.request_id,
                retry_after_s=5.0,
                reason=DenyReason.POOL_UNAVAILABLE.value)
        return GatewayResponse(
            status=429, request_id=p.req.request_id,
            retry_after_s=p.best_retry, reason=p.first_reason.value,
            priority=p.first_priority)

    @hot_path
    def _dispatch_admit(self, pool: TokenPool, snap, rows, tokens, kvs,
                        m: int) -> tuple[np.ndarray, np.ndarray,
                                         np.ndarray]:
        """ONE padded ``admit_quantum`` dispatch for a pool batch of
        ``m`` live requests in replay order (``rows``/``tokens``/
        ``kvs`` may be lists or arrays).  Returns host-side
        (admitted, reasons, weights) trimmed to the live prefix."""
        width = quantum_width(m)
        row_width = bucket_width(snap.state.n_rows)

        def padvec(xs, dtype):
            a = np.zeros(width, dtype)
            a[:m] = xs
            return a

        live = np.zeros(width, bool)
        live[:m] = True
        mesh = shard_plane.pool_mesh(pool)
        admit_kw = {} if mesh is None else {"mesh": mesh}
        admit_fn = admit_quantum if mesh is None \
            else shard_plane.shard_admit_quantum
        admitted, reasons, req_w = admit_fn(
            pad_state(snap.state, row_width),
            pad_rows(snap.bucket_level, row_width),
            pad_rows(snap.in_flight, row_width),
            pad_rows(snap.kv_in_use, row_width),
            pool_in_flight=jnp.int32(snap.pool_in_flight),
            pool_conc_cap=jnp.float32(snap.pool_conc_cap),
            running_min_priority=jnp.float32(snap.running_min_priority),
            pool_avg_slo=jnp.float32(snap.pool_avg_slo),
            req_ent=padvec(rows, np.int32),
            req_tokens=padvec(tokens, np.float32),
            req_kv=padvec(kvs, np.float32),
            pool_resident=jnp.int32(snap.pool_resident),
            req_live=live,
            weights=pad_rows(snap.weights, row_width),
            coeff=pool.spec.coefficients,
            slack=pool.spec.admission_slack,
            **admit_kw)
        return (np.asarray(admitted)[:m], np.asarray(reasons)[:m],
                np.asarray(req_w)[:m])

    @hot_path
    def _quantum_fast(self, requests: Sequence[QuantumRequest],
                      now: float) -> Optional[list[GatewayResponse]]:
        """Array-native quantum for ALL-single-leg route sets — the
        dominant deployment shape, where every key resolves to exactly
        one live leg, a denial is terminal, and no leg-round loop is
        needed.

        Requests group per distinct (key, token shape): routes resolve
        once per group, group constants (row, tokens, hop) expand to
        request arrays with ``np.full``, and each pool batch runs the
        SAME padded kernel dispatch and batched row-op scatters as the
        generic path — so per-request Python shrinks to one response
        tuple plus id extraction.  Decision/state parity with the
        generic leg-round loop is pinned by
        ``tests/test_gateway_quantum.py``.

        Returns None — before touching ANY state — when some key's
        route has several live legs; the generic loop takes over."""
        n = len(requests)
        by_ck: dict[tuple, list[int]] = {}
        for i, q in enumerate(requests):
            ck = (q.api_key, q.input_tokens, q.max_tokens)
            try:
                by_ck[ck].append(i)
            except KeyError:
                by_ck[ck] = [i]
        # resolve every distinct key first — pure reads, so the
        # multi-leg bail-out leaves no partial state behind
        resolved = []
        for ck, idxs in by_ck.items():
            key, inp, mx = ck
            route = self.route(key, now)
            legs = None if route is None else \
                self.manager.route_order_indexed(
                    list(route), inp, mx, now, policy=self.spill_policy)
            if legs is not None and len(legs) > 1:
                return None
            resolved.append((idxs, ck, legs))
        responses: list[Optional[GatewayResponse]] = [None] * n
        pools: dict[str, list] = {}
        tel = self.telemetry
        unknown_ids: list[str] = []
        unroutable_ids: list[str] = []
        unroutable_incr: dict[str, float] = {}
        for idxs, ck, legs in resolved:
            key, inp, mx = ck
            if legs is None:
                for i in idxs:
                    responses[i] = GatewayResponse(
                        status=401, request_id=requests[i].request_id,
                        reason="unknown_key")
                    unknown_ids.append(requests[i].request_id)
            elif not legs:               # route exists, no live pool
                for i in idxs:
                    responses[i] = GatewayResponse(
                        status=429, request_id=requests[i].request_id,
                        retry_after_s=5.0,
                        reason=DenyReason.POOL_UNAVAILABLE.value)
                    unroutable_ids.append(requests[i].request_id)
                unroutable_incr[f"unroutable:{key}"] = \
                    unroutable_incr.get(f"unroutable:{key}", 0.0) \
                    + float(len(idxs))
            else:
                hop, leg = legs[0]
                pools.setdefault(leg.pool, []).append(
                    (idxs, key, leg.entitlement, inp, mx, hop))
        if unroutable_incr:
            self.store.incr_many(unroutable_incr, now)
        if tel is not None:
            if unknown_ids:
                tel.record_terminal(now, unknown_ids,
                                    flightrec.VERDICT_UNKNOWN_KEY,
                                    flightrec.REASON_NONE)
            if unroutable_ids:
                tel.record_terminal(now, unroutable_ids,
                                    flightrec.VERDICT_DENY,
                                    flightrec.REASON_POOL_UNAVAILABLE)
        for pool_name, entries in pools.items():
            self._admit_batch_fast(pool_name, entries, requests,
                                   responses, now)
        return responses

    @hot_path
    def _admit_batch_fast(self, pool_name: str, entries: list,
                          requests: Sequence[QuantumRequest],
                          responses: list, now: float) -> None:
        """One pool's single-leg quantum batch: snapshot → kernel →
        batched scatter, exactly like ``_admit_batch``, but built from
        per-group constants (every request of a (key, shape) group
        shares its row/tokens/hop) stitched back into arrival order."""
        pool = self.manager.pool(pool_name)
        snap = quantum_snapshot(pool, now)
        row_of = snap.row_of
        default_mt = pool.spec.default_max_tokens
        store = self.store
        tel = self.telemetry
        #: StateStore deltas for the whole batch — flushed as ONE
        #: ``incr_many`` (the Redis pipeline shape) instead of one
        #: ``incr`` per key
        incr_acc: dict[str, float] = {}
        # NOT_BOUND skips never reach the kernel; their decision rows
        # record with ent_slot -1 and zeroed state dims
        nb_rids: list[str] = []
        nb_hops: list[int] = []
        nb_toks: list[float] = []
        g_ent: list[str] = []
        g_key: list[str] = []
        g_hop: list[int] = []
        g_row: list[int] = []
        g_tok: list[float] = []
        g_inp: list[int] = []
        g_mt: list[int] = []
        counts: list[int] = []
        idx_lists: list[list[int]] = []
        for idxs, key, ent, inp, mx, hop in entries:
            row = row_of.get(ent)
            mt = mx if mx is not None else default_mt
            if row is None:
                # the scalar pipeline's espec-is-None early out:
                # terminal NOT_BOUND without touching pool state
                for i in idxs:
                    responses[i] = GatewayResponse(
                        status=429, request_id=requests[i].request_id,
                        reason=DenyReason.NOT_BOUND.value)
                    if tel is not None:
                        nb_rids.append(requests[i].request_id)
                        nb_hops.append(hop)
                        nb_toks.append(float(inp + mt))
                incr_acc[f"denials:{ent}"] = \
                    incr_acc.get(f"denials:{ent}", 0.0) + float(len(idxs))
                continue
            g_ent.append(ent)
            g_key.append(key)
            g_hop.append(hop)
            g_row.append(row)
            g_tok.append(float(inp + mt))
            g_inp.append(inp)
            g_mt.append(mt)
            counts.append(len(idxs))
            idx_lists.append(idxs)
        if tel is not None and nb_rids:
            tel.record_decisions(
                pool_name, now, nb_rids,
                np.full(len(nb_rids), -1, np.int64),
                np.asarray(nb_hops, np.int64),
                np.zeros(len(nb_rids), bool),
                np.full(len(nb_rids), 1, np.int16),   # NOT_BOUND
                0.0, float(snap.running_min_priority)
                * (1.0 - pool.spec.admission_slack),
                np.asarray(nb_toks, np.float64))
        if not counts:
            if incr_acc:
                store.incr_many(incr_acc, now)
            return
        # per-group constants expand to per-request arrays by GATHER,
        # not per-group np.full loops; argsort restores arrival order
        cnt = np.asarray(counts, np.int64)
        m = int(cnt.sum())
        idx_cat = np.fromiter(chain.from_iterable(idx_lists),
                              np.int64, count=m)
        order = np.argsort(idx_cat)
        idx_arr = idx_cat[order]
        gids = np.repeat(np.arange(len(counts), dtype=np.int64),
                         cnt)[order]
        rows64 = np.asarray(g_row, np.int64)[gids]
        toks64 = np.asarray(g_tok, np.float64)[gids]
        inps = np.asarray(g_inp, np.int64)[gids]
        mts = np.asarray(g_mt, np.int64)[gids]
        idx_l = idx_arr.tolist()
        if m == len(requests):
            # whole quantum in one pool batch (the common single-pool
            # deployment): arrival order IS input order, so attribute
            # extraction runs as C-speed maps with no index gather
            rids = list(map(_Q_RID, requests))
            kvpt = np.fromiter(map(_Q_KV, requests), np.float64,
                               count=m)
        else:
            rids = [requests[i].request_id for i in idx_l]
            kvpt = np.fromiter(
                (requests[i].kv_bytes_per_token for i in idx_l),
                np.float64, count=m)
        kvs64 = toks64 * kvpt

        admitted, reasons, req_w = self._dispatch_admit(
            pool, snap, rows64, toks64, kvs64, m)

        ledger = pool.ledger
        js = np.flatnonzero(admitted)
        charged = np.zeros(m, bool)
        ch_slots = np.empty(0, np.int64)
        charge_ids: list[str] = []
        if js.size:
            # buckets ensured once per group with kernel admits (the
            # same entitlement set the generic pass-1 loop ensures),
            # vectorized: rates come off the eff_tps column, with the
            # scalar path's spec-f64 baseline on the eff==0 fallback
            ub = np.unique(gids[js])
            uslots = np.asarray(g_row, np.int64)[ub]
            rates = pool.store.col["eff_tps"][uslots].copy()
            for t in np.flatnonzero(rates == 0.0).tolist():
                rates[t] = pool.entitlements[
                    g_ent[int(ub[t])]].baseline.tokens_per_second
            ledger.ensure_rows(uslots, rates, now)
            charge_ids = rids if js.size == m else \
                [rids[t] for t in js.tolist()]
            ok, ch_slots = ledger.charge_rows(
                charge_ids, rows64[js], toks64[js], inps[js], mts[js],
                now)
            charged[js] = ok

        acc = np.flatnonzero(charged)
        w_l = req_w.tolist()
        gid_l = gids.tolist()
        if acc.size:
            admit_ids = charge_ids if acc.size == js.size else \
                [rids[t] for t in acc.tolist()]
            pool.admit_rows(admit_ids, rows64[acc], kvs64[acc],
                            toks64[acc], now, slots=ch_slots)
            # demand lands exactly like the scalar register_admit
            # loop: one unbuffered index-ordered f64 add chain
            np.add.at(pool.store.col["demand_window"], rows64[acc],
                      toks64[acc])
            per_gid = np.bincount(gids[acc], minlength=len(g_ent))
            for gid, cnt in enumerate(per_gid.tolist()):
                if cnt:
                    k_adm = f"admits:{g_ent[gid]}"
                    incr_acc[k_adm] = incr_acc.get(k_adm, 0.0) \
                        + float(cnt)
                    if g_hop[gid] > 0:
                        k_sp = f"spills:{g_key[gid]}"
                        incr_acc[k_sp] = incr_acc.get(k_sp, 0.0) \
                            + float(cnt)
            if acc.size == m:
                it = zip(idx_l, rids, w_l, gid_l)
            else:
                it = ((idx_l[k], rids[k], w_l[k], gid_l[k])
                      for k in acc.tolist())
            # tuple.__new__ skips the NamedTuple default-filling
            # wrapper — measurably faster at 10^5 responses/quantum
            mk = tuple.__new__
            for i, rid, w, gid in it:
                responses[i] = mk(GatewayResponse,
                                  (200, rid, None, None, w, pool_name,
                                   g_ent[gid], g_hop[gid]))

        den = np.flatnonzero(~charged)
        if den.size:
            hint_cache: dict = {}
            deny_ents: list[str] = []
            deny_demand = np.zeros(den.size, np.float64)
            deny_lp = np.zeros(den.size, bool)
            adm_kernel = admitted.tolist()
            reasons_l = reasons.tolist()
            toks_l = toks64.tolist()
            dcount: dict[str, int] = {}
            for d, k in enumerate(den.tolist()):
                ent = g_ent[gid_l[k]]
                w = w_l[k]
                code = 3 if adm_kernel[k] else int(reasons_l[k])
                reason = _REASON_CODES[code]
                retry = self._deny_hint(pool, pool_name, ent, reason,
                                        toks_l[k], w, now,
                                        cache=hint_cache)
                deny_ents.append(ent)
                if reason is not DenyReason.NOT_BOUND:
                    deny_demand[d] = toks_l[k]
                lp = reason is DenyReason.LOW_PRIORITY
                deny_lp[d] = lp
                dcount[ent] = dcount.get(ent, 0) + 1
                responses[idx_l[k]] = GatewayResponse(
                    status=429, request_id=rids[k],
                    retry_after_s=retry, reason=reason.value,
                    priority=w if lp else 0.0)
            pool.register_deny_batch(deny_ents, deny_demand, deny_lp)
            for ent, cnt in dcount.items():
                k_den = f"denials:{ent}"
                incr_acc[k_den] = incr_acc.get(k_den, 0.0) + float(cnt)
        if incr_acc:
            store.incr_many(incr_acc, now)
        if tel is not None:
            # ONE flight scatter for the kernel batch, with reasons
            # finalized the way responses were: a kernel admit the
            # ledger rejected flips to TOKEN_BUDGET (code 3)
            final_reasons = np.where(
                charged, 0,
                np.where(admitted, 3, reasons.astype(np.int64)))
            tel.record_decisions(
                pool_name, now, rids, rows64,
                np.asarray(g_hop, np.int64)[gids], charged,
                final_reasons.astype(np.int16),
                np.asarray(req_w, np.float64),
                float(snap.running_min_priority)
                * (1.0 - pool.spec.admission_slack),
                toks64,
                levels_at=np.asarray(snap.bucket_level, np.float64))

    @hot_path
    def _admit_batch(self, pool_name: str, batch: list[_Pending],
                     responses: list, now: float) -> list[_Pending]:
        """One fused kernel dispatch for one pool's leg-round group;
        scatters results into ``responses`` / pool state and returns
        the requests that spill into the next round."""
        pool = self.manager.pool(pool_name)
        snap = quantum_snapshot(pool, now)
        spilled: list[_Pending] = []

        # Legs naming an entitlement the pool has never heard of deny
        # NOT_BOUND without touching pool state (the scalar pipeline's
        # espec-is-None early out) — they skip the kernel entirely.
        kernel_batch: list[_Pending] = []
        tel = self.telemetry
        nb_rids: list[str] = []
        nb_hops: list[int] = []
        nb_toks: list[float] = []
        #: declared route position per kernel-batch entry, captured
        #: BEFORE the denial pass advances leg_ptr
        hops: list[int] = []
        rows, tokens, kvs, eff_max = [], [], [], []
        for p in batch:
            hop, leg = p.current()
            row = snap.row_of.get(leg.entitlement)
            mt = (p.req.max_tokens if p.req.max_tokens is not None
                  else pool.spec.default_max_tokens)
            if row is None:
                if tel is not None:
                    nb_rids.append(p.req.request_id)
                    nb_hops.append(hop)
                    nb_toks.append(float(p.req.input_tokens + mt))
                p.note_denial(DenyReason.NOT_BOUND, 0.0, None)
                p.leg_ptr += 1
                spilled.append(p)
                continue
            kernel_batch.append(p)
            hops.append(hop)
            rows.append(row)
            tokens.append(float(p.req.input_tokens + mt))
            kvs.append(float(p.req.input_tokens + mt)
                       * p.req.kv_bytes_per_token)
            eff_max.append(mt)
        if tel is not None and nb_rids:
            tel.record_decisions(
                pool_name, now, nb_rids,
                np.full(len(nb_rids), -1, np.int64),
                np.asarray(nb_hops, np.int64),
                np.zeros(len(nb_rids), bool),
                np.full(len(nb_rids), 1, np.int16),   # NOT_BOUND
                0.0, float(snap.running_min_priority)
                * (1.0 - pool.spec.admission_slack),
                np.asarray(nb_toks, np.float64))
        if not kernel_batch:
            return spilled

        m = len(kernel_batch)
        admitted, reasons, req_w = self._dispatch_admit(
            pool, snap, rows, tokens, kvs, m)

        # -- scatter, pass 1: the quantum's charges, in replay order —
        # array-native: no per-request ``Charge`` objects, accepted
        # charges land as batched request-table column writes
        # (``Ledger.charge_rows``).  Buckets are ensured once per
        # entitlement; the ledger re-checks every charge (it stays
        # authoritative if f32/f64 disagree on an exact budget
        # boundary — those flip to budget denials below).
        ledger = pool.ledger
        slot_of = pool.store.slot_of
        ensured: set = set()
        charge_js: list[int] = []
        charge_ids: list[str] = []
        ent_slots: list[int] = []
        inp_toks: list[int] = []
        max_toks: list[int] = []
        for j, p in enumerate(kernel_batch):
            if not admitted[j]:
                continue
            ent = p.current()[1].entitlement
            if ent not in ensured:
                st = pool.status[ent]
                ledger.ensure(
                    ent, st.effective.tokens_per_second
                    or pool.entitlements[ent].baseline.tokens_per_second,
                    now)
                ensured.add(ent)
            charge_js.append(j)
            charge_ids.append(p.req.request_id)
            ent_slots.append(slot_of[ent])
            inp_toks.append(p.req.input_tokens)
            max_toks.append(int(eff_max[j]))
        tokens64 = np.asarray(tokens, np.float64)
        kvs64 = np.asarray(kvs, np.float64)
        charged = np.zeros(m, bool)
        js = np.asarray(charge_js, np.int64)
        owners = np.asarray(ent_slots, np.int64)
        ch_slots = np.empty(0, np.int64)
        if charge_js:
            ok, ch_slots = ledger.charge_rows(
                charge_ids, owners, tokens64[js],
                np.asarray(inp_toks, np.int64),
                np.asarray(max_toks, np.int64), now)
            charged[js] = ok

        # -- scatter, pass 2a: admits.  ONE ``admit_rows`` column
        # scatter — no per-request ``InFlight`` objects — and counter
        # increments are aggregated: the StateStore and store columns
        # are hit once per distinct key per quantum, not per request.
        acc = np.flatnonzero(charged[js]) if charge_js else js
        if acc.size:
            n_admits: dict = {}
            n_spills: dict = {}
            demand: dict = {}
            # (row slot index in this admit batch, preferred leg) for
            # requests served off a spill leg — tagged on the new rows
            # below for completion-time debt transfer
            spill_tags: list[tuple[int, tuple[str, str]]] = []
            acc_l = acc.tolist()
            for k, i in enumerate(acc_l):
                p = kernel_batch[charge_js[i]]
                hop, leg = p.current()
                ent = leg.entitlement
                w = float(req_w[charge_js[i]])
                demand[ent] = demand.get(ent, 0.0) \
                    + float(tokens[charge_js[i]])
                n_admits[ent] = n_admits.get(ent, 0) + 1
                if hop > 0:
                    key = p.req.api_key
                    n_spills[key] = n_spills.get(key, 0) + 1
                if p.leg_ptr > 0:
                    first = p.legs[0][1]
                    spill_tags.append((k, (first.pool,
                                           first.entitlement)))
                responses[p.idx] = GatewayResponse(
                    status=200, request_id=p.req.request_id,
                    priority=w, pool=pool_name, entitlement=ent,
                    spill_hops=hop)
            js_acc = js[acc]
            # ch_slots aligns with the accepted subset of the charge
            # batch in charge order — exactly this admit batch, so the
            # rows charged are the rows admitted (no second id lookup)
            slots = pool.admit_rows(
                [charge_ids[i] for i in acc_l], owners[acc],
                kvs64[js_acc], tokens64[js_acc], now,
                demand_tokens=demand, slots=ch_slots)
            spill_col = pool.table.spill_from
            for k, leg_from in spill_tags:
                spill_col[int(slots[k])] = leg_from
            incr_acc = {f"admits:{ent}": float(cnt)
                        for ent, cnt in n_admits.items()}
            for key, cnt in n_spills.items():
                incr_acc[f"spills:{key}"] = float(cnt)
            self.store.incr_many(incr_acc, now)

        # -- scatter, pass 2b: denials.  Runs AFTER the quantum's
        # admits are registered, so Retry-After hints reflect the pool
        # the retrying client will actually face (the scalar loop's
        # hints see only the admits that preceded each request).
        # Bookkeeping lands as ONE ``register_deny_batch`` scatter, and
        # hints are memoized per (reason, entitlement, tokens): a
        # denial mutates only demand/denial counters, which no hint
        # formula reads, so within one batch equal keys give equal
        # hints — and the priority threshold (a pool-wide Eq. 1 min)
        # is evaluated at most once per batch.
        deny_js = np.flatnonzero(~charged)
        if deny_js.size:
            hint_cache: dict = {}
            deny_ents: list[str] = []
            deny_demand = np.zeros(deny_js.size, np.float64)
            deny_lp = np.zeros(deny_js.size, bool)
            for k, j in enumerate(deny_js.tolist()):
                p = kernel_batch[j]
                ent = p.current()[1].entitlement
                w = float(req_w[j])
                code = 3 if admitted[j] else int(reasons[j])
                reason = _REASON_CODES[code]
                retry = self._deny_hint(pool, pool_name, ent, reason,
                                        float(tokens[j]), w, now,
                                        cache=hint_cache)
                deny_ents.append(ent)
                if reason is not DenyReason.NOT_BOUND:
                    deny_demand[k] = float(tokens[j])
                deny_lp[k] = reason is DenyReason.LOW_PRIORITY
                p.note_denial(reason,
                              w if reason is DenyReason.LOW_PRIORITY
                              else 0.0, retry)
                p.leg_ptr += 1
                spilled.append(p)
            pool.register_deny_batch(deny_ents, deny_demand, deny_lp)
        if tel is not None:
            final_reasons = np.where(
                charged, 0,
                np.where(admitted, 3, reasons.astype(np.int64)))
            tel.record_decisions(
                pool_name, now,
                [p.req.request_id for p in kernel_batch],
                np.asarray(rows, np.int64), np.asarray(hops, np.int64),
                charged, final_reasons.astype(np.int16),
                np.asarray(req_w, np.float64),
                float(snap.running_min_priority)
                * (1.0 - pool.spec.admission_slack),
                tokens64,
                levels_at=np.asarray(snap.bucket_level, np.float64))
        return spilled

    def _deny_hint(self, pool: TokenPool, pool_name: str, ent: str,
                   reason: DenyReason, tokens: float, w: float,
                   now: float, cache: Optional[dict] = None
                   ) -> Optional[float]:
        """Retry-After for a kernel denial — the scalar pipeline's
        §4.3 hint formulas, evaluated on the post-quantum pool state
        (all of this batch's admits applied): the hint describes what
        a client retrying AFTER this quantum will face.

        ``cache`` (one dict per batch) memoizes hints per
        (reason, entitlement, tokens) and the priority threshold per
        batch — valid because post-quantum pool state is fixed for the
        whole denial pass (denials mutate nothing a hint reads)."""
        ctrl = self._controller(pool_name)
        if reason is DenyReason.NOT_BOUND:
            return 5.0
        if reason is DenyReason.LOW_PRIORITY:
            threshold = (cache.get("threshold")
                         if cache is not None else None)
            if threshold is None:
                threshold = (pool.admission_threshold()
                             * (1.0 - pool.spec.admission_slack))
                if cache is not None:
                    cache["threshold"] = threshold
            return ctrl._priority_backoff(w, threshold)
        key = (reason, ent, tokens)
        if cache is not None and key in cache:
            return cache[key]
        if reason is DenyReason.CONCURRENCY:
            hint = ctrl._concurrency_backoff(ent)
        else:                                # TOKEN_BUDGET
            espec = pool.entitlements[ent]
            st = pool.status[ent]
            bucket = pool.ledger.ensure(
                ent, st.effective.tokens_per_second
                or espec.baseline.tokens_per_second, now)
            if not bucket.can_afford(tokens, now):
                hint = min(pool.ledger.retry_after(ent, tokens, now),
                           60.0)
            else:
                hint = 1.0                   # KV headroom denial
        if cache is not None:
            cache[key] = hint
        return hint

    # -- fleet planning -----------------------------------------------------------
    def plan_quantum(self, now: float, records=None):
        """Run one fleet planning round (``PoolManager.plan_quantum``)
        and surface it in the gateway's stats store: per-pool replica
        gauges, scale-up/down counters, and migration counters —
        the same observability surface the admission counters use."""
        t0 = time.perf_counter()
        plan = self.manager.plan_quantum(now, records=records)
        if self.telemetry is not None:
            self.telemetry.on_plan(now, plan,
                                   time.perf_counter() - t0)
        for name, d in plan.decisions.items():
            self.store.set(f"replicas:{name}", float(d.desired), now)
        # count authorization TRANSITIONS, not convergence rounds —
        # under provisioning lag `desired > current` repeats every
        # plan until the replicas come live
        for name, (old, new) in plan.scale_events.items():
            if new > old:
                self.store.incr(f"scale_ups:{name}", 1.0, now)
            elif new < old:
                self.store.incr(f"scale_downs:{name}", 1.0, now)
        for prop in plan.applied:
            self.store.incr(f"migrations:{prop.entitlement}", 1.0, now)
            self.store.set(f"migrated_to:{prop.entitlement}", prop.dst,
                           now)
        return plan

    # -- completion callback ----------------------------------------------------------
    def on_complete(self, request_id: str, actual_output_tokens: int,
                    latency_s: float, now: float) -> None:
        settled = self.manager.on_complete(request_id,
                                           actual_output_tokens, now)
        if settled is not None:
            pool_name, rec = settled
            self.store.incr(f"tokens:{rec.entitlement}",
                            float(actual_output_tokens), now)
            self.store.set(f"last_latency:{rec.entitlement}", latency_s,
                           now)
            if self.telemetry is not None:
                self.telemetry.record_completions(
                    now, [pool_name], [rec.entitlement], [latency_s])

    @hot_path
    def on_complete_batch(self, completions: Sequence[tuple], now: float
                          ) -> None:
        """Batched completion callback — one vectorized settle per
        admitting pool per scheduling quantum.

        ``completions`` is a sequence of
        ``(request_id, actual_output_tokens, latency_s)`` tuples.
        Semantics per element match :meth:`on_complete` (the retained
        scalar oracle); StateStore counters are aggregated so the
        store is hit once per distinct entitlement per batch
        (``last_latency`` keeps last-write-wins order)."""
        if not completions:
            return
        settled = self.manager.on_complete_batch(
            [(rid, out) for rid, out, _ in completions], now)
        tel = self.telemetry
        tokens_incr: dict = {}
        last_lat: dict = {}
        done_pools: list[str] = []
        done_ents: list[str] = []
        done_lats: list[float] = []
        for (_, out, lat), res in zip(completions, settled):
            if res is None:
                continue
            ent = res[1]
            tokens_incr[f"tokens:{ent}"] = \
                tokens_incr.get(f"tokens:{ent}", 0.0) + float(out)
            last_lat[ent] = lat
            if tel is not None:
                done_pools.append(res[0])
                done_ents.append(ent)
                done_lats.append(lat)
        self.store.incr_many(tokens_incr, now)
        for ent, lat in last_lat.items():
            self.store.set(f"last_latency:{ent}", lat, now)
        if tel is not None and done_ents:
            # one SLO row-op for the whole drain (per-tier latency
            # histograms + attainment counters)
            tel.record_completions(now, done_pools, done_ents,
                                   done_lats)

    def on_failure(self, request_id: str, now: float) -> None:
        self.manager.on_evict(request_id, now)
