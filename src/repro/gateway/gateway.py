"""AI Gateway: the admission boundary (paper Fig. 1, LiteLLM role).

Responsibilities (paper §4.3):
  - resolve the inference key to an entitlement (auth);
  - run the admission pipeline BEFORE the request reaches a backend;
  - on rejection return 429 + Retry-After;
  - on completion, post actual token consumption back to the auth
    service (the callback that closes admission ↔ execution accounting).

State lives in the StateStore (Redis contract): key → entitlement
mapping and per-entitlement counters, so a real deployment can point
this class at an actual Redis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import (
    AdmissionController,
    AdmissionRequest,
    StateStore,
    TokenPool,
)


@dataclasses.dataclass(frozen=True)
class GatewayResponse:
    status: int                      # 200 admitted / 401 / 429
    request_id: str
    retry_after_s: Optional[float] = None
    reason: Optional[str] = None
    priority: float = 0.0


class Gateway:
    def __init__(self, pool: TokenPool,
                 store: Optional[StateStore] = None) -> None:
        self.pool = pool
        self.controller = AdmissionController(pool)
        self.store = store or StateStore()

    # -- key management ---------------------------------------------------------
    def register_key(self, api_key: str, entitlement: str) -> None:
        self.store.set(f"key:{api_key}", entitlement)

    def resolve(self, api_key: str, now: float = 0.0) -> Optional[str]:
        return self.store.get(f"key:{api_key}", now)

    # -- request path --------------------------------------------------------------
    def handle(self, api_key: str, request_id: str, input_tokens: int,
               max_tokens: Optional[int], now: float,
               kv_bytes_per_token: float = 0.0) -> GatewayResponse:
        ent = self.resolve(api_key, now)
        if ent is None:
            return GatewayResponse(status=401, request_id=request_id,
                                   reason="unknown_key")
        decision = self.controller.decide(AdmissionRequest(
            entitlement=ent, input_tokens=input_tokens,
            max_tokens=max_tokens, arrival_s=now, request_id=request_id,
            kv_bytes_per_token=kv_bytes_per_token))
        if not decision.admitted:
            self.store.incr(f"denials:{ent}", 1.0, now)
            return GatewayResponse(
                status=429, request_id=request_id,
                retry_after_s=decision.retry_after_s,
                reason=decision.reason.value if decision.reason else None,
                priority=decision.priority)
        self.store.incr(f"admits:{ent}", 1.0, now)
        return GatewayResponse(status=200, request_id=request_id,
                               priority=decision.priority)

    # -- completion callback ----------------------------------------------------------
    def on_complete(self, request_id: str, actual_output_tokens: int,
                    latency_s: float, now: float) -> None:
        rec = self.pool.in_flight.get(request_id)
        self.pool.on_complete(request_id, actual_output_tokens, now)
        if rec is not None:
            self.store.incr(f"tokens:{rec.entitlement}",
                            float(actual_output_tokens), now)
            self.store.set(f"last_latency:{rec.entitlement}", latency_s,
                           now)

    def on_failure(self, request_id: str, now: float) -> None:
        self.pool.on_evict(request_id, now)
