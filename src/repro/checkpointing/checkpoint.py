"""Checkpointing: pytree save/restore with a manifest, async saves, and
elastic restore (reshard onto whatever mesh is alive).

Layout per step:
    <dir>/step_<k>/manifest.json       tree structure, shapes, dtypes
    <dir>/step_<k>/arrays.npz          flattened leaves (addressable data)
    <dir>/step_<k>/COMMIT              written last — torn saves are
                                       invisible to ``latest_step``

Elastic restore: the manifest stores *logical* (global) shapes; on load
each process materialises its shards for the current mesh via
``jax.make_array_from_callback``, so a checkpoint written on N devices
restores on M ≠ N (tested 8→4 and 1→8 in tests/test_checkpoint.py).
Async saves hand the (host-local) arrays to a background thread —
training continues while bytes hit disk; ``wait()`` joins before the
next save or shutdown (a crash between save and COMMIT is equivalent to
the save never happening — restart resumes from the previous commit).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

#: dtypes numpy's npz format can't round-trip natively — stored as raw
#: uint views with the logical dtype recorded in the manifest
_VIEW_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
}


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                       for k in path)
        out.append((key, leaf))
    return out


def save(directory: str, step: int, tree: Any) -> str:
    """Synchronous commit-protocol save."""
    step_dir = os.path.join(directory, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)

    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    arrays = {}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[logical][1])
        name = f"a{i}"
        arrays[name] = arr
        manifest["leaves"].append({
            "key": key, "name": name, "shape": list(arr.shape),
            "dtype": logical})
    np.savez(os.path.join(tmp_dir, "arrays.npz"), **arrays)
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp_dir, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)
    return step_dir


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "COMMIT")):
                best = max(best or -1, int(d[5:]))
    return best


def restore(directory: str, step: int, target: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  With ``shardings`` (pytree of NamedSharding,
    congruent with target) leaves are placed shard-by-shard on the
    current mesh — the elastic-resume path."""
    step_dir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(step_dir, "arrays.npz"))
    by_key = {}
    for l in manifest["leaves"]:
        arr = data[l["name"]]
        if l["dtype"] in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[l["dtype"]][0])
        by_key[l["key"]] = arr

    tgt_leaves = _flatten_with_paths(target)
    missing = [k for k, _ in tgt_leaves if k not in by_key]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]} ...")

    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(shardings)
    else:
        shard_leaves = [None] * len(tgt_leaves)

    out_leaves = []
    for (key, tgt), sh in zip(tgt_leaves, shard_leaves):
        arr = by_key[key]
        want_dtype = np.dtype(tgt.dtype)
        if arr.dtype != want_dtype:
            arr = arr.astype(want_dtype)
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target "
                f"{tgt.shape}")
        if sh is not None:
            leaf = jax.make_array_from_callback(
                arr.shape, sh, lambda idx, a=arr: a[idx])
        else:
            leaf = jax.numpy.asarray(arr)
        out_leaves.append(leaf)
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


class AsyncCheckpointer:
    """Background-thread saver with the same commit protocol."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved: list[int] = []

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        # snapshot to host memory on the caller's thread (cheap, avoids
        # racing live buffers), then write in background
        host_tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save(self.directory, step, host_tree)
            self.saved.append(step)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(d[5:]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
