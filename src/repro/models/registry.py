"""Model registry: one uniform interface over every architecture family.

``build_model(cfg)`` returns a ``Model`` whose five methods are the
entire contract the rest of the framework (engine, trainer, dry-run)
programs against:

    init(rng)                          → params
    forward_train(params, batch, rt)   → logits
    prefill(params, batch, cache, rt)  → (logits, cache)
    decode_step(params, tok, cache, i, rt) → (logits, cache)
    init_cache(batch, max_seq, rt)     → cache pytree

``batch`` carries ``tokens`` plus optional ``extra_embed`` (VLM patch /
audio frame stub embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from repro.models import encdec, transformer
from repro.models.config import ArchConfig
from repro.models.runtime import LOCAL, Runtime


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., dict]
    forward_train: Callable[..., jax.Array]
    prefill: Callable[..., tuple]
    decode_step: Callable[..., tuple]
    init_cache: Callable[..., dict]


def build_model(cfg: ArchConfig) -> Model:
    cfg.validate()
    if cfg.is_encoder_decoder:
        return Model(
            cfg=cfg,
            init=lambda rng: encdec.init_params(rng, cfg),
            forward_train=lambda p, tokens, rt=LOCAL, extra_embed=None:
                encdec.forward_train(p, tokens, cfg, rt, extra_embed),
            prefill=lambda p, tokens, cache, rt=LOCAL, extra_embed=None:
                encdec.prefill(p, tokens, cfg, cache, rt, extra_embed),
            decode_step=lambda p, tok, cache, cur, rt=LOCAL:
                encdec.decode_step(p, tok, cfg, cache, cur, rt),
            init_cache=lambda batch, max_seq, rt=LOCAL:
                encdec.init_cache(cfg, batch, max_seq, rt),
        )
    return Model(
        cfg=cfg,
        init=lambda rng: transformer.init_params(rng, cfg),
        forward_train=lambda p, tokens, rt=LOCAL, extra_embed=None:
            transformer.forward_train(p, tokens, cfg, rt, extra_embed),
        prefill=lambda p, tokens, cache, rt=LOCAL, extra_embed=None:
            transformer.prefill(p, tokens, cfg, cache, rt, extra_embed),
        decode_step=lambda p, tok, cache, cur, rt=LOCAL:
            transformer.decode_step(p, tok, cfg, cache, cur, rt),
        init_cache=lambda batch, max_seq, rt=LOCAL:
            transformer.init_cache(cfg, batch, max_seq, rt),
    )


def param_count(params: Any) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
