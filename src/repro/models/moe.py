"""Mixture-of-Experts MLP (qwen3-style: top-k routing over E experts,
softmax gate, renormalised top-k probabilities).

Dispatch is sort-based and static-shape (TPU-friendly):

  1. router logits → top-k (gates, expert ids) per token;
  2. flatten (T·k) assignments, stable-sort by expert id;
  3. rank-within-expert via exclusive-cumsum of expert counts; tokens
     ranked beyond the per-expert capacity C are dropped (their gate
     contribution is zero — the residual path carries them, standard
     capacity-factor semantics);
  4. scatter into a dense (E, C, d) buffer → batched expert einsum
     (E,C,d)×(E,d,f) — FLOPs ≈ k·cf·T·d·f·(3 matmuls), i.e. within
     capacity_factor of the model FLOPs (no dense-dispatch waste);
  5. gather-combine back to (T, d) with gate weighting.

Distribution: ``moe_mlp`` is the shard-local compute.  Under a mesh it
runs inside ``shard_map`` with experts sharded over the EP axes (data,
and pod when present) and the expert ffn dim sharded over the TP axis:

  tokens (T_loc, d) —all_to_all(EP)→ local experts' slots
  → expert einsum (f sharded over TP, partial down-proj psum over TP)
  —all_to_all(EP)→ back to source shard → local combine.

This is the canonical MoE EP schedule; its all-to-all bytes are what
§Roofline measures for the qwen3 cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def _axis_size(name: str) -> int:
    """Mapped-axis size; jax < 0.6 has no ``jax.lax.axis_size`` but
    constant-folds ``psum(1, axis)`` to the same value."""
    try:
        return jax.lax.axis_size(name)
    except AttributeError:
        return jax.lax.psum(1, name)


def init_moe(key, cfg, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(k1, (d, E), jnp.float32),
        "w_gate": dense_init(k2, (E, d, f), dtype),
        "w_up": dense_init(k3, (E, d, f), dtype),
        "w_down": dense_init(k4, (E, f, d), dtype),
    }


def route(router_w: jax.Array, x: jax.Array, cfg
          ) -> tuple[jax.Array, jax.Array]:
    """x (T,d) → (gates (T,k) f32, expert ids (T,k) i32)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    if cfg.norm_topk_prob:
        gates = gates / jnp.maximum(
            jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx.astype(jnp.int32)


def _dispatch_indices(expert_ids: jax.Array, E: int, C: int
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sort-based dispatch bookkeeping.

    expert_ids: (N,) flattened token→expert assignments.
    Returns (perm, dst_slot, keep): ``perm`` sorts assignments by
    expert; ``dst_slot`` is the (E·C)-buffer slot for each *sorted*
    assignment; ``keep`` masks assignments within capacity.
    """
    N = expert_ids.shape[0]
    perm = jnp.argsort(expert_ids, stable=True)
    sorted_e = expert_ids[perm]
    counts = jnp.bincount(expert_ids, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(N, dtype=jnp.int32) - offsets[sorted_e].astype(jnp.int32)
    keep = rank < C
    dst = sorted_e * C + jnp.minimum(rank, C - 1)
    return perm, dst, keep


def moe_mlp(params: dict, x: jax.Array, cfg,
            capacity: int | None = None) -> jax.Array:
    """Shard-local MoE MLP: x (T, d) → (T, d).  SwiGLU experts."""
    T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    if capacity is None:
        capacity = max(1, int(T * k / E * cfg.moe_capacity_factor))
    gates, idx = route(params["router"], x, cfg)

    flat_e = idx.reshape(T * k)
    flat_g = gates.reshape(T * k)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    perm, dst, keep = _dispatch_indices(flat_e, E, capacity)
    src_tok = flat_t[perm]
    src_gate = jnp.where(keep, flat_g[perm], 0.0)

    # scatter tokens into the (E·C, d) dispatch buffer (dropped → no-op
    # add of zeros)
    buf = jnp.zeros((E * capacity, d), x.dtype)
    vals = jnp.where(keep[:, None], x[src_tok], 0)
    buf = buf.at[dst].add(vals, mode="drop")
    disp = buf.reshape(E, capacity, d)

    g = jnp.einsum("ecd,edf->ecf", disp, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", disp, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    y = y.reshape(E * capacity, d)

    # combine: each kept assignment contributes gate · y[slot]
    contrib = y[dst] * src_gate[:, None].astype(y.dtype)
    out = jnp.zeros((T, d), y.dtype).at[src_tok].add(contrib)
    return out


def moe_mlp_ep(params: dict, x: jax.Array, cfg, ep_axes: tuple[str, ...],
               tp_axis: str | None) -> jax.Array:
    """The shard_map body: x (T_loc, d) with experts sharded over
    ``ep_axes`` (weights arrive as local blocks (E_loc, d, f_loc)) and
    ffn dim over ``tp_axis``.

    all_to_all #1 ships each source shard's per-expert slots to the
    expert's owner; all_to_all #2 ships results back.
    """
    T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    n_ep = 1
    for a in ep_axes:
        n_ep *= _axis_size(a)
    E_loc = E // n_ep
    C = max(1, int(T * k / E * cfg.moe_capacity_factor))

    gates, idx = route(params["router"], x, cfg)
    flat_e = idx.reshape(T * k)
    flat_g = gates.reshape(T * k)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    perm, dst, keep = _dispatch_indices(flat_e, E, C)
    src_tok = flat_t[perm]
    src_gate = jnp.where(keep, flat_g[perm], 0.0)

    buf = jnp.zeros((E * C, d), x.dtype)
    vals = jnp.where(keep[:, None], x[src_tok], 0)
    buf = buf.at[dst].add(vals, mode="drop")
    send = buf.reshape(E, C, d)

    # EP all-to-all: (E, C, d) → (E_loc, n_ep·C, d), slots grouped by src
    recv = jax.lax.all_to_all(send, ep_axes, split_axis=0, concat_axis=1,
                              tiled=True)

    g = jnp.einsum("ecd,edf->ecf", recv, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", recv, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)      # partial down-proj over f_loc

    # return trip: (E_loc, n_ep·C, d) → (E, C, d)
    back = jax.lax.all_to_all(y, ep_axes, split_axis=1, concat_axis=0,
                              tiled=True)
    back = back.reshape(E * C, d)

    contrib = back[dst] * src_gate[:, None].astype(back.dtype)
    out = jnp.zeros((T, d), back.dtype).at[src_tok].add(contrib)
    return out


def moe_dense_reference(params: dict, x: jax.Array, cfg) -> jax.Array:
    """Oracle: every expert computed for every token, gate-weighted sum.
    Exact match to moe_mlp when capacity_factor admits all tokens."""
    gates, idx = route(params["router"], x, cfg)       # (T,k)
    g = jnp.einsum("td,edf->tef", x, params["w_gate"])
    u = jnp.einsum("td,edf->tef", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("tef,efd->ted", h, params["w_down"])   # (T,E,d)
    T, E = x.shape[0], cfg.num_experts
    dense_gate = jnp.zeros((T, E), jnp.float32)
    dense_gate = dense_gate.at[
        jnp.arange(T)[:, None], idx].add(gates)
    return jnp.einsum("te,ted->td", dense_gate.astype(y.dtype), y)
