"""Runtime: the static distribution context threaded through model code.

Separates *what* the model computes (ArchConfig) from *where* it runs
(mesh axes, MoE strategy, cache dtype).  ``Runtime()`` with no mesh is
the single-device CPU path used by smoke tests and the engine; the
launcher builds mesh-ful runtimes for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Runtime:
    mesh: Optional[jax.sharding.Mesh] = None
    dp_axes: tuple[str, ...] = ()        # batch/data axes (("pod","data"))
    tp_axis: Optional[str] = None        # tensor-parallel axis ("model")
    ep_axes: tuple[str, ...] = ()        # expert-parallel axes (MoE)
    moe: str = "local"                   # "local" | "ep" (shard_map)
    attn_shard: str = "auto"             # "head" | "sequence" | "auto"
    kv_cache_dtype: str = "bfloat16"     # "int8" is the §Perf option
    # remat policy for training: "none" | "full" | "dots"
    remat: str = "none"
    # scan unroll factor over layer periods (cost-analysis variants use
    # 2; production keeps 1 for O(1) HLO size)
    scan_unroll: int = 1
    # §Perf hillclimb A: blocked online-softmax attention on no-grad
    # paths (prefill/encode) — O(S·block) temp instead of O(S²)
    blocked_attn: bool = False
    # K/V block size for the blocked schedule: larger blocks amortize
    # the (q, acc) HBM round-trips of the XLA scan at O(S·block) temp
    attn_block_k: int = 1024
    # §Perf hillclimb B: decode cache update as a one-hot masked select
    # instead of a dynamic scatter — elementwise ⇒ sharding-preserving,
    # eliminating GSPMD's replicate-then-repartition of seq-sharded KV
    onehot_cache_update: bool = False
    # §Perf hillclimb B: grouped-query decode — contract q groups
    # against the raw H_kv cache (no jnp.repeat ⇒ no replication of a
    # sequence-sharded cache, KV read once instead of H/H_kv times)
    grouped_gqa_decode: bool = False

    def spec(self, *axes) -> jax.sharding.PartitionSpec:
        return jax.sharding.PartitionSpec(*axes)

    @property
    def dp(self):
        """The combined data axes entry for a PartitionSpec."""
        if not self.dp_axes:
            return None
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def constrain(self, x, *axes):
        """with_sharding_constraint when a mesh is present; no-op otherwise."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec(*axes)))

    def cache_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                "int8": jnp.int8}[self.kv_cache_dtype]


LOCAL = Runtime()
