"""Shared neural building blocks (pure-functional, pjit-friendly).

Parameters are plain dict pytrees created by ``init_*`` helpers; forward
functions take ``(params, x, ...)``.  Norm statistics are computed in
fp32 regardless of param dtype (standard mixed-precision practice);
matmuls run in the configured dtype (bf16 target).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# -- init -----------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# -- norms -----------------------------------------------------------------------
def init_rmsnorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}   # gemma-style (1 + scale)


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    out = normed * (1.0 + params["scale"].astype(jnp.float32))
    return out.astype(dt)


def init_layernorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) \
        + params["bias"].astype(jnp.float32)
    return out.astype(dt)


# -- positional -------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,dh/2)
    sin = jnp.sin(angles)[..., :, None, :]              # (...,S,1,dh/2)
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings (fp32)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    emb = jnp.zeros((seq_len, d_model), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(angle))
    emb = emb.at[:, 1::2].set(jnp.cos(angle))
    return emb


# -- soft capping (gemma2) ----------------------------------------------------------
def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x.astype(jnp.float32) / cap)


# -- MLPs -----------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d_model, d_ff), dtype),
            "w_up": dense_init(k2, (d_model, d_ff), dtype),
            "w_down": dense_init(k3, (d_ff, d_model), dtype),
        }
    return {
        "w_up": dense_init(k1, (d_model, d_ff), dtype),
        "w_down": dense_init(k2, (d_ff, d_model), dtype),
    }


def mlp(params: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        u = jnp.einsum("...d,df->...f", x, params["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif kind == "geglu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        u = jnp.einsum("...d,df->...f", x, params["w_up"])
        h = jax.nn.gelu(g.astype(jnp.float32),
                        approximate=True).astype(x.dtype) * u
    else:  # gelu
        u = jnp.einsum("...d,df->...f", x, params["w_up"])
        h = jax.nn.gelu(u.astype(jnp.float32),
                        approximate=True).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# -- embeddings ----------------------------------------------------------------------
def init_embedding(key, vocab_padded: int, d_model: int, dtype) -> dict:
    return {"table": embed_init(key, (vocab_padded, d_model), dtype)}


def embed(params: dict, tokens: jax.Array, scale_by_sqrt_dim: bool = False
          ) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    if scale_by_sqrt_dim:
        out = out * jnp.asarray(np.sqrt(out.shape[-1]), out.dtype)
    return out


def unembed(params: dict, x: jax.Array, vocab_size: int,
            cap: float | None = None) -> jax.Array:
    """Logits against the (tied) embedding table; padded ids masked."""
    logits = jnp.einsum("...d,vd->...v", x, params["table"])
    logits = softcap(logits, cap)
    padded = logits.shape[-1]
    if padded > vocab_size:
        neg = jnp.asarray(-1e9, logits.dtype)
        mask = jnp.arange(padded) < vocab_size
        logits = jnp.where(mask, logits, neg)
    return logits
