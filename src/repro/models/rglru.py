"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The temporal-mixing block: LN → two linear branches to ``d_rnn``;
branch A → causal depthwise conv (width 4) → RG-LRU; branch B → GeLU;
merge (A ⊙ B) → down-proj → residual.

RG-LRU recurrence (per channel, fp32):

  r_t = σ(W_r x_t + b_r)                 recurrence gate
  i_t = σ(W_i x_t + b_i)                 input gate
  log a_t = −c · r_t · softplus(Λ)       (a = σ(Λ)^(c·r), c = 8)
  h_t = a_t · h_{t−1} + √(1 − a_t²) · (i_t ⊙ x_t)

Sequence processing uses ``lax.associative_scan`` (first-order linear
recurrence is associative) — O(log S) depth, fully parallel: this is
the sub-quadratic path that makes recurrentgemma's long_500k cell
feasible.  Decode carries (h, conv buffer) — O(1) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_rmsnorm, rmsnorm

_C_EXP = 8.0


def init_rglru_block(key, cfg, dtype) -> dict:
    d = cfg.d_model
    dr = cfg.rnn_width or d
    ks = jax.random.split(key, 7)
    # Λ init so that a^c spreads over (0.9, 0.999) — Griffin practice
    u = jax.random.uniform(ks[6], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / _C_EXP) / (1 - u ** (1.0 / _C_EXP)))
    return {
        "ln": init_rmsnorm(d),
        "w_a": dense_init(ks[0], (d, dr), dtype),
        "w_b": dense_init(ks[1], (d, dr), dtype),
        "conv_w": dense_init(ks[2], (cfg.conv_width, dr), jnp.float32,
                             scale=0.5),
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "w_r": dense_init(ks[3], (dr, dr), jnp.float32, scale=0.01),
        "b_r": jnp.zeros((dr,), jnp.float32),
        "w_i": dense_init(ks[4], (dr, dr), jnp.float32, scale=0.01),
        "b_i": jnp.zeros((dr,), jnp.float32),
        "lambda": lam,
        "w_down": dense_init(ks[5], (dr, d), dtype),
    }


def rglru_state(batch: int, cfg, dtype=jnp.float32) -> dict:
    dr = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, dr), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), dtype),
    }


def _gates(params: dict, x: jax.Array):
    """x (..., dr) fp32 → (log_a, beta·input) for the linear recurrence
    h_t = a·h + b."""
    r = jax.nn.sigmoid(jnp.einsum("...d,dk->...k", x, params["w_r"])
                       + params["b_r"])
    i = jax.nn.sigmoid(jnp.einsum("...d,dk->...k", x, params["w_i"])
                       + params["b_i"])
    log_a = -_C_EXP * r * jax.nn.softplus(params["lambda"])
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * (i * x)


def _causal_conv(params: dict, x: jax.Array, carry: jax.Array | None
                 ) -> jax.Array:
    """Depthwise causal conv width W.  x (B,S,dr); carry (B,W-1,dr) of
    trailing context (decode) or None (fresh sequence → zero pad)."""
    W = params["conv_w"].shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :]
              * params["conv_w"][i].astype(x.dtype)
              for i in range(W))
    return out + params["conv_b"].astype(x.dtype)


def rglru_sequence(params: dict, x: jax.Array, h0: jax.Array
                   ) -> tuple[jax.Array, jax.Array]:
    """x (B,S,dr) fp32 → (h (B,S,dr), h_last).  Associative scan over S
    of the affine recurrence (a_t, b_t)∘(a_s, b_s) = (a_t a_s, a_t b_s + b_t)."""
    a, b = _gates(params, x)
    # fold h0 into the first step: b_0 ← a_0 h0 + b_0
    b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_r * a_l, a_r * b_l + b_r

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1, :]


def rglru_block(params: dict, x: jax.Array, state: dict
                ) -> tuple[jax.Array, dict]:
    """Full residual temporal-mixing block over a sequence."""
    y = rmsnorm(params["ln"], x)
    xa = jnp.einsum("bsd,dk->bsk", y, params["w_a"]).astype(jnp.float32)
    xb = jnp.einsum("bsd,dk->bsk", y, params["w_b"]).astype(jnp.float32)
    conv_out = _causal_conv(params, xa, None)
    h, h_last = rglru_sequence(params, conv_out, state["h"])
    merged = (h * jax.nn.gelu(xb, approximate=True)).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", merged, params["w_down"])
    new_state = {
        "h": h_last,
        "conv": xa[:, -(params["conv_w"].shape[0] - 1):, :]
        if xa.shape[1] >= params["conv_w"].shape[0] - 1 else
        jnp.concatenate([state["conv"], xa], axis=1)[
            :, -(params["conv_w"].shape[0] - 1):, :],
    }
    return x + out, new_state


def rglru_decode_step(params: dict, x: jax.Array, state: dict
                      ) -> tuple[jax.Array, dict]:
    """One-token step: x (B,1,d); carries (h, conv buffer)."""
    y = rmsnorm(params["ln"], x)
    xa = jnp.einsum("bsd,dk->bsk", y, params["w_a"]).astype(jnp.float32)
    xb = jnp.einsum("bsd,dk->bsk", y, params["w_b"]).astype(jnp.float32)
    conv_out = _causal_conv(params, xa, state["conv"])       # (B,1,dr)
    a, b = _gates(params, conv_out[:, 0, :])
    h_new = a * state["h"] + b
    merged = (h_new[:, None, :]
              * jax.nn.gelu(xb, approximate=True)).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", merged, params["w_down"])
    new_state = {
        "h": h_new,
        "conv": jnp.concatenate([state["conv"], xa], axis=1)[:, 1:, :],
    }
    return x + out, new_state
