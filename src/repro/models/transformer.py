"""Decoder-only transformer trunk covering the dense / MoE / SSM /
hybrid / VLM families through the layer-kind pattern mechanism.

Layers are grouped into repeating *periods* (cfg.pattern); parameters
for each pattern position are stacked across periods and the stack is
consumed by one ``jax.lax.scan`` — HLO size stays O(|pattern|) no
matter how deep the model (94-layer qwen3-235b compiles as one period
body).  The non-divisible tail (recurrentgemma's 26 = 8·3 + 2) runs as
explicit layers after the scan.

Three entry points, matching the serving/training split:
  ``forward_train``  — full-sequence logits (no cache)
  ``prefill``        — full-sequence logits + populated caches
  ``decode_step``    — one token in, one logits column out, cache updated

Cache pytree layout (stacked like params):
  attention kinds  → {"k","v"}: (n_periods, B, S_kind, H_kv, dh)
  rglru            → {"h": (n,B,dr), "conv": (n,B,W-1,dr)}
  mlstm            → {"C","n","m"}; slstm → {"c","n","h","m"}
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

try:                                 # jax ≥ 0.6 exports it at top level
    _shard_map = jax.shard_map
except AttributeError:               # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ArchConfig
from repro.models.layers import (
    dense_init,
    dtype_of,
    embed,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
    softcap,
    unembed,
)
from repro.models.runtime import LOCAL, Runtime

ATTN_KINDS = ("global", "local")


# ============================ init ==============================================
def init_layer(key, cfg: ArchConfig, kind: str, dtype) -> dict:
    if kind in ATTN_KINDS:
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": init_rmsnorm(cfg.d_model),
            "attn": attn.init_attention(k1, cfg, dtype),
            "ln2": init_rmsnorm(cfg.d_model),
        }
        if cfg.is_moe:
            p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                                dtype)
        if cfg.use_post_norm:
            p["post_ln1"] = init_rmsnorm(cfg.d_model)
            p["post_ln2"] = init_rmsnorm(cfg.d_model)
        return p
    if kind == "rglru":
        k1, k2 = jax.random.split(key)
        return {
            "rec": rglru_lib.init_rglru_block(k1, cfg, dtype),
            "ln2": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype),
        }
    if kind == "mlstm":
        return {"cell": ssm_lib.init_mlstm_block(key, cfg, dtype)}
    if kind == "slstm":
        return {"cell": ssm_lib.init_slstm_block(key, cfg, dtype)}
    raise ValueError(f"unknown layer kind {kind!r}")


def init_params(key, cfg: ArchConfig) -> dict:
    dtype = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 4 + len(cfg.tail_kinds))
    params: dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.padded_vocab, cfg.d_model,
                                dtype),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if cfg.num_vision_tokens:
        params["vision_proj"] = dense_init(
            keys[1], (cfg.d_model, cfg.d_model), dtype)
    # stacked periods: vmap init over per-period keys
    period = {}
    pkeys = jax.random.split(keys[2], len(cfg.pattern))
    for i, kind in enumerate(cfg.pattern):
        lkeys = jax.random.split(pkeys[i], cfg.n_periods)
        period[f"k{i}"] = jax.vmap(
            lambda k, kind=kind: init_layer(k, cfg, kind, dtype))(lkeys)
    params["periods"] = period
    for j, kind in enumerate(cfg.tail_kinds):
        params[f"tail{j}"] = init_layer(keys[3 + j], cfg, kind, dtype)
    return params


# ============================ caches ============================================
def init_layer_cache(cfg: ArchConfig, kind: str, batch: int, max_seq: int,
                     rt: Runtime) -> dict:
    if kind in ATTN_KINDS:
        return attn.init_kv_cache(batch, max_seq, cfg, rt.cache_dtype(),
                                  kind)
    if kind == "rglru":
        return rglru_lib.rglru_state(batch, cfg)
    if kind == "mlstm":
        return ssm_lib.mlstm_state(batch, cfg)
    if kind == "slstm":
        return ssm_lib.slstm_state(batch, cfg)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               rt: Runtime = LOCAL) -> dict:
    cache: dict[str, Any] = {"periods": {}}
    for i, kind in enumerate(cfg.pattern):
        one = init_layer_cache(cfg, kind, batch, max_seq, rt)
        cache["periods"][f"k{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (cfg.n_periods,) + x.shape).copy(), one)
    for j, kind in enumerate(cfg.tail_kinds):
        cache[f"tail{j}"] = init_layer_cache(cfg, kind, batch, max_seq, rt)
    return cache


# ============================ layer application ===================================
def _apply_mlp(params: dict, x: jax.Array, cfg: ArchConfig, rt: Runtime
               ) -> jax.Array:
    """Dense MLP or MoE, with the MoE distribution strategy applied."""
    if not cfg.is_moe:
        return mlp(params["mlp"], x, cfg.mlp_kind)
    B, S, d = x.shape
    tokens = x.reshape(B * S, d)
    if rt.moe == "ep" and rt.mesh is not None:
        P = jax.sharding.PartitionSpec
        ep = rt.ep_axes if len(rt.ep_axes) > 1 else (
            rt.ep_axes[0] if rt.ep_axes else None)
        tp = rt.tp_axis
        specs = {
            "router": P(None, None),
            "w_gate": P(ep, None, tp),
            "w_up": P(ep, None, tp),
            "w_down": P(ep, tp, None),
        }
        fn = functools.partial(moe_lib.moe_mlp_ep, cfg=cfg,
                               ep_axes=rt.ep_axes, tp_axis=rt.tp_axis)
        out = _shard_map(
            fn, mesh=rt.mesh,
            in_specs=(specs, P(rt.dp, None)),
            out_specs=P(rt.dp, None),
        )(params["moe"], tokens)
    else:
        out = moe_lib.moe_mlp(params["moe"], tokens, cfg)
    return out.reshape(B, S, d)


def apply_layer(params: dict, x: jax.Array, cfg: ArchConfig, kind: str,
                mode: str, positions: jax.Array,
                cache: Optional[dict], cur_index, rt: Runtime
                ) -> tuple[jax.Array, Optional[dict]]:
    """One residual layer of the given kind.  Returns (x, new_cache)."""
    if kind in ATTN_KINDS:
        y = rmsnorm(params["ln1"], x)
        if mode == "train":
            y = attn.attention_block(params["attn"], y, cfg, kind,
                                     positions)
            new_kv = None
        elif mode == "prefill":
            y, new_kv = attn.prefill_attention(params["attn"], y, cfg,
                                               kind, positions, cache,
                                               blocked=rt.blocked_attn,
                                               block_k=rt.attn_block_k)
        else:
            y, new_kv = attn.decode_attention(
                params["attn"], y, cfg, kind, cache, cur_index,
                onehot_update=rt.onehot_cache_update,
                grouped_gqa=rt.grouped_gqa_decode)
        if cfg.use_post_norm:
            y = rmsnorm(params["post_ln1"], y)
        x = x + y
        y = rmsnorm(params["ln2"], x)
        y = _apply_mlp(params, y, cfg, rt)
        if cfg.use_post_norm:
            y = rmsnorm(params["post_ln2"], y)
        return x + y, new_kv

    if kind == "rglru":
        if mode == "decode":
            x, new_state = rglru_lib.rglru_decode_step(params["rec"], x,
                                                       cache)
        else:
            state = cache if cache is not None else \
                rglru_lib.rglru_state(x.shape[0], cfg)
            x, new_state = rglru_lib.rglru_block(params["rec"], x, state)
        y = rmsnorm(params["ln2"], x)
        x = x + _apply_mlp(params, y, cfg, rt)
        return x, (new_state if mode != "train" else None)

    if kind == "mlstm":
        state = cache if cache is not None else \
            ssm_lib.mlstm_state(x.shape[0], cfg)
        x, new_state = ssm_lib.mlstm_block(params["cell"], x, state)
        return x, (new_state if mode != "train" else None)

    if kind == "slstm":
        state = cache if cache is not None else \
            ssm_lib.slstm_state(x.shape[0], cfg)
        x, new_state = ssm_lib.slstm_block(params["cell"], x, state)
        return x, (new_state if mode != "train" else None)

    raise ValueError(kind)


# ============================ trunk ==============================================
def embed_inputs(params: dict, tokens: jax.Array, cfg: ArchConfig,
                 extra_embed: Optional[jax.Array] = None) -> jax.Array:
    """Token embeddings, optionally prefixed with projected modality
    embeddings (VLM patch tokens / audio frames)."""
    x = embed(params["embed"], tokens, scale_by_sqrt_dim=cfg.embed_scale)
    if extra_embed is not None:
        v = jnp.einsum("bnd,de->bne", extra_embed.astype(x.dtype),
                       params["vision_proj"])
        x = jnp.concatenate([v, x], axis=1)
    return x


def _run_layers(params: dict, x: jax.Array, cfg: ArchConfig, mode: str,
                positions: jax.Array, cache: Optional[dict],
                cur_index, rt: Runtime
                ) -> tuple[jax.Array, Optional[dict]]:
    x = rt.constrain(x, rt.dp, None, None)

    def body(h, xs):
        pparams, pcache = xs
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            c = pcache[f"k{i}"] if pcache is not None else None
            h, nc = apply_layer(pparams[f"k{i}"], h, cfg, kind, mode,
                                positions, c, cur_index, rt)
            h = rt.constrain(h, rt.dp, None, None)
            if nc is not None:
                new_caches[f"k{i}"] = nc
        return h, (new_caches if new_caches else None)

    if mode == "train" and rt.remat == "full":
        # activation checkpointing per layer period: backward recomputes
        # the period body — O(1) stored activations per layer instead of
        # O(S²) attention internals (required at train_4k scale)
        body = jax.checkpoint(body)

    pcaches = cache["periods"] if cache is not None else None
    x, new_period_caches = jax.lax.scan(
        body, x, (params["periods"], pcaches), unroll=rt.scan_unroll)

    new_cache: Optional[dict] = None
    if mode != "train":
        new_cache = {"periods": new_period_caches}
    for j, kind in enumerate(cfg.tail_kinds):
        c = cache[f"tail{j}"] if cache is not None else None
        x, nc = apply_layer(params[f"tail{j}"], x, cfg, kind, mode,
                            positions, c, cur_index, rt)
        if new_cache is not None:
            new_cache[f"tail{j}"] = nc
    return x, new_cache


def _logits(params: dict, x: jax.Array, cfg: ArchConfig, rt: Runtime
            ) -> jax.Array:
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg.vocab_size,
                     cap=cfg.final_logit_softcap)
    return rt.constrain(logits, rt.dp, None, rt.tp_axis)


def forward_train(params: dict, tokens: jax.Array, cfg: ArchConfig,
                  rt: Runtime = LOCAL,
                  extra_embed: Optional[jax.Array] = None) -> jax.Array:
    """(B,S) tokens → (B,S',V_padded) logits (S' includes modality prefix)."""
    x = embed_inputs(params, tokens, cfg, extra_embed)
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None, :],
        (x.shape[0], x.shape[1]))
    x, _ = _run_layers(params, x, cfg, "train", positions, None, None, rt)
    return _logits(params, x, cfg, rt)


def prefill(params: dict, tokens: jax.Array, cfg: ArchConfig,
            cache: dict, rt: Runtime = LOCAL,
            extra_embed: Optional[jax.Array] = None
            ) -> tuple[jax.Array, dict]:
    """Populate caches over the prompt; returns last-position logits."""
    x = embed_inputs(params, tokens, cfg, extra_embed)
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None, :],
        (x.shape[0], x.shape[1]))
    x, new_cache = _run_layers(params, x, cfg, "prefill", positions,
                               cache, None, rt)
    logits = _logits(params, x[:, -1:, :], cfg, rt)
    return logits, new_cache


def decode_step(params: dict, token: jax.Array, cfg: ArchConfig,
                cache: dict, cur_index, rt: Runtime = LOCAL
                ) -> tuple[jax.Array, dict]:
    """token (B,1) at position ``cur_index`` (scalar or per-sequence
    (B,) vector) → (B,1,V) logits + updated caches."""
    x = embed_inputs(params, token, cfg)
    cur = jnp.broadcast_to(jnp.asarray(cur_index, jnp.int32),
                           (x.shape[0],))
    positions = cur[:, None]
    x, new_cache = _run_layers(params, x, cfg, "decode", positions,
                               cache, cur, rt)
    return _logits(params, x, cfg, rt), new_cache
