"""xLSTM cells (arXiv:2405.04517): mLSTM (matrix memory, parallel-able)
and sLSTM (scalar memory, sequential) — the ``[ssm]`` family.

Both are exact recurrences with exponential gating and the paper's
max-stabiliser m_t.  Sequence processing uses ``lax.scan`` over time
(exact; the chunked-parallel mLSTM form is a recorded §Perf follow-up);
decode is a single recurrence step with O(1) carried state — which is
why xlstm-350m runs the long_500k cell.

State shapes (per layer):
  mLSTM: C (B,H,dh,dh), n (B,H,dh), m (B,H)
  sLSTM: c,n,h (B,H,dh), m (B,H)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_rmsnorm, rmsnorm


# =========================== mLSTM ============================================
def init_mlstm_block(key, cfg, dtype) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    inner = int(cfg.mlstm_proj_factor * d)
    dh = inner // H
    ks = jax.random.split(key, 8)
    return {
        "ln": init_rmsnorm(d),
        "w_up": dense_init(ks[0], (d, inner), dtype),
        "w_gate_branch": dense_init(ks[1], (d, inner), dtype),
        "wq": dense_init(ks[2], (inner, H, dh), dtype),
        "wk": dense_init(ks[3], (inner, H, dh), dtype),
        "wv": dense_init(ks[4], (inner, H, dh), dtype),
        # scalar gate preactivations per head
        "w_i": dense_init(ks[5], (inner, H), jnp.float32, scale=0.01),
        "w_f": dense_init(ks[6], (inner, H), jnp.float32, scale=0.01),
        "b_i": jnp.zeros((H,), jnp.float32),
        # forget bias init positive → long memory at init
        "b_f": jnp.full((H,), 3.0, jnp.float32),
        "w_down": dense_init(ks[7], (inner, d), dtype),
        "out_ln": init_rmsnorm(inner),
    }


def mlstm_state(batch: int, cfg, dtype=jnp.float32) -> dict:
    H = cfg.num_heads
    dh = int(cfg.mlstm_proj_factor * cfg.d_model) // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), dtype),
        "n": jnp.zeros((batch, H, dh), dtype),
        "m": jnp.full((batch, H), -1e30, dtype),
    }


def _mlstm_step(state: dict, qkvif) -> tuple[dict, jax.Array]:
    """One stabilised mLSTM recurrence step (all fp32).

    q,k,v: (B,H,dh); i_pre,f_pre: (B,H)."""
    q, k, v, i_pre, f_pre = qkvif
    C, n, m = state["C"], state["n"], state["m"]
    dh = q.shape[-1]
    k = k / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    # log-space forget (sigmoid-style: log σ(f̃) keeps f ∈ (0,1))
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    f_eff = jnp.exp(log_f + m - m_new)          # (B,H)
    i_eff = jnp.exp(i_pre - m_new)
    C_new = (f_eff[..., None, None] * C
             + i_eff[..., None, None] * v[..., :, None] * k[..., None, :])
    n_new = f_eff[..., None] * n + i_eff[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C_new, q)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q))
    den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
    h = num / den
    return {"C": C_new, "n": n_new, "m": m_new}, h


def mlstm_sequence(params: dict, x_inner: jax.Array, state: dict,
                   ) -> tuple[jax.Array, dict]:
    """x_inner (B,S,inner) → (h (B,S,inner), final state).  Exact scan."""
    B, S, inner = x_inner.shape
    H = params["wq"].shape[1]
    dh = params["wq"].shape[2]
    xf = x_inner
    q = jnp.einsum("bsi,ihd->bshd", xf, params["wq"]).astype(jnp.float32)
    k = jnp.einsum("bsi,ihd->bshd", xf, params["wk"]).astype(jnp.float32)
    v = jnp.einsum("bsi,ihd->bshd", xf, params["wv"]).astype(jnp.float32)
    i_pre = (jnp.einsum("bsi,ih->bsh", xf.astype(jnp.float32), params["w_i"])
             + params["b_i"])
    f_pre = (jnp.einsum("bsi,ih->bsh", xf.astype(jnp.float32), params["w_f"])
             + params["b_f"])

    def body(st, inp):
        st2, h = _mlstm_step(st, inp)
        return st2, h

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1))
    state, hs = jax.lax.scan(body, state, xs)          # hs (S,B,H,dh)
    h = hs.swapaxes(0, 1).reshape(B, S, H * dh)
    return h.astype(x_inner.dtype), state


def mlstm_block(params: dict, x: jax.Array, state: dict,
                ) -> tuple[jax.Array, dict]:
    """Full mLSTM residual block: LN → up-proj (2 branches) → cell →
    SiLU-gated merge → down-proj → residual."""
    y = rmsnorm(params["ln"], x)
    up = jnp.einsum("bsd,di->bsi", y, params["w_up"])
    gate = jnp.einsum("bsd,di->bsi", y, params["w_gate_branch"])
    h, state = mlstm_sequence(params, up, state)
    h = rmsnorm(params["out_ln"], h)
    h = h * jax.nn.silu(gate.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("bsi,id->bsd", h, params["w_down"])
    return x + out, state


# =========================== sLSTM ============================================
def init_slstm_block(key, cfg, dtype) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    f_inner = int(cfg.slstm_proj_factor * d)
    ks = jax.random.split(key, 10)
    return {
        "ln": init_rmsnorm(d),
        # input projections per gate
        "w_z": dense_init(ks[0], (d, H, dh), dtype),
        "w_i": dense_init(ks[1], (d, H, dh), jnp.float32, scale=0.01),
        "w_f": dense_init(ks[2], (d, H, dh), jnp.float32, scale=0.01),
        "w_o": dense_init(ks[3], (d, H, dh), dtype),
        # block-diagonal (per-head) recurrent matrices
        "r_z": dense_init(ks[4], (H, dh, dh), jnp.float32),
        "r_i": dense_init(ks[5], (H, dh, dh), jnp.float32, scale=0.01),
        "r_f": dense_init(ks[6], (H, dh, dh), jnp.float32, scale=0.01),
        "r_o": dense_init(ks[7], (H, dh, dh), jnp.float32),
        "b_z": jnp.zeros((H, dh), jnp.float32),
        "b_i": jnp.zeros((H, dh), jnp.float32),
        "b_f": jnp.full((H, dh), 3.0, jnp.float32),
        "b_o": jnp.zeros((H, dh), jnp.float32),
        "out_ln": init_rmsnorm(d),
        # post-cell gated FFN (proj factor 4/3)
        "w_ff_up": dense_init(ks[8], (d, 2 * f_inner), dtype),
        "w_ff_down": dense_init(ks[9], (f_inner, d), dtype),
    }


def slstm_state(batch: int, cfg, dtype=jnp.float32) -> dict:
    H = cfg.num_heads
    dh = cfg.d_model // H
    return {
        "c": jnp.zeros((batch, H, dh), dtype),
        "n": jnp.zeros((batch, H, dh), dtype),
        "h": jnp.zeros((batch, H, dh), dtype),
        "m": jnp.full((batch, H, dh), -1e30, dtype),
    }


def _slstm_step(params: dict, state: dict, x_t: jax.Array
                ) -> tuple[dict, jax.Array]:
    """x_t (B,d) → h (B,H,dh).  Stabilised sLSTM with per-head
    recurrent block-diagonal matrices."""
    c, n, h_prev, m = state["c"], state["n"], state["h"], state["m"]
    xf = x_t.astype(jnp.float32)

    def inp(w):  # (B,H,dh)
        return jnp.einsum("bd,dhk->bhk", xf, w.astype(jnp.float32))

    def rec(r):  # recurrent contribution
        return jnp.einsum("bhk,hkj->bhj", h_prev, r)

    z = jnp.tanh(inp(params["w_z"]) + rec(params["r_z"]) + params["b_z"])
    o = jax.nn.sigmoid(inp(params["w_o"]) + rec(params["r_o"])
                       + params["b_o"])
    i_pre = inp(params["w_i"]) + rec(params["r_i"]) + params["b_i"]
    f_pre = inp(params["w_f"]) + rec(params["r_f"]) + params["b_f"]
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    f_eff = jnp.exp(log_f + m - m_new)
    i_eff = jnp.exp(i_pre - m_new)
    c_new = f_eff * c + i_eff * z
    n_new = f_eff * n + i_eff
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return ({"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new)


def slstm_block(params: dict, x: jax.Array, state: dict
                ) -> tuple[jax.Array, dict]:
    """sLSTM residual block + its gated FFN (xLSTM paper structure)."""
    B, S, d = x.shape
    y = rmsnorm(params["ln"], x)

    def body(st, x_t):
        return _slstm_step(params, st, x_t)

    state, hs = jax.lax.scan(body, state, y.swapaxes(0, 1))  # (S,B,H,dh)
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    x = x + rmsnorm(params["out_ln"], h)
    # gated FFN
    y2 = rmsnorm(params["out_ln"], x)
    up = jnp.einsum("bsd,df->bsf", y2, params["w_ff_up"])
    a, b = jnp.split(up, 2, axis=-1)
    hff = jax.nn.gelu(a.astype(jnp.float32),
                      approximate=True).astype(x.dtype) * b
    return x + jnp.einsum("bsf,fd->bsd", hff, params["w_ff_down"]), state
