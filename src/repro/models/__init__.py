"""Model stack: unified configs + family implementations.

Families: dense GQA (llama / gemma2 local+global softcap), MoE (qwen3),
xLSTM (mLSTM/sLSTM), RG-LRU hybrid (recurrentgemma), encoder-decoder
(whisper), VLM backbone (internvl2, stub vision frontend).
"""
from repro.models.config import SHAPES, ArchConfig, ShapeSpec
from repro.models.registry import Model, build_model, param_count
from repro.models.runtime import LOCAL, Runtime

__all__ = ["ArchConfig", "LOCAL", "Model", "Runtime", "SHAPES",
           "ShapeSpec", "build_model", "param_count"]
