"""Encoder-decoder transformer (whisper-small backbone).

The audio frontend (log-mel conv stem) is a STUB per the assignment:
``input_specs`` supplies precomputed frame embeddings (B, S_enc, d).
Encoder: sinusoidal positions + bidirectional attention.  Decoder:
causal self-attention (KV cache) + cross-attention over precomputed
encoder K/V + MLP.  Decoder layers are scanned like the decoder-only
trunk; encoder likewise.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.config import ArchConfig
from repro.models.layers import (
    dtype_of,
    embed,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
    sinusoidal_positions,
    unembed,
)
from repro.models.runtime import LOCAL, Runtime


def init_encoder_layer(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": attn.init_attention(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype),
    }


def init_decoder_layer(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model),
        "self_attn": attn.init_attention(k1, cfg, dtype),
        "ln_x": init_rmsnorm(cfg.d_model),
        "cross_attn": attn.init_attention(k2, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype),
    }


def init_params(key, cfg: ArchConfig) -> dict:
    dtype = dtype_of(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    ekeys = jax.random.split(k2, cfg.encoder_layers)
    dkeys = jax.random.split(k3, cfg.num_layers)
    return {
        "embed": init_embedding(k1, cfg.padded_vocab, cfg.d_model, dtype),
        "enc_layers": jax.vmap(
            lambda k: init_encoder_layer(k, cfg, dtype))(ekeys),
        "enc_norm": init_rmsnorm(cfg.d_model),
        "dec_layers": jax.vmap(
            lambda k: init_decoder_layer(k, cfg, dtype))(dkeys),
        "final_norm": init_rmsnorm(cfg.d_model),
    }


def encode(params: dict, frames: jax.Array, cfg: ArchConfig,
           rt: Runtime = LOCAL, blocked: bool = False) -> jax.Array:
    """frames: precomputed (B, S_enc, d) stub-frontend embeddings."""
    S = frames.shape[1]
    x = frames.astype(dtype_of(cfg.dtype))
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :],
                                 (x.shape[0], S))
    x = rt.constrain(x, rt.dp, None, None)

    def body(h, lp):
        y = rmsnorm(lp["ln1"], h)
        y = attn.encoder_attention_block(lp["attn"], y, cfg, positions,
                                         blocked=blocked)
        h = h + y
        y = rmsnorm(lp["ln2"], h)
        h = h + mlp(lp["mlp"], y, cfg.mlp_kind)
        return rt.constrain(h, rt.dp, None, None), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=rt.scan_unroll)
    return rmsnorm(params["enc_norm"], x)


def cross_kv(params: dict, enc_out: jax.Array) -> dict:
    """(stacked over decoder layers): {"k","v"}: (L,B,S_enc,H_kv,dh)."""
    wk = params["dec_layers"]["cross_attn"]["wk"]   # (L,d,hk,dh)
    wv = params["dec_layers"]["cross_attn"]["wv"]
    k = jnp.einsum("bsd,ldhk->lbshk", enc_out, wk)
    v = jnp.einsum("bsd,ldhk->lbshk", enc_out, wv)
    return {"k": k, "v": v}


def _decoder_stack(params: dict, x: jax.Array, cfg: ArchConfig,
                   mode: str, positions: jax.Array, xkv: dict,
                   cache: Optional[dict], cur_index, rt: Runtime
                   ) -> tuple[jax.Array, Optional[dict]]:
    def body(h, xs):
        lp, lxkv, lcache = xs
        y = rmsnorm(lp["ln1"], h)
        if mode == "train":
            y = attn.attention_block(lp["self_attn"], y, cfg, "global",
                                     positions)
            new_kv = None
        elif mode == "prefill":
            y, new_kv = attn.prefill_attention(lp["self_attn"], y, cfg,
                                               "global", positions,
                                               lcache,
                                               blocked=rt.blocked_attn)
        else:
            y, new_kv = attn.decode_attention(
                lp["self_attn"], y, cfg, "global", lcache, cur_index,
                onehot_update=rt.onehot_cache_update,
                grouped_gqa=rt.grouped_gqa_decode)
        h = h + y
        y = rmsnorm(lp["ln_x"], h)
        y = attn.cross_attention_block(lp["cross_attn"], y, lxkv, cfg)
        h = h + y
        y = rmsnorm(lp["ln2"], h)
        h = h + mlp(lp["mlp"], y, cfg.mlp_kind)
        h = rt.constrain(h, rt.dp, None, None)
        return h, new_kv

    lcaches = cache["dec"] if cache is not None else None
    x, new_kv = jax.lax.scan(body, x, (params["dec_layers"], xkv, lcaches),
                             unroll=rt.scan_unroll)
    new_cache = {"dec": new_kv} if mode != "train" else None
    return x, new_cache


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               rt: Runtime = LOCAL) -> dict:
    one = attn.init_kv_cache(batch, max_seq, cfg, rt.cache_dtype())
    dt = dtype_of(cfg.dtype)
    L = cfg.num_layers
    return {
        "dec": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), one),
        # cross-KV over the encoder output (populated by prefill; sized
        # to max_seq so the decode dry-run cell is self-contained)
        "xkv": {
            "k": jnp.zeros((L, batch, max_seq, cfg.num_kv_heads,
                            cfg.head_dim), dt),
            "v": jnp.zeros((L, batch, max_seq, cfg.num_kv_heads,
                            cfg.head_dim), dt),
        },
    }


def forward_train(params: dict, tokens: jax.Array, cfg: ArchConfig,
                  rt: Runtime = LOCAL,
                  extra_embed: Optional[jax.Array] = None) -> jax.Array:
    """Teacher-forced training: frames (extra_embed) + decoder tokens."""
    enc_out = encode(params, extra_embed, cfg, rt)
    xkv = cross_kv(params, enc_out)
    x = embed(params["embed"], tokens)
    S = x.shape[1]
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :],
                                 (x.shape[0], S))
    x, _ = _decoder_stack(params, x, cfg, "train", positions, xkv, None,
                          None, rt)
    x = rmsnorm(params["final_norm"], x)
    return unembed(params["embed"], x, cfg.vocab_size,
                   cap=cfg.final_logit_softcap)


def prefill(params: dict, tokens: jax.Array, cfg: ArchConfig,
            cache: dict, rt: Runtime = LOCAL,
            extra_embed: Optional[jax.Array] = None
            ) -> tuple[jax.Array, dict]:
    """Encode audio + consume the decoder prompt; cache ready to decode.

    The cross-KV is recomputed at decode; callers that decode many steps
    should stash it via ``cross_kv`` (the engine does)."""
    enc_out = encode(params, extra_embed, cfg, rt,
                     blocked=rt.blocked_attn)
    xkv = cross_kv(params, enc_out)
    x = embed(params["embed"], tokens)
    S = x.shape[1]
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :],
                                 (x.shape[0], S))
    x, new_cache = _decoder_stack(params, x, cfg, "prefill", positions,
                                  xkv, cache, None, rt)
    new_cache["xkv"] = xkv
    x = rmsnorm(params["final_norm"], x[:, -1:, :])
    logits = unembed(params["embed"], x, cfg.vocab_size,
                     cap=cfg.final_logit_softcap)
    return logits, new_cache


def decode_step(params: dict, token: jax.Array, cfg: ArchConfig,
                cache: dict, cur_index, rt: Runtime = LOCAL
                ) -> tuple[jax.Array, dict]:
    xkv = cache["xkv"]
    x = embed(params["embed"], token)
    cur = jnp.broadcast_to(jnp.asarray(cur_index, jnp.int32),
                           (x.shape[0],))
    # sinusoidal embedding of the (traced, per-sequence) positions
    dim = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)
    angle = cur.astype(jnp.float32)[:, None] \
        / jnp.power(10000.0, dim / cfg.d_model)[None, :]   # (B, d/2)
    pos_emb = jnp.zeros((x.shape[0], cfg.d_model), jnp.float32)
    pos_emb = pos_emb.at[:, 0::2].set(jnp.sin(angle))
    pos_emb = pos_emb.at[:, 1::2].set(jnp.cos(angle))
    x = x + pos_emb.astype(x.dtype)[:, None, :]
    positions = cur[:, None]
    x, new_cache = _decoder_stack(params, x, cfg, "decode", positions,
                                  xkv, cache, cur, rt)
    new_cache["xkv"] = xkv
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg.vocab_size,
                     cap=cfg.final_logit_softcap)
    return logits, new_cache
