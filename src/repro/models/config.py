"""Unified architecture config covering all assigned families.

One ``ArchConfig`` describes any of: dense GQA transformers (incl.
gemma2's alternating local/global attention with logit soft-capping),
MoE transformers (qwen3), xLSTM stacks (mLSTM/sLSTM), RG-LRU hybrids
(recurrentgemma), encoder-decoder (whisper) and VLM backbones
(internvl2, stub vision frontend).

The decoder stack is described by ``pattern``: a repeating tuple of
layer *kinds*.  Layers are stacked per pattern position and scanned
(``jax.lax.scan``) over the repeat count, keeping HLO size O(pattern)
instead of O(num_layers) — this is what makes the 94-layer 235B config
compile in seconds.  A non-divisible tail (e.g. recurrentgemma's
26 = 8×3 + 2) is materialised as explicit unstacked layers.

Layer kinds:
  "global"  — full causal self-attention
  "local"   — sliding-window causal self-attention (window_size)
  "mlstm"   — xLSTM matrix-memory cell (chunked parallel / recurrent)
  "slstm"   — xLSTM scalar-memory cell (sequential scan)
  "rglru"   — Griffin RG-LRU recurrent block (associative scan)
Any kind can carry an MoE MLP (``num_experts > 0``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    max_seq_len: int = 32768

    # decoder layer pattern (repeats to cover num_layers)
    pattern: tuple[str, ...] = ("global",)
    window_size: int = 4096

    # gemma2-style soft-capping
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None

    mlp_kind: str = "swiglu"          # swiglu|geglu|gelu
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    use_post_norm: bool = False       # gemma2 sandwich norms
    embed_scale: bool = False         # gemma-style √d embedding scale

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    norm_topk_prob: bool = True

    # xLSTM
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    mlstm_chunk: int = 256

    # RG-LRU (Griffin)
    rnn_width: int = 0                # 0 → d_model
    conv_width: int = 4

    # encoder-decoder (whisper): encoder is full-attention bidirectional
    encoder_layers: int = 0
    is_encoder_decoder: bool = False

    # VLM stub frontend: number of patch-embedding tokens prepended
    num_vision_tokens: int = 0

    dtype: str = "bfloat16"

    # citation / provenance tag from the assignment table
    source: str = ""

    # ---- derived ------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def n_periods(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def tail_kinds(self) -> tuple[str, ...]:
        tail = self.num_layers % len(self.pattern)
        return self.pattern[:tail]

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the TP axis divides the
        embedding table (internvl2's 92553, whisper's 51865)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def kv_bytes_per_token(self) -> float:
        """c = 2·L·H_kv·d_h·b over *attention* layers only (paper §3.1);
        recurrent layers contribute O(1) state, not per-token KV."""
        bytes_per = 2 if self.dtype == "bfloat16" else 4
        attn_layers = sum(
            1 for k in self._all_kinds() if k in ("global", "local"))
        return 2.0 * attn_layers * self.num_kv_heads * self.head_dim * bytes_per

    def _all_kinds(self) -> list[str]:
        kinds = list(self.pattern) * self.n_periods + list(self.tail_kinds)
        return kinds

    @property
    def supports_long_context(self) -> bool:
        """True iff per-token KV state is bounded (windowed/recurrent
        layers only) or half-bounded (gemma2: global layers sequence-
        shardable).  Pure full-attention stacks are excluded."""
        kinds = set(self._all_kinds())
        if kinds <= {"local", "mlstm", "slstm", "rglru"}:
            return True
        # gemma2: alternating local/global — global KV sequence-sharded
        return "local" in kinds and "global" in kinds

    def validate(self) -> None:
        assert self.num_layers >= 1
        assert self.d_model % 2 == 0
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, \
            "GQA requires H % H_kv == 0"
        if self.is_moe:
            assert self.experts_per_token <= self.num_experts
        if self.is_encoder_decoder:
            assert self.encoder_layers > 0

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=max(2, 2 * len(self.pattern) if len(self.pattern) > 1
                           else 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=512,
            max_seq_len=128,
            window_size=min(self.window_size, 32),
            num_experts=min(self.num_experts, 8) if self.is_moe else 0,
            experts_per_token=(min(self.experts_per_token, 2)
                               if self.is_moe else 0),
            rnn_width=0 if self.rnn_width == 0 else 64,
            encoder_layers=2 if self.is_encoder_decoder else 0,
            num_vision_tokens=(8 if self.num_vision_tokens else 0),
            mlstm_chunk=16,
            name=self.name + "-smoke",
        )
        # keep the layer pattern's *structure* (tail included) by
        # matching num_layers to pattern period + tail shape
        period = len(self.pattern)
        tail = self.num_layers % period
        small["num_layers"] = period * 2 + tail
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Input shape sets (assigned): every LM arch × these four cells.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
