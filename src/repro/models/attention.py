"""Attention: GQA with RoPE, full/sliding-window causal masks, gemma2
logit soft-capping; prefill (writes KV cache) and single-token decode
(reads dense KV cache) paths.

The dense-KV paths here are the XLA reference used for training, the
multi-pod dry-run, and as oracles for the Pallas kernels
(``repro.kernels.flash_attention`` / ``paged_attention``).  A
``kernel_backend`` switch in the engine selects the Pallas path on real
TPU hardware.

Shapes: activations (B, S, d); q/k/v (B, S, H, dh); dense KV cache per
layer (B, S_max, H_kv, dh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, softcap


def init_attention(key, cfg, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "wq": dense_init(k1, (d, cfg.num_heads, cfg.head_dim), dtype),
        "wk": dense_init(k2, (d, cfg.num_kv_heads, cfg.head_dim), dtype),
        "wv": dense_init(k3, (d, cfg.num_kv_heads, cfg.head_dim), dtype),
        "wo": dense_init(k4, (cfg.num_heads, cfg.head_dim, d), dtype),
    }


def _repeat_kv(x: jax.Array, groups: int) -> jax.Array:
    """(B,S,H_kv,dh) → (B,S,H,dh) by repeating each kv head."""
    if groups == 1:
        return x
    return jnp.repeat(x, groups, axis=2)


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: int | None, k_valid: jax.Array | None = None
               ) -> jax.Array:
    """Additive attention bias (Sq, Sk) in fp32; -inf where masked."""
    neg = jnp.asarray(-2.38e38, jnp.float32)
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok = ok & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
    if k_valid is not None:
        ok = ok & k_valid[None, :]
    return jnp.where(ok, 0.0, neg)


def attend(q: jax.Array, k: jax.Array, v: jax.Array, bias: jax.Array,
           logit_cap: float | None) -> jax.Array:
    """q:(B,Sq,H,dh) k,v:(B,Sk,H,dh) bias:(Sq,Sk) or (B,Sq,Sk)
    → (B,Sq,H,dh).  Softmax in fp32 (bf16 logits lose too much range
    with softcaps)."""
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, logit_cap)
    if bias.ndim == 2:
        logits = logits + bias[None, None, :, :]
    else:                                   # per-batch bias (decode)
        logits = logits + bias[:, None, :, :]
    weights = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", weights, v)


def attend_blocked(q: jax.Array, k: jax.Array, v: jax.Array,
                   q_pos: jax.Array, causal: bool, window: int | None,
                   logit_cap: float | None,
                   block_k: int = 1024) -> jax.Array:
    """Online-softmax attention, scanning K/V blocks — the XLA-level
    flash schedule.  Peak temp drops from O(S²) logits to O(S·block_k):
    this is what makes prefill_32k fit HBM (§Perf hillclimb A).

    Inference-path only (the scan carries (m, l, acc); its backward
    would store per-block carries — training uses the fused+remat
    path instead).  q (B,S,H,dh); k,v (B,Sk,H,dh) head-expanded.
    """
    B, S, H, dh = q.shape
    Sk = k.shape[1]
    bk = min(block_k, Sk)
    # pad Sk to a block multiple (padded keys masked via k_pos >= Sk)
    pad = (-Sk) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = (Sk + pad) // bk
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    neg = jnp.asarray(-2.38e38, jnp.float32)
    qf = q.astype(jnp.float32) * scale

    def body(carry, j):
        m, l, acc = carry
        kj = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, 1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, 1)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kj.astype(jnp.float32))
        s = softcap(s, logit_cap)
        k_pos = j * bk + jnp.arange(bk)
        ok = (k_pos[None, :] < Sk) & jnp.ones((S, bk), bool)
        if causal:
            ok = ok & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(ok[None, None], s, neg)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(ok[None, None], jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.where(m > neg / 2, jnp.exp(m - m_new), 0.0)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = (acc * alpha[..., None]
                   + jnp.einsum("bhqk,bkhd->bhqd", p,
                                vj.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S), neg, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    acc0 = jnp.zeros((B, H, S, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B,S,H,dh)


#: sequences at or above this use the blocked schedule on no-grad paths
BLOCKED_ATTN_THRESHOLD = 4096


def attention_block(params: dict, x: jax.Array, cfg, kind: str,
                    positions: jax.Array) -> jax.Array:
    """Self-attention over full sequences (train / prefill compute).

    kind: "global" (full causal) or "local" (sliding window causal)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k = _repeat_kv(k, cfg.num_heads // cfg.num_kv_heads)
    v = _repeat_kv(v, cfg.num_heads // cfg.num_kv_heads)
    pos1d = positions[0] if positions.ndim > 1 else positions
    bias = _mask_bias(pos1d, pos1d, causal=True,
                      window=cfg.window_size if kind == "local" else None)
    out = attend(q, k, v, bias, cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def encoder_attention_block(params: dict, x: jax.Array, cfg,
                            positions: jax.Array,
                            blocked: bool = False) -> jax.Array:
    """Bidirectional self-attention (whisper encoder)."""
    S = x.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    k = _repeat_kv(k, cfg.num_heads // cfg.num_kv_heads)
    v = _repeat_kv(v, cfg.num_heads // cfg.num_kv_heads)
    pos1d = positions[0] if positions.ndim > 1 else positions
    if blocked and S >= BLOCKED_ATTN_THRESHOLD:
        out = attend_blocked(q, k, v, pos1d, causal=False, window=None,
                             logit_cap=cfg.attn_logit_softcap)
    else:
        bias = _mask_bias(pos1d, pos1d, causal=False, window=None)
        out = attend(q, k, v, bias, cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def cross_attention_block(params: dict, x: jax.Array, enc_kv: dict, cfg
                          ) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = _repeat_kv(enc_kv["k"], cfg.num_heads // cfg.num_kv_heads)
    v = _repeat_kv(enc_kv["v"], cfg.num_heads // cfg.num_kv_heads)
    Sq, Sk = q.shape[1], k.shape[1]
    bias = jnp.zeros((Sq, Sk), jnp.float32)
    out = attend(q, k, v, bias, cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def encoder_kv(params: dict, enc_out: jax.Array) -> dict:
    return {
        "k": jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"]),
        "v": jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"]),
    }


# -- KV-cache paths --------------------------------------------------------------
def kv_cache_len(cfg, kind: str, max_seq: int) -> int:
    """Windowed layers keep a ring buffer of ``window_size`` slots —
    this is what bounds gemma2/recurrentgemma KV at 500k context."""
    if kind == "local":
        return min(cfg.window_size, max_seq)
    return max_seq


def init_kv_cache(batch: int, max_seq: int, cfg, dtype,
                  kind: str = "global") -> dict:
    S = kv_cache_len(cfg, kind, max_seq)
    return {
        "k": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def prefill_attention(params: dict, x: jax.Array, cfg, kind: str,
                      positions: jax.Array, cache: dict,
                      blocked: bool = False,
                      block_k: int = 1024) -> tuple[jax.Array, dict]:
    """Full-sequence attention that also writes the KV cache.

    Global layers write [0, S); local layers write the last
    ``window_size`` tokens into their ring buffer (slot = pos % S_loc).
    """
    S = x.shape[1]
    S_loc = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    def write(buf, new):
        new = new.astype(buf.dtype)
        if S <= S_loc:
            return jax.lax.dynamic_update_slice(buf, new, (0, 0, 0, 0))
        # ring: last S_loc tokens; token j of the chunk lands in slot
        # (j + S) % S_loc  (static shift — S, S_loc static at trace time)
        chunk = new[:, S - S_loc:, :, :]
        return jnp.roll(chunk, S % S_loc, axis=1)

    new_cache = {"k": write(cache["k"], k), "v": write(cache["v"], v)}
    kf = _repeat_kv(k, cfg.num_heads // cfg.num_kv_heads)
    vf = _repeat_kv(v, cfg.num_heads // cfg.num_kv_heads)
    pos1d = positions[0] if positions.ndim > 1 else positions
    window = cfg.window_size if kind == "local" else None
    if blocked and S >= BLOCKED_ATTN_THRESHOLD:
        # no-grad path: blocked online-softmax keeps temp O(S·block)
        out = attend_blocked(q, kf, vf, pos1d, causal=True,
                             window=window,
                             logit_cap=cfg.attn_logit_softcap,
                             block_k=block_k)
    else:
        bias = _mask_bias(pos1d, pos1d, causal=True, window=window)
        out = attend(q, kf, vf, bias, cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), new_cache


def decode_attention(params: dict, x: jax.Array, cfg, kind: str,
                     cache: dict, cur_index: jax.Array,
                     onehot_update: bool = False,
                     grouped_gqa: bool = False
                     ) -> tuple[jax.Array, dict]:
    """One-token decode: x (B,1,d); reads/updates the KV cache.

    ``cur_index``: position of the new token (context length so far) —
    a scalar, or a (B,) vector for continuous batching where every
    sequence sits at a different offset.  Global layers use the linear
    cache; local layers use the ring buffer — slot s holds absolute
    position ``cur − ((cur − s) mod S_loc)``, from which the
    causal+window mask is reconstructed.
    """
    B, _, _ = x.shape
    S_loc = cache["k"].shape[1]
    cur = jnp.broadcast_to(jnp.asarray(cur_index, jnp.int32), (B,))
    positions = cur[:, None]                               # (B,1)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    ring = kind == "local"
    slot = jnp.mod(cur, S_loc) if ring else jnp.minimum(cur, S_loc - 1)
    if onehot_update:
        # sharding-preserving write: select along the (possibly
        # sequence-sharded) S axis — GSPMD keeps it fully local,
        # whereas a dynamic scatter forces cache replication (§Perf B)
        hit = (jnp.arange(S_loc)[None, :] == slot[:, None])  # (B,S)
        sel = hit[:, :, None, None]
        new_cache = {
            "k": jnp.where(sel, k.astype(cache["k"].dtype), cache["k"]),
            "v": jnp.where(sel, v.astype(cache["v"].dtype), cache["v"]),
        }
    else:
        bidx = jnp.arange(B)
        new_cache = {
            "k": cache["k"].at[bidx, slot].set(
                k[:, 0].astype(cache["k"].dtype)),
            "v": cache["v"].at[bidx, slot].set(
                v[:, 0].astype(cache["v"].dtype)),
        }
    slots = jnp.arange(S_loc)                              # (S,)
    if ring:
        k_pos = cur[:, None] - jnp.mod(cur[:, None] - slots[None, :],
                                       S_loc)              # (B,S)
    else:
        k_pos = jnp.broadcast_to(slots[None, :], (B, S_loc))
    window = cfg.window_size if kind == "local" else None
    ok = (k_pos <= cur[:, None]) & (k_pos >= 0)
    if window is not None:
        ok = ok & (cur[:, None] - k_pos < window)
    neg = jnp.asarray(-2.38e38, jnp.float32)
    if grouped_gqa:
        # §Perf hillclimb: contract against the RAW (B,S,H_kv,dh) cache
        # by grouping the query heads — no jnp.repeat, so GSPMD never
        # replicates a sequence-sharded cache to materialise the
        # broadcast (the long_500k all-gather pathology), and KV is
        # read once instead of H/H_kv times.
        Hkv = cfg.num_kv_heads
        G = cfg.num_heads // Hkv
        dh = cfg.head_dim
        qg = q.reshape(B, 1, Hkv, G, dh)
        scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, new_cache["k"],
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cfg.attn_logit_softcap)
        s = s + jnp.where(ok, 0.0, neg)[:, None, None, None, :]
        w = jax.nn.softmax(s, axis=-1).astype(new_cache["v"].dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w, new_cache["v"])
        out = out.reshape(B, 1, cfg.num_heads, dh)
    else:
        kf = _repeat_kv(new_cache["k"], cfg.num_heads // cfg.num_kv_heads)
        vf = _repeat_kv(new_cache["v"], cfg.num_heads // cfg.num_kv_heads)
        bias = jnp.where(ok, 0.0, neg)[:, None, :]         # (B,1,S)
        out = attend(q, kf, vf, bias, cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), new_cache
