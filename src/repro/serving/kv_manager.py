"""Paged KV-cache block manager (vLLM-style, adapted for TPU).

Physical KV memory is divided into fixed-size pages of ``page_tokens``
token slots; each sequence owns an ordered block table of page ids.
The manager does allocation/free/extension bookkeeping and exposes the
χ (KV bytes) accounting that token-pool admission charges against.

TPU adaptation (vs. CUDA vLLM): pages are sized to the Pallas decode
kernel's block shape (multiples of the 128-lane register tile), and the
block table is consumed by ``repro.kernels.paged_attention`` via scalar
prefetch rather than warp-level pointer chasing.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


class OutOfPages(RuntimeError):
    pass


class DoubleFree(RuntimeError):
    """A sequence's pages were returned twice — the second free would
    corrupt the free list (pages handed to two owners)."""


@dataclasses.dataclass
class SequenceAlloc:
    seq_id: str
    pages: list[int]
    tokens_used: int


class KVBlockManager:
    def __init__(self, total_pages: int, page_tokens: int = 128,
                 bytes_per_token: float = 0.0) -> None:
        assert page_tokens % 128 == 0 or page_tokens in (16, 32, 64), \
            "page size should align to TPU lane tiling"
        self.total_pages = total_pages
        self.page_tokens = page_tokens
        self.bytes_per_token = bytes_per_token
        self._free: list[int] = list(range(total_pages - 1, -1, -1))
        self._seqs: dict[str, SequenceAlloc] = {}
        #: seq ids already freed once — a second ``free`` is rejected
        #: (cleared when the id is legitimately re-allocated)
        self._freed: set[str] = set()
        #: observability: rejected double frees / frees of ids never
        #: allocated (both are lifecycle bugs upstream; neither touches
        #: the free list)
        self.double_free_rejections = 0
        self.unknown_frees = 0

    # -- capacity queries ------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.total_pages - self.free_pages

    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_tokens)

    def can_allocate(self, tokens: int) -> bool:
        return self.pages_needed(tokens) <= self.free_pages

    def kv_bytes_in_use(self) -> float:
        return self.used_pages * self.page_tokens * self.bytes_per_token

    # -- allocation --------------------------------------------------------------
    def allocate(self, seq_id: str, tokens: int) -> SequenceAlloc:
        need = self.pages_needed(max(tokens, 1))
        if need > self.free_pages:
            raise OutOfPages(
                f"{seq_id}: need {need} pages, {self.free_pages} free")
        pages = [self._free.pop() for _ in range(need)]
        alloc = SequenceAlloc(seq_id=seq_id, pages=pages,
                              tokens_used=tokens)
        self._seqs[seq_id] = alloc
        self._freed.discard(seq_id)
        return alloc

    def extend(self, seq_id: str, new_total_tokens: int) -> SequenceAlloc:
        """Grow a sequence (decode appends); allocates pages on crossing
        a page boundary."""
        alloc = self._seqs[seq_id]
        need = self.pages_needed(new_total_tokens)
        while len(alloc.pages) < need:
            if not self._free:
                raise OutOfPages(f"{seq_id}: extension needs a page")
            alloc.pages.append(self._free.pop())
        alloc.tokens_used = new_total_tokens
        return alloc

    def free(self, seq_id: str, strict: bool = False) -> int:
        """Return a sequence's pages to the free list.  A double free
        is REJECTED — counted, raised under ``strict`` — because
        re-extending the free list would hand the same pages to two
        owners.  Freeing an id that was never allocated stays a
        counted no-op (late duplicate completions)."""
        alloc = self._seqs.pop(seq_id, None)
        if alloc is None:
            if seq_id in self._freed:
                self.double_free_rejections += 1
                if strict:
                    raise DoubleFree(seq_id)
            else:
                self.unknown_frees += 1
            return 0
        self._free.extend(reversed(alloc.pages))
        self._freed.add(seq_id)
        return len(alloc.pages)

    def block_table(self, seq_id: str, max_pages: int) -> np.ndarray:
        """Padded block table row for the paged-attention kernel."""
        alloc = self._seqs[seq_id]
        row = np.full((max_pages,), -1, np.int32)
        row[:len(alloc.pages)] = alloc.pages
        return row

    def sequences(self) -> list[str]:
        return sorted(self._seqs)
