"""Discrete-time serving simulator — the experiment harness.

The CONTROL PLANE under test is the real code (TokenPool,
AdmissionController, ledger, debt/burst accounting).  Only the GPU
backend is simulated: each replica is a processor-sharing server with
``slots`` concurrent sequences and an aggregate decode rate Λ_r
(tokens/s) split evenly among active sequences — calibrated to the
paper's single vLLM replica (16 slots, ~240 tok/s on Qwen3-8B).

Fixed-step simulation (dt = 20 ms): deterministic, fine enough for
sub-second TTFT claims.  Supports: replica failure/recovery events
(paper Exp 2's outage), entitlement join/leave windows (Exp 1/2),
work-conserving backfill, hedged re-dispatch of stragglers, and a
no-admission baseline mode.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.core import (
    AdmissionController,
    AdmissionRequest,
    EntitlementSpec,
    InFlight,
    PoolSpec,
    PriorityCoefficients,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)
from repro.serving.request import Request, RequestState


@dataclasses.dataclass
class Workload:
    name: str                      # entitlement name
    service_class: ServiceClass
    slots: float                   # baseline concurrency r_e
    slo_ms: float
    rate_rps: float                # arrival rate
    in_tokens: int = 64
    out_tokens: int = 64
    start_s: float = 0.0
    end_s: float = 1e9
    tokens_per_second: float = 0.0  # λ_e baseline (0 → derive from slots)
    #: client retry behaviour on 429 (Retry-After honoured, capped)
    max_retries: int = 0
    retry_cap_s: float = 5.0
    #: ordered pool preference for MultiPoolSimulator routing (first =
    #: preferred, later legs are spill-over targets); ignored by the
    #: single-pool ServingSimulator
    pools: tuple[str, ...] = ()


@dataclasses.dataclass
class ReplicaSim:
    name: str
    slots: int
    rate_tps: float
    prefill_tps: float = 4000.0
    alive: bool = True
    #: scale-down drain: no new dispatch, residual work completes
    draining: bool = False
    #: replica lost to a FAILURE (cannot be re-provisioned until the
    #: matching recover event, unlike a scaled-down slot)
    failed: bool = False
    active: dict = dataclasses.field(default_factory=dict)
    # req_id → [remaining_out_tokens, prefill_remaining_tokens]

    def load(self) -> int:
        return len(self.active)

    def serving(self) -> bool:
        return self.alive and not self.draining


def dispatch_waiting(waiting: list, alive: list[ReplicaSim],
                     requests: dict[str, Request], on_start) -> None:
    """Drain a priority heap onto the least-loaded live replicas.
    Shared by both simulators so the scheduling policy cannot diverge."""
    while waiting:
        candidates = [r for r in alive if r.load() < r.slots]
        if not candidates:
            return
        replica = min(candidates, key=lambda r: r.load() / r.slots)
        _, _, rid = heapq.heappop(waiting)
        req = requests[rid]
        if req.state not in (RequestState.QUEUED,):
            continue                          # stale/duplicate entry
        req.state = RequestState.PREFILLING
        req.replica = replica.name
        replica.active[rid] = [float(req.max_tokens),
                               float(req.input_len)]
        on_start(rid)           # KV becomes resident (§3.1 r)


def advance_replicas(alive: list[ReplicaSim],
                     requests: dict[str, Request], dt: float, now: float,
                     on_finish) -> None:
    """One dt of processor-sharing prefill/decode on live replicas.
    ``on_finish(rid, req)`` receives each completed request AFTER its
    terminal fields are stamped.  Shared by both simulators: the
    timing model (TTFT stamping, decode-rate sharing) lives here once."""
    for replica in alive:
        if not replica.active:
            continue
        decoding = [rid for rid, st in replica.active.items()
                    if st[1] <= 0.0]
        n_prefilling = max(1, len(replica.active) - len(decoding))
        decode_rate = replica.rate_tps / max(len(replica.active), 1)
        finished = []
        for rid, st in replica.active.items():
            req = requests[rid]
            if st[1] > 0.0:                      # prefilling
                st[1] -= replica.prefill_tps * dt / n_prefilling
                if st[1] <= 0.0:
                    req.state = RequestState.DECODING
            else:                                # decoding
                before = st[0]
                st[0] -= decode_rate * dt
                if req.first_token_s is None and st[0] < before:
                    req.first_token_s = now + dt
                if st[0] <= 0.0:
                    finished.append(rid)
        for rid in finished:
            req = requests[rid]
            req.state = RequestState.FINISHED
            req.finished_s = now + dt
            req.output_tokens = [1] * req.max_tokens
            del replica.active[rid]
            on_finish(rid, req)


@dataclasses.dataclass
class TimelinePoint:
    t: float
    running: int
    waiting: int
    per_ent_running: dict[str, int]
    capacity_slots: int


class ServingSimulator:
    def __init__(self, workloads: list[Workload],
                 replica_slots: int = 16, replica_tps: float = 240.0,
                 n_replicas: int = 1, admission: bool = True,
                 coeff: PriorityCoefficients = PriorityCoefficients(),
                 dt: float = 0.02, seed: int = 0,
                 hedge_after_s: Optional[float] = None,
                 accounting_interval_s: float = 1.0,
                 fixed_avg_slo_ms: Optional[float] = None,
                 bucket_window_s: float = 4.0,
                 telemetry=None) -> None:
        self.dt = dt
        self.admission = admission
        self.workloads = {w.name: w for w in workloads}
        self.rng = np.random.RandomState(seed)
        self.hedge_after_s = hedge_after_s

        per_slot_tps = replica_tps / replica_slots
        # Admission charges input+max_tokens (paper check 4) while the
        # backend decode rate counts output tokens only; express pool λ
        # capacity in *charged* units so the two ledgers agree.
        charge_factor = float(np.mean(
            [(w.in_tokens + w.out_tokens) / max(w.out_tokens, 1)
             for w in workloads]))
        self.charge_factor = charge_factor
        spec = PoolSpec(
            name="sim-pool", model="qwen3-8b",
            scaling=ScalingBounds(1, n_replicas),
            per_replica=Resources(replica_tps * charge_factor, 0.0,
                                  float(replica_slots)),
            coefficients=coeff,
            accounting_interval_s=accounting_interval_s,
            fixed_avg_slo_ms=fixed_avg_slo_ms,
            bucket_window_s=bucket_window_s,
        )
        self.pool = TokenPool(spec)
        self.pool.set_replicas(n_replicas)
        self.controller = AdmissionController(self.pool)
        for w in workloads:
            lam = w.tokens_per_second or w.slots * per_slot_tps \
                * (w.in_tokens + w.out_tokens) / max(w.out_tokens, 1)
            if w.service_class in (ServiceClass.SPOT,
                                   ServiceClass.PREEMPTIBLE):
                lam = 0.0
            self.pool.add_entitlement(EntitlementSpec(
                name=w.name, tenant_id=w.name, pool="sim-pool",
                qos=QoS(service_class=w.service_class,
                        slo_target_ms=w.slo_ms),
                baseline=Resources(lam, 0.0, w.slots)))
            # spot buckets are funded by backfill ticks; give them the
            # pool surplus initially so t=0 arrivals aren't starved
            if lam == 0.0:
                self.pool.ledger.set_rate(
                    w.name, replica_tps * charge_factor, 0.0)

        # telemetry=True builds a fresh plane; an instance is shared
        if telemetry is True:
            from repro.telemetry import Telemetry
            telemetry = Telemetry()
        self.telemetry = telemetry or None
        if self.telemetry is not None:
            self.telemetry.attach_pool(self.pool)

        self.replicas = [ReplicaSim(f"r{i}", replica_slots, replica_tps)
                         for i in range(n_replicas)]
        self.waiting: list[tuple[float, float, str]] = []  # heap
        self.requests: dict[str, Request] = {}
        self.timeline: list[TimelinePoint] = []
        self._events: list[tuple[float, int, str, dict]] = []
        self._eid = 0
        self._req_counter = 0
        self._next_arrival: dict[str, float] = {
            w.name: w.start_s for w in workloads}

    # -- event API -----------------------------------------------------------
    def at(self, t: float, kind: str, **payload) -> None:
        """Schedule an external event: ``fail_replica`` (idx),
        ``recover_replica`` (idx)."""
        heapq.heappush(self._events, (t, self._eid, kind, payload))
        self._eid += 1

    # -- internals ------------------------------------------------------------
    def _alive(self) -> list[ReplicaSim]:
        return [r for r in self.replicas if r.alive]

    def _arrive(self, w: Workload, now: float, attempt: int = 0) -> None:
        self._req_counter += 1
        rid = f"{w.name}-{self._req_counter}"
        req = Request(request_id=rid, entitlement=w.name,
                      prompt_tokens=[1] * w.in_tokens,
                      max_tokens=w.out_tokens, arrival_s=now)
        self.requests[rid] = req
        if self.admission:
            dec = self.controller.decide(AdmissionRequest(
                entitlement=w.name, input_tokens=w.in_tokens,
                max_tokens=w.out_tokens, arrival_s=now, request_id=rid))
            if self.telemetry is not None:
                from repro.telemetry import flight as flightrec
                code = (flightrec.REASON_NONE if dec.reason is None
                        else flightrec.REASON_CODES[dec.reason.value])
                self.telemetry.record_decision(
                    self.pool.spec.name, now, rid, 0, w.name,
                    dec.admitted, code, dec.priority,
                    float(w.in_tokens + w.out_tokens))
            if not dec.admitted:
                req.state = RequestState.DENIED
                req.deny_reason = dec.reason.value if dec.reason else None
                req.retry_after_s = dec.retry_after_s
                # client honours Retry-After (bounded retries)
                if attempt < w.max_retries:
                    backoff = min(dec.retry_after_s or 1.0, w.retry_cap_s)
                    self.at(now + max(backoff, self.dt), "retry",
                            workload=w.name, attempt=attempt + 1)
                return
            req.priority = dec.priority
            req.admitted_s = now
        else:
            # baseline: everything admitted, FIFO (priority constant)
            req.priority = 0.0
            req.admitted_s = now
            self.pool.register_admit(
                InFlight(rid, w.name, 0.0, 0.0,
                         w.in_tokens + w.out_tokens, now),
                float(w.in_tokens + w.out_tokens))
        # waiting heap ordered by (-priority, arrival)
        heapq.heappush(self.waiting, (-req.priority, now, rid))

    def _dispatch(self, now: float) -> None:
        dispatch_waiting(self.waiting, self._alive(), self.requests,
                         self.pool.on_start)

    def _advance_replicas(self, now: float) -> None:
        # every completion of one dt step is stamped
        # ``finished_s = now + dt`` — drain them in ONE vectorized
        # settle per step instead of a scalar ``on_complete`` each
        done: list[tuple[str, Request]] = []
        advance_replicas(self._alive(), self.requests, self.dt, now,
                         lambda rid, req: done.append((rid, req)))
        # settle in (finished_s, rid) order — collection order follows
        # dict iteration over ``replica.active``, which tracks dispatch
        # history; sorting pins the settle sequence regardless of how
        # requests were interleaved onto replicas
        done.sort(key=lambda p: (p[1].finished_s, p[0]))
        if done:
            self.pool.on_complete_batch(
                [rid for rid, _ in done],
                [req.max_tokens for _, req in done], now + self.dt)
            if self.telemetry is not None:
                name = self.pool.spec.name
                self.telemetry.record_completions(
                    now + self.dt, [name] * len(done),
                    [req.entitlement for _, req in done],
                    [now + self.dt - req.arrival_s
                     for _, req in done])

    def _handle_event(self, kind: str, payload: dict, now: float) -> None:
        if kind == "fail_replica":
            replica = self.replicas[payload["idx"]]
            replica.alive = False
            # in-flight requests on the dead node are re-queued (charged
            # budget is kept — they are still owed service)
            for rid in list(replica.active):
                req = self.requests[rid]
                req.state = RequestState.QUEUED
                req.replica = None
                heapq.heappush(self.waiting,
                               (-req.priority, req.arrival_s, rid))
                del replica.active[rid]
            self.pool.set_replicas(len(self._alive()))
            if self.telemetry is not None:
                self.telemetry.incident_start(
                    f"replica{payload['idx']}", now)
        elif kind == "recover_replica":
            self.replicas[payload["idx"]].alive = True
            self.pool.set_replicas(len(self._alive()))
            if self.telemetry is not None:
                self.telemetry.incident_end(
                    f"replica{payload['idx']}", now)
        elif kind == "retry":
            w = self.workloads[payload["workload"]]
            if now < w.end_s:
                self._arrive(w, now, attempt=payload["attempt"])
        else:
            raise ValueError(kind)

    def _hedge(self, now: float) -> None:
        """Straggler mitigation: a request queued longer than the hedge
        timeout is re-enqueued at boosted priority (front of the line
        within its class) — bounded to one hedge per request.  The
        stale heap entry is skipped by the started-state check in
        ``_dispatch`` (lazy deletion)."""
        if self.hedge_after_s is None:
            return
        for _, t_arr, rid in list(self.waiting):
            req = self.requests[rid]
            if (req.state == RequestState.QUEUED
                    and not getattr(req, "_hedged", False)
                    and now - t_arr > self.hedge_after_s):
                req._hedged = True           # type: ignore[attr-defined]
                req.priority += 1e4          # jump the queue
                heapq.heappush(self.waiting,
                               (-req.priority, t_arr, rid))

    # -- main loop ------------------------------------------------------------
    def run(self, duration_s: float) -> dict:
        now = 0.0
        next_tick = self.pool.spec.accounting_interval_s
        steps = int(duration_s / self.dt)
        for _ in range(steps):
            # external events
            while self._events and self._events[0][0] <= now:
                _, _, kind, payload = heapq.heappop(self._events)
                self._handle_event(kind, payload, now)
            # arrivals
            for w in self.workloads.values():
                while (self._next_arrival[w.name] <= now
                       and w.start_s <= now < w.end_s):
                    self._arrive(w, now)
                    self._next_arrival[w.name] += 1.0 / w.rate_rps
                if now >= w.end_s:
                    self._next_arrival[w.name] = 1e18
            self._hedge(now)
            self._dispatch(now)
            self._advance_replicas(now)
            if now >= next_tick:
                self.pool.tick(now)
                next_tick += self.pool.spec.accounting_interval_s
            # timeline sample every 0.5 s
            if int(now / self.dt) % max(1, int(0.5 / self.dt)) == 0:
                per_ent: dict[str, int] = {}
                running = 0
                for r in self._alive():
                    for rid in r.active:
                        running += 1
                        e = self.requests[rid].entitlement
                        per_ent[e] = per_ent.get(e, 0) + 1
                self.timeline.append(TimelinePoint(
                    t=now, running=running,
                    waiting=len([1 for _, _, rid in self.waiting
                                 if self.requests[rid].state
                                 == RequestState.QUEUED]),
                    per_ent_running=per_ent,
                    capacity_slots=sum(r.slots for r in self._alive())))
            now += self.dt
        return self.summary()

    # -- results ---------------------------------------------------------------
    def per_entitlement(self) -> dict[str, list[Request]]:
        out: dict[str, list[Request]] = {w: [] for w in self.workloads}
        for req in self.requests.values():
            out[req.entitlement].append(req)
        return out

    def summary(self) -> dict:
        from repro.serving.request import latency_summary
        per = {}
        for name, reqs in self.per_entitlement().items():
            s = latency_summary(reqs)
            st = self.pool.status[name]
            s["denied_low_priority"] = st.denied_low_priority
            s["denied_total"] = st.denied_total
            s["peak_debt"] = max(
                (h.debts.get(name, 0.0) for h in self.pool.history),
                default=0.0)
            per[name] = s
        return {
            "per_entitlement": per,
            "max_waiting": max((p.waiting for p in self.timeline),
                               default=0),
            # the pool keeps a bounded deque (PoolSpec.history_maxlen);
            # expose a list so consumers can slice it
            "history": list(self.pool.history),
            "timeline": self.timeline,
        }


# -- multi-pool simulation -------------------------------------------------------


@dataclasses.dataclass
class PoolSite:
    """One pool's backend fleet in a multi-pool simulation."""

    name: str
    n_replicas: int = 1
    replica_slots: int = 16
    replica_tps: float = 240.0
    #: autoscaling ceiling (0 → n_replicas, i.e. a fixed fleet).  With
    #: ``autoscale=True`` the fleet starts at ``n_replicas`` live and
    #: the planner provisions up to this many.
    max_replicas: int = 0
    #: resident-store shard count (0 → flat store; pow2 → sharded
    #: store + ``shard_map`` kernel dispatch when devices allow, see
    #: ``PoolSpec.shards``)
    shards: int = 0


class MultiPoolSimulator:
    """Discrete-time simulator over a ``PoolManager`` fleet.

    The control plane under test is the real multi-pool code: a
    ``Gateway`` with ordered (pool, entitlement) routes per workload,
    spill-over on denial, and the BATCHED accounting tick
    (``PoolManager.tick`` — one fused kernel for all pools).  Each pool
    has its own simulated replica fleet; per-pool replica outages
    (``at(t, "fail_replica", pool=..., idx=...)``) shrink only that
    pool, pushing its traffic across the route to the surviving pools
    — the cross-pool spill scenario of dual-pool routing.

    Each workload is entitled on every pool in its ``pools`` preference
    list (entitlement name ``{workload}@{pool}``); metrics are reported
    per workload with per-pool admission attribution.
    """

    def __init__(self, workloads: list[Workload], sites: list[PoolSite],
                 coeff: PriorityCoefficients = PriorityCoefficients(),
                 dt: float = 0.02, seed: int = 0,
                 accounting_interval_s: float = 1.0,
                 bucket_window_s: float = 4.0,
                 spill_policy: str = "static",
                 admission_mode: str = "quantum",
                 autoscale: bool = False,
                 planner_config=None,
                 provision_lag_s: float = 2.0,
                 drain_s: float = 2.0,
                 telemetry=None) -> None:
        from repro.core import FleetPlanner, PoolManager
        from repro.gateway import Gateway

        if admission_mode not in ("quantum", "scalar"):
            raise ValueError(f"unknown admission_mode {admission_mode!r};"
                             " expected 'quantum' or 'scalar'")
        #: "quantum" (default) batches each dt-step's arrivals through
        #: ``Gateway.handle_quantum`` — one fused kernel dispatch per
        #: (pool, leg round); "scalar" keeps the per-request
        #: ``Gateway.handle`` pipeline.  Per pool both decide the same
        #: arrival sequence identically; when workloads declare pools
        #: in DIFFERENT orders, cross-pool spills settle in leg-round
        #: order rather than the scalar loop's interleaving (see
        #: ``Gateway.handle_quantum``).
        self.admission_mode = admission_mode
        self.dt = dt
        self.workloads = {w.name: w for w in workloads}
        self.sites = {s.name: s for s in sites}
        self.rng = np.random.RandomState(seed)

        # Admission charges input+max_tokens while decode counts output
        # tokens; express pool λ capacity in charged units (see
        # ServingSimulator).
        charge_factor = float(np.mean(
            [(w.in_tokens + w.out_tokens) / max(w.out_tokens, 1)
             for w in workloads]))
        self.charge_factor = charge_factor

        self.autoscale = autoscale
        self.provision_lag_s = provision_lag_s
        self.drain_s = drain_s
        self.manager = PoolManager()
        self.replicas: dict[str, list[ReplicaSim]] = {}
        for s in sites:
            max_r = s.max_replicas or s.n_replicas
            spec = PoolSpec(
                name=s.name, model="sim-model",
                scaling=ScalingBounds(1, max_r),
                per_replica=Resources(s.replica_tps * charge_factor, 0.0,
                                      float(s.replica_slots)),
                coefficients=coeff,
                accounting_interval_s=accounting_interval_s,
                bucket_window_s=bucket_window_s,
                shards=s.shards or None)
            pool = self.manager.add_pool(spec)
            pool.set_replicas(s.n_replicas)
            # fleet sized to the autoscaling ceiling; slots beyond the
            # initial n_replicas start dead, awaiting provisioning
            self.replicas[s.name] = [
                ReplicaSim(f"{s.name}/r{i}", s.replica_slots,
                           s.replica_tps, alive=i < s.n_replicas)
                for i in range(max_r)]
        if autoscale:
            self.manager.planner = FleetPlanner(planner_config)
            self.manager.provision_hook = self._provision
        #: replicas scheduled to come live (pool → replica indices)
        self._incoming: dict[str, set[int]] = {s.name: set() for s in sites}
        #: per-replica drain deadline (replica name → t)
        self._drain_deadline: dict[str, float] = {}
        #: (t, FleetPlan) per planning round (autoscale mode)
        self.plans: list = []
        #: per-pool (t, live_replicas) trajectory, sampled at each tick
        self.replica_timeline: dict[str, list[tuple[float, int]]] = {
            s.name: [] for s in sites}

        self.gateway = Gateway(self.manager, spill_policy=spill_policy,
                               telemetry=telemetry)
        self.telemetry = self.gateway.telemetry
        for w in workloads:
            if not w.pools:
                raise ValueError(f"workload {w.name!r} names no pools")
            for pname in w.pools:
                site = self.sites[pname]
                per_slot_tps = site.replica_tps / site.replica_slots
                lam = w.tokens_per_second or w.slots * per_slot_tps \
                    * (w.in_tokens + w.out_tokens) / max(w.out_tokens, 1)
                if w.service_class in (ServiceClass.SPOT,
                                       ServiceClass.PREEMPTIBLE):
                    lam = 0.0
                ent = f"{w.name}@{pname}"
                pool = self.manager.pool(pname)
                pool.add_entitlement(EntitlementSpec(
                    name=ent, tenant_id=w.name, pool=pname,
                    qos=QoS(service_class=w.service_class,
                            slo_target_ms=w.slo_ms),
                    baseline=Resources(lam, 0.0, w.slots)))
                if lam == 0.0:   # spot: fund as the first backfill would
                    pool.ledger.set_rate(
                        ent, site.replica_tps * charge_factor, 0.0)
            self.gateway.register_route(
                w.name, [(p, f"{w.name}@{p}") for p in w.pools])

        self.waiting: dict[str, list[tuple[float, float, str]]] = {
            s.name: [] for s in sites}
        self.requests: dict[str, Request] = {}
        self._events: list[tuple[float, int, str, dict]] = []
        self._eid = 0
        self._req_counter = 0
        self._next_arrival: dict[str, float] = {
            w.name: w.start_s for w in workloads}
        self.tick_records: dict[str, list] = {s.name: [] for s in sites}
        self._step_batch: list = []     # quantum mode: this step's batch
        #: callables ``hook(sim, now)`` run after EVERY completed step
        #: (post-settle, post-tick) — the chaos harness registers its
        #: invariant checkers here; the simulator stays policy-free
        self.step_hooks: list = []
        #: optional override ``fn(workload, req, attempt, resp) -> s``
        #: replacing the Retry-After-driven client backoff (see
        #: ``_apply_response``)
        self.retry_backoff = None

    # -- event API -----------------------------------------------------------
    def at(self, t: float, kind: str, **payload) -> None:
        """Schedule an external event: ``fail_replica`` /
        ``recover_replica`` (pool=<name>, idx=<replica>), or the
        generic ``call`` (fn=<callable(sim, now)>) used by scripted
        scenarios to inject arbitrary control-plane actions."""
        heapq.heappush(self._events, (t, self._eid, kind, payload))
        self._eid += 1

    # -- internals ------------------------------------------------------------
    def _alive(self, pool: str) -> list[ReplicaSim]:
        """Replicas still decoding — includes DRAINING ones, whose
        residual work must finish even though they accept no new
        dispatch (scale-down drains; see :meth:`_serving`)."""
        return [r for r in self.replicas[pool] if r.alive]

    def _serving(self, pool: str) -> list[ReplicaSim]:
        """Replicas eligible for new dispatch (alive, not draining)."""
        return [r for r in self.replicas[pool] if r.serving()]

    def _sync_replicas(self, pool: str) -> None:
        """Pool runtime capacity follows the SERVING replica count:
        a draining replica stops counting the moment the planner
        marks it (admission must see the post-decision capacity)."""
        self.manager.pool(pool).set_replicas(len(self._serving(pool)))

    # -- provisioning-lag model (the fleet planner's provision hook) ----------
    def _provision(self, pool, decision, now: float) -> None:
        """Apply a ScaleDecision to the simulated fleet.

        Scale-up: each missing replica becomes live ``provision_lag_s``
        seconds from now (draining slots are un-drained first — they
        are already warm).  Scale-down: surplus serving replicas drain
        — no new dispatch, residual requests finish (bounded by
        ``drain_s``, after which leftovers are re-queued) — and the
        pool's admission capacity drops immediately."""
        pname = pool.spec.name
        fleet = self.replicas[pname]
        incoming = self._incoming[pname]
        eff = len(self._serving(pname)) + len(incoming)
        target = decision.desired
        if target > eff:
            want = target - eff
            # warm slots first: cancel drains in progress
            for r in fleet:
                if want <= 0:
                    break
                if r.alive and r.draining:
                    r.draining = False
                    self._drain_deadline.pop(r.name, None)
                    want -= 1
            for i, r in enumerate(fleet):
                if want <= 0:
                    break
                if not r.alive and not r.failed and i not in incoming:
                    incoming.add(i)
                    self.at(now + self.provision_lag_s, "replica_live",
                            pool=pname, idx=i)
                    want -= 1
        elif target < eff:
            shrink = eff - target
            # cancel not-yet-live arrivals first (cheapest to undo)
            for i in sorted(incoming, reverse=True):
                if shrink <= 0:
                    break
                incoming.discard(i)
                shrink -= 1
            serving = sorted(self._serving(pname), key=ReplicaSim.load)
            for r in serving:
                if shrink <= 0:
                    break
                r.draining = True
                self._drain_deadline[r.name] = now + self.drain_s
                shrink -= 1
        self._sync_replicas(pname)

    def _complete_drains(self, now: float) -> None:
        """Retire draining replicas that emptied (or hit the drain
        deadline — leftovers re-queue on the same pool, like a
        failure)."""
        for pname, fleet in self.replicas.items():
            for r in fleet:
                if not (r.alive and r.draining):
                    continue
                if r.active and now < self._drain_deadline.get(
                        r.name, now):
                    continue
                for rid in list(r.active):
                    req = self.requests[rid]
                    req.state = RequestState.QUEUED
                    req.replica = None
                    heapq.heappush(self.waiting[pname],
                                   (-req.priority, req.arrival_s, rid))
                    del r.active[rid]
                r.alive = False
                r.draining = False
                self._drain_deadline.pop(r.name, None)

    def _new_request(self, w: Workload, now: float) -> Request:
        self._req_counter += 1
        rid = f"{w.name}-{self._req_counter}"
        req = Request(request_id=rid, entitlement=w.name,
                      prompt_tokens=[1] * w.in_tokens,
                      max_tokens=w.out_tokens, arrival_s=now,
                      api_key=w.name)
        self.requests[rid] = req
        return req

    def _apply_response(self, w: Workload, attempt: int, req: Request,
                        resp, now: float) -> None:
        if resp.status != 200:
            req.state = RequestState.DENIED
            req.deny_reason = resp.reason
            req.retry_after_s = resp.retry_after_s
            if attempt < w.max_retries:
                if self.retry_backoff is not None:
                    # scenario-controlled backoff: Retry-After hints
                    # legitimately differ between the scalar and
                    # quantum admission paths, so differential replay
                    # substitutes a deterministic function of
                    # (workload, attempt) to keep retry timelines —
                    # and therefore decision traces — comparable
                    backoff = self.retry_backoff(w, req, attempt, resp)
                else:
                    backoff = min(resp.retry_after_s or 1.0,
                                  w.retry_cap_s)
                self.at(now + max(backoff, self.dt), "retry",
                        workload=w.name, attempt=attempt + 1)
            return
        req.priority = resp.priority
        req.admitted_s = now
        req.pool = resp.pool
        req.spill_hops = resp.spill_hops
        heapq.heappush(self.waiting[resp.pool],
                       (-req.priority, now, req.request_id))

    def _arrive(self, w: Workload, now: float, attempt: int = 0) -> None:
        """Scalar per-request admission (the parity oracle path)."""
        req = self._new_request(w, now)
        resp = self.gateway.handle(
            w.name, req.request_id, input_tokens=w.in_tokens,
            max_tokens=w.out_tokens, now=now)
        self._apply_response(w, attempt, req, resp, now)

    def _arrive_batch(self, batch: list, now: float) -> None:
        """Quantum admission: ONE ``handle_quantum`` call for all of a
        step's arrivals (new + due retries), in arrival order."""
        from repro.gateway import QuantumRequest
        if not batch:
            return
        reqs = [self._new_request(w, now) for w, _ in batch]
        resps = self.gateway.handle_quantum(
            [QuantumRequest(api_key=w.name, request_id=r.request_id,
                            input_tokens=w.in_tokens,
                            max_tokens=w.out_tokens)
             for (w, _), r in zip(batch, reqs)], now)
        for (w, attempt), req, resp in zip(batch, reqs, resps):
            self._apply_response(w, attempt, req, resp, now)

    def _dispatch(self, now: float) -> None:
        for pname, waiting in self.waiting.items():
            dispatch_waiting(waiting, self._serving(pname), self.requests,
                             self.manager.pool(pname).on_start)

    def _advance_replicas(self, now: float) -> None:
        # all pools' completions of one dt step share
        # ``finished_s = now + dt`` — ONE batched gateway callback per
        # step (the gateway settles each admitting pool's share in one
        # vectorized ``settle_rows``)
        done: list[tuple[str, Request]] = []
        for pname in self.replicas:
            advance_replicas(self._alive(pname), self.requests, self.dt,
                             now, lambda rid, req: done.append((rid, req)))
        # settle in (finished_s, rid) order — collection order follows
        # per-replica dict iteration and the pool map; sorting pins the
        # settle (and retry re-submission) sequence deterministically
        done.sort(key=lambda p: (p[1].finished_s, p[0]))
        if done:
            self.gateway.on_complete_batch(
                [(rid, req.max_tokens, req.finished_s - req.arrival_s)
                 for rid, req in done], now + self.dt)

    def _handle_event(self, kind: str, payload: dict, now: float) -> None:
        if kind == "fail_replica":
            pname = payload["pool"]
            replica = self.replicas[pname][payload["idx"]]
            replica.alive = False
            replica.failed = True
            replica.draining = False
            # in-flight requests on the dead node are re-queued on the
            # SAME pool (their charge lives in its ledger)
            for rid in list(replica.active):
                req = self.requests[rid]
                req.state = RequestState.QUEUED
                req.replica = None
                heapq.heappush(self.waiting[pname],
                               (-req.priority, req.arrival_s, rid))
                del replica.active[rid]
            self._sync_replicas(pname)
            if self.telemetry is not None:
                self.telemetry.incident_start(
                    f"{pname}/r{payload['idx']}", now)
        elif kind == "recover_replica":
            replica = self.replicas[payload["pool"]][payload["idx"]]
            replica.failed = False
            replica.alive = True
            self._sync_replicas(payload["pool"])
            if self.telemetry is not None:
                self.telemetry.incident_end(
                    f"{payload['pool']}/r{payload['idx']}", now)
        elif kind == "replica_live":
            # provisioning completed (scheduled by ``_provision``);
            # ignored if the planner cancelled it or the slot failed
            pname, idx = payload["pool"], payload["idx"]
            if idx not in self._incoming[pname]:
                return
            self._incoming[pname].discard(idx)
            replica = self.replicas[pname][idx]
            if replica.failed:
                return
            replica.alive = True
            replica.draining = False
            self._sync_replicas(pname)
        elif kind == "set_rate":
            # demand change (e.g. the experiment-3 surge): takes effect
            # from the next arrival on
            self.workloads[payload["workload"]].rate_rps = payload["rate"]
        elif kind == "retry":
            w = self.workloads[payload["workload"]]
            if now < w.end_s:
                if self.admission_mode == "quantum":
                    # retries join the step's quantum (ahead of new
                    # arrivals — same order the scalar path processes)
                    self._step_batch.append((w, payload["attempt"]))
                else:
                    self._arrive(w, now, attempt=payload["attempt"])
        elif kind == "call":
            # scripted-scenario escape hatch: run an arbitrary action
            # against the simulator at a scheduled instant (entitlement
            # churn, migrations, rate reshaping, ...)
            payload["fn"](self, now)
        else:
            raise ValueError(kind)

    # -- main loop ------------------------------------------------------------
    def run(self, duration_s: float) -> dict:
        now = 0.0
        interval = min(p.spec.accounting_interval_s
                       for p in self.manager.pools.values())
        next_tick = interval
        steps = int(duration_s / self.dt)
        quantum = self.admission_mode == "quantum"
        for _ in range(steps):
            self._step_batch = []
            while self._events and self._events[0][0] <= now:
                _, _, kind, payload = heapq.heappop(self._events)
                self._handle_event(kind, payload, now)
            for w in self.workloads.values():
                while (self._next_arrival[w.name] <= now
                       and w.start_s <= now < w.end_s):
                    if quantum:
                        self._step_batch.append((w, 0))
                    else:
                        self._arrive(w, now)
                    self._next_arrival[w.name] += 1.0 / w.rate_rps
                if now >= w.end_s:
                    self._next_arrival[w.name] = 1e18
            if quantum:
                self._arrive_batch(self._step_batch, now)
            if self.autoscale:
                self._complete_drains(now)
            self._dispatch(now)
            self._advance_replicas(now)
            if now >= next_tick:
                recs = self.manager.tick(now)   # ONE batched dispatch
                for pname, rec in recs.items():
                    self.tick_records[pname].append(rec)
                if self.autoscale:
                    # close the loop: tick outputs → ONE fused
                    # plan_fleet dispatch → authorize/provision/migrate
                    plan = self.gateway.plan_quantum(now, records=recs)
                    self.plans.append((now, plan))
                for pname in self.replicas:
                    self.replica_timeline[pname].append(
                        (now, self.manager.pool(pname).replicas))
                next_tick += interval
            for hook in self.step_hooks:
                hook(self, now)
            now += self.dt
        return self.summary()

    # -- results ---------------------------------------------------------------
    def summary(self) -> dict:
        from repro.serving.request import latency_summary
        per: dict[str, dict] = {}
        for wname in self.workloads:
            reqs = [r for r in self.requests.values()
                    if r.entitlement == wname]
            s = latency_summary(reqs)
            s["admitted_by_pool"] = {}
            for r in reqs:
                if r.pool is not None:
                    s["admitted_by_pool"][r.pool] = (
                        s["admitted_by_pool"].get(r.pool, 0) + 1)
            s["spilled"] = sum(1 for r in reqs if r.spill_hops > 0)
            s["denied_total"] = sum(
                1 for r in reqs if r.state == RequestState.DENIED)
            per[wname] = s
        return {
            "per_workload": per,
            "per_pool_history": {n: list(p.history)
                                 for n, p in self.manager.pools.items()},
            "replica_timeline": self.replica_timeline,
            "migrations": [prop for _, plan in self.plans
                           for prop in plan.applied],
        }
