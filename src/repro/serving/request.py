"""Request lifecycle + latency metrics (TTFT / TPOT / E2E)."""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np


class RequestState(str, enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    DENIED = "denied"
    EVICTED = "evicted"
    FAILED = "failed"


@dataclasses.dataclass
class Request:
    request_id: str
    entitlement: str
    prompt_tokens: list[int]
    max_tokens: int
    arrival_s: float
    api_key: str = ""
    priority: float = 0.0

    state: RequestState = RequestState.QUEUED
    output_tokens: list[int] = dataclasses.field(default_factory=list)
    admitted_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    deny_reason: Optional[str] = None
    retry_after_s: Optional[float] = None
    replica: Optional[str] = None
    #: pool that admitted the request (multi-pool routing)
    pool: Optional[str] = None
    #: legs denied before the admitting pool (0 = preferred pool)
    spill_hops: int = 0

    @property
    def input_len(self) -> int:
        return len(self.prompt_tokens)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def e2e(self) -> Optional[float]:
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s

    @property
    def tpot(self) -> Optional[float]:
        """Time per output token (decode phase)."""
        if (self.finished_s is None or self.first_token_s is None
                or len(self.output_tokens) <= 1):
            return None
        return ((self.finished_s - self.first_token_s)
                / (len(self.output_tokens) - 1))


def percentile(values: list[float], p: float) -> float:
    if not values:
        return float("nan")
    return float(np.percentile(np.asarray(values), p))


def latency_summary(requests: list[Request]) -> dict:
    ttfts = [r.ttft for r in requests if r.ttft is not None]
    e2es = [r.e2e for r in requests if r.e2e is not None]
    return {
        "count": len(requests),
        "finished": sum(r.state == RequestState.FINISHED
                        for r in requests),
        "denied": sum(r.state == RequestState.DENIED for r in requests),
        "ttft_p50": percentile(ttfts, 50),
        "ttft_p99": percentile(ttfts, 99),
        "e2e_p50": percentile(e2es, 50),
        "e2e_p99": percentile(e2es, 99),
    }
