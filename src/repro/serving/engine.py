"""InferenceEngine: continuous batching over a real JAX model.

A fixed pool of ``slots`` batch lanes shares one jitted decode step;
each lane holds one sequence at its own position (the vectorised
``cur_index`` decode path).  Prefill runs per-request (B=1) and its KV
rows are scattered into the lane's cache slice — iteration-level
scheduling in the Orca/vLLM sense, admission-gated by the token-pool
gateway at the API boundary (the paper's control point).

KV accounting runs through the paged ``KVBlockManager`` so χ usage is
tracked in pages exactly as a TPU deployment would (the dense per-lane
cache is the XLA reference layout; the Pallas paged kernel consumes
the same block tables on real hardware).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.gateway import Gateway
from repro.models import Model, Runtime
from repro.serving.kv_manager import KVBlockManager
from repro.serving.request import Request, RequestState


def _batch_axis_for(path) -> int:
    """Cache leaves under stacked groups carry batch at axis 1."""
    keys = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
    return 1 if any(k in ("periods", "dec", "xkv") for k in keys) else 0


def cache_insert(batch_cache, one_cache, lane: int):
    """Scatter a B=1 cache into lane ``lane`` of the batched cache."""
    def ins(path, full, one):
        ax = _batch_axis_for(path)
        idx = [slice(None)] * full.ndim
        idx[ax] = lane
        one_squeezed = jnp.take(one, 0, axis=ax)
        return full.at[tuple(idx)].set(one_squeezed.astype(full.dtype))
    return jax.tree_util.tree_map_with_path(ins, batch_cache, one_cache)


@dataclasses.dataclass
class Lane:
    request: Optional[Request] = None
    position: int = 0              # next decode position
    remaining: int = 0


class InferenceEngine:
    def __init__(self, model: Model, params, slots: int, max_seq: int,
                 gateway: Optional[Gateway] = None,
                 rt: Runtime = Runtime(), page_tokens: int = 16,
                 eos_id: Optional[int] = None) -> None:
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.gateway = gateway
        self.rt = rt
        self.eos_id = eos_id
        self.kv_pages = KVBlockManager(
            total_pages=slots * (max_seq // page_tokens + 1),
            page_tokens=page_tokens,
            bytes_per_token=model.cfg.kv_bytes_per_token)
        self.cache = model.init_cache(slots, max_seq, rt)
        self.lanes = [Lane() for _ in range(slots)]
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._decode = jax.jit(
            lambda p, tok, cache, pos: model.decode_step(
                p, tok, cache, pos, rt))
        self._tokens = np.zeros((slots, 1), np.int32)
        self._positions = np.zeros((slots,), np.int32)

    # -- submission ----------------------------------------------------------
    def submit(self, req: Request, now: float,
               api_key: Optional[str] = None) -> bool:
        """Admission-gated enqueue.  Returns False on 429/401."""
        if self.gateway is not None:
            resp = self.gateway.handle(
                api_key or req.api_key, req.request_id,
                input_tokens=req.input_len, max_tokens=req.max_tokens,
                now=now,
                kv_bytes_per_token=self.model.cfg.kv_bytes_per_token)
            if resp.status != 200:
                req.state = RequestState.DENIED
                req.deny_reason = resp.reason
                req.retry_after_s = resp.retry_after_s
                self.finished.append(req)
                return False
            req.priority = resp.priority
        req.admitted_s = now
        self.queue.append(req)
        self.queue.sort(key=lambda r: (-r.priority, r.arrival_s))
        return True

    # -- scheduling ------------------------------------------------------------
    def _free_lanes(self) -> list[int]:
        return [i for i, l in enumerate(self.lanes) if l.request is None]

    def _start(self, lane_idx: int, req: Request, now: float) -> None:
        lane = self.lanes[lane_idx]
        total = req.input_len + req.max_tokens
        self.kv_pages.allocate(req.request_id, req.input_len)
        one_cache = self.model.init_cache(1, self.max_seq, self.rt)
        tokens = jnp.asarray([req.prompt_tokens], jnp.int32)
        logits, one_cache = self.model.prefill(
            self.params, tokens, one_cache, self.rt)
        self.cache = cache_insert(self.cache, one_cache, lane_idx)
        first = int(jnp.argmax(logits[0, -1]))
        req.first_token_s = now
        req.output_tokens.append(first)
        req.state = RequestState.DECODING
        lane.request = req
        lane.position = req.input_len
        lane.remaining = req.max_tokens - 1
        self._tokens[lane_idx, 0] = first
        self._positions[lane_idx] = req.input_len
        self.kv_pages.extend(req.request_id, req.input_len + 1)

    def step(self, now: float) -> int:
        """One engine iteration: admit-from-queue → batched decode.
        Returns the number of tokens produced."""
        for lane_idx in self._free_lanes():
            if not self.queue:
                break
            req = self.queue.pop(0)
            self._start(lane_idx, req, now)

        active = [i for i, l in enumerate(self.lanes)
                  if l.request is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self._tokens),
            self.cache, jnp.asarray(self._positions))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1),
                         np.int32)
        produced = 0
        for i in active:
            lane = self.lanes[i]
            req = lane.request
            tok = int(nxt[i])
            req.output_tokens.append(tok)
            produced += 1
            lane.position += 1
            lane.remaining -= 1
            self._tokens[i, 0] = tok
            self._positions[i] = lane.position
            self.kv_pages.extend(req.request_id, lane.position + 1)
            done = (lane.remaining <= 0
                    or (self.eos_id is not None and tok == self.eos_id)
                    or lane.position + 1 >= self.max_seq)
            if done:
                req.state = RequestState.FINISHED
                req.finished_s = now
                self.finished.append(req)
                self.kv_pages.free(req.request_id)
                if self.gateway is not None:
                    self.gateway.on_complete(
                        req.request_id, len(req.output_tokens),
                        latency_s=now - req.arrival_s, now=now)
                lane.request = None
                lane.remaining = 0
        return produced

    def evict(self, request_id: str, now: float) -> bool:
        """Mid-stream eviction (preemption / client disconnect): free
        the lane and its KV pages, cancel the admission charge through
        the gateway failure path.  Queued-but-unstarted requests are
        evicted too (no KV to reclaim).  Returns False for unknown or
        already-terminal ids — nothing is freed twice."""
        for lane in self.lanes:
            if lane.request is not None \
                    and lane.request.request_id == request_id:
                req = lane.request
                req.state = RequestState.EVICTED
                req.finished_s = now
                self.finished.append(req)
                self.kv_pages.free(request_id)
                if self.gateway is not None:
                    self.gateway.on_failure(request_id, now)
                lane.request = None
                lane.remaining = 0
                return True
        for i, req in enumerate(self.queue):
            if req.request_id == request_id:
                req.state = RequestState.EVICTED
                req.finished_s = now
                self.finished.append(self.queue.pop(i))
                if self.gateway is not None:
                    self.gateway.on_failure(request_id, now)
                return True
        return False

    def run_until_drained(self, now: float = 0.0,
                          time_per_step: float = 0.05,
                          max_steps: int = 10_000) -> float:
        """Drive steps until queue+lanes empty; returns final time."""
        steps = 0
        while (self.queue or any(l.request for l in self.lanes)) \
                and steps < max_steps:
            self.step(now)
            now += time_per_step
            steps += 1
        return now
