from repro.serving.engine import InferenceEngine
from repro.serving.kv_manager import KVBlockManager, OutOfPages
from repro.serving.request import Request, RequestState, latency_summary
from repro.serving.simulation import (
    MultiPoolSimulator,
    PoolSite,
    ReplicaSim,
    ServingSimulator,
    Workload,
)

__all__ = ["InferenceEngine", "KVBlockManager", "MultiPoolSimulator",
           "OutOfPages", "PoolSite", "ReplicaSim", "Request",
           "RequestState", "ServingSimulator", "Workload",
           "latency_summary"]
