from repro.serving.engine import InferenceEngine
from repro.serving.kv_manager import KVBlockManager, OutOfPages
from repro.serving.request import Request, RequestState, latency_summary
from repro.serving.simulation import ReplicaSim, ServingSimulator, Workload

__all__ = ["InferenceEngine", "KVBlockManager", "OutOfPages",
           "ReplicaSim", "Request", "RequestState", "ServingSimulator",
           "Workload", "latency_summary"]
