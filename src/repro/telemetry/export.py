"""Exporters: Prometheus text exposition, JSON snapshot, and a
Chrome-trace-event (Perfetto-loadable) timeline.

All three are COLD paths — they read registry arrays / the trace
buffer, never the other way round.  The trace buffer itself is
append-only Python (events are rare relative to decisions: one per
quantum / tick / scale event / incident, not one per request), with a
hard cap so a long simulation cannot grow without bound.

Chrome trace format notes (``chrome://tracing`` / ui.perfetto.dev):
timestamps and durations are MICROseconds; ``ph`` codes used here are
``X`` (complete slice), ``i`` (instant), ``C`` (counter) and ``M``
(metadata, for track names).
"""
from __future__ import annotations

import json
from typing import Optional

from repro.telemetry.registry import (Counter, Gauge, Histogram,
                                      MetricsRegistry)

__all__ = ["TraceBuffer", "chrome_trace_json", "json_snapshot",
           "prometheus_text"]


# ---------------------------------------------------------------------------
# Prometheus text exposition (version 0.0.4)
# ---------------------------------------------------------------------------

def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels_str(names: tuple, values: tuple, extra: str = "") -> str:
    parts = [f'{n}="{_escape_label(str(v))}"'
             for n, v in zip(names, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def prometheus_text(registry: MetricsRegistry) -> str:
    """Render every family in the Prometheus text format.  Histograms
    emit cumulative ``_bucket{le=...}`` samples (closing with
    ``le="+Inf"``), ``_sum`` and ``_count``; callback gauges are
    evaluated at scrape time — exactly the Redis/Prometheus shape the
    paper's platform would scrape."""
    lines: list[str] = []
    for fam in registry.families():
        if fam.help:
            lines.append(f"# HELP {fam.name} {fam.help}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        if isinstance(fam, Histogram):
            for sid, labels in enumerate(fam.series_labels):
                cum = 0
                for b, edge in enumerate(fam.edges):
                    cum += int(fam.counts[sid, b])
                    ls = _labels_str(fam.label_names, labels,
                                     f'le="{_fmt(edge)}"')
                    lines.append(f"{fam.name}_bucket{ls} {cum}")
                total = int(fam.totals[sid])
                ls = _labels_str(fam.label_names, labels, 'le="+Inf"')
                lines.append(f"{fam.name}_bucket{ls} {total}")
                ls = _labels_str(fam.label_names, labels)
                lines.append(f"{fam.name}_sum{ls} {_fmt(fam.sums[sid])}")
                lines.append(f"{fam.name}_count{ls} {total}")
        elif isinstance(fam, (Counter, Gauge)):
            for sid, labels in enumerate(fam.series_labels):
                ls = _labels_str(fam.label_names, labels)
                lines.append(f"{fam.name}{ls} {_fmt(fam.read(sid))}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# JSON snapshot
# ---------------------------------------------------------------------------

def json_snapshot(registry: MetricsRegistry) -> dict:
    """Registry state as plain JSON-serializable dicts (one entry per
    family; series keyed by their joined label values)."""
    out: dict = {}
    for fam in registry.families():
        series: dict = {}
        for sid, labels in enumerate(fam.series_labels):
            key = ",".join(str(v) for v in labels) or "_"
            if isinstance(fam, Histogram):
                series[key] = {
                    "count": int(fam.totals[sid]),
                    "sum": float(fam.sums[sid]),
                    "p50": fam.quantile(sid, 0.50),
                    "p99": fam.quantile(sid, 0.99),
                }
            else:
                series[key] = float(fam.read(sid))
        out[fam.name] = {"kind": fam.kind,
                         "labels": list(fam.label_names),
                         "series": series}
    return out


# ---------------------------------------------------------------------------
# Chrome trace events
# ---------------------------------------------------------------------------

class TraceBuffer:
    """Append-only Chrome-trace event list with a hard cap.  Tracks
    (``tid``) are interned per pool/source; ``pid`` is always 1 (one
    logical process — the control plane)."""

    def __init__(self, max_events: int = 200_000) -> None:
        self.events: list[dict] = []
        self.max_events = max_events
        self.dropped = 0
        self._tids: dict[str, int] = {}

    def tid(self, track: str) -> int:
        """Intern a track name → tid (emits the ``M`` metadata event
        naming the track on first use)."""
        t = self._tids.get(track)
        if t is None:
            t = len(self._tids) + 1
            self._tids[track] = t
            self.events.append({
                "name": "thread_name", "ph": "M", "pid": 1, "tid": t,
                "args": {"name": track}})
        return t

    def _push(self, ev: dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def complete(self, name: str, track: str, ts_s: float,
                 dur_s: float, args: Optional[dict] = None) -> None:
        """A ``ph:X`` slice — quanta, ticks, incident windows."""
        self._push({"name": name, "ph": "X", "pid": 1,
                    "tid": self.tid(track),
                    "ts": ts_s * 1e6, "dur": max(0.0, dur_s) * 1e6,
                    "args": args or {}})

    def instant(self, name: str, track: str, ts_s: float,
                args: Optional[dict] = None) -> None:
        """A ``ph:i`` marker — scale/migration events."""
        self._push({"name": name, "ph": "i", "s": "t", "pid": 1,
                    "tid": self.tid(track), "ts": ts_s * 1e6,
                    "args": args or {}})

    def counter(self, name: str, track: str, ts_s: float,
                values: dict) -> None:
        """A ``ph:C`` sample — water-fill level / debt timelines."""
        self._push({"name": name, "ph": "C", "pid": 1,
                    "tid": self.tid(track), "ts": ts_s * 1e6,
                    "args": values})


def chrome_trace_json(trace: TraceBuffer) -> str:
    """Serialize to the JSON object form Perfetto loads directly."""
    return json.dumps({"traceEvents": trace.events,
                       "displayTimeUnit": "ms"})
