"""Vectorized telemetry plane: metrics registry, admission flight
recorder, SLO attainment tracking, and Prometheus / JSON /
Chrome-trace exporters.

Quickstart::

    from repro.telemetry import Telemetry
    gw = Gateway(pool, telemetry=True)       # or telemetry=Telemetry()
    ...
    print(gw.telemetry.prometheus())         # Prometheus exposition
    print(gw.telemetry.flight.explain(rid).narrative())
    open("trace.json", "w").write(gw.telemetry.chrome_trace())
"""
from repro.telemetry.export import (TraceBuffer, chrome_trace_json,
                                    json_snapshot, prometheus_text)
from repro.telemetry.facade import Telemetry
from repro.telemetry.flight import (DecisionTrace, FlightRecorder,
                                    FlightRow)
from repro.telemetry.registry import (Counter, Gauge, Histogram,
                                      MetricsRegistry)
from repro.telemetry.slo import SloTracker, TIER_NAMES

__all__ = [
    "Counter",
    "DecisionTrace",
    "FlightRecorder",
    "FlightRow",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SloTracker",
    "TIER_NAMES",
    "Telemetry",
    "TraceBuffer",
    "chrome_trace_json",
    "json_snapshot",
    "prometheus_text",
]
