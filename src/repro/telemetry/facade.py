"""The ``Telemetry`` facade — one object wiring the registry, the
admission flight recorder, the SLO tracker and the trace buffer into
the gateway / pool / simulator instrumentation points.

Recording discipline matches the rest of the control plane:

* per-REQUEST surfaces (``record_decisions``, ``record_completions``,
  ``record_terminal``) are ``@hot_path`` and batch-only — one flight
  scatter + a handful of registry row-ops per quantum, with series ids
  pre-resolved per pool at attach time;
* per-EVENT surfaces (``on_tick``, ``on_quantum``, ``on_plan``,
  incidents) fire once per tick/quantum/plan — O(pools) per tick, not
  O(requests) — so they may use the scalar recorders;
* the scalar ``record_decision`` twin serves the sequential
  ``Gateway.handle`` path and doubles as the flight-recorder parity
  oracle.

``attach_pool`` BINDS (not copies) the pool's legacy ``gauges()``
callables into registry gauge series, so ``pool.stats()`` and the
Prometheus exposition read the same underlying values — the legacy
dict is a thin view, per the migration contract.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import numpy as np

from repro.core.control_plane import CLASS_CODES
from repro.core.markers import hot_path
from repro.telemetry import flight as fl
from repro.telemetry.export import (TraceBuffer, chrome_trace_json,
                                    json_snapshot, prometheus_text)
from repro.telemetry.flight import FlightRecorder
from repro.telemetry.registry import MetricsRegistry
from repro.telemetry.slo import TIER_NAMES, SloTracker

__all__ = ["Telemetry"]

_N_TIERS = len(TIER_NAMES)


class Telemetry:
    """Registry + flight recorder + SLO tracker + trace timeline."""

    def __init__(self, flight_capacity: int = 65536,
                 trace_max_events: int = 200_000) -> None:
        self.registry = MetricsRegistry()
        self.flight = FlightRecorder(flight_capacity)
        self.slo = SloTracker(self.registry)
        self.trace = TraceBuffer(trace_max_events)

        r = self.registry
        self.decisions = r.counter(
            "repro_admission_decisions_total",
            help="Admission decisions by pool, tier and verdict.",
            labels=("pool", "tier", "verdict"))
        self.terminal = r.counter(
            "repro_gateway_terminal_total",
            help="Requests that never reached a pool decision.",
            labels=("verdict",))
        self.tick_duration = r.histogram(
            "repro_pool_tick_duration_seconds",
            help="Wall-clock duration of one control tick.",
            labels=("pool",), lo=1e-6, hi=10.0, buckets=40)
        self.quantum_duration = r.histogram(
            "repro_gateway_quantum_duration_seconds",
            help="Wall-clock duration of one admission quantum.",
            lo=1e-6, hi=10.0, buckets=40)
        self.quantum_requests = r.counter(
            "repro_gateway_quantum_requests_total",
            help="Requests processed through handle_quantum.")
        self.waterfill = r.gauge(
            "repro_pool_waterfill_tokens",
            help="Water-filling allocation total at the last tick.",
            labels=("pool",))
        self.debt_total = r.gauge(
            "repro_pool_debt_total",
            help="Summed entitlement debt at the last tick.",
            labels=("pool",))
        self.replicas = r.gauge(
            "repro_pool_replicas_desired",
            help="Fleet planner's desired replica count.",
            labels=("pool",))
        self.scale_events = r.counter(
            "repro_fleet_scale_events_total",
            help="Authorized scale transitions by direction.",
            labels=("pool", "direction"))
        self.migrations = r.counter(
            "repro_fleet_migrations_total",
            help="Entitlement migrations applied by the planner.")
        self.incidents = r.counter(
            "repro_incidents_total",
            help="Incident windows opened (failures, chaos events).")

        self._q_sid = self.quantum_duration.series(())
        self._qreq_sid = self.quantum_requests.series(())
        self._migr_sid = self.migrations.series(())
        self._incid_sid = self.incidents.series(())
        #: terminal verdict name → counter sid
        self._term_sids = {
            name: self.terminal.series((name,))
            for name in ("unknown_key", "unroutable")}

        #: pool name → attached TokenPool (decision-time column reads)
        self._pools: dict = {}
        #: pool name → (2, n_tiers) decision sids [admit/deny, tier]
        self._dec_sids: dict[str, np.ndarray] = {}
        #: pool name → (tick-histogram sid, waterfill sid, debt sid)
        self._tick_sids: dict[str, tuple[int, int, int]] = {}
        #: (pool, entitlement) → (class code, slo seconds)
        self._tier_cache: dict[tuple, tuple[int, float]] = {}
        #: open incident windows: key → start clock
        self._open_incidents: dict[str, float] = {}
        #: closed incident windows: (key, start, end) in close order
        self._closed_incidents: list[tuple[str, float, float]] = []

    # -- attachment --------------------------------------------------------
    def attach_pool(self, pool) -> None:
        """Wire one pool in (idempotent): set ``pool.telemetry``, bind
        its legacy ``gauges()`` callables as registry gauge series, and
        pre-resolve every hot-path series id."""
        name = pool.spec.name
        if name in self._pools:
            return
        self._pools[name] = pool
        pool.telemetry = self
        self.flight.pool_id(name)
        for stat, fn in pool.gauges().items():
            self.registry.gauge(
                f"repro_pool_{stat}",
                help=f"Live pool {stat} (bound to pool.gauges()).",
                labels=("pool",)).bind((name,), fn)
        sids = np.empty((2, _N_TIERS), np.int64)
        for t, tier in enumerate(TIER_NAMES):
            sids[0, t] = self.decisions.series((name, tier, "admit"))
            sids[1, t] = self.decisions.series((name, tier, "deny"))
        self._dec_sids[name] = sids
        self._tick_sids[name] = (
            self.tick_duration.series((name,)),
            self.waterfill.series((name,)),
            self.debt_total.series((name,)))

    def _tier_of(self, pool_name: str, ent: str) -> tuple[int, float]:
        key = (pool_name, ent)
        hit = self._tier_cache.get(key)
        if hit is None:
            espec = self._pools[pool_name].entitlements[ent]
            hit = (CLASS_CODES[espec.qos.service_class],
                   espec.qos.slo_target_ms / 1000.0)
            self._tier_cache[key] = hit
        return hit

    # -- per-request hot surfaces -----------------------------------------
    @hot_path
    def record_decisions(self, pool_name: str, now: float,
                         rids, rows, legs,
                         admitted: np.ndarray, reasons, prios,
                         threshold: float, tokens,
                         levels_at=None) -> None:
        """One pool dispatch's decisions: ONE flight scatter + ONE
        counter row-op.  ``rows`` may contain -1 (NOT_BOUND skips that
        never reached the kernel); their state dims record as 0.
        ``levels_at`` optionally supplies the full-width bucket-level
        array AT DECISION TIME (the quantum snapshot) — without it the
        current resident column is read, which for a post-charge call
        reflects this batch's own deductions."""
        pool = self._pools.get(pool_name)
        if pool is None:
            raise KeyError(
                f"pool {pool_name!r} not attached to telemetry; "
                "call attach_pool first (Gateway does this on init)")
        c = pool.store.col
        rows = np.asarray(rows, np.int64)
        level_src = (np.asarray(levels_at, np.float64)
                     if levels_at is not None else c["bucket_level"])
        ok = rows >= 0
        if ok.all():                       # common case: no NB skips
            codes = c["class_code"][rows]
            levels = level_src[rows]
            debts = c["debt"][rows]
            bursts = c["burst"][rows]
        else:
            safe = np.where(ok, rows, 0)
            codes = np.where(ok, c["class_code"][safe], 0)
            levels = np.where(ok, level_src[safe], 0.0)
            debts = np.where(ok, c["debt"][safe], 0.0)
            bursts = np.where(ok, c["burst"][safe], 0.0)
        admitted = np.asarray(admitted, bool)
        verdicts = np.where(admitted, fl.VERDICT_ADMIT,
                            fl.VERDICT_DENY).astype(np.int16)
        self.flight.record_batch(
            rids, now,
            self.flight.pool_id(pool_name), legs, rows, verdicts,
            np.asarray(reasons, np.int16), prios, threshold, levels,
            debts, bursts, tokens)
        sids = self._dec_sids[pool_name][
            np.where(admitted, 0, 1), codes]
        self.decisions.inc_rows(sids, 1.0)

    @hot_path
    def record_terminal(self, now: float, request_ids: Sequence[str],
                        verdict: int, reason: int) -> None:
        """Route-level terminal rows (unknown key / no live pool):
        pool-less flight rows + one aggregated counter bump."""
        m = len(request_ids)
        if m == 0:
            return
        self.flight.record_batch(
            request_ids, now, -1, -1, -1,
            np.int16(verdict), np.int16(reason),
            0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
        name = ("unknown_key" if verdict == fl.VERDICT_UNKNOWN_KEY
                else "unroutable")
        self.terminal.inc(self._term_sids[name], float(m))

    @hot_path
    def record_completions(self, now: float, pools: Sequence[str],
                           ents: Sequence[str],
                           latencies: Sequence[float]) -> None:
        """One completion drain: resolve (pool, ent) → (tier, SLO)
        through the cold cache, then ONE SLO row-op."""
        m = len(ents)
        if m == 0:
            return
        codes = np.empty(m, np.int64)
        slos = np.empty(m, np.float64)
        tier_of = self._tier_of
        for i in range(m):
            codes[i], slos[i] = tier_of(pools[i], ents[i])
        self.slo.observe_rows(np.asarray(latencies, np.float64),
                              codes, slos)

    def record_decision(self, pool_name: str, now: float,
                        request_id: str, leg: int,
                        entitlement: Optional[str], admitted: bool,
                        reason_code: int, priority: float,
                        tokens: float) -> None:
        """Scalar twin for the sequential ``Gateway.handle`` path (and
        the flight recorder's parity oracle): one decision, state dims
        read off the resident columns at call time."""
        pool = self._pools.get(pool_name)
        row = -1
        level = debt = burst = 0.0
        code = 0
        threshold = 0.0
        if pool is not None:
            threshold = (pool.admission_threshold()
                         * (1.0 - pool.spec.admission_slack))
            if entitlement is not None:
                row = pool.store.slot_of.get(entitlement, -1)
            if row >= 0:
                c = pool.store.col
                code = int(c["class_code"][row])
                level = float(c["bucket_level"][row])
                debt = float(c["debt"][row])
                burst = float(c["burst"][row])
        self.flight.record(
            request_id, now, pool_name, leg, row,
            fl.VERDICT_ADMIT if admitted else fl.VERDICT_DENY,
            reason_code, priority, threshold, level, debt, burst,
            tokens)
        if pool_name in self._dec_sids:
            sid = self._dec_sids[pool_name][0 if admitted else 1, code]
            self.decisions.inc(int(sid))

    def record_terminal_one(self, now: float, request_id: str,
                            verdict: int, reason: int) -> None:
        """Scalar terminal twin (sequential path)."""
        self.flight.record(request_id, now, None, -1, -1, verdict,
                           reason, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
        name = ("unknown_key" if verdict == fl.VERDICT_UNKNOWN_KEY
                else "unroutable")
        self.terminal.inc(self._term_sids[name])

    # -- per-event surfaces (once per tick/quantum/plan) -------------------
    def on_tick(self, pool_name: str, now: float, duration_s: float,
                alloc_total: float, debt_total: float,
                in_flight: int) -> None:
        """One pool control tick: duration histogram, water-fill /
        debt gauges, and a trace slice + counter track."""
        sids = self._tick_sids.get(pool_name)
        if sids is None:
            return
        tick_sid, wf_sid, debt_sid = sids
        self.tick_duration.observe(tick_sid, duration_s)
        self.waterfill.set(wf_sid, alloc_total)
        self.debt_total.set(debt_sid, debt_total)
        track = f"pool:{pool_name}"
        self.trace.complete(
            "control_tick", track, now, duration_s,
            args={"alloc_tokens": alloc_total, "debt": debt_total,
                  "in_flight": in_flight})
        self.trace.counter(
            f"waterfill:{pool_name}", track, now,
            {"tokens": alloc_total, "debt": debt_total})

    def on_quantum(self, now: float, n_requests: int,
                   duration_s: float) -> None:
        """One admission quantum through ``handle_quantum``."""
        self.quantum_duration.observe(self._q_sid, duration_s)
        self.quantum_requests.inc(self._qreq_sid, float(n_requests))
        self.trace.complete("admit_quantum", "gateway", now, duration_s,
                            args={"requests": n_requests})

    def on_plan(self, now: float, plan, duration_s: float) -> None:
        """One fleet planning round: replica gauges, scale/migration
        counters, trace markers."""
        for name, d in plan.decisions.items():
            self.replicas.set(self.replicas.series((name,)),
                              float(d.desired))
        for name, (old, new) in plan.scale_events.items():
            if new == old:
                continue
            direction = "up" if new > old else "down"
            self.scale_events.inc(
                self.scale_events.series((name, direction)))
            self.trace.instant(
                f"scale_{direction}:{name}", "fleet", now,
                args={"from": old, "to": new})
        for prop in plan.applied:
            self.migrations.inc(self._migr_sid)
            self.trace.instant(
                f"migrate:{prop.entitlement}", "fleet", now,
                args={"dst": prop.dst})
        self.trace.complete("plan_quantum", "fleet", now, duration_s)

    def incident_start(self, key: str, now: float) -> None:
        self._open_incidents[key] = now
        self.incidents.inc(self._incid_sid)
        self.trace.instant(f"incident_start:{key}", "incidents", now)

    def incident_end(self, key: str, now: float) -> None:
        start = self._open_incidents.pop(key, None)
        if start is None:
            return
        self._closed_incidents.append((key, start, now))
        self.trace.complete(f"incident:{key}", "incidents", start,
                            now - start)

    def incident_windows(self) -> list[tuple[str, float, Optional[float]]]:
        """All incident windows as ``(key, start, end)`` — closed ones
        first (in close order), then still-open ones with ``end=None``.
        Scenario assertions (the chaos harness) read THIS rather than
        the trace buffer."""
        out: list[tuple[str, float, Optional[float]]] = list(
            self._closed_incidents)
        out.extend((k, s, None) for k, s in self._open_incidents.items())
        return out

    # -- export ------------------------------------------------------------
    def prometheus(self) -> str:
        return prometheus_text(self.registry)

    def snapshot(self) -> dict:
        return {
            "metrics": json_snapshot(self.registry),
            "slo": self.slo.snapshot(),
            "flight_rows": len(self.flight),
            "trace_events": len(self.trace.events),
        }

    def chrome_trace(self) -> str:
        return chrome_trace_json(self.trace)

    @staticmethod
    def clock() -> float:
        """Wall-clock source for duration measurements."""
        return time.perf_counter()
