"""Admission flight recorder — a fixed-capacity SoA ring buffer with
one row per admission DECISION (one request may contribute several
rows: one per route leg it was tried on, plus terminal rows for
unknown keys and unroutable requests).

Each row captures the decision *and the control-plane state it was
made against*: rid hash, clock, pool, leg, verdict, deny-reason code,
the request's live Eq. 1 priority vs the pool's admission threshold,
and the owning entitlement's bucket level / debt / burst dims at
decision time — enough to answer "why was request X denied at t=42.3"
without replaying the simulation.

Writes are batched: the gateway emits ONE ``record_batch`` call per
``admit_quantum`` / ``_quantum_fast`` dispatch (a masked scatter per
column into ring positions ``(head + arange(m)) & (cap-1)``); the
scalar ``record`` twin is the parity oracle and serves the scalar
``Gateway.handle`` path.  ``explain(request_id)`` reconstructs the
full multi-leg decision narrative; ``recent(...)`` is the structured
query surface.

The columns are registered in the analyzer's merged column manifest
(``column_manifest`` below, wired into
``repro.analysis.manifest.default_manifest``) so dtype discipline and
mirror rules cover them the moment one is declared.  Requests are
matched by Python string hash — stable within a process (explain is
an in-process debugging surface), 64-bit so collisions are
negligible.  The hot path stores raw id POINTERS only; the
``rid_hash`` column is materialized lazily at query time so dispatch
never pays the per-string hash loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.markers import hot_path
from repro.core.types import DenyReason

__all__ = [
    "DecisionTrace",
    "FlightRecorder",
    "FlightRow",
    "REASON_NAMES",
    "REASON_NONE",
    "REASON_POOL_UNAVAILABLE",
    "VERDICT_ADMIT",
    "VERDICT_DENY",
    "VERDICT_NAMES",
    "VERDICT_UNKNOWN_KEY",
    "column_manifest",
    "hash_ids",
]

#: verdict codes (``verdict`` column)
VERDICT_ADMIT = 0
VERDICT_DENY = 1
VERDICT_UNKNOWN_KEY = 2
VERDICT_NAMES = {VERDICT_ADMIT: "admit", VERDICT_DENY: "deny",
                 VERDICT_UNKNOWN_KEY: "unknown_key"}

#: deny-reason codes (``reason`` column): 1–4 are the kernel's
#: ``admit_quantum`` codes (``gateway._REASON_CODES``), 5 is the
#: route-level "no live pool" denial, 0 means "no denial".
REASON_NONE = 0
REASON_POOL_UNAVAILABLE = 5
REASON_NAMES = {
    REASON_NONE: None,
    1: DenyReason.NOT_BOUND.value,
    2: DenyReason.CONCURRENCY.value,
    3: DenyReason.TOKEN_BUDGET.value,
    4: DenyReason.LOW_PRIORITY.value,
    REASON_POOL_UNAVAILABLE: DenyReason.POOL_UNAVAILABLE.value,
}
#: DenyReason → code (the scalar ``Gateway.handle`` path records
#: through enum values; the quantum paths carry kernel codes already)
REASON_CODES = {v: k for k, v in REASON_NAMES.items() if v is not None}

#: SoA ring columns.  Names are distinct from every resident /
#: request-table column (the analyzer's mirror & dtype rules match by
#: column NAME across all manifests).
_COLUMNS: dict[str, np.dtype] = {
    "rid_hash": np.dtype(np.int64),
    "t": np.dtype(np.float64),        # decision clock (sim seconds)
    "pool_id": np.dtype(np.int32),    # interned pool (-1: no pool)
    "ent_slot": np.dtype(np.int32),   # resident row (-1: not bound)
    "leg": np.dtype(np.int32),        # declared route position (-1: n/a)
    "verdict": np.dtype(np.int16),
    "reason": np.dtype(np.int16),
    "prio": np.dtype(np.float64),     # live Eq. 1 priority w
    "threshold": np.dtype(np.float64),  # pool admission threshold
    "level_at": np.dtype(np.float64),   # bucket level at decision
    "debt_at": np.dtype(np.float64),    # debt dim at decision
    "burst_at": np.dtype(np.float64),   # burst dim at decision
    "tokens_at": np.dtype(np.float64),  # charged tokens requested
    "seq": np.dtype(np.int64),        # global write sequence (1-based)
}


def column_manifest() -> dict:
    """Machine-readable column contract for the static analyzer (the
    telemetry twin of ``resident.column_manifest``).  No device
    mirror, no kernel-facing f32 columns — but the f64 value columns
    get dtype-discipline coverage the moment they land here."""
    return {
        "store": "FlightRecorder",
        "module": "repro.telemetry.flight",
        "columns": {name: str(dtype) for name, dtype in _COLUMNS.items()},
        "mirrored": [],
        "kernel_f32": [],
        "sanctioned_mutators": [],
    }


def hash_ids(request_ids) -> np.ndarray:
    """Vectorize ``hash`` over request-id strings (C-speed map) —
    what lazy ``rid_hash`` materialization runs at query time."""
    return np.fromiter(map(hash, request_ids), np.int64,
                       count=len(request_ids))


@dataclasses.dataclass(frozen=True)
class FlightRow:
    """One materialized decision row (query results / explain legs)."""

    t: float
    pool: Optional[str]
    ent_slot: int
    leg: int
    verdict: int
    reason_code: int
    priority: float
    threshold: float
    bucket_level: float
    debt: float
    burst: float
    tokens: float
    seq: int

    @property
    def verdict_name(self) -> str:
        return VERDICT_NAMES.get(self.verdict, f"verdict{self.verdict}")

    @property
    def reason(self) -> Optional[str]:
        return REASON_NAMES.get(self.reason_code)


@dataclasses.dataclass(frozen=True)
class DecisionTrace:
    """The reconstructed multi-leg narrative for one request.  The
    summary properties reproduce the ``GatewayResponse`` attribution
    rules exactly (pinned request-by-request by the randomized parity
    sweep in ``tests/test_telemetry.py``): admit anywhere → 200 with
    the admitting leg's pool/priority/hops; otherwise the FIRST
    denial's reason, with priority surfaced only for low-priority
    denials — same as ``_Pending.note_denial``."""

    request_id: str
    legs: tuple[FlightRow, ...]

    @property
    def _admit(self) -> Optional[FlightRow]:
        for row in self.legs:
            if row.verdict == VERDICT_ADMIT:
                return row
        return None

    @property
    def status(self) -> int:
        if self._admit is not None:
            return 200
        if self.legs[0].verdict == VERDICT_UNKNOWN_KEY:
            return 401
        return 429

    @property
    def reason(self) -> Optional[str]:
        if self._admit is not None:
            return None
        if self.legs[0].verdict == VERDICT_UNKNOWN_KEY:
            return "unknown_key"
        return self.legs[0].reason

    @property
    def priority(self) -> float:
        adm = self._admit
        if adm is not None:
            return adm.priority
        first = self.legs[0]
        if REASON_NAMES.get(first.reason_code) \
                == DenyReason.LOW_PRIORITY.value:
            return first.priority
        return 0.0

    @property
    def pool(self) -> Optional[str]:
        adm = self._admit
        return adm.pool if adm is not None else None

    @property
    def spill_hops(self) -> int:
        adm = self._admit
        return adm.leg if adm is not None else 0

    def narrative(self) -> str:
        """Human-readable multi-leg decision story."""
        lines = [f"{self.request_id}: status={self.status}"
                 + (f" reason={self.reason}" if self.reason else "")]
        for row in self.legs:
            where = (f"pool={row.pool} leg={row.leg}"
                     if row.pool is not None else "route")
            lines.append(
                f"  t={row.t:.3f} {where} -> {row.verdict_name}"
                + (f" ({row.reason})" if row.reason else "")
                + f" prio={row.priority:.3f}/thr={row.threshold:.3f}"
                + f" level={row.bucket_level:.1f} debt={row.debt:.3f}"
                + f" burst={row.burst:.3f} tokens={row.tokens:.0f}")
        return "\n".join(lines)


class FlightRecorder:
    """Fixed-capacity SoA decision ring (pow2, masked positions)."""

    def __init__(self, capacity: int = 65536) -> None:
        cap = 1
        while cap < max(2, capacity):
            cap *= 2
        self.capacity = cap
        self.col: dict[str, np.ndarray] = {
            name: np.zeros(cap, dtype)
            for name, dtype in _COLUMNS.items()}
        #: total rows ever written (ring head); row seq is 1-based
        self.head = 0
        #: raw request-id ring (pointer copies on the hot path); the
        #: ``rid_hash`` column is materialized LAZILY from this at
        #: query time so dispatch never pays the per-string hash loop
        self._rids = np.empty(cap, object)
        self._hashed_upto = 0
        self._pool_ids: dict[str, int] = {}
        self._pool_names: list[str] = []

    # -- pool interning ----------------------------------------------------
    def pool_id(self, name: str) -> int:
        pid = self._pool_ids.get(name)
        if pid is None:
            pid = len(self._pool_names)
            self._pool_ids[name] = pid
            self._pool_names.append(name)
        return pid

    def pool_name(self, pid: int) -> Optional[str]:
        if 0 <= pid < len(self._pool_names):
            return self._pool_names[pid]
        return None

    # -- recording ---------------------------------------------------------
    @hot_path
    def record_batch(self, rids, now: float,
                     pool_id, legs, ent_slots, verdicts, reasons,
                     prios, threshold, levels, debts, bursts,
                     tokens) -> None:
        """ONE masked scatter per column for a whole dispatch batch.
        ``rids`` is the raw request-id sequence (hashing is deferred to
        query time); every value argument may be a scalar (broadcast)
        or a length-m array.  A batch longer than the ring keeps its
        TAIL (newest rows win, same as sequential wraparound)."""
        m = len(rids)
        if m == 0:
            return
        cap = self.capacity
        if m > cap:
            drop = m - cap

            def tail(x):
                return x[drop:] if np.ndim(x) else x

            rids = rids[drop:]
            legs, ent_slots = tail(legs), tail(ent_slots)
            verdicts, reasons = tail(verdicts), tail(reasons)
            prios, levels = tail(prios), tail(levels)
            debts, bursts = tail(debts), tail(bursts)
            tokens = tail(tokens)
            self.head += drop
            m = cap
        start = self.head & (cap - 1)
        if start + m <= cap:               # no wrap: slice writes
            pos = slice(start, start + m)
        else:
            pos = (self.head + np.arange(m)) & (cap - 1)
        c = self.col
        self._rids[pos] = rids
        c["t"][pos] = now
        c["pool_id"][pos] = pool_id
        c["ent_slot"][pos] = ent_slots
        c["leg"][pos] = legs
        c["verdict"][pos] = verdicts
        c["reason"][pos] = reasons
        c["prio"][pos] = prios
        c["threshold"][pos] = threshold
        c["level_at"][pos] = levels
        c["debt_at"][pos] = debts
        c["burst_at"][pos] = bursts
        c["tokens_at"][pos] = tokens
        c["seq"][pos] = np.arange(self.head + 1, self.head + 1 + m)
        self.head += m

    def record(self, request_id: str, now: float,
               pool: Optional[str], leg: int, ent_slot: int,
               verdict: int, reason: int, priority: float,
               threshold: float, level: float, debt: float,
               burst: float, tokens: float) -> None:
        """Scalar oracle — one decision row, written independently of
        ``record_batch`` so the parity sweep pins batch == loop-of-
        scalar ring state.  Serves the scalar ``Gateway.handle``."""
        pos = self.head & (self.capacity - 1)
        c = self.col
        self._rids[pos] = request_id
        c["t"][pos] = now
        c["pool_id"][pos] = -1 if pool is None else self.pool_id(pool)
        c["ent_slot"][pos] = ent_slot
        c["leg"][pos] = leg
        c["verdict"][pos] = verdict
        c["reason"][pos] = reason
        c["prio"][pos] = priority
        c["threshold"][pos] = threshold
        c["level_at"][pos] = level
        c["debt_at"][pos] = debt
        c["burst_at"][pos] = burst
        c["tokens_at"][pos] = tokens
        self.head += 1
        c["seq"][pos] = self.head

    # -- queries -----------------------------------------------------------
    def _materialize(self) -> None:
        """Fill ``rid_hash`` for rows written since the last query —
        the hot path stores raw id pointers only, so the per-string
        hash loop runs at (cold) query time, amortized over the span
        written in between."""
        dirty = self.head - self._hashed_upto
        if dirty <= 0:
            return
        cap = self.capacity
        dirty = min(dirty, cap)
        start = (self.head - dirty) & (cap - 1)
        if start + dirty <= cap:
            pos = slice(start, start + dirty)
        else:
            pos = (self.head - dirty + np.arange(dirty)) & (cap - 1)
        self.col["rid_hash"][pos] = np.fromiter(
            map(hash, self._rids[pos]), np.int64, count=dirty)
        self._hashed_upto = self.head

    def _valid_mask(self) -> np.ndarray:
        """Rows not yet overwritten (and ever written: seq 0 = empty)."""
        return self.col["seq"] > max(0, self.head - self.capacity)

    def _row(self, i: int) -> FlightRow:
        c = self.col
        return FlightRow(
            t=float(c["t"][i]),
            pool=self.pool_name(int(c["pool_id"][i])),
            ent_slot=int(c["ent_slot"][i]),
            leg=int(c["leg"][i]),
            verdict=int(c["verdict"][i]),
            reason_code=int(c["reason"][i]),
            priority=float(c["prio"][i]),
            threshold=float(c["threshold"][i]),
            bucket_level=float(c["level_at"][i]),
            debt=float(c["debt_at"][i]),
            burst=float(c["burst_at"][i]),
            tokens=float(c["tokens_at"][i]),
            seq=int(c["seq"][i]))

    def explain(self, request_id: str) -> Optional[DecisionTrace]:
        """Reconstruct one request's decision narrative: every
        still-resident row whose rid hash matches, in decision (seq)
        order.  None once the ring has overwritten the request (or it
        was never seen)."""
        self._materialize()
        h = hash(request_id)
        c = self.col
        hits = np.flatnonzero((c["rid_hash"] == h) & self._valid_mask())
        if hits.size == 0:
            return None
        hits = hits[np.argsort(c["seq"][hits])]
        return DecisionTrace(
            request_id=request_id,
            legs=tuple(self._row(int(i)) for i in hits))

    def recent(self, n: int = 50, pool: Optional[str] = None,
               verdict: Optional[int] = None,
               reason: Optional[int] = None) -> list[FlightRow]:
        """The last ``n`` matching decisions, newest first."""
        c = self.col
        mask = self._valid_mask()
        if pool is not None:
            pid = self._pool_ids.get(pool)
            if pid is None:
                return []
            mask &= c["pool_id"] == pid
        if verdict is not None:
            mask &= c["verdict"] == verdict
        if reason is not None:
            mask &= c["reason"] == reason
        hits = np.flatnonzero(mask)
        hits = hits[np.argsort(c["seq"][hits])][::-1][:n]
        return [self._row(int(i)) for i in hits]

    def __len__(self) -> int:
        return min(self.head, self.capacity)
