"""Metrics registry — numpy-backed counters, gauges and histograms.

The recording surface mirrors the repo's control-plane discipline:
series values live in flat numpy arrays keyed by an interned series id
(one id per label tuple, e.g. ``(pool, tier, verdict)``), and the HOT
recording APIs are *batch row-ops* —

* ``Counter.inc_rows(sids, by)``    — one ``np.add.at`` per quantum;
* ``Histogram.observe_rows(values, sids)`` — one ``np.searchsorted``
  over the log-spaced bucket edges + one 2-D ``np.add.at`` into the
  per-series count matrix per quantum.

The scalar ``inc()`` / ``observe()`` twins are retained as the parity
oracles (``tests/test_telemetry.py`` pins batch == scalar state through
random sweeps) and are FORBIDDEN inside ``@hot_path`` functions by the
``telemetry-hot-path`` sanitizer pass — the same arrangement the
request lifecycle uses (row-ops hot, scalars as oracles).

Series creation (``series(labels)``) is a cold-path dict lookup with
pow2 array growth; hot paths pre-resolve their ids into lookup arrays
(see ``Telemetry._pool_sids``) so per-quantum work is pure indexing.
"""
from __future__ import annotations

from typing import Callable, Iterator, Optional

import numpy as np

from repro.core.markers import hot_path

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


def _grown(arr: np.ndarray, n: int) -> np.ndarray:
    """Pow2-grow ``arr``'s leading axis to hold at least ``n`` rows."""
    cap = arr.shape[0]
    while cap < n:
        cap *= 2
    out = np.zeros((cap,) + arr.shape[1:], arr.dtype)
    out[:arr.shape[0]] = arr
    return out


class _Family:
    """One named metric family: label tuples interned to series ids."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: tuple = ()) -> None:
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self._index: dict[tuple, int] = {}
        #: sid → label tuple (same order as the value arrays)
        self.series_labels: list[tuple] = []

    def series(self, labels: tuple = ()) -> int:
        """Intern a label tuple → series id (get-or-create).  Cold
        path: hot recorders pre-resolve ids into lookup arrays."""
        labels = tuple(labels)
        sid = self._index.get(labels)
        if sid is None:
            if len(labels) != len(self.label_names):
                raise ValueError(
                    f"{self.name}: expected labels {self.label_names}, "
                    f"got {labels!r}")
            sid = len(self.series_labels)
            self._index[labels] = sid
            self.series_labels.append(labels)
            self._grow(sid + 1)
        return sid

    def _grow(self, n: int) -> None:  # pragma: no cover - overridden
        raise NotImplementedError


class Counter(_Family):
    """Monotone counter family (``_total`` by Prometheus convention)."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: tuple = ()) -> None:
        super().__init__(name, help, labels)
        self.values = np.zeros(8, np.float64)

    def _grow(self, n: int) -> None:
        if n > self.values.shape[0]:
            self.values = _grown(self.values, n)

    def inc(self, sid: int, by: float = 1.0) -> None:
        """Scalar oracle — one series, one increment."""
        self.values[sid] += by
        self._check(by)

    @hot_path
    def inc_rows(self, sids: np.ndarray, by) -> None:
        """Batch recorder: ``by`` is a scalar or per-row array.  The
        scatter-add runs as one ``bincount`` over the (small, dense)
        sid space — ~10x ``np.add.at`` on 10k-row quanta."""
        self._check(by)
        sids = np.asarray(sids)
        if sids.size == 0:
            return
        n = self.values.shape[0]
        if np.ndim(by) == 0:
            self.values += float(by) * np.bincount(sids, minlength=n)
        else:
            self.values += np.bincount(
                sids, weights=np.asarray(by, np.float64), minlength=n)

    def _check(self, by) -> None:
        if np.any(np.asarray(by) < 0):
            raise ValueError(f"{self.name}: counters only go up")

    def read(self, sid: int) -> float:
        return float(self.values[sid])


class Gauge(_Family):
    """Point-in-time value family.  A series is either *set* directly
    or *bound* to a zero-arg callable — callback gauges are how the
    legacy ``pool.stats()`` dict stays a thin view over the registry
    (both read the SAME callables; see ``TokenPool.gauges``)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labels: tuple = ()) -> None:
        super().__init__(name, help, labels)
        self.values = np.zeros(8, np.float64)
        self._callbacks: dict[int, Callable[[], float]] = {}

    def _grow(self, n: int) -> None:
        if n > self.values.shape[0]:
            self.values = _grown(self.values, n)

    def set(self, sid: int, value: float) -> None:
        self.values[sid] = value

    @hot_path
    def set_rows(self, sids: np.ndarray, values: np.ndarray) -> None:
        self.values[sids] = values

    def bind(self, labels: tuple, fn: Callable[[], float]) -> int:
        """Register a callback series: ``read`` evaluates ``fn``."""
        sid = self.series(labels)
        self._callbacks[sid] = fn
        return sid

    def read(self, sid: int) -> float:
        fn = self._callbacks.get(sid)
        return float(fn()) if fn is not None else float(self.values[sid])


class Histogram(_Family):
    """Log-spaced-bucket histogram family.

    ``edges`` are the bucket UPPER bounds (Prometheus ``le``
    semantics): a value lands in the first bucket whose edge is >= it,
    values beyond ``hi`` land in the implicit +Inf overflow bucket
    (index ``buckets``).  Per-series state is one row of the 2-D count
    matrix plus a sum and a total — everything quantiles, attainment
    ratios and the Prometheus exposition need."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", labels: tuple = (),
                 lo: float = 1e-3, hi: float = 1e3,
                 buckets: int = 36) -> None:
        super().__init__(name, help, labels)
        if not (0 < lo < hi):
            raise ValueError(f"{name}: need 0 < lo < hi")
        self.edges = np.geomspace(lo, hi, buckets)
        self.counts = np.zeros((8, buckets + 1), np.int64)
        self.sums = np.zeros(8, np.float64)
        self.totals = np.zeros(8, np.int64)

    def _grow(self, n: int) -> None:
        if n > self.sums.shape[0]:
            self.counts = _grown(self.counts, n)
            self.sums = _grown(self.sums, n)
            self.totals = _grown(self.totals, n)

    def observe(self, sid: int, value: float) -> None:
        """Scalar oracle — the parity twin of ``observe_rows``."""
        b = int(np.searchsorted(self.edges, value, side="left"))
        self.counts[sid, b] += 1
        self.sums[sid] += value
        self.totals[sid] += 1

    @hot_path
    def observe_rows(self, values: np.ndarray,
                     sids: np.ndarray) -> None:
        """Batch recorder: one ``searchsorted`` + one 2-D ``add.at``
        (plus the sum/total scatters) for the whole quantum."""
        values = np.asarray(values, np.float64)
        b = np.searchsorted(self.edges, values, side="left")
        np.add.at(self.counts, (sids, b), 1)
        np.add.at(self.sums, sids, values)
        np.add.at(self.totals, sids, 1)

    def quantile(self, sid: int, q: float) -> float:
        """Bucket-interpolated quantile (P50/P99 live views).  Returns
        0.0 for an empty series; overflow-bucket hits clamp to the top
        edge (the histogram cannot see past ``hi``)."""
        total = int(self.totals[sid])
        if total == 0:
            return 0.0
        target = q * total
        cum = np.cumsum(self.counts[sid])
        b = int(np.searchsorted(cum, target, side="left"))
        if b >= self.edges.shape[0]:
            return float(self.edges[-1])
        hi = float(self.edges[b])
        lo = float(self.edges[b - 1]) if b > 0 else 0.0
        in_bucket = int(self.counts[sid, b])
        prev = float(cum[b - 1]) if b > 0 else 0.0
        if in_bucket == 0:
            return hi
        frac = min(1.0, max(0.0, (target - prev) / in_bucket))
        return lo + frac * (hi - lo)


class MetricsRegistry:
    """Name → family registry (get-or-create, kind-checked)."""

    def __init__(self) -> None:
        self._families: dict[str, _Family] = {}

    def _get(self, cls, name: str, help: str, labels: tuple,
             **kwargs) -> _Family:
        fam = self._families.get(name)
        if fam is None:
            fam = cls(name, help=help, labels=labels, **kwargs)
            self._families[name] = fam
        elif not isinstance(fam, cls):
            raise TypeError(f"{name} is a {fam.kind}, not {cls.kind}")
        return fam

    def counter(self, name: str, help: str = "",
                labels: tuple = ()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: tuple = ()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", labels: tuple = (),
                  lo: float = 1e-3, hi: float = 1e3,
                  buckets: int = 36) -> Histogram:
        return self._get(Histogram, name, help, labels,
                         lo=lo, hi=hi, buckets=buckets)

    def get(self, name: str) -> Optional[_Family]:
        return self._families.get(name)

    def families(self) -> Iterator[_Family]:
        for name in sorted(self._families):
            yield self._families[name]
