"""SLO attainment tracking — per-tier latency histograms and
attainment ratios fed from ``on_complete_batch``.

The tracker owns three registry families:

* ``repro_request_latency_seconds{tier}`` — log-spaced histogram of
  end-to-end request latency per service class (live P50/P99 views);
* ``repro_slo_completions_total{tier}`` / ``repro_slo_met_total{tier}``
  — completion and SLO-met counters, whose ratio is the attainment
  fraction the experiments assert against.

The hot surface is ``observe_rows(latencies, tier_codes, slo_s)`` —
one histogram ``observe_rows`` plus two ``inc_rows`` per completion
drain.  The scalar ``observe`` twin is the parity oracle.  Series ids
are pre-resolved per class code at construction so the hot path does
no dict work.
"""
from __future__ import annotations

import numpy as np

from repro.core.control_plane import CLASS_CODES
from repro.core.markers import hot_path
from repro.telemetry.registry import MetricsRegistry

__all__ = ["SloTracker", "TIER_NAMES"]

#: class code → tier label, ordered by code (see CLASS_CODES).
TIER_NAMES: tuple[str, ...] = tuple(
    sc.value for sc, _ in sorted(CLASS_CODES.items(), key=lambda kv: kv[1]))


class SloTracker:
    """Per-tier latency + attainment accounting over the registry."""

    def __init__(self, registry: MetricsRegistry) -> None:
        self.latency = registry.histogram(
            "repro_request_latency_seconds",
            help="End-to-end request latency by service tier.",
            labels=("tier",), lo=1e-3, hi=120.0, buckets=40)
        self.completions = registry.counter(
            "repro_slo_completions_total",
            help="Completed requests by service tier.",
            labels=("tier",))
        self.met = registry.counter(
            "repro_slo_met_total",
            help="Completions that met their SLO latency target.",
            labels=("tier",))
        #: class code → series id (identical across the 3 families by
        #: construction order; kept separate anyway for robustness)
        self._lat_sids = np.array(
            [self.latency.series((t,)) for t in TIER_NAMES], np.int64)
        self._cmp_sids = np.array(
            [self.completions.series((t,)) for t in TIER_NAMES], np.int64)
        self._met_sids = np.array(
            [self.met.series((t,)) for t in TIER_NAMES], np.int64)

    def observe(self, latency_s: float, tier_code: int,
                slo_s: float) -> None:
        """Scalar oracle — one completion."""
        self.latency.observe(int(self._lat_sids[tier_code]), latency_s)
        self.completions.inc(int(self._cmp_sids[tier_code]))
        if latency_s <= slo_s:
            self.met.inc(int(self._met_sids[tier_code]))

    @hot_path
    def observe_rows(self, latencies: np.ndarray,
                     tier_codes: np.ndarray, slo_s: np.ndarray) -> None:
        """Batch recorder: one completion drain = three row-ops."""
        latencies = np.asarray(latencies, np.float64)
        tier_codes = np.asarray(tier_codes, np.int64)
        self.latency.observe_rows(latencies, self._lat_sids[tier_codes])
        self.completions.inc_rows(self._cmp_sids[tier_codes], 1.0)
        met = latencies <= np.asarray(slo_s, np.float64)
        if np.any(met):
            self.met.inc_rows(self._met_sids[tier_codes[met]], 1.0)

    # -- live views --------------------------------------------------------
    def _code(self, tier: str) -> int:
        return TIER_NAMES.index(tier)

    def attainment(self, tier: str) -> float:
        """SLO-met fraction for ``tier`` (1.0 when nothing completed —
        an idle tier has not violated anything)."""
        code = self._code(tier)
        total = self.completions.read(int(self._cmp_sids[code]))
        if total == 0:
            return 1.0
        return self.met.read(int(self._met_sids[code])) / total

    def p50(self, tier: str) -> float:
        return self.latency.quantile(
            int(self._lat_sids[self._code(tier)]), 0.50)

    def p99(self, tier: str) -> float:
        return self.latency.quantile(
            int(self._lat_sids[self._code(tier)]), 0.99)

    def snapshot(self) -> dict:
        """Per-tier {completions, attainment, p50_s, p99_s} dict."""
        out = {}
        for code, tier in enumerate(TIER_NAMES):
            total = self.completions.read(int(self._cmp_sids[code]))
            if total == 0:
                continue
            out[tier] = {
                "completions": total,
                "attainment": self.attainment(tier),
                "p50_s": self.p50(tier),
                "p99_s": self.p99(tier),
            }
        return out
