from repro.distributed.sharding import (
    ShardingPlan,
    cache_pspecs,
    make_plan,
    param_pspecs,
)

__all__ = ["ShardingPlan", "cache_pspecs", "make_plan", "param_pspecs"]
