"""qwen3-moe-235b-a22b [moe] — 128 experts top-8
[hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                    # per-expert ffn
    vocab_size=151936,
    max_seq_len=32768,
    pattern=("global",),
    mlp_kind="swiglu",
    num_experts=128,
    experts_per_token=8,
    norm_topk_prob=True,
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
