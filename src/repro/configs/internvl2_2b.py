"""internvl2-2b [vlm] — InternViT + InternLM2 backbone
[arXiv:2404.16821; hf].  The vision frontend is a STUB per the
assignment: ``input_specs()`` supplies precomputed patch embeddings
(256 tokens for one 448² tile), projected into the LM width."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,             # padded to 92672 for the TP axis
    max_seq_len=32768,
    pattern=("global",),
    mlp_kind="swiglu",
    num_vision_tokens=256,
    source="arXiv:2404.16821; hf",
)
