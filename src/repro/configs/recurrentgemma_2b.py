"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attention
per 2 recurrent blocks [arXiv:2402.19427; hf].  26 layers = 8 full
(rec, rec, attn) periods + a (rec, rec) tail."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,               # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    max_seq_len=524288,           # O(1)/windowed state → long_500k runs
    pattern=("rglru", "rglru", "local"),
    window_size=2048,
    rnn_width=2560,
    conv_width=4,
    mlp_kind="geglu",
    embed_scale=True,
    source="arXiv:2402.19427; hf",
)
