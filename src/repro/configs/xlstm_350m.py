"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517;
unverified].  We alternate mLSTM/sLSTM 1:1 (the 350M point in the
paper's family; block ratio is a free parameter there — recorded in
DESIGN.md as an assumption for this unverified-tier config)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,                       # cells carry their own projections
    vocab_size=50304,
    max_seq_len=524288,           # O(1) state → long_500k runs
    pattern=("mlstm", "slstm"),
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    source="arXiv:2405.04517; unverified",
)
