"""Architecture configs — the 10 assigned archs + the paper's own model.

``get_config(name)`` accepts the assignment ids (``gemma2-9b`` etc.).
"""
from __future__ import annotations

import importlib

_MODULES = {
    "gemma2-9b": "gemma2_9b",
    "deepseek-7b": "deepseek_7b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "gemma2-2b": "gemma2_2b",
    "xlstm-350m": "xlstm_350m",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "internvl2-2b": "internvl2_2b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-small": "whisper_small",
    "qwen3-8b": "qwen3_8b",          # the paper's serving model
}

#: the 10 assignment architectures (dry-run / roofline coverage)
ASSIGNED = tuple(n for n in _MODULES if n != "qwen3-8b")


def get_config(name: str):
    mod = _MODULES.get(name)
    if mod is None:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def all_configs() -> dict:
    return {n: get_config(n) for n in _MODULES}
