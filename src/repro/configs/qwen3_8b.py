"""qwen3-8b [dense] — the PAPER'S OWN serving model
(nvidia/Qwen3-8B-NVFP4 in §5.1; bf16 here — NVFP4 has no TPU analogue).
Used by the examples and the serving benchmarks."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    max_seq_len=32768,
    pattern=("global",),
    mlp_kind="swiglu",
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen3-8B (paper §5.1)",
)
