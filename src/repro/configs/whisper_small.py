"""whisper-small [audio] — enc-dec, conv frontend STUB
[arXiv:2212.04356; unverified].  12 encoder + 12 decoder layers;
``input_specs()`` supplies precomputed frame embeddings."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,                # decoder layers
    encoder_layers=12,
    is_encoder_decoder=True,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,             # padded to 52224 for the TP axis
    max_seq_len=32768,
    pattern=("global",),
    mlp_kind="gelu",
    source="arXiv:2212.04356; unverified",
)
