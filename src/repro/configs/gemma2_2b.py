"""gemma2-2b [dense] — local+global alternating, logit softcaps
[arXiv:2408.00118; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    max_seq_len=524288,
    pattern=("local", "global"),
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_kind="geglu",
    use_post_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    source="arXiv:2408.00118; hf",
)
