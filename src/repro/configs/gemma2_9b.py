"""gemma2-9b [dense] — local+global alternating attention, logit
softcaps [arXiv:2408.00118; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    max_seq_len=524288,          # long_500k cell (global KV seq-sharded)
    pattern=("local", "global"),
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_kind="geglu",
    use_post_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    source="arXiv:2408.00118; hf",
)
