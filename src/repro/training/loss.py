"""LM loss: next-token cross-entropy with padding + modality-prefix
masking, computed in fp32 with a vocab-padded logits mask."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_loss(logits: jax.Array, targets: jax.Array,
            mask: jax.Array | None = None,
            vocab_size: int | None = None) -> tuple[jax.Array, dict]:
    """logits (B,S,Vp) vs targets (B,S).  ``mask`` (B,S) of {0,1}
    excludes padding; padded-vocab ids already carry -1e9 logits."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    mask = mask.astype(jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / total
    acc = ((jnp.argmax(logits, axis=-1) == targets) * mask).sum() / total
    return loss, {"loss": loss, "accuracy": acc, "tokens": total}
