"""AdamW with decoupled weight decay, global-norm clipping and a
warmup+cosine schedule — pure JAX, shard-friendly (optimizer state is a
pytree congruent with params, so it inherits the FSDP sharding =
ZeRO-style sharded optimizer state).

Moments are kept in fp32 even for bf16 params (mixed-precision
practice); the update is computed in fp32 and cast back.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params: Any) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)   # noqa: E731
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(f32, params),
                      nu=jax.tree.map(f32, params))


def lr_schedule(step: jax.Array, cfg: OptimizerConfig) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    progress = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
    cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    floor = cfg.min_lr_ratio
    return cfg.lr * warm * (floor + (1.0 - floor) * cosine)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
        grads), norm


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / 1-d params."""
    keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    name = str(keys[-1]) if keys else ""
    return not any(s in name for s in
                   ("scale", "bias", "b_", "lambda", "ln"))


def adamw_update(params: Any, grads: Any, state: AdamWState,
                 cfg: OptimizerConfig) -> tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(state.step, cfg)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
        state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2)
        * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    mu_hat_scale = 1.0 / (1 - b1 ** step.astype(jnp.float32))
    nu_hat_scale = 1.0 / (1 - b2 ** step.astype(jnp.float32))

    def upd(path, p, m, v):
        u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + cfg.eps)
        if _decay_mask(path):
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map_with_path(upd, params, mu, nu)
    metrics = {"lr": lr, "grad_norm": gnorm, "step": step}
    return new_params, AdamWState(step=step, mu=mu, nu=nu), metrics
