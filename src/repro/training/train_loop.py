"""Fault-tolerant training loop.

Composes: model forward/loss → grad → (optional) gradient compression
with error feedback → AdamW → periodic async checkpoints → restart
recovery (resume from the latest committed step, re-deriving data
batches from the counter-based pipeline).

Failure handling exercised by tests:
  - ``crash_after_step``-style interruption: a new TrainLoop on the same
    checkpoint dir resumes bit-exactly from the last commit;
  - straggler mitigation at the data layer: any host can regenerate any
    shard (counter-based PRNG), so a hedged host swap needs no stream
    replay;
  - NaN-step rejection: a non-finite loss/grad skips the update
    (the step still counts — matching large-run practice of dropping
    bad batches) and is reported in metrics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpointing import AsyncCheckpointer, latest_step, restore
from repro.data.pipeline import SyntheticLMData
from repro.models import Model, Runtime
from repro.training.grad_compress import (
    CompressorConfig,
    compress_grads,
    init_error_state,
)
from repro.training.loss import lm_loss
from repro.training.optimizer import (
    AdamWState,
    OptimizerConfig,
    adamw_init,
    adamw_update,
)


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: Optional[str] = None
    optimizer: OptimizerConfig = dataclasses.field(
        default_factory=OptimizerConfig)
    compressor: CompressorConfig = dataclasses.field(
        default_factory=CompressorConfig)
    log_every: int = 10


def make_train_step(model: Model, tcfg: TrainConfig,
                    rt: Runtime = Runtime()) -> Callable:
    """Builds the jitted (params, opt, err, batch) → ... step."""
    cfg = model.cfg

    def step_fn(params, opt_state: AdamWState, err_state, batch):
        def loss_fn(p):
            logits = model.forward_train(
                p, batch["tokens"], rt=rt,
                extra_embed=batch.get("extra_embed"))
            tgt = batch["targets"]
            logits = logits[:, -tgt.shape[1]:, :]
            loss, metrics = lm_loss(logits, tgt,
                                    batch.get("mask"))
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)

        # gradient compression round-trip (cross-pod wire format)
        grads, err_state = compress_grads(grads, err_state,
                                          tcfg.compressor)

        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, tcfg.optimizer)

        # NaN-step rejection: keep old state when loss/grads blew up
        ok = jnp.isfinite(loss) & jnp.isfinite(opt_metrics["grad_norm"])
        new_params = jax.tree.map(
            lambda new, old: jnp.where(ok, new, old), new_params, params)
        new_opt = jax.tree.map(
            lambda new, old: jnp.where(ok, new, old), new_opt, opt_state)
        metrics = {**metrics, **opt_metrics,
                   "skipped": (~ok).astype(jnp.float32)}
        return new_params, new_opt, err_state, metrics

    return jax.jit(step_fn, donate_argnums=(0, 1, 2))


class TrainLoop:
    def __init__(self, model: Model, data: SyntheticLMData,
                 tcfg: TrainConfig, rt: Runtime = Runtime()) -> None:
        self.model = model
        self.data = data
        self.tcfg = tcfg
        self.rt = rt
        self.step_fn = make_train_step(model, tcfg, rt)
        self.params = model.init(jax.random.PRNGKey(0))
        self.opt_state = adamw_init(self.params)
        self.err_state = (init_error_state(self.params)
                          if tcfg.compressor.kind != "none" else
                          jax.tree.map(lambda p: jnp.zeros((1,)),
                                       {"_": 0}))
        self.start_step = 0
        self.ckpt = (AsyncCheckpointer(tcfg.checkpoint_dir)
                     if tcfg.checkpoint_dir else None)
        self.history: list[dict] = []
        self._maybe_resume()

    # -- fault tolerance -----------------------------------------------------
    def _maybe_resume(self) -> None:
        if not self.tcfg.checkpoint_dir:
            return
        step = latest_step(self.tcfg.checkpoint_dir)
        if step is None:
            return
        state = {"params": self.params, "opt": self.opt_state}
        restored = restore(self.tcfg.checkpoint_dir, step, state)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.start_step = step
        self.history.append({"resumed_from": step})

    def _checkpoint(self, step: int) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(step, {"params": self.params,
                              "opt": self.opt_state})

    # -- main loop ------------------------------------------------------------
    def run(self, steps: Optional[int] = None,
            crash_after_step: Optional[int] = None) -> list[dict]:
        """Run (resuming from the last commit).  ``crash_after_step``
        raises after that step — the fault-injection hook for tests."""
        total = steps if steps is not None else self.tcfg.steps
        logs = []
        for step in range(self.start_step, total):
            batch = {k: jnp.asarray(v) for k, v in
                     self.data.global_batch_at(step).items()}
            self.params, self.opt_state, self.err_state, metrics = \
                self.step_fn(self.params, self.opt_state,
                             self.err_state, batch)
            if (step % self.tcfg.log_every == 0 or step == total - 1):
                entry = {"step": step,
                         "loss": float(metrics["loss"]),
                         "accuracy": float(metrics["accuracy"]),
                         "grad_norm": float(metrics["grad_norm"]),
                         "lr": float(metrics["lr"]),
                         "skipped": float(metrics["skipped"])}
                logs.append(entry)
                self.history.append(entry)
            if ((step + 1) % self.tcfg.checkpoint_every == 0
                    or step == total - 1):
                self._checkpoint(step + 1)
            if crash_after_step is not None and step >= crash_after_step:
                if self.ckpt:
                    self.ckpt.wait()
                raise RuntimeError(f"injected crash after step {step}")
        if self.ckpt:
            self.ckpt.wait()
        self.start_step = total
        return logs
