"""Gradient compression for cross-pod data parallelism.

At multi-pod scale the inter-pod all-reduce of dense gradients is the
dominant collective (DCN links are ~10× slower than in-pod ICI).  Two
standard compressors, both with **error feedback** so compression error
accumulates locally and is re-applied next step (convergence-preserving,
Stich et al. / Karimireddy et al.):

  - ``topk``: keep the k largest-magnitude entries per tensor
    (sparsification); the all-reduce then moves k values + indices.
  - ``int8``: per-tensor symmetric quantisation to int8 with an fp32
    scale (8× byte reduction).

Compression is applied to the *cross-pod* reduction only; in-pod
reduce-scatter stays dense.  ``compress → (simulated) all-reduce →
decompress`` is exposed functionally so the train loop can insert it
between the in-pod and cross-pod reductions.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    kind: str = "none"            # none | topk | int8
    topk_ratio: float = 0.01      # fraction of entries kept


def _topk_compress(g: jax.Array, ratio: float
                   ) -> tuple[jax.Array, jax.Array]:
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    return kept, idx


def _topk_decompress(kept: jax.Array, idx: jax.Array, shape, dtype
                     ) -> jax.Array:
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    flat = flat.at[idx].set(kept)
    return flat.reshape(shape).astype(dtype)


def _int8_compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _int8_decompress(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Any, error: Any, cfg: CompressorConfig
                   ) -> tuple[Any, Any]:
    """Returns (decompressed grads after the lossy round-trip, new error
    state).  The round-trip models exactly what the cross-pod wire
    carries; callers insert the actual collective on the compressed
    representation (see train_loop)."""
    if cfg.kind == "none":
        return grads, error

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        if cfg.kind == "topk":
            kept, idx = _topk_compress(corrected, cfg.topk_ratio)
            approx = _topk_decompress(kept, idx, g.shape, jnp.float32)
        elif cfg.kind == "int8":
            q, scale = _int8_compress(corrected)
            approx = _int8_decompress(q, scale, jnp.float32)
        else:
            raise ValueError(cfg.kind)
        new_e = corrected - approx
        return approx.astype(g.dtype), new_e

    out = jax.tree.map(one, grads, error)
    new_grads = jax.tree.map(lambda pair: pair[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_error = jax.tree.map(lambda pair: pair[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_error


def compressed_bytes(params: Any, cfg: CompressorConfig) -> float:
    """Wire bytes per step for the cross-pod reduction (for §Roofline)."""
    n = sum(p.size for p in jax.tree.leaves(params))
    if cfg.kind == "none":
        return n * 4.0
    if cfg.kind == "topk":
        k = n * cfg.topk_ratio
        return k * (4.0 + 4.0)        # value + index
    if cfg.kind == "int8":
        return n * 1.0 + 4.0 * len(jax.tree.leaves(params))
    raise ValueError(cfg.kind)
