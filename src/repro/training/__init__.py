from repro.training.optimizer import (
    AdamWState,
    OptimizerConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    lr_schedule,
)
from repro.training.loss import lm_loss

__all__ = ["AdamWState", "OptimizerConfig", "adamw_init", "adamw_update",
           "clip_by_global_norm", "lm_loss", "lr_schedule"]
