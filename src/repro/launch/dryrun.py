"""Multi-pod dry-run: prove every (architecture × shape × mesh) cell
lowers AND compiles under the production sharding — without hardware.

MUST set the host-device count before ANY other import (jax locks the
device count on first backend init):
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.distributed.sharding import cache_pspecs, make_plan, param_pspecs
from repro.launch.mesh import make_production_mesh
from repro.models import SHAPES, build_model
from repro.models.config import ArchConfig, ShapeSpec
from repro.training.loss import lm_loss
from repro.training.optimizer import OptimizerConfig, adamw_init, adamw_update

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "benchmarks", "artifacts",
                            "dryrun")

#: long_500k applicability (DESIGN.md §5): bounded-state archs only
LONG_OK = {"gemma2-9b", "gemma2-2b", "xlstm-350m", "recurrentgemma-2b"}

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*?\s+f(?:32|16)?\S*\s", re.IGNORECASE)


def cell_applicable(arch: str, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and arch not in LONG_OK:
        return False, ("SKIP: pure full-attention KV at 524288 ctx "
                       "(see DESIGN.md §Arch-applicability)")
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input — weak-type
    correct, shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sds((B, S), i32), "targets": sds((B, S), i32)}
        if cfg.is_encoder_decoder:
            specs["extra_embed"] = sds((B, S, cfg.d_model), f32)
        elif cfg.num_vision_tokens:
            specs["extra_embed"] = sds((B, cfg.num_vision_tokens,
                                        cfg.d_model), f32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((B, S), i32)}
        if cfg.is_encoder_decoder:
            specs["extra_embed"] = sds((B, S, cfg.d_model), f32)
        elif cfg.num_vision_tokens:
            specs["extra_embed"] = sds((B, cfg.num_vision_tokens,
                                        cfg.d_model), f32)
        return specs
    # decode: one new token against an S-token KV cache
    return {"token": sds((B, 1), i32), "cur_index": sds((), i32)}


def batch_pspec(plan, specs: dict) -> dict:
    P = jax.sharding.PartitionSpec
    out = {}
    for k, v in specs.items():
        if v.ndim == 0 or v.shape[0] % plan.dp_size != 0:
            out[k] = P(*([None] * v.ndim))
        else:
            out[k] = P(plan.dp, *([None] * (v.ndim - 1)))
    return out


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum per-device operand bytes of every collective op in the
    post-SPMD HLO.  Returns totals by collective kind."""
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "f64": 8, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
                   "f8e4m3fn": 1, "f8e5m2": 1, "u64": 8, "s16": 2,
                   "u16": 2}
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    totals = {k: 0.0 for k in kinds}
    counts = {k: 0 for k in kinds}
    shape_re = re.compile(r"(f32|bf16|f16|f64|s32|u32|s8|u8|pred|s64|"
                          r"u64|s16|u16|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
    for line in hlo.splitlines():
        stripped = line.strip()
        # match op lines like:  %x = bf16[...] all-gather(...)
        m = re.search(r"=\s+(?:\([^)]*\)|\S+)\s+"
                      r"(all-gather|all-reduce|reduce-scatter|"
                      r"all-to-all|collective-permute)", stripped)
        if not m:
            continue
        kind = m.group(1)
        # operand bytes: shapes on the RHS result (covers tuple results)
        rhs = stripped.split("=", 1)[1]
        total = 0.0
        for dt, dims in shape_re.findall(rhs.split(kind)[0] + " "):
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            total += n * dtype_bytes.get(dt, 4)
        totals[kind] += total
        counts[kind] += 1
    return {"bytes_by_kind": totals,
            "counts": counts,
            "total_bytes": sum(totals.values())}


def build_step(model, plan, shape: ShapeSpec, specs: dict,
               scan_unroll: int = 1, rt_overrides: dict = None):
    """Returns (fn, example_args, in_shardings, donate, out_shardings)
    for the cell's step.  ``pin_out_shardings`` (a harness-level §Perf
    option) pins outputs — notably the updated KV cache — to the input
    layout; leaving them unspecified lets XLA replicate outputs, which
    shows up as full-cache all-gathers in serve_step."""
    rt_overrides = dict(rt_overrides or {})
    pin_out = rt_overrides.pop("pin_out_shardings", False)
    cfg = model.cfg
    rt = plan.runtime(remat="full" if shape.kind == "train" else "none",
                      scan_unroll=scan_unroll, **rt_overrides)
    P = jax.sharding.PartitionSpec
    named = lambda spec: jax.sharding.NamedSharding(plan.mesh, spec)  # noqa

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_spec = param_pspecs(plan, params_shape)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        o_spec = jax.tree.map(
            lambda _: None, opt_shape,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        # moments inherit the param sharding; step counter replicated
        o_spec = type(opt_shape)(
            step=P(), mu=p_spec, nu=p_spec)
        ocfg = OptimizerConfig()

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                logits = model.forward_train(
                    p, batch["tokens"], rt=rt,
                    extra_embed=batch.get("extra_embed"))
                tgt = batch["targets"]
                logits = logits[:, -tgt.shape[1]:, :]
                loss, metrics = lm_loss(logits, tgt)
                return loss, metrics

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            new_params, new_opt, om = adamw_update(params, grads,
                                                   opt_state, ocfg)
            return new_params, new_opt, {**metrics, **om}

        b_spec = batch_pspec(plan, specs)
        in_shardings = (jax.tree.map(named, p_spec),
                        jax.tree.map(named, o_spec),
                        jax.tree.map(named, b_spec))
        args = (params_shape, opt_shape, specs)
        out_sh = None
        if pin_out:
            metrics_spec = {k: named(P()) for k in
                            ("loss", "accuracy", "tokens", "lr",
                             "grad_norm", "step")}
            out_sh = (jax.tree.map(named, p_spec),
                      type(opt_shape)(step=named(P()),
                                      mu=jax.tree.map(named, p_spec),
                                      nu=jax.tree.map(named, p_spec)),
                      metrics_spec)
        return train_step, args, in_shardings, (0, 1), out_sh

    if shape.kind == "prefill":
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     rt))
        c_spec = cache_pspecs(plan, cache_shape)

        def prefill_step(params, cache, batch):
            logits, new_cache = model.prefill(
                params, batch["tokens"], cache, rt,
                extra_embed=batch.get("extra_embed"))
            return logits, new_cache

        b_spec = batch_pspec(plan, specs)
        in_shardings = (jax.tree.map(named, p_spec),
                        jax.tree.map(named, c_spec),
                        jax.tree.map(named, b_spec))
        args = (params_shape, cache_shape, specs)
        out_sh = None
        if pin_out:
            B = shape.global_batch
            logit_spec = P(plan.dp if B % plan.dp_size == 0 else None,
                           None, plan.tp_axis)
            out_sh = (named(logit_spec), jax.tree.map(named, c_spec))
        return prefill_step, args, in_shardings, (1,), out_sh

    # decode
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, rt))
    c_spec = cache_pspecs(plan, cache_shape)

    def serve_step(params, cache, batch):
        logits, new_cache = model.decode_step(
            params, batch["token"], cache, batch["cur_index"], rt)
        return logits, new_cache

    b_spec = batch_pspec(plan, specs)
    in_shardings = (jax.tree.map(named, p_spec),
                    jax.tree.map(named, c_spec),
                    jax.tree.map(named, b_spec))
    args = (params_shape, cache_shape, specs)
    out_sh = None
    if pin_out:
        B = shape.global_batch
        logit_spec = P(plan.dp if B % plan.dp_size == 0 else None,
                       None, plan.tp_axis)
        out_sh = (named(logit_spec), jax.tree.map(named, c_spec))
    return serve_step, args, in_shardings, (1,), out_sh


def _compile_costs(cfg, plan_mode, mesh, shape, scan_unroll,
                   rt_overrides=None) -> dict:
    """Lower+compile one variant; return raw cost numbers."""
    model = build_model(cfg)
    plan = make_plan(cfg, mesh, plan_mode)
    specs = input_specs(cfg, shape)
    fn, args, in_shardings, donate, out_sh = build_step(
        model, plan, shape, specs, scan_unroll, rt_overrides)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_shardings,
                          out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    coll = collective_bytes_from_hlo(hlo)
    out = {
        "flops": float(cost.get("flops", -1.0)) if cost else -1.0,
        "bytes_accessed": float(cost.get("bytes accessed", -1.0))
        if cost else -1.0,
        "collectives": coll,
        "hlo_lines": hlo.count("\n"),
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                out[attr] = int(v)
    return out


def _variant_cfg(cfg, periods: int):
    """Same architecture, ``periods`` repeats of the layer pattern (no
    tail) — the probe models for per-period HLO cost extraction."""
    kw = dict(num_layers=periods * len(cfg.pattern))
    if cfg.is_encoder_decoder:
        kw["encoder_layers"] = periods
        kw["num_layers"] = periods
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = None, verbose: bool = True,
             rt_overrides: dict = None, tag: str = "") -> dict:
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(arch, shape)
    result = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "kind": shape.kind, "rt_overrides": rt_overrides or {},
              "tag": tag}
    if not ok:
        result["status"] = "skip"
        result["reason"] = why
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            name = f"{arch}__{shape_name}__{result['mesh']}.json"
            with open(os.path.join(out_dir, name), "w") as f:
                json.dump(result, f, indent=1)
        return result

    cfg = get_config(arch)
    mode = "train" if shape.kind == "train" else "serve"
    mesh = make_production_mesh(multi_pod=multi_pod)

    t0 = time.time()
    full = _compile_costs(cfg, mode, mesh, shape, scan_unroll=1,
                          rt_overrides=rt_overrides)
    t_full = time.time() - t0

    # XLA's cost analysis counts a while-loop body ONCE regardless of
    # trip count, so the layer scan hides (n_periods−1)× the flops.
    # Probe with 1-period and 2-period (unroll=2) variants: the diff is
    # exactly one period's body; scale it back in.
    n_periods = cfg.n_periods if not cfg.is_encoder_decoder \
        else cfg.num_layers
    try:
        c1 = _compile_costs(_variant_cfg(cfg, 1), mode, mesh, shape, 1,
                            rt_overrides)
        c2 = _compile_costs(_variant_cfg(cfg, 2), mode, mesh, shape, 2,
                            rt_overrides)
        scale_extra = n_periods - 1

        def corrected(key):
            body = max(0.0, c2[key] - c1[key])
            return full[key] + scale_extra * body

        flops_c = corrected("flops")
        bytes_c = corrected("bytes_accessed")
        coll_body = max(0.0, c2["collectives"]["total_bytes"]
                        - c1["collectives"]["total_bytes"])
        coll_c = (full["collectives"]["total_bytes"]
                  + scale_extra * coll_body)
        coll_by_kind = {}
        for k in full["collectives"]["bytes_by_kind"]:
            body_k = max(0.0, c2["collectives"]["bytes_by_kind"][k]
                         - c1["collectives"]["bytes_by_kind"][k])
            coll_by_kind[k] = (full["collectives"]["bytes_by_kind"][k]
                               + scale_extra * body_k)
        probes_ok = True
    except Exception as e:  # noqa: BLE001
        flops_c, bytes_c, coll_c = (full["flops"],
                                    full["bytes_accessed"],
                                    full["collectives"]["total_bytes"])
        coll_by_kind = full["collectives"]["bytes_by_kind"]
        probes_ok = False
        print(f"  probe variants failed ({e!r}); reporting uncorrected",
              file=sys.stderr)

    result.update({
        "status": "ok",
        "compile_s": round(t_full, 2),
        "flops_raw": full["flops"],
        "flops": flops_c,
        "bytes_accessed_raw": full["bytes_accessed"],
        "bytes_accessed": bytes_c,
        "collectives": {"total_bytes": coll_c,
                        "bytes_by_kind": coll_by_kind,
                        "counts": full["collectives"]["counts"]},
        "hlo_lines": full["hlo_lines"],
        "scan_correction": probes_ok,
        "n_periods": n_periods,
    })
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes"):
        if attr in full:
            result[attr] = full[attr]
    if verbose:
        print(f"[{arch} × {shape_name} × {result['mesh']}] "
              f"compile {result.get('compile_s')}s  "
              f"flops/dev {flops_c:.3e} (raw {full['flops']:.3e})  "
              f"coll {coll_c:.3e} B")
        print("memory_analysis:", {k: result[k] for k in result
                                   if k.endswith("_in_bytes")})
        print("cost_analysis: flops=%.4e bytes=%.4e"
              % (result["flops"], result["bytes_accessed"]))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        name = f"{arch}__{shape_name}__{result['mesh']}{suffix}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(result, f, indent=1)
    return result


#: named optimization bundles for --opt (the §Perf hillclimb knobs)
OPTIMIZATIONS = {
    "blocked_attn": {"blocked_attn": True},
    "blocked_attn_2k": {"blocked_attn": True, "attn_block_k": 2048},
    "blocked_attn_4k": {"blocked_attn": True, "attn_block_k": 4096},
    "blocked_attn_512": {"blocked_attn": True, "attn_block_k": 512},
    "int8_kv": {"kv_cache_dtype": "int8"},
    "onehot_update": {"onehot_cache_update": True},
    "pin_out": {"pin_out_shardings": True},
    "gqa_decode": {"grouped_gqa_decode": True},
    # the combined serve-side bundle
    "serve_opt": {"grouped_gqa_decode": True,
                  "onehot_cache_update": True,
                  "pin_out_shardings": True},
    "serve_opt_int8": {"grouped_gqa_decode": True,
                       "onehot_cache_update": True,
                       "pin_out_shardings": True,
                       "kv_cache_dtype": "int8"},
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: all assigned)")
    ap.add_argument("--shape", default=None,
                    help="shape cell (default: all four)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--opt", action="append", default=[],
                    choices=sorted(OPTIMIZATIONS),
                    help="enable a §Perf optimization bundle")
    ap.add_argument("--tag", default="",
                    help="artifact filename suffix (hillclimb runs)")
    ap.add_argument("--out", default=os.path.normpath(ARTIFACT_DIR))
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    overrides = {}
    for o in args.opt:
        overrides.update(OPTIMIZATIONS[o])

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp, out_dir=args.out,
                             rt_overrides=overrides, tag=args.tag)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)[:200]))
                    print(f"FAIL [{arch} × {shape} × mp={mp}]: {e}",
                          file=sys.stderr)
    if failures:
        print(f"{len(failures)} cell(s) failed", file=sys.stderr)
        sys.exit(1)
    print("all cells passed")


if __name__ == "__main__":
    main()
