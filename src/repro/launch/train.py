"""Training launcher.

Single-host example (the end-to-end driver trains a ~100M model):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduce 100m --steps 300

Multi-pod production: the same script under `jax.distributed` with the
production mesh — every host runs identical code; data sharding is
host-local (`SyntheticLMData.shard_at`); checkpoints restore onto
whatever mesh is alive (elastic).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMData
from repro.models import build_model
from repro.training.grad_compress import CompressorConfig
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainConfig, TrainLoop


def reduce_to_100m(cfg):
    """A ~100M-param member of the same family."""
    return dataclasses.replace(
        cfg,
        num_layers=max(len(cfg.pattern) * 2, 8 // max(len(cfg.pattern), 1)
                       * len(cfg.pattern)),
        d_model=768, num_heads=12,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 4)),
        head_dim=64, d_ff=0 if cfg.d_ff == 0 else 2048,
        vocab_size=32000, max_seq_len=2048,
        num_experts=min(cfg.num_experts, 8) if cfg.is_moe else 0,
        experts_per_token=min(cfg.experts_per_token, 2)
        if cfg.is_moe else 0,
        rnn_width=0 if cfg.rnn_width == 0 else 768,
        name=cfg.name + "-100m")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduce", choices=["none", "100m", "smoke"],
                    default="100m")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--compress", choices=["none", "topk", "int8"],
                    default="none")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce == "100m":
        cfg = reduce_to_100m(cfg)
    elif args.reduce == "smoke":
        cfg = cfg.reduced()
    model = build_model(cfg)
    from repro.models import param_count
    n = param_count(jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    print(f"arch={cfg.name} params={n/1e6:.1f}M devices="
          f"{jax.device_count()}")

    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch))
    tcfg = TrainConfig(
        steps=args.steps, checkpoint_every=100,
        checkpoint_dir=args.checkpoint_dir,
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps),
        compressor=CompressorConfig(kind=args.compress),
        log_every=10)
    loop = TrainLoop(model, data, tcfg)
    logs = loop.run()
    print("step,loss,accuracy,grad_norm,lr")
    for e in logs:
        print(f"{e['step']},{e['loss']:.4f},{e['accuracy']:.4f},"
              f"{e['grad_norm']:.3f},{e['lr']:.2e}")


if __name__ == "__main__":
    main()
