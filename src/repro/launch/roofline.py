"""Roofline analysis from dry-run artifacts (TPU v5e target).

Per (arch × shape × mesh) cell:
  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

Sources: ``compiled.cost_analysis()`` supplies HLO FLOPs and bytes
(NOTE: on the CPU-AOT path these are per-PROGRAM = per-device numbers
for the SPMD executable; we scale per-device × chips for the global
figure and divide back per the formulas).  Collective bytes are parsed
from the post-SPMD HLO (per-device operand bytes summed over collective
ops), multiplied by the ring algo-bandwidth factor 2(n−1)/n ≈ 2 for
all-reduce and (n−1)/n ≈ 1 for the others.

MODEL_FLOPS: 6·N·D for train (N = non-embedding params; N_active for
MoE), 2·N·D + attention for prefill, 2·N·B (+ KV reads) per decode
step.  The ratio MODEL_FLOPS / HLO_FLOPs flags remat/dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

import jax

from repro.configs import get_config
from repro.models import SHAPES, build_model

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    bytes_per_device: float

    def row(self) -> str:
        return (f"{self.arch},{self.shape},{self.mesh},{self.chips},"
                f"{self.compute_s:.3e},{self.memory_s:.3e},"
                f"{self.collective_s:.3e},{self.dominant},"
                f"{self.model_flops:.3e},{self.hlo_flops_global:.3e},"
                f"{self.useful_ratio:.3f},{self.bytes_per_device:.3e}")


def _param_counts(cfg) -> tuple[float, float]:
    """(total non-embedding params, active non-embedding params)."""
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = 0
    moe = 0
    emb = 0

    def walk(path, leaf):
        nonlocal total, moe, emb
        keys = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                        for k in path)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "moe/w_" in keys:
            moe += n
        if keys.endswith("embed/table"):
            emb += n

    jax.tree_util.tree_map_with_path(walk, shapes)
    non_emb = total - emb
    if cfg.is_moe and cfg.num_experts:
        frac = cfg.experts_per_token / cfg.num_experts
        active = non_emb - moe + moe * frac
    else:
        active = non_emb
    return float(non_emb), float(active)


def model_flops(cfg, shape) -> float:
    n_total, n_active = _param_counts(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * B * S
    if shape.kind == "prefill":
        attn_layers = sum(1 for k in (list(cfg.pattern) * cfg.n_periods
                                      + list(cfg.tail_kinds))
                          if k in ("global", "local"))
        attn = (2.0 * 2.0 * B * S * S / 2.0 * cfg.num_heads
                * cfg.head_dim * attn_layers / max(cfg.num_layers, 1))
        return 2.0 * n_active * B * S + attn
    # decode: one token per sequence + attention over the KV history
    attn_layers = sum(1 for k in (list(cfg.pattern) * cfg.n_periods
                                  + list(cfg.tail_kinds))
                      if k in ("global", "local"))
    kv_read = (2.0 * 2.0 * B * S * cfg.num_heads * cfg.head_dim
               * attn_layers / max(cfg.num_layers, 1))
    return 2.0 * n_active * B + kv_read


def analyze(artifact: dict) -> Roofline | None:
    if artifact.get("status") != "ok":
        return None
    arch, shape_name = artifact["arch"], artifact["shape"]
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = 512 if artifact["mesh"] == "2x16x16" else 256

    flops_dev = artifact["flops"]            # per-device (SPMD program)
    bytes_dev = artifact["bytes_accessed"]
    coll = artifact["collectives"]
    # ring algo-bandwidth factors
    ar = coll["bytes_by_kind"].get("all-reduce", 0.0) * 2.0
    rest = (coll["total_bytes"]
            - coll["bytes_by_kind"].get("all-reduce", 0.0)) * 1.0
    coll_dev = ar + rest

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / LINK_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * chips
    return Roofline(
        arch=arch, shape=shape_name, mesh=artifact["mesh"], chips=chips,
        compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant,
        model_flops=mf, hlo_flops_global=hlo_global,
        useful_ratio=mf / hlo_global if hlo_global > 0 else 0.0,
        bytes_per_device=float(artifact.get("argument_size_in_bytes", 0)
                               + artifact.get("temp_size_in_bytes", 0)))


def load_artifacts(directory: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=os.path.normpath(
        os.path.join(os.path.dirname(__file__), "..", "..", "..",
                     "benchmarks", "artifacts", "dryrun")))
    args = ap.parse_args()
    print("arch,shape,mesh,chips,compute_s,memory_s,collective_s,"
          "dominant,model_flops,hlo_flops_global,useful_ratio,"
          "bytes_per_device")
    for art in load_artifacts(args.artifacts):
        r = analyze(art)
        if r is not None:
            print(r.row())
        else:
            print(f"{art['arch']},{art['shape']},{art['mesh']},,,,,"
                  f"SKIP,,,,")


if __name__ == "__main__":
    main()
