"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count locks on first backend init — the dry-run sets
XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CI (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
