"""Serving launcher: a token-pool-governed engine on a small model.

    PYTHONPATH=src python -m repro.launch.serve --requests 24

Brings up: TokenPool (+virtual node) → Gateway (key auth, admission) →
InferenceEngine (continuous batching over a JAX model), and drives a
two-tenant workload (guaranteed + spot) through it.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.core import (
    EntitlementSpec,
    PoolSpec,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    TokenPool,
)
from repro.gateway import Gateway
from repro.models import build_model
from repro.serving import InferenceEngine, Request
from repro.serving.request import latency_summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(vocab_size=1024, num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    spec = PoolSpec(name=cfg.name, model=cfg.name,
                    scaling=ScalingBounds(1, 1),
                    per_replica=Resources(2e4, float(1 << 30),
                                          float(args.slots)),
                    default_max_tokens=args.max_tokens)
    pool = TokenPool(spec)
    pool.add_entitlement(EntitlementSpec(
        name="prod", tenant_id="prod", pool=cfg.name,
        qos=QoS(ServiceClass.GUARANTEED, 200.0),
        baseline=Resources(1e4, 0.0, float(args.slots))))
    pool.add_entitlement(EntitlementSpec(
        name="batch", tenant_id="batch", pool=cfg.name,
        qos=QoS(ServiceClass.SPOT, 30000.0),
        baseline=Resources(0.0, 0.0, 0.0)))
    pool.ledger.set_rate("batch", 2e4, 0.0)
    pool.ledger.bucket("batch").level = 2e4
    gw = Gateway(pool)
    gw.register_key("k-prod", "prod")
    gw.register_key("k-batch", "batch")

    eng = InferenceEngine(model, params, slots=args.slots,
                          max_seq=cfg.max_seq_len, gateway=gw)
    reqs = []
    for i in range(args.requests):
        tenant = "prod" if i % 2 == 0 else "batch"
        r = Request(request_id=f"r{i}", entitlement=tenant,
                    prompt_tokens=[2 + i % 7, 3, 5],
                    max_tokens=args.max_tokens, arrival_s=float(i) * 0.01,
                    api_key=f"k-{tenant}")
        reqs.append(r)
        eng.submit(r, now=r.arrival_s)
    eng.run_until_drained()

    for tenant in ("prod", "batch"):
        sel = [r for r in reqs if r.entitlement == tenant]
        print(tenant, latency_summary(sel))
    print("pool tokens served:", {
        n: pool.status[n].tokens_total for n in pool.status})


if __name__ == "__main__":
    main()
