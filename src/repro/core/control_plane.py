"""The unified control plane — the ONE implementation of the paper's
capacity model (Eq. 1–3 + priority-weighted water-filling).

Every accounting tick in the system executes here: ``TokenPool.tick``
gathers its entitlement state into a :class:`ControlState` (array of
rows), runs :func:`control_tick` (a single fused, jit-compiled jnp op),
and scatters the results back into the ledger and per-entitlement
status.  ``PoolManager`` batches P pools into one
:func:`control_tick_pools` call (a ``vmap`` over an added pool axis),
so the whole fleet's accounting is one XLA dispatch.

The module also keeps :func:`reference_tick` — a deliberately naive
pure-Python replay of the same math built on the scalar oracle
functions in ``core.priority`` and ``core.pool.waterfill``.  It is the
TEST ORACLE (and the "paper-style per-entitlement loop" baseline in
``benchmarks/admission_throughput.py``); production code must never
call it.

Everything jnp here is pure-functional: state arrays in, state arrays
out.  Entitlements are rows; service classes are small int codes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.markers import kernel
from repro.core.types import PriorityCoefficients, ServiceClass

# class codes (row order matters: used for lookups)
CLASS_CODES: dict[ServiceClass, int] = {
    ServiceClass.DEDICATED: 0,
    ServiceClass.GUARANTEED: 1,
    ServiceClass.ELASTIC: 2,
    ServiceClass.SPOT: 3,
    ServiceClass.PREEMPTIBLE: 4,
}
CLASS_W = jnp.array([1000.0, 1000.0, 100.0, 1.0, 0.1])     # CLASS_WEIGHT
PROTECTED_MASK = jnp.array([True, True, False, False, False])
BURSTOK_MASK = jnp.array([True, False, True, True, True])   # Table 1 "Burst"
DEBTOK_MASK = jnp.array([False, False, True, False, False])  # debt classes
ELASTIC_MASK = jnp.array([False, False, True, False, False])

#: Python-side trace counters: a jitted kernel's body only executes as
#: Python while TRACING, so bumping a counter inside it counts compiled
#: variants.  Tests pin that entitlement churn within a pow2 resident
#: bucket never retraces (``tests/test_resident.py``).
TRACE_COUNTS: dict[str, int] = {"control_tick": 0, "admit_quantum": 0,
                                "shard_tick": 0, "shard_admit_quantum": 0,
                                "shard_plan_fleet": 0}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ControlState:
    """Per-entitlement state-of-the-world, array-of-rows layout.

    The first five fields mirror the EntitlementSpec (static between
    membership changes); ``burst``/``debt`` are the Eq. 2–3 EWMAs that
    the tick evolves.  A leading pool axis turns this into the batched
    multi-pool state consumed by :func:`control_tick_pools`.
    """

    class_code: jax.Array        # int32 [N]
    bound: jax.Array             # bool  [N]
    baseline_tps: jax.Array      # f32 [N] λ_e
    baseline_kv: jax.Array       # f32 [N] χ_e
    baseline_conc: jax.Array     # f32 [N] r_e
    slo_ms: jax.Array            # f32 [N] ℓ*_e
    burst: jax.Array             # f32 [N] b_e
    debt: jax.Array              # f32 [N] d_e

    @property
    def n_rows(self) -> int:
        return self.class_code.shape[-1]


def priority_rows(state: ControlState, pool_avg_slo: jax.Array,
                  coeff: PriorityCoefficients) -> jax.Array:
    """Eq. (1), row-parallel."""
    w_class = CLASS_W[state.class_code]
    slo_f = 1.0 / (1.0 + coeff.alpha_slo * (state.slo_ms / pool_avg_slo))
    burst_f = 1.0 / (1.0 + coeff.alpha_burst
                     * jnp.maximum(state.burst, 0.0))
    debt_f = jnp.maximum(1e-3, 1.0 + coeff.alpha_debt * state.debt)
    return w_class * slo_f * burst_f * debt_f


def burst_delta_rows(used_tps: jax.Array, used_kv: jax.Array,
                     used_conc: jax.Array, state: ControlState) -> jax.Array:
    """Eq. (3), row-parallel, matching the scalar zero-baseline rule:
    a dimension with no baseline contributes 1 whenever it is used."""

    def term(used, base):
        return jnp.where(
            base > 0.0,
            jnp.maximum(0.0, used / jnp.maximum(base, 1e-30) - 1.0),
            jnp.where(used > 0.0, 1.0, 0.0))

    return (term(used_tps, state.baseline_tps)
            + term(used_kv, state.baseline_kv)
            + term(used_conc, state.baseline_conc))


def ewma(prev: jax.Array, x: jax.Array, gamma: float) -> jax.Array:
    """Eq. (2) form: γ·prev + (1−γ)·x."""
    return gamma * prev + (1.0 - gamma) * x


# -- shard-stable reductions --------------------------------------------------
#
# Every pool-level aggregate in the tick (protected floor, water-filling
# shares, demand totals) reduces the row axis with a FIXED binary tree
# over the pow2-padded rows instead of ``jnp.sum``'s backend-chosen
# order.  The pairing depends only on element POSITION, so any
# contiguous pow2 blocking of the rows computes bit-identical partials:
# per-shard subtrees plus the top tree over the gathered shard roots IS
# the full single-device tree.  That is what lets ``shard_plane`` run
# the same math under ``shard_map`` with ``axis_name`` set and return
# decisions bit-identical to the single-device kernel, without f64
# accumulation (x64 stays disabled) or Kahan compensation.

def _pairwise(x: jax.Array, op) -> jax.Array:
    """Reduce the trailing (pow2) axis with positional pairing."""
    while x.shape[-1] > 1:
        x = op(x[..., 0::2], x[..., 1::2])
    return x[..., 0]


def tree_sum(x: jax.Array, axis_name: str | None = None) -> jax.Array:
    """Binary-tree sum over the row axis; with ``axis_name`` the rows
    are a shard_map block and the shard roots combine through the top
    of the same tree (``all_gather`` orders roots by device index, i.e.
    block order).  Non-pow2 widths pad with zeros (exact for adds)."""
    w = bucket_width(x.shape[-1])
    if w != x.shape[-1]:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (w - x.shape[-1],), x.dtype)],
            axis=-1)
    local = _pairwise(x, jnp.add)
    if axis_name is None:
        return local
    return _pairwise(jax.lax.all_gather(local, axis_name), jnp.add)


def tree_any(x: jax.Array, axis_name: str | None = None) -> jax.Array:
    """Binary-tree logical-or over the row axis (pad with False)."""
    w = bucket_width(x.shape[-1])
    if w != x.shape[-1]:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (w - x.shape[-1],), bool)],
            axis=-1)
    local = _pairwise(x, jnp.logical_or)
    if axis_name is None:
        return local
    return _pairwise(jax.lax.all_gather(local, axis_name),
                     jnp.logical_or)


def tree_count(x: jax.Array, axis_name: str | None = None) -> jax.Array:
    """Row count of a bool mask as int32 (integer adds are exact, so
    any order agrees — the tree keeps the structure uniform)."""
    return tree_sum(x.astype(jnp.int32), axis_name)


def waterfill_rows(capacity: jax.Array, want: jax.Array,
                   weight: jax.Array, max_rounds: int = 32,
                   axis_name: str | None = None) -> jax.Array:
    """Priority-weighted progressive water-filling (jnp mirror of
    ``core.pool.waterfill``).  Runs the same cap-and-redistribute rounds
    inside a ``lax.while_loop``; converges in ≤ #distinct-caps rounds,
    bounded by ``max_rounds`` for compile-time safety.

    With ``axis_name`` the rows are one shard_map block: the per-round
    couplings (total weight, active count, filled total, the done /
    progress flags) combine across shards through the shard-stable tree
    reductions, and the loop state that the ``cond`` reads (remaining /
    round counter / any-active) is replicated — every device runs the
    same trip count."""
    want = jnp.maximum(want, 0.0)
    active0 = want > 1e-12

    def cond(state):
        alloc, remaining, active, i, has_active = state
        return (remaining > 1e-9) & has_active & (i < max_rounds)

    def body(state):
        alloc, remaining, active, i, _ = state
        w = jnp.where(active, weight, 0.0)
        total_w = tree_sum(w, axis_name)
        n_active = tree_count(active, axis_name)
        total_w_safe = jnp.where(total_w > 0.0, total_w, 1.0)
        share = jnp.where(
            total_w > 0.0,
            remaining * (w / total_w_safe),
            jnp.where(active, remaining / jnp.maximum(n_active, 1), 0.0))
        room = want - alloc
        take = jnp.minimum(room, share)
        take = jnp.where(active, take, 0.0)
        alloc = alloc + take
        remaining = remaining - tree_sum(take, axis_name)
        # done when the share covered the remaining room — compare take
        # to room with a magnitude-scaled epsilon (f32-safe; an absolute
        # 1e-12 misfires once want ≳ 1e2 in float32)
        newly_done = active & (take >= room
                               - 1e-6 * jnp.maximum(1.0, want))
        # scalar loop breaks when a round fills nobody
        progress = tree_any(newly_done, axis_name)
        active = active & ~newly_done
        i = jnp.where(progress, i + 1, max_rounds)
        return alloc, remaining, active, i, tree_any(active, axis_name)

    alloc0 = jnp.zeros_like(want)
    alloc, _, _, _, _ = jax.lax.while_loop(
        cond, body, (alloc0, jnp.maximum(capacity, 0.0), active0,
                     jnp.asarray(0), tree_any(active0, axis_name)))
    return alloc


def allocate_rows(capacity: jax.Array, state: ControlState,
                  weights: jax.Array, demand_tps: jax.Array,
                  axis_name: str | None = None) -> jax.Array:
    """Funding allocation with work conservation (the Table-1 ordering):
    protected funded at baseline (emergency-scaled if their *active* use
    exceeds capacity) → elastic demand-capped baselines water-filled →
    work-conserving backfill of the surplus to burst-eligible classes."""
    live = state.bound
    protected = live & PROTECTED_MASK[state.class_code]
    base_p = jnp.where(protected, state.baseline_tps, 0.0)
    active_p = jnp.minimum(base_p, jnp.where(protected, demand_tps, 0.0))
    total_active_p = tree_sum(active_p, axis_name)
    emergency = total_active_p > capacity
    scale = jnp.where(emergency,
                      capacity / jnp.maximum(total_active_p, 1e-30), 1.0)
    alloc_p = base_p * scale
    remaining = jnp.where(
        emergency, 0.0, jnp.maximum(0.0, capacity - total_active_p))

    elastic = live & ELASTIC_MASK[state.class_code]
    want_e = jnp.where(elastic,
                       jnp.minimum(state.baseline_tps, demand_tps), 0.0)
    fill_e = waterfill_rows(remaining, want_e,
                            jnp.where(elastic, weights, 0.0),
                            axis_name=axis_name)
    alloc = alloc_p + fill_e
    remaining = jnp.maximum(0.0, remaining - tree_sum(fill_e, axis_name))

    burst_ok = live & BURSTOK_MASK[state.class_code]
    used = jnp.where(protected, active_p,
                     jnp.minimum(alloc, demand_tps))
    want_b = jnp.where(burst_ok,
                       jnp.maximum(0.0, demand_tps - used), 0.0)
    fill_b = waterfill_rows(remaining, want_b,
                            jnp.where(burst_ok, weights, 0.0),
                            axis_name=axis_name)
    return alloc + fill_b


def _tick_impl(state: ControlState, capacity_tps: jax.Array,
               measured_tps: jax.Array, used_kv: jax.Array,
               used_conc: jax.Array, demand_tps: jax.Array,
               avg_slo_ms: jax.Array, coeff: PriorityCoefficients,
               axis_name: str | None = None,
               ) -> tuple[ControlState, jax.Array, jax.Array]:
    """Tick body shared by the single-pool and vmapped entry points.
    Mirrors the scalar controller's steps 2–5: burst EWMA → priority →
    allocation → debt EWMA."""
    TRACE_COUNTS["control_tick"] += 1          # repro: allow[retrace-hazard] -- trace-time counter: runs only while compiling, counts variants
    delta = burst_delta_rows(measured_tps, used_kv, used_conc, state)
    burst = ewma(state.burst, delta, coeff.gamma_burst)
    s1 = dataclasses.replace(state, burst=burst)

    weights = priority_rows(s1, jnp.maximum(avg_slo_ms, 1e-9), coeff)
    alloc = allocate_rows(capacity_tps, s1, weights, demand_tps,
                          axis_name=axis_name)

    # Eq. 2 debt: underservice only counts against live demand, service
    # is the measured completion rate floored by demand-capped funding.
    served = jnp.maximum(measured_tps, jnp.minimum(alloc, demand_tps))
    entitled_now = jnp.minimum(s1.baseline_tps,
                               jnp.maximum(demand_tps, served))
    gap = jnp.where(
        (demand_tps > 1e-9) & (s1.baseline_tps > 0.0),
        (entitled_now - served) / jnp.maximum(s1.baseline_tps, 1e-30),
        0.0)
    gap = jnp.clip(gap, -coeff.gap_clip, coeff.gap_clip)
    debtok = DEBTOK_MASK[s1.class_code]
    debt = jnp.where(
        debtok,
        jnp.clip(ewma(s1.debt, gap, coeff.gamma_debt),
                 coeff.debt_min, coeff.debt_max),
        s1.debt)
    return dataclasses.replace(s1, debt=debt), alloc, weights


@kernel(oracle="repro.core.control_plane.reference_tick")
@partial(jax.jit, static_argnames=("coeff",))
def control_tick(state: ControlState, capacity_tps: jax.Array,
                 measured_tps: jax.Array, used_kv: jax.Array,
                 used_conc: jax.Array, demand_tps: jax.Array,
                 avg_slo_ms: jax.Array,
                 coeff: PriorityCoefficients = PriorityCoefficients(),
                 ) -> tuple[ControlState, jax.Array, jax.Array]:
    """One accounting tick for one pool, fused: returns (new state,
    allocations λ̂, priority weights).  ``avg_slo_ms`` is ℓ̄* — the
    caller owns the Fixed-vs-live-mean policy (PoolSpec.fixed_avg_slo_ms)."""
    return _tick_impl(state, capacity_tps, measured_tps, used_kv,
                      used_conc, demand_tps, avg_slo_ms, coeff)


@kernel(oracle="repro.core.control_plane.reference_tick")
@partial(jax.jit, static_argnames=("coeff",))
def control_tick_pools(states: ControlState, capacity_tps: jax.Array,
                       measured_tps: jax.Array, used_kv: jax.Array,
                       used_conc: jax.Array, demand_tps: jax.Array,
                       avg_slo_ms: jax.Array,
                       coeff: PriorityCoefficients = PriorityCoefficients(),
                       ) -> tuple[ControlState, jax.Array, jax.Array]:
    """Batched tick across P pools: every array carries a leading pool
    axis ([P, N] rows, [P] scalars) and the whole fleet ticks in one
    fused dispatch.  Pools with fewer rows are padded with unbound rows
    (see :func:`pad_state`) — padding provably cannot affect live rows
    because every mask is ANDed with ``bound``."""

    def one(s, cap, m, kv, conc, d, slo):
        return _tick_impl(s, cap, m, kv, conc, d, slo, coeff)

    return jax.vmap(one)(states, capacity_tps, measured_tps, used_kv,
                         used_conc, demand_tps, avg_slo_ms)


# -- padding / stacking helpers (PoolManager batching) -----------------------

def bucket_width(n_rows: int) -> int:
    """Next power of two ≥ ``n_rows`` (min 1).  Shapes are static under
    jit, so ticking on exact widths would retrace the kernel on every
    entitlement add/remove; padding to pow2 buckets bounds the number
    of compiled variants to log2(N) while padding stays inert."""
    return max(1, 1 << (max(n_rows, 1) - 1).bit_length())


def quantum_width(n_requests: int) -> int:
    """Pad width for the REQUEST axis of an admission quantum: pow2
    buckets up to 4096, quarter-steps (5/8, 6/8, 7/8 of the next
    pow2) between octaves above that.  Large quanta pay for every
    padded row inside the kernel scan, so capping the waste at 25%
    (instead of pow2's 100%) is a real throughput lever — at the cost
    of at most three extra compiled variants per octave, still
    O(log n) traces.  Small quanta keep pure pow2 widths: the
    no-retrace pins (and row-axis padding, which always uses
    :func:`bucket_width`) rely on them."""
    w = bucket_width(n_requests)
    if n_requests > 4096:
        step = w >> 3
        for num in (5, 6, 7):
            c = step * num
            if n_requests <= c:
                return c
    return w


def pad_rows(x: jax.Array, n_rows: int, fill=0) -> jax.Array:
    """Right-pad a row vector to ``n_rows`` (the single source of the
    padding idiom — ``pad_state``, ``PoolManager.tick`` and the
    gateway's quantum batches all bucket through this)."""
    n = x.shape[0]
    if n == n_rows:
        return x
    return jnp.concatenate(
        [x, jnp.full((n_rows - n,), fill, dtype=x.dtype)])


def pad_state(state: ControlState, n_rows: int) -> ControlState:
    """Right-pad a state to ``n_rows`` with inert rows: unbound, zero
    baselines, class 0.  Unbound rows are excluded from every allocation
    mask and their EWMAs see zero inputs, so they stay identically zero."""
    if state.n_rows == n_rows:
        return state
    return ControlState(
        class_code=pad_rows(state.class_code, n_rows),
        bound=pad_rows(state.bound, n_rows, False),
        baseline_tps=pad_rows(state.baseline_tps, n_rows),
        baseline_kv=pad_rows(state.baseline_kv, n_rows),
        baseline_conc=pad_rows(state.baseline_conc, n_rows),
        slo_ms=pad_rows(state.slo_ms, n_rows, 1.0),
        burst=pad_rows(state.burst, n_rows),
        debt=pad_rows(state.debt, n_rows),
    )


def stack_states(states: Sequence[ControlState],
                 width: int = 0) -> ControlState:
    """Stack per-pool states (padded to a common width — at least the
    widest state; pass ``width`` to bucket it) along a new leading
    pool axis."""
    width = max(width, max(s.n_rows for s in states))
    padded = [pad_state(s, width) for s in states]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)


# -- the scalar test oracle ---------------------------------------------------

@dataclasses.dataclass
class OracleRow:
    """One entitlement row for :func:`reference_tick` — plain floats."""

    service_class: ServiceClass
    bound: bool
    baseline_tps: float
    baseline_kv: float
    baseline_conc: float
    slo_ms: float
    burst: float
    debt: float
    measured_tps: float = 0.0
    used_kv: float = 0.0
    used_conc: float = 0.0
    demand_tps: float = 0.0


def reference_tick(rows: list[OracleRow], capacity_tps: float,
                   avg_slo_ms: float,
                   coeff: PriorityCoefficients = PriorityCoefficients(),
                   ) -> tuple[list[OracleRow], list[float], list[float]]:
    """Pure-Python per-entitlement replay of the tick — the TEST ORACLE.

    Exactly the pre-unification ``TokenPool.tick`` steps 2–5: a dict
    loop over ``core.priority`` Eq. 1–3 plus ``core.pool.waterfill``.
    Returns (updated rows, allocations, priority weights) in row order.
    O(N) Python — this is the paper-style baseline the unified tick is
    benchmarked against; never call it from the serving path.
    """
    from repro.core import priority as prio
    from repro.core.pool import waterfill
    from repro.core.types import (
        BURST_CLASSES,
        DEBT_CLASSES,
        PROTECTED_CLASSES,
        Resources,
    )

    rows = [dataclasses.replace(r) for r in rows]
    idx = list(range(len(rows)))

    # burst EWMA (Eq. 3) then priority (Eq. 1)
    weights: list[float] = []
    for r in rows:
        delta = prio.burst_overconsumption(
            Resources(r.measured_tps, r.used_kv, r.used_conc),
            Resources(r.baseline_tps, r.baseline_kv, r.baseline_conc))
        r.burst = prio.burst_update(r.burst, delta, coeff.gamma_burst)
        weights.append(prio.priority_weight(
            r.service_class, r.slo_ms, max(avg_slo_ms, 1e-9),
            r.burst, r.debt, coeff))

    # allocation: protected reserved → elastic baselines → backfill
    alloc = [0.0] * len(rows)
    live = [i for i in idx if rows[i].bound]
    protected = [i for i in live
                 if rows[i].service_class in PROTECTED_CLASSES]
    base_p = {i: rows[i].baseline_tps for i in protected}
    active_p = {i: min(base_p[i], rows[i].demand_tps) for i in protected}
    total_active_p = sum(active_p.values())
    if total_active_p > capacity_tps and total_active_p > 0:
        scale = capacity_tps / total_active_p
        for i in protected:
            alloc[i] = base_p[i] * scale
        remaining = 0.0
    else:
        for i in protected:
            alloc[i] = base_p[i]
        remaining = max(0.0, capacity_tps - total_active_p)

        elastic = [i for i in live
                   if rows[i].service_class is ServiceClass.ELASTIC]
        want_e = {i: min(rows[i].baseline_tps, rows[i].demand_tps)
                  for i in elastic}
        fill = waterfill(remaining, want_e, {i: weights[i] for i in elastic})
        for i in elastic:
            alloc[i] = fill[i]
        remaining = max(0.0, remaining - sum(fill.values()))

        burst_ok = [i for i in live
                    if rows[i].service_class in BURST_CLASSES]
        want_b = {}
        for i in burst_ok:
            used = (active_p[i] if i in active_p
                    else min(alloc[i], rows[i].demand_tps))
            want_b[i] = max(0.0, rows[i].demand_tps - used)
        fill = waterfill(remaining, want_b, {i: weights[i] for i in burst_ok})
        for i in burst_ok:
            alloc[i] += fill[i]

    # debt EWMA (Eq. 2) for debt-bearing classes
    for i, r in enumerate(rows):
        if r.service_class not in DEBT_CLASSES:
            continue
        demand, base = r.demand_tps, r.baseline_tps
        if demand <= 1e-9 or base <= 0.0:
            gap = 0.0
        else:
            served = max(r.measured_tps, min(alloc[i], demand))
            entitled_now = min(base, max(demand, served))
            gap = (entitled_now - served) / base
        gap = min(coeff.gap_clip, max(-coeff.gap_clip, gap))
        r.debt = min(coeff.debt_max, max(
            coeff.debt_min, prio.debt_update(r.debt, gap, coeff.gamma_debt)))
    return rows, alloc, weights


def state_from_rows(rows: Sequence[OracleRow]) -> ControlState:
    """Build a ControlState from oracle rows (tests/benchmarks)."""
    return ControlState(
        class_code=jnp.array([CLASS_CODES[r.service_class] for r in rows],
                             jnp.int32),
        bound=jnp.array([r.bound for r in rows], bool),
        baseline_tps=jnp.array([r.baseline_tps for r in rows], jnp.float32),
        baseline_kv=jnp.array([r.baseline_kv for r in rows], jnp.float32),
        baseline_conc=jnp.array([r.baseline_conc for r in rows],
                                jnp.float32),
        slo_ms=jnp.array([r.slo_ms for r in rows], jnp.float32),
        burst=jnp.array([r.burst for r in rows], jnp.float32),
        debt=jnp.array([r.debt for r in rows], jnp.float32),
    )
