"""TokenPool controller — allocation, reclamation, debt accounting.

Realises paper §3–§4: a pool aggregates backend replicas into capacity
(Λ_p tokens/s, X_p KV bytes, R_p concurrency); entitlements hold
baselines (λ_e, χ_e, r_e) with a service class; every accounting tick
the controller

  1. measures per-entitlement usage (tokens completed, KV resident,
     in-flight sequences),
  2. updates burst intensity b_e (Eq. 3 EWMA),
  3. computes effective allocations λ̂_e by priority-weighted
     water-filling with the Table-1 protection ordering
     (dedicated/guaranteed reserved even when idle → elastic baselines,
     shrunk under scarcity → work-conserving backfill of surplus to
     burst-eligible classes),
  4. updates service debt d_e (Eq. 2) for debt-bearing classes,
  5. pushes λ̂_e into the token-bucket ledger that funds admission.

Steps 2–4 execute on the UNIFIED control plane
(``core.control_plane.control_tick``): this class is a thin stateful
shell that gathers entitlement state into a ``ControlState`` array of
rows, runs the fused jit-compiled tick, and scatters allocations /
debts / priorities back into the ledger and per-entitlement status.
The old scalar dict-loop survives only as the test oracle
(``control_plane.reference_tick``); ``waterfill`` below is part of that
oracle.  ``PoolManager`` batches many pools through the same kernel via
the split ``begin_tick`` / ``apply_tick`` halves.

Entitlement *creation* is admitted through the virtual-node scheduler
(`core.virtual_node`) against the pool's entitleable capacity
(per-replica × maxReplicas): a pool never promises more than it could
ever provision.  Runtime capacity (per-replica × live replicas) is what
allocation and admission run against, so replica failure shows up as
scarcity — shrinking elastic tenants and accruing debt — exactly the
paper's Experiment 2.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import control_plane, priority as prio
from repro.core.control_plane import CLASS_CODES, ControlState
from repro.core.ledger import Ledger
from repro.core.types import (
    EntitlementSpec,
    EntitlementState,
    EntitlementStatus,
    PoolSpec,
    Resources,
    ServiceClass,
)
from repro.core.virtual_node import LeasePod, VirtualNodeProvider


@dataclasses.dataclass
class InFlight:
    """One admitted, not-yet-completed request."""

    request_id: str
    entitlement: str
    priority: float
    kv_bytes: float
    charged_tokens: int
    admitted_at: float
    resident: bool = False       # dispatched to a decode worker


@dataclasses.dataclass
class TickInputs:
    """Gathered per-tick state, ready for the control-plane kernel.
    Produced by ``TokenPool.begin_tick``; ``PoolManager`` stacks these
    across pools for the batched tick."""

    names: list[str]
    state: ControlState
    capacity_tps: float
    measured_tps: jnp.ndarray
    used_kv: jnp.ndarray
    used_conc: jnp.ndarray
    demand_tps: jnp.ndarray
    avg_slo_ms: float


@dataclasses.dataclass
class EntitlementMigration:
    """Everything one entitlement owns, detached from its pool and
    ready to re-attach elsewhere (``PoolManager.migrate_entitlement``).

    Invariants (documented in ``core.fleet``): the ledger bucket keeps
    its accrued level and outstanding charges, the status keeps debt /
    burst / usage counters, and in-flight records follow the
    entitlement so completions settle on the NEW owner."""

    espec: EntitlementSpec
    status: EntitlementStatus
    bucket: object                       # Optional[TokenBucket]
    charges: list
    in_flight: list
    demand_window: float
    demand_tps: float


@dataclasses.dataclass
class TickRecord:
    """Per-tick observability snapshot (drives the experiment figures)."""

    t: float
    capacity_tps: float
    allocations: dict[str, float]
    priorities: dict[str, float]
    debts: dict[str, float]
    bursts: dict[str, float]
    in_flight: dict[str, int]
    demand_tps: dict[str, float]


def waterfill(capacity: float, want: dict[str, float],
              weight: dict[str, float]) -> dict[str, float]:
    """Priority-weighted progressive water-filling.

    Distributes ``capacity`` across keys proportionally to ``weight``,
    capping each key at ``want[key]`` and re-distributing the excess to
    still-unsatisfied keys.  Work-conserving: either every want is met
    or the full capacity is used.
    """
    alloc = {k: 0.0 for k in want}
    remaining = max(0.0, capacity)
    active = {k for k, w in want.items() if w > 1e-12}
    while remaining > 1e-9 and active:
        total_w = sum(weight[k] for k in active)
        if total_w <= 0:
            # equal split among zero-weight entitlements
            share = {k: remaining / len(active) for k in active}
        else:
            share = {k: remaining * weight[k] / total_w for k in active}
        done = set()
        used = 0.0
        for k in list(active):
            room = want[k] - alloc[k]
            take = min(room, share[k])
            alloc[k] += take
            used += take
            if alloc[k] >= want[k] - 1e-12:
                done.add(k)
        remaining -= used
        if not done:        # all shares landed below caps → finished
            break
        active -= done
    return alloc


class TokenPool:
    """The TokenPool controller (one instance per pool CRD)."""

    def __init__(self, spec: PoolSpec,
                 provider: Optional[VirtualNodeProvider] = None,
                 now: float = 0.0) -> None:
        self.spec = spec
        self.provider = provider or VirtualNodeProvider()
        self.replicas = spec.scaling.min_replicas
        self.entitlements: dict[str, EntitlementSpec] = {}
        self.status: dict[str, EntitlementStatus] = {}
        self.ledger = Ledger(burst_window_s=spec.bucket_window_s)
        self.in_flight: dict[str, InFlight] = {}
        self.history: list[TickRecord] = []
        self._last_tick = now
        self._demand_window: dict[str, float] = {}
        self._demand_tps: dict[str, float] = {}
        # Row layout cache for the control plane (rebuilt on membership
        # or spec changes; row order is sorted-name, matching
        # ``vectorized.arrays_from_pool``).
        self._rows_dirty = True
        self._row_names: list[str] = []
        self._static_rows: Optional[dict[str, np.ndarray]] = None
        # Replica count last AUTHORIZED by the fleet planner (None until
        # a planner has run: the virtual node then still advertises the
        # full entitleable ceiling).
        self._authorized: Optional[int] = None
        # Entitleable capacity: what may ever be promised (maxReplicas).
        self.provider.create_node(spec.name, self.entitleable_capacity())

    # -- capacity -------------------------------------------------------------
    def entitleable_capacity(self) -> Resources:
        return self.spec.per_replica.scale(self.spec.scaling.max_replicas)

    def capacity(self) -> Resources:
        """Runtime capacity from live replicas."""
        return self.spec.per_replica.scale(self.replicas)

    def set_replicas(self, n: int, planned: bool = False) -> list[str]:
        """Autoscaler / failure-injection entry point.

        ``planned=False`` (failure injection, recovery, the scalar
        oracle) moves RUNTIME capacity only: the virtual node keeps its
        promise ceiling, entitlements stay bound, and the scarcity
        shows up as shrunken allocations + debt (paper Exp. 2 — an
        outage must not unbind tenants).  ``planned=True`` (the fleet
        planner) is a deliberate capacity decision: the promise ceiling
        moves with it through :meth:`authorize_replicas`, preempting
        the least-protected leases if the committed reservations no
        longer fit.  Returns the preempted entitlement names (always
        empty for unplanned changes)."""
        self.replicas = max(0, n)
        if planned:
            return self.authorize_replicas(n)
        return []

    def authorize_replicas(self, n: int) -> list[str]:
        """Move the virtual node's promise ceiling to ``n`` replicas
        (the fleet planner's decision).  A shrink below the committed
        lease reservations preempts in reverse-protection order (the
        §4.1 scheduler pass); a grow reschedules pending leases.
        Entitlement states are re-synced from the lease outcomes —
        preempted entitlements degrade, re-bound ones recover.
        Returns the entitlement names whose leases were preempted."""
        n = max(0, int(n))
        self._authorized = n
        preempted = self.provider.set_capacity(
            self.spec.name, self.spec.per_replica.scale(n))
        self._sync_lease_states()
        prefix = "lease-"
        return [name[len(prefix):] for name in preempted
                if name.startswith(prefix)]

    def _sync_lease_states(self) -> None:
        """Reconcile entitlement Bound/Degraded states with the actual
        lease bind outcomes after a virtual-node capacity change."""
        for name, st in self.status.items():
            if st.state not in (EntitlementState.BOUND,
                                EntitlementState.DEGRADED):
                continue
            bound = self.provider.is_bound(f"lease-{name}")
            st.state = (EntitlementState.BOUND if bound
                        else EntitlementState.DEGRADED)

    def reserved_baseline(self) -> Resources:
        """Σ baselines the pool has promised to keep provisionable —
        dedicated/guaranteed/elastic entitlements in Bound OR Degraded
        state (a Degraded promise is precisely what the planner must
        raise capacity for).  Spot/preemptible reserve nothing.  This
        is the reserved floor of the scale policy (``core.autoscaler``
        / ``core.fleet``)."""
        from repro.core.types import PROTECTED_CLASSES
        total = Resources.zero()
        for name, espec in self.entitlements.items():
            st = self.status[name]
            if st.state not in (EntitlementState.BOUND,
                                EntitlementState.DEGRADED):
                continue
            klass = espec.qos.service_class
            if klass in PROTECTED_CLASSES or klass is ServiceClass.ELASTIC:
                total = total + espec.baseline
        return total

    def demand_snapshot(self) -> dict[str, float]:
        """Public copy of the per-entitlement demand EWMA (tok/s) the
        accounting tick maintains — the same values the latest
        ``TickRecord.demand_tps`` carries.  Planners read THIS, never
        the private accounting dicts."""
        return dict(self._demand_tps)

    # -- entitlement lifecycle --------------------------------------------------
    def add_entitlement(self, espec: EntitlementSpec, now: float = 0.0
                        ) -> EntitlementState:
        self.entitlements[espec.name] = espec
        st = EntitlementStatus(created_at=now)
        self.status[espec.name] = st
        # Lease request: protected + elastic reserve their baseline on
        # the virtual node; spot/preemptible request nothing.
        reserve = (espec.baseline
                   if espec.qos.service_class not in
                   (ServiceClass.SPOT, ServiceClass.PREEMPTIBLE)
                   else Resources.zero())
        lease = LeasePod(
            name=f"lease-{espec.name}",
            entitlement=espec.name,
            request=reserve,
            protection_weight=prio.CLASS_WEIGHT[espec.qos.service_class],
        )
        bound = self.provider.submit(self.spec.name, lease)
        st.state = EntitlementState.BOUND if bound else EntitlementState.DEGRADED
        # Fund the bucket at baseline immediately; ticks refine it.
        self.ledger.ensure(espec.name, espec.baseline.tokens_per_second, now)
        self._demand_window.setdefault(espec.name, 0.0)
        self._demand_tps.setdefault(espec.name, 0.0)
        self._rows_dirty = True
        return st.state

    def remove_entitlement(self, name: str, now: float = 0.0) -> None:
        """Tear down an entitlement COMPLETELY.  Every piece of state
        keyed by the name must go: surviving in-flight records would
        make a later ``on_complete``/``on_evict`` KeyError on the
        missing status row, a surviving ledger bucket would keep
        refilling a dead tenant's budget, and surviving demand-window
        keys would leak into every future ``TickRecord.demand_tps``."""
        self.provider.delete(f"lease-{name}")
        # evict in-flight requests first (status row must still exist):
        # charges are refunded, then the whole bucket is dropped anyway
        for rid in [r.request_id for r in self.in_flight.values()
                    if r.entitlement == name]:
            self.on_evict(rid, now)
        self.entitlements.pop(name, None)
        self.status.pop(name, None)
        self.ledger.drop(name)
        self._demand_window.pop(name, None)
        self._demand_tps.pop(name, None)
        # the freed reservation may have re-bound pending leases
        self._sync_lease_states()
        self._rows_dirty = True

    def detach_entitlement(self, name: str, now: float = 0.0
                           ) -> EntitlementMigration:
        """Detach an entitlement for migration to another pool
        (``PoolManager.migrate_entitlement``).  Unlike
        :meth:`remove_entitlement` nothing is torn down: the ledger
        bucket (accrued level + outstanding charges), the status row
        (debt, burst, usage counters), the in-flight records and the
        demand signal all travel with the entitlement — only the lease
        reservation is released here."""
        if name not in self.entitlements:
            raise KeyError(f"no entitlement {name!r} in pool "
                           f"{self.spec.name!r}")
        self.provider.delete(f"lease-{name}")
        recs = [r for r in self.in_flight.values() if r.entitlement == name]
        for r in recs:
            del self.in_flight[r.request_id]
        bucket, charges = self.ledger.detach(name)
        mig = EntitlementMigration(
            espec=self.entitlements.pop(name),
            status=self.status.pop(name),
            bucket=bucket, charges=charges, in_flight=recs,
            demand_window=self._demand_window.pop(name, 0.0),
            demand_tps=self._demand_tps.pop(name, 0.0))
        # the freed reservation may have re-bound a previously
        # preempted/pending lease — Degraded stickiness here would deny
        # a now-bound tenant with NOT_BOUND until the next authorize
        self._sync_lease_states()
        self._rows_dirty = True
        return mig

    def attach_entitlement(self, mig: EntitlementMigration,
                           now: float = 0.0) -> EntitlementState:
        """Adopt a migrated entitlement: submit its lease on THIS
        pool's virtual node (baseline reserve, same rule as
        :meth:`add_entitlement`) and restore every piece of carried
        state.  Debt is preserved verbatim — an underserved tenant
        arrives at the new pool with the priority boost it is owed
        (cross-pool debt, ROADMAP item 4)."""
        espec = mig.espec
        name = espec.name
        if name in self.entitlements:
            raise ValueError(f"entitlement {name!r} already in pool "
                             f"{self.spec.name!r}")
        espec.pool = self.spec.name
        self.entitlements[name] = espec
        st = mig.status
        self.status[name] = st
        reserve = (espec.baseline
                   if espec.qos.service_class not in
                   (ServiceClass.SPOT, ServiceClass.PREEMPTIBLE)
                   else Resources.zero())
        lease = LeasePod(
            name=f"lease-{name}",
            entitlement=name,
            request=reserve,
            protection_weight=prio.CLASS_WEIGHT[espec.qos.service_class],
        )
        bound = self.provider.submit(self.spec.name, lease)
        st.state = (EntitlementState.BOUND if bound
                    else EntitlementState.DEGRADED)
        if mig.bucket is not None:
            self.ledger.attach(name, mig.bucket, mig.charges, now)
        else:
            self.ledger.ensure(name, espec.baseline.tokens_per_second, now)
            self.ledger.attach(name, None, mig.charges, now)
        for rec in mig.in_flight:
            self.in_flight[rec.request_id] = rec
        self._demand_window[name] = mig.demand_window
        self._demand_tps[name] = mig.demand_tps
        self._rows_dirty = True
        return st.state

    def expire_entitlements(self, now: float) -> None:
        for name, espec in self.entitlements.items():
            st = self.status[name]
            if (espec.ttl_s is not None
                    and now - st.created_at >= espec.ttl_s
                    and st.state != EntitlementState.EXPIRED):
                st.state = EntitlementState.EXPIRED
                self.provider.delete(f"lease-{name}")

    # -- priority --------------------------------------------------------------
    def pool_avg_slo(self) -> float:
        if self.spec.fixed_avg_slo_ms is not None:
            return self.spec.fixed_avg_slo_ms
        targets = [e.qos.slo_target_ms for e in self.entitlements.values()
                   if self.status[e.name].state == EntitlementState.BOUND]
        return prio.pool_average_slo(targets)

    def priority(self, name: str) -> float:
        """Live Eq. 1 weight for ONE entitlement (admission check 5).

        Single-request admission is inherently scalar, so this uses the
        scalar oracle directly; the accounting tick computes the same
        weights for ALL rows on the vectorized control plane (pinned
        equal by ``tests/test_control_plane.py``)."""
        espec = self.entitlements[name]
        st = self.status[name]
        return prio.priority_weight(
            espec.qos.service_class,
            espec.qos.slo_target_ms,
            self.pool_avg_slo(),
            st.burst,
            st.debt,
            self.spec.coefficients,
        )

    # -- in-flight bookkeeping (called by admission / completion) -----------------
    def register_admit(self, rec: InFlight, demand_tokens: float) -> None:
        st = self.status[rec.entitlement]
        st.in_flight += 1
        st.kv_bytes_in_use += rec.kv_bytes
        st.admitted_total += 1
        self.in_flight[rec.request_id] = rec
        self._demand_window[rec.entitlement] = (
            self._demand_window.get(rec.entitlement, 0.0) + demand_tokens)

    def register_admit_batch(self, recs: list[InFlight],
                             demand_tokens: dict[str, float]) -> None:
        """One scheduling quantum's admits in a single call — same
        bookkeeping as :meth:`register_admit`, with the status row
        resolved once per entitlement and the demand window bumped once
        per entitlement instead of once per request."""
        st_cache: dict[str, EntitlementStatus] = {}
        for rec in recs:
            st = st_cache.get(rec.entitlement)
            if st is None:
                st = st_cache[rec.entitlement] = self.status[rec.entitlement]
            st.in_flight += 1
            st.kv_bytes_in_use += rec.kv_bytes
            st.admitted_total += 1
            self.in_flight[rec.request_id] = rec
        for ent, tokens in demand_tokens.items():
            self._demand_window[ent] = (
                self._demand_window.get(ent, 0.0) + tokens)

    def register_deny(self, entitlement: str, demand_tokens: float,
                      low_priority: bool) -> None:
        st = self.status[entitlement]
        st.denied_total += 1
        if low_priority:
            st.denied_low_priority += 1
        # Denied demand still counts as demand (drives backfill/scaling).
        self._demand_window[entitlement] = (
            self._demand_window.get(entitlement, 0.0) + demand_tokens)

    def on_start(self, request_id: str) -> None:
        """Backend callback: the request acquired a decode slot (its KV
        is now resident) — this is what §3.1's concurrency r counts."""
        rec = self.in_flight.get(request_id)
        if rec is None or rec.resident:
            return
        rec.resident = True
        self.status[rec.entitlement].resident += 1

    def on_complete(self, request_id: str, actual_output_tokens: int,
                    now: float) -> Optional[InFlight]:
        """Gateway completion callback (paper §4.3): settle the charge,
        update usage counters that feed burst/debt at the next tick.

        Returns the settled ``InFlight`` record (None if unknown) so
        callers attribute the completion WITHOUT re-reading
        ``self.in_flight`` — the record is already popped by the time
        this returns, and read-after-call would silently miss."""
        rec = self.in_flight.pop(request_id, None)
        if rec is None:
            return None
        st = self.status[rec.entitlement]
        st.in_flight = max(0, st.in_flight - 1)
        if rec.resident:
            st.resident = max(0, st.resident - 1)
        st.kv_bytes_in_use = max(0.0, st.kv_bytes_in_use - rec.kv_bytes)
        st.completed_total += 1
        actual = self.ledger.settle(request_id, actual_output_tokens, now)
        st.window_tokens += actual
        st.tokens_total += actual
        return rec

    def on_evict(self, request_id: str, now: float) -> Optional[InFlight]:
        """Request terminated before completion (preemption/failure).
        Returns the evicted ``InFlight`` record (None if unknown)."""
        rec = self.in_flight.pop(request_id, None)
        if rec is None:
            return None
        st = self.status[rec.entitlement]
        st.in_flight = max(0, st.in_flight - 1)
        if rec.resident:
            st.resident = max(0, st.resident - 1)
        st.kv_bytes_in_use = max(0.0, st.kv_bytes_in_use - rec.kv_bytes)
        self.ledger.cancel(request_id, now)
        return rec

    # -- contention & reclamation -------------------------------------------------
    def pool_in_flight(self) -> int:
        return len(self.in_flight)

    def total_resident(self) -> int:
        return sum(st.resident for st in self.status.values())

    def has_free_slots(self) -> bool:
        return self.total_resident() < self.capacity().concurrency

    def contended(self) -> bool:
        """Demand exceeds supply: more admitted requests in flight than
        the pool has decode slots — i.e. someone is *waiting*.  A pool
        running at exactly full occupancy with an empty queue is busy,
        not contended (paper Exp. 1 phase 1: spot fills the pool)."""
        return self.pool_in_flight() > self.capacity().concurrency

    def admission_threshold(self) -> float:
        """Min priority among currently-admitted requests (paper §4.3),
        evaluated at the owners' LIVE priorities: debt and burst evolve
        after admission, and the threshold must reflect what those
        tenants are entitled to *now* — otherwise a tenant whose debt is
        rising would strictly exceed its own older snapshots and push
        unbounded work into a contended pool.

        Only meaningful when contended; returns 0.0 (admit-all) otherwise."""
        if not self.contended() or not self.in_flight:
            return 0.0
        ents = {r.entitlement for r in self.in_flight.values()}
        return min(self.priority(e) for e in ents
                   if e in self.entitlements)

    def reclaim_preemptible(self) -> list[str]:
        """Table-1 eviction: returns request ids of preemptible in-flight
        requests to terminate (KV reclaimed, pod killed).  The caller
        (engine) performs the kill and then `on_evict`s each."""
        victims = []
        for rec in self.in_flight.values():
            espec = self.entitlements.get(rec.entitlement)
            if espec and espec.qos.service_class == ServiceClass.PREEMPTIBLE:
                victims.append(rec.request_id)
        return victims

    # -- the accounting tick ------------------------------------------------------
    #
    # Split into gather (``begin_tick``) → fused control-plane kernel →
    # scatter (``apply_tick``) so ``PoolManager`` can stack the gathered
    # inputs of many pools and dispatch ONE batched kernel for all of
    # them.  ``tick`` composes the three for the single-pool case.

    def _static_row_arrays(self) -> dict[str, np.ndarray]:
        """Spec-derived row columns, cached until membership changes."""
        if self._rows_dirty or self._static_rows is None:
            names = sorted(self.entitlements)
            self._row_names = names
            es = [self.entitlements[n] for n in names]
            self._static_rows = {
                "class_code": np.array(
                    [CLASS_CODES[e.qos.service_class] for e in es],
                    np.int32),
                "baseline_tps": np.array(
                    [e.baseline.tokens_per_second for e in es], np.float32),
                "baseline_kv": np.array(
                    [e.baseline.kv_bytes for e in es], np.float32),
                "baseline_conc": np.array(
                    [e.baseline.concurrency for e in es], np.float32),
                "slo_ms": np.array(
                    [e.qos.slo_target_ms for e in es], np.float32),
            }
            self._rows_dirty = False
        return self._static_rows

    def begin_tick(self, now: float) -> TickInputs:
        """Step 1 (measurement) + gather: fold the accounting window
        into measured/demand signals and snapshot entitlement state as
        control-plane rows."""
        dt = max(1e-9, now - self._last_tick)
        self._last_tick = now
        self.expire_entitlements(now)
        static = self._static_row_arrays()
        names = self._row_names
        n = len(names)

        bound = np.zeros(n, bool)
        burst = np.zeros(n, np.float32)
        debt = np.zeros(n, np.float32)
        measured = np.zeros(n, np.float32)
        used_kv = np.zeros(n, np.float32)
        used_conc = np.zeros(n, np.float32)
        demand = np.zeros(n, np.float32)
        for i, name in enumerate(names):
            st = self.status[name]
            st.measured_tps = st.window_tokens / dt
            st.window_tokens = 0.0
            inst_demand = self._demand_window.get(name, 0.0) / dt
            # demand signal: EWMA for stability, floored by live usage
            self._demand_tps[name] = max(
                0.5 * self._demand_tps.get(name, 0.0) + 0.5 * inst_demand,
                st.measured_tps)
            self._demand_window[name] = 0.0
            bound[i] = st.state == EntitlementState.BOUND
            burst[i] = st.burst
            debt[i] = st.debt
            measured[i] = st.measured_tps
            used_kv[i] = st.kv_bytes_in_use
            used_conc[i] = float(st.resident)
            demand[i] = self._demand_tps[name]

        state = ControlState(
            class_code=jnp.asarray(static["class_code"]),
            bound=jnp.asarray(bound),
            baseline_tps=jnp.asarray(static["baseline_tps"]),
            baseline_kv=jnp.asarray(static["baseline_kv"]),
            baseline_conc=jnp.asarray(static["baseline_conc"]),
            slo_ms=jnp.asarray(static["slo_ms"]),
            burst=jnp.asarray(burst),
            debt=jnp.asarray(debt),
        )
        return TickInputs(
            names=list(names),
            state=state,
            capacity_tps=self.capacity().tokens_per_second,
            measured_tps=jnp.asarray(measured),
            used_kv=jnp.asarray(used_kv),
            used_conc=jnp.asarray(used_conc),
            demand_tps=jnp.asarray(demand),
            avg_slo_ms=self.pool_avg_slo(),
        )

    def apply_tick(self, now: float, names: list[str],
                   new_burst: np.ndarray, new_debt: np.ndarray,
                   alloc: np.ndarray, weights: np.ndarray) -> TickRecord:
        """Scatter kernel outputs back into status + ledger (steps 5–6)
        and append the observability record."""
        alloc_f = [float(a) for a in alloc]
        for i, name in enumerate(names):
            st = self.status[name]
            st.burst = float(new_burst[i])
            st.debt = float(new_debt[i])
            st.effective = Resources(alloc_f[i], st.effective.kv_bytes,
                                     st.effective.concurrency)
            self.ledger.set_rate(name, alloc_f[i], now)

        rec = TickRecord(
            t=now,
            capacity_tps=self.capacity().tokens_per_second,
            allocations=dict(zip(names, alloc_f)),
            priorities={n: float(weights[i])
                        for i, n in enumerate(names)},
            debts={n: self.status[n].debt for n in names},
            bursts={n: self.status[n].burst for n in names},
            in_flight={n: self.status[n].in_flight for n in names},
            demand_tps=dict(self._demand_tps),
        )
        self.history.append(rec)
        return rec

    def tick(self, now: float) -> TickRecord:
        """One accounting tick on the unified control plane.

        Rows are padded to a power-of-two bucket (inert unbound rows)
        so entitlement churn does not retrace the jitted kernel; the
        outputs are sliced back to the live rows."""
        inp = self.begin_tick(now)
        n = inp.state.n_rows
        width = control_plane.bucket_width(n)
        pad = width - n

        def padvec(x):
            return (jnp.concatenate([x, jnp.zeros(pad, x.dtype)])
                    if pad else x)

        new_state, alloc, weights = control_plane.control_tick(
            control_plane.pad_state(inp.state, width),
            jnp.float32(inp.capacity_tps), padvec(inp.measured_tps),
            padvec(inp.used_kv), padvec(inp.used_conc),
            padvec(inp.demand_tps), jnp.float32(inp.avg_slo_ms),
            coeff=self.spec.coefficients)
        return self.apply_tick(
            now, inp.names, np.asarray(new_state.burst)[:n],
            np.asarray(new_state.debt)[:n], np.asarray(alloc)[:n],
            np.asarray(weights)[:n])
