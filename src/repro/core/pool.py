"""TokenPool controller — allocation, reclamation, debt accounting.

Realises paper §3–§4: a pool aggregates backend replicas into capacity
(Λ_p tokens/s, X_p KV bytes, R_p concurrency); entitlements hold
baselines (λ_e, χ_e, r_e) with a service class; every accounting tick
the controller

  1. measures per-entitlement usage (tokens completed, KV resident,
     in-flight sequences),
  2. updates burst intensity b_e (Eq. 3 EWMA),
  3. computes effective allocations λ̂_e by priority-weighted
     water-filling with the Table-1 protection ordering
     (dedicated/guaranteed reserved even when idle → elastic baselines,
     shrunk under scarcity → work-conserving backfill of surplus to
     burst-eligible classes),
  4. updates service debt d_e (Eq. 2) for debt-bearing classes,
  5. pushes λ̂_e into the token-bucket ledger that funds admission.

Entitlement *creation* is admitted through the virtual-node scheduler
(`core.virtual_node`) against the pool's entitleable capacity
(per-replica × maxReplicas): a pool never promises more than it could
ever provision.  Runtime capacity (per-replica × live replicas) is what
allocation and admission run against, so replica failure shows up as
scarcity — shrinking elastic tenants and accruing debt — exactly the
paper's Experiment 2.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import priority as prio
from repro.core.ledger import Charge, Ledger
from repro.core.types import (
    BURST_CLASSES,
    DEBT_CLASSES,
    PROTECTED_CLASSES,
    AdmissionRequest,
    EntitlementSpec,
    EntitlementState,
    EntitlementStatus,
    PoolSpec,
    Resources,
    ServiceClass,
)
from repro.core.virtual_node import LeasePod, VirtualNodeProvider


@dataclasses.dataclass
class InFlight:
    """One admitted, not-yet-completed request."""

    request_id: str
    entitlement: str
    priority: float
    kv_bytes: float
    charged_tokens: int
    admitted_at: float
    resident: bool = False       # dispatched to a decode worker


@dataclasses.dataclass
class TickRecord:
    """Per-tick observability snapshot (drives the experiment figures)."""

    t: float
    capacity_tps: float
    allocations: dict[str, float]
    priorities: dict[str, float]
    debts: dict[str, float]
    bursts: dict[str, float]
    in_flight: dict[str, int]
    demand_tps: dict[str, float]


def waterfill(capacity: float, want: dict[str, float],
              weight: dict[str, float]) -> dict[str, float]:
    """Priority-weighted progressive water-filling.

    Distributes ``capacity`` across keys proportionally to ``weight``,
    capping each key at ``want[key]`` and re-distributing the excess to
    still-unsatisfied keys.  Work-conserving: either every want is met
    or the full capacity is used.
    """
    alloc = {k: 0.0 for k in want}
    remaining = max(0.0, capacity)
    active = {k for k, w in want.items() if w > 1e-12}
    while remaining > 1e-9 and active:
        total_w = sum(weight[k] for k in active)
        if total_w <= 0:
            # equal split among zero-weight entitlements
            share = {k: remaining / len(active) for k in active}
        else:
            share = {k: remaining * weight[k] / total_w for k in active}
        done = set()
        used = 0.0
        for k in list(active):
            room = want[k] - alloc[k]
            take = min(room, share[k])
            alloc[k] += take
            used += take
            if alloc[k] >= want[k] - 1e-12:
                done.add(k)
        remaining -= used
        if not done:        # all shares landed below caps → finished
            break
        active -= done
    return alloc


class TokenPool:
    """The TokenPool controller (one instance per pool CRD)."""

    def __init__(self, spec: PoolSpec,
                 provider: Optional[VirtualNodeProvider] = None,
                 now: float = 0.0) -> None:
        self.spec = spec
        self.provider = provider or VirtualNodeProvider()
        self.replicas = spec.scaling.min_replicas
        self.entitlements: dict[str, EntitlementSpec] = {}
        self.status: dict[str, EntitlementStatus] = {}
        self.ledger = Ledger(burst_window_s=spec.bucket_window_s)
        self.in_flight: dict[str, InFlight] = {}
        self.history: list[TickRecord] = []
        self._last_tick = now
        self._demand_window: dict[str, float] = {}
        self._demand_tps: dict[str, float] = {}
        # Entitleable capacity: what may ever be promised (maxReplicas).
        self.provider.create_node(spec.name, self.entitleable_capacity())

    # -- capacity -------------------------------------------------------------
    def entitleable_capacity(self) -> Resources:
        return self.spec.per_replica.scale(self.spec.scaling.max_replicas)

    def capacity(self) -> Resources:
        """Runtime capacity from live replicas."""
        return self.spec.per_replica.scale(self.replicas)

    def set_replicas(self, n: int) -> None:
        """Autoscaler / failure-injection entry point."""
        self.replicas = max(0, n)

    # -- entitlement lifecycle --------------------------------------------------
    def add_entitlement(self, espec: EntitlementSpec, now: float = 0.0
                        ) -> EntitlementState:
        self.entitlements[espec.name] = espec
        st = EntitlementStatus(created_at=now)
        self.status[espec.name] = st
        # Lease request: protected + elastic reserve their baseline on
        # the virtual node; spot/preemptible request nothing.
        reserve = (espec.baseline
                   if espec.qos.service_class not in
                   (ServiceClass.SPOT, ServiceClass.PREEMPTIBLE)
                   else Resources.zero())
        lease = LeasePod(
            name=f"lease-{espec.name}",
            entitlement=espec.name,
            request=reserve,
            protection_weight=prio.CLASS_WEIGHT[espec.qos.service_class],
        )
        bound = self.provider.submit(self.spec.name, lease)
        st.state = EntitlementState.BOUND if bound else EntitlementState.DEGRADED
        # Fund the bucket at baseline immediately; ticks refine it.
        self.ledger.ensure(espec.name, espec.baseline.tokens_per_second, now)
        self._demand_window.setdefault(espec.name, 0.0)
        self._demand_tps.setdefault(espec.name, 0.0)
        return st.state

    def remove_entitlement(self, name: str) -> None:
        self.provider.delete(f"lease-{name}")
        self.entitlements.pop(name, None)
        self.status.pop(name, None)

    def expire_entitlements(self, now: float) -> None:
        for name, espec in self.entitlements.items():
            st = self.status[name]
            if (espec.ttl_s is not None
                    and now - st.created_at >= espec.ttl_s
                    and st.state != EntitlementState.EXPIRED):
                st.state = EntitlementState.EXPIRED
                self.provider.delete(f"lease-{name}")

    # -- priority --------------------------------------------------------------
    def pool_avg_slo(self) -> float:
        if self.spec.fixed_avg_slo_ms is not None:
            return self.spec.fixed_avg_slo_ms
        targets = [e.qos.slo_target_ms for e in self.entitlements.values()
                   if self.status[e.name].state == EntitlementState.BOUND]
        return prio.pool_average_slo(targets)

    def priority(self, name: str) -> float:
        espec = self.entitlements[name]
        st = self.status[name]
        return prio.priority_weight(
            espec.qos.service_class,
            espec.qos.slo_target_ms,
            self.pool_avg_slo(),
            st.burst,
            st.debt,
            self.spec.coefficients,
        )

    # -- in-flight bookkeeping (called by admission / completion) -----------------
    def register_admit(self, rec: InFlight, demand_tokens: float) -> None:
        st = self.status[rec.entitlement]
        st.in_flight += 1
        st.kv_bytes_in_use += rec.kv_bytes
        st.admitted_total += 1
        self.in_flight[rec.request_id] = rec
        self._demand_window[rec.entitlement] = (
            self._demand_window.get(rec.entitlement, 0.0) + demand_tokens)

    def register_deny(self, entitlement: str, demand_tokens: float,
                      low_priority: bool) -> None:
        st = self.status[entitlement]
        st.denied_total += 1
        if low_priority:
            st.denied_low_priority += 1
        # Denied demand still counts as demand (drives backfill/scaling).
        self._demand_window[entitlement] = (
            self._demand_window.get(entitlement, 0.0) + demand_tokens)

    def on_start(self, request_id: str) -> None:
        """Backend callback: the request acquired a decode slot (its KV
        is now resident) — this is what §3.1's concurrency r counts."""
        rec = self.in_flight.get(request_id)
        if rec is None or rec.resident:
            return
        rec.resident = True
        self.status[rec.entitlement].resident += 1

    def on_complete(self, request_id: str, actual_output_tokens: int,
                    now: float) -> None:
        """Gateway completion callback (paper §4.3): settle the charge,
        update usage counters that feed burst/debt at the next tick."""
        rec = self.in_flight.pop(request_id, None)
        if rec is None:
            return
        st = self.status[rec.entitlement]
        st.in_flight = max(0, st.in_flight - 1)
        if rec.resident:
            st.resident = max(0, st.resident - 1)
        st.kv_bytes_in_use = max(0.0, st.kv_bytes_in_use - rec.kv_bytes)
        st.completed_total += 1
        actual = self.ledger.settle(request_id, actual_output_tokens, now)
        st.window_tokens += actual
        st.tokens_total += actual

    def on_evict(self, request_id: str, now: float) -> None:
        """Request terminated before completion (preemption/failure)."""
        rec = self.in_flight.pop(request_id, None)
        if rec is None:
            return
        st = self.status[rec.entitlement]
        st.in_flight = max(0, st.in_flight - 1)
        if rec.resident:
            st.resident = max(0, st.resident - 1)
        st.kv_bytes_in_use = max(0.0, st.kv_bytes_in_use - rec.kv_bytes)
        self.ledger.cancel(request_id, now)

    # -- contention & reclamation -------------------------------------------------
    def pool_in_flight(self) -> int:
        return len(self.in_flight)

    def total_resident(self) -> int:
        return sum(st.resident for st in self.status.values())

    def has_free_slots(self) -> bool:
        return self.total_resident() < self.capacity().concurrency

    def contended(self) -> bool:
        """Demand exceeds supply: more admitted requests in flight than
        the pool has decode slots — i.e. someone is *waiting*.  A pool
        running at exactly full occupancy with an empty queue is busy,
        not contended (paper Exp. 1 phase 1: spot fills the pool)."""
        return self.pool_in_flight() > self.capacity().concurrency

    def admission_threshold(self) -> float:
        """Min priority among currently-admitted requests (paper §4.3),
        evaluated at the owners' LIVE priorities: debt and burst evolve
        after admission, and the threshold must reflect what those
        tenants are entitled to *now* — otherwise a tenant whose debt is
        rising would strictly exceed its own older snapshots and push
        unbounded work into a contended pool.

        Only meaningful when contended; returns 0.0 (admit-all) otherwise."""
        if not self.contended() or not self.in_flight:
            return 0.0
        ents = {r.entitlement for r in self.in_flight.values()}
        return min(self.priority(e) for e in ents
                   if e in self.entitlements)

    def reclaim_preemptible(self) -> list[str]:
        """Table-1 eviction: returns request ids of preemptible in-flight
        requests to terminate (KV reclaimed, pod killed).  The caller
        (engine) performs the kill and then `on_evict`s each."""
        victims = []
        for rec in self.in_flight.values():
            espec = self.entitlements.get(rec.entitlement)
            if espec and espec.qos.service_class == ServiceClass.PREEMPTIBLE:
                victims.append(rec.request_id)
        return victims

    # -- the accounting tick ------------------------------------------------------
    def tick(self, now: float) -> TickRecord:
        dt = max(1e-9, now - self._last_tick)
        self._last_tick = now
        self.expire_entitlements(now)
        cap = self.capacity()
        names = [n for n in self.entitlements]
        coeff = self.spec.coefficients
        avg_slo = self.pool_avg_slo()

        # 1. measure usage + demand
        measured: dict[str, float] = {}
        for n in names:
            st = self.status[n]
            st.measured_tps = st.window_tokens / dt
            measured[n] = st.measured_tps
            st.window_tokens = 0.0
            inst_demand = self._demand_window.get(n, 0.0) / dt
            # demand signal: EWMA for stability, floored by live usage
            self._demand_tps[n] = max(
                0.5 * self._demand_tps.get(n, 0.0) + 0.5 * inst_demand,
                measured[n])
            self._demand_window[n] = 0.0

        # 2. burst intensity (Eq. 3 EWMA) — must precede priority calc
        for n in names:
            espec, st = self.entitlements[n], self.status[n]
            usage = Resources(measured[n], st.kv_bytes_in_use,
                              float(st.resident))
            delta = prio.burst_overconsumption(usage, espec.baseline)
            st.burst = prio.burst_update(st.burst, delta, coeff.gamma_burst)

        # 3. priority weights (Eq. 1) with updated burst, previous debt
        weights = {}
        for n in names:
            espec, st = self.entitlements[n], self.status[n]
            weights[n] = prio.priority_weight(
                espec.qos.service_class, espec.qos.slo_target_ms, avg_slo,
                st.burst, st.debt, coeff)

        # 4. allocation: protected reserved → elastic baselines → backfill
        alloc = self._allocate_tps(cap.tokens_per_second, names, weights)

        # 5. debt update (Eq. 2) for debt-bearing classes
        for n in names:
            espec, st = self.entitlements[n], self.status[n]
            if espec.qos.service_class in DEBT_CLASSES:
                # Underservice only counts when there is demand to serve:
                # an idle elastic entitlement is not "underserved", and
                # demand below baseline is not a gap either.  Service
                # above baseline (backfill burst) accrues credit.
                demand = self._demand_tps[n]
                base = espec.baseline.tokens_per_second
                if demand <= 1e-9 or base <= 0.0:
                    gap = 0.0
                else:
                    # debt tracks DELIVERED service ("underserved over
                    # time", §3.3): the measured completion rate,
                    # floored by the demand-capped funding (a tenant
                    # whose work is still in flight is not underserved
                    # by more than its funding shortfall).
                    served = max(measured[n], min(alloc[n], demand))
                    entitled_now = min(base, max(demand, served))
                    gap = (entitled_now - served) / base
                gap = min(coeff.gap_clip, max(-coeff.gap_clip, gap))
                st.debt = min(coeff.debt_max, max(
                    coeff.debt_min,
                    prio.debt_update(st.debt, gap, coeff.gamma_debt)))

        # 6. fund the ledger at effective rates
        for n in names:
            st = self.status[n]
            st.effective = Resources(alloc[n], st.effective.kv_bytes,
                                     st.effective.concurrency)
            self.ledger.set_rate(n, alloc[n], now)

        rec = TickRecord(
            t=now,
            capacity_tps=cap.tokens_per_second,
            allocations=dict(alloc),
            priorities=dict(weights),
            debts={n: self.status[n].debt for n in names},
            bursts={n: self.status[n].burst for n in names},
            in_flight={n: self.status[n].in_flight for n in names},
            demand_tps=dict(self._demand_tps),
        )
        self.history.append(rec)
        return rec

    def _allocate_tps(self, capacity: float, names: list[str],
                      weights: dict[str, float]) -> dict[str, float]:
        """Funding allocation with work conservation.

        Protected classes are FUNDED at baseline unconditionally (their
        buckets can always admit up to baseline — "never reclaimed");
        but surplus for backfill is computed against their *active use*
        min(baseline, demand), so idle reserved capacity is borrowable
        by lower classes and reclaimed within one accounting tick when
        the protected tenant returns (the paper's Exp. 1 squeeze).
        """
        alloc = {n: 0.0 for n in names}
        live = [n for n in names
                if self.status[n].state == EntitlementState.BOUND]

        def demand(n: str) -> float:
            return self._demand_tps.get(n, 0.0)

        # (a) protected: fund at baseline; emergency-scale only if the
        #     *active* protected use exceeds runtime capacity.
        protected = [n for n in live
                     if self.entitlements[n].qos.service_class
                     in PROTECTED_CLASSES]
        base_p = {n: self.entitlements[n].baseline.tokens_per_second
                  for n in protected}
        active_p = {n: min(base_p[n], demand(n)) for n in protected}
        total_active_p = sum(active_p.values())
        if total_active_p > capacity and total_active_p > 0:
            scale = capacity / total_active_p
            for n in protected:
                alloc[n] = base_p[n] * scale
            return alloc           # nothing left for anyone else
        for n in protected:
            alloc[n] = base_p[n]
        remaining = max(0.0, capacity - total_active_p)

        # (b) elastic baselines (demand-capped) — weighted water-fill
        #     under scarcity; an idle elastic strands nothing.
        elastic = [n for n in live
                   if self.entitlements[n].qos.service_class
                   == ServiceClass.ELASTIC]
        want_e = {n: min(self.entitlements[n].baseline.tokens_per_second,
                         demand(n))
                  for n in elastic}
        fill = waterfill(remaining, want_e,
                         {n: weights[n] for n in elastic})
        for n in elastic:
            alloc[n] = fill[n]
        remaining = max(0.0, remaining - sum(fill.values()))

        # (c) work-conserving backfill of surplus to burst-eligible
        #     classes with unmet demand (incl. spot/preemptible which
        #     have no baseline, and dedicated bursting above baseline).
        burst_ok = [n for n in live
                    if self.entitlements[n].qos.service_class
                    in BURST_CLASSES]
        want_b = {}
        for n in burst_ok:
            used = (active_p[n] if n in active_p
                    else min(alloc[n], demand(n)))
            want_b[n] = max(0.0, demand(n) - used)
        fill = waterfill(remaining, want_b,
                         {n: weights[n] for n in burst_ok})
        for n in burst_ok:
            alloc[n] += fill[n]
        return alloc
