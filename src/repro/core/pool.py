"""TokenPool controller — allocation, reclamation, debt accounting.

Realises paper §3–§4: a pool aggregates backend replicas into capacity
(Λ_p tokens/s, X_p KV bytes, R_p concurrency); entitlements hold
baselines (λ_e, χ_e, r_e) with a service class; every accounting tick
the controller

  1. measures per-entitlement usage (tokens completed, KV resident,
     in-flight sequences),
  2. updates burst intensity b_e (Eq. 3 EWMA),
  3. computes effective allocations λ̂_e by priority-weighted
     water-filling with the Table-1 protection ordering
     (dedicated/guaranteed reserved even when idle → elastic baselines,
     shrunk under scarcity → work-conserving backfill of surplus to
     burst-eligible classes),
  4. updates service debt d_e (Eq. 2) for debt-bearing classes,
  5. pushes λ̂_e into the token-bucket ledger that funds admission.

Steps 2–4 execute on the UNIFIED control plane
(``core.control_plane.control_tick``).  State ownership is RESIDENT
(``core.resident``): every control-plane column — statics, the
burst/debt EWMAs, window accumulators, KV/concurrency in use, token
bucket levels — lives in one structure-of-arrays per pool, padded to a
power-of-two capacity with free-slot recycling, mirrored as a cached
device ``ControlState``.  ``pool.status[name]`` hands out
``ResidentStatus`` VIEWS over rows (dicts are views, arrays are
truth), the accounting-window fold in :meth:`TokenPool._measure` is a
handful of vectorized column expressions, and :meth:`TokenPool.tick`
runs the fused kernel directly over the resident arrays — per-tick
Python work no longer scales with the entitlement count.  The old
scalar dict-loop survives only as the test oracle
(``control_plane.reference_tick``); ``waterfill`` below is part of that
oracle.  ``PoolManager`` batches many pools through the same kernel by
stacking their resident arrays.

Entitlement *creation* is admitted through the virtual-node scheduler
(`core.virtual_node`) against the pool's entitleable capacity
(per-replica × maxReplicas): a pool never promises more than it could
ever provision.  Runtime capacity (per-replica × live replicas) is what
allocation and admission run against, so replica failure shows up as
scarcity — shrinking elastic tenants and accruing debt — exactly the
paper's Experiment 2.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import control_plane, priority as prio, shard_plane
from repro.core.control_plane import CLASS_CODES, ControlState
from repro.core.ledger import Ledger
from repro.core.markers import hot_path
from repro.core.request_table import InFlight, InFlightMap, RequestTable
from repro.core.resident import (ResidentStatus, ResidentStore,
                                 ShardedResidentStore, _DictView)
from repro.core.types import (
    EntitlementSpec,
    EntitlementState,
    EntitlementStatus,
    PoolSpec,
    Resources,
    ServiceClass,
)
from repro.core.virtual_node import LeasePod, VirtualNodeProvider

__all__ = [  # noqa: F822 — InFlight re-exported from request_table
    "EntitlementMigration", "InFlight", "SettleBatch", "TickInputs",
    "TickRecord", "TokenPool", "waterfill",
]

#: class codes (DED/GUAR/ELASTIC) whose baseline counts toward the
#: reserved provisioning floor — see ``TokenPool.reserved_baseline``.
_RESERVING_CLASS = np.array([True, True, True, False, False])

#: Eq. 1 class weight by class CODE (f64 — mirrors the exact
#: ``priority.CLASS_WEIGHT`` values for the vectorized threshold).
_CLASS_WEIGHT_F64 = np.zeros(len(CLASS_CODES), np.float64)
for _sc, _code in CLASS_CODES.items():
    _CLASS_WEIGHT_F64[_code] = prio.CLASS_WEIGHT[_sc]
del _sc, _code


@dataclasses.dataclass
class SettleBatch:
    """Result of one batched settle/evict row-op, aligned with the
    input request ids (``known[i]`` False → unknown id, nothing
    changed for it)."""

    #: request id had an in-flight record
    known: np.ndarray
    #: owning entitlement per request (None where unknown)
    entitlements: list
    #: actual settled token cost per request (0.0 where unknown or
    #: uncharged; always 0.0 for evictions)
    settled_tokens: np.ndarray
    #: MATERIALIZED records of requests admitted via a spill leg
    #: (``spill_from`` set) — what cross-pool debt transfer consumes
    spills: list


@dataclasses.dataclass
class TickInputs:
    """Gathered per-tick state, ready for the control-plane kernel.
    Produced by ``TokenPool.begin_tick`` (live rows, compacted in slot
    order); ``PoolManager`` batches pools on the full resident arrays
    instead."""

    names: list[str]
    state: ControlState
    capacity_tps: float
    measured_tps: jnp.ndarray
    used_kv: jnp.ndarray
    used_conc: jnp.ndarray
    demand_tps: jnp.ndarray
    avg_slo_ms: float


@dataclasses.dataclass
class EntitlementMigration:
    """Everything one entitlement owns, detached from its pool and
    ready to re-attach elsewhere (``PoolManager.migrate_entitlement``).

    Invariants (documented in ``core.fleet``): the ledger bucket keeps
    its accrued level and outstanding charges, the status keeps debt /
    burst / usage counters, and in-flight records follow the
    entitlement so completions settle on the NEW owner.  The payload is
    fully MATERIALIZED (plain ``EntitlementStatus`` / ``TokenBucket``):
    the source row is recycled the moment the entitlement detaches."""

    espec: EntitlementSpec
    status: EntitlementStatus
    bucket: object                       # Optional[TokenBucket]
    charges: list
    in_flight: list
    demand_window: float
    demand_tps: float


class TickRecord:
    """Per-tick observability snapshot (drives the experiment figures).

    The resident tick hands this class raw kernel-output ARRAYS; the
    per-name dicts (``allocations``/``priorities``/``debts``/…) are
    materialized lazily on first access and cached — observability
    costs nothing until somebody looks.  The dict-kwargs constructor is
    kept for oracles and tests that build records by hand."""

    _DICT_FIELDS = ("allocations", "priorities", "debts", "bursts",
                    "in_flight", "demand_tps")
    __slots__ = ("t", "capacity_tps", "_names", "_arrays", "_cache")

    def __init__(self, t: float, capacity_tps: float,
                 allocations: Optional[dict] = None,
                 priorities: Optional[dict] = None,
                 debts: Optional[dict] = None,
                 bursts: Optional[dict] = None,
                 in_flight: Optional[dict] = None,
                 demand_tps: Optional[dict] = None) -> None:
        self.t = t
        self.capacity_tps = capacity_tps
        self._names: Optional[list[str]] = None
        self._arrays: Optional[dict] = None
        self._cache = {
            "allocations": {} if allocations is None else allocations,
            "priorities": {} if priorities is None else priorities,
            "debts": {} if debts is None else debts,
            "bursts": {} if bursts is None else bursts,
            "in_flight": {} if in_flight is None else in_flight,
            "demand_tps": {} if demand_tps is None else demand_tps,
        }

    @classmethod
    def from_arrays(cls, t: float, capacity_tps: float, names: list[str],
                    allocations: np.ndarray, priorities: np.ndarray,
                    debts: np.ndarray, bursts: np.ndarray,
                    in_flight: np.ndarray, demand_tps: np.ndarray
                    ) -> "TickRecord":
        """Lazy record over compact per-live-row arrays (row i ↔
        ``names[i]``).  The arrays must be snapshots the caller will
        not mutate."""
        rec = cls(t, capacity_tps)
        rec._names = names
        rec._arrays = {
            "allocations": allocations, "priorities": priorities,
            "debts": debts, "bursts": bursts, "in_flight": in_flight,
            "demand_tps": demand_tps,
        }
        rec._cache = {}
        return rec

    def _dict(self, key: str) -> dict:
        d = self._cache.get(key)
        if d is None:
            conv = int if key == "in_flight" else float
            arr = self._arrays[key]
            d = {n: conv(arr[i]) for i, n in enumerate(self._names)}
            self._cache[key] = d
        return d

    @property
    def allocations(self) -> dict:
        return self._dict("allocations")

    @property
    def priorities(self) -> dict:
        return self._dict("priorities")

    @property
    def debts(self) -> dict:
        return self._dict("debts")

    @property
    def bursts(self) -> dict:
        return self._dict("bursts")

    @property
    def in_flight(self) -> dict:
        return self._dict("in_flight")

    @property
    def demand_tps(self) -> dict:
        return self._dict("demand_tps")

    def __repr__(self) -> str:
        return (f"TickRecord(t={self.t}, capacity_tps={self.capacity_tps},"
                f" rows={len(self._names) if self._names is not None else len(self._cache.get('allocations', {}))})")


def waterfill(capacity: float, want: dict[str, float],
              weight: dict[str, float]) -> dict[str, float]:
    """Priority-weighted progressive water-filling.

    Distributes ``capacity`` across keys proportionally to ``weight``,
    capping each key at ``want[key]`` and re-distributing the excess to
    still-unsatisfied keys.  Work-conserving: either every want is met
    or the full capacity is used.
    """
    alloc = {k: 0.0 for k in want}
    remaining = max(0.0, capacity)
    active = {k for k, w in want.items() if w > 1e-12}
    while remaining > 1e-9 and active:
        total_w = sum(weight[k] for k in active)
        if total_w <= 0:
            # equal split among zero-weight entitlements
            share = {k: remaining / len(active) for k in active}
        else:
            share = {k: remaining * weight[k] / total_w for k in active}
        done = set()
        used = 0.0
        for k in list(active):
            room = want[k] - alloc[k]
            take = min(room, share[k])
            alloc[k] += take
            used += take
            if alloc[k] >= want[k] - 1e-12:
                done.add(k)
        remaining -= used
        if not done:        # all shares landed below caps → finished
            break
        active -= done
    return alloc


class TokenPool:
    """The TokenPool controller (one instance per pool CRD)."""

    def __init__(self, spec: PoolSpec,
                 provider: Optional[VirtualNodeProvider] = None,
                 now: float = 0.0) -> None:
        self.spec = spec
        self.provider = provider or VirtualNodeProvider()
        self.replicas = spec.scaling.min_replicas
        #: the resident structure-of-arrays — source of truth for every
        #: control-plane column (``core.resident``); ``spec.shards``
        #: opts into the sharded facade (``core.shard_plane``)
        if spec.shards is not None and spec.shards > 1:
            self.store = ShardedResidentStore(n_shards=spec.shards)
        else:
            self.store = ResidentStore()
        #: the resident request table — source of truth for every
        #: in-flight record and outstanding charge
        #: (``core.request_table``)
        self.table = RequestTable(self.store)
        self.entitlements: dict[str, EntitlementSpec] = {}
        #: name → ResidentStatus VIEW over the entitlement's row
        self.status: dict[str, ResidentStatus] = {}
        self.ledger = Ledger(burst_window_s=spec.bucket_window_s,
                             store=self.store, table=self.table)
        #: request id → InFlightRow VIEW over the request's row
        self.in_flight: InFlightMap = InFlightMap(self.table)
        #: bounded tick history (spec.history_maxlen; None = unbounded)
        self.history: deque = deque(maxlen=spec.history_maxlen)
        self._last_tick = now
        #: optional ``repro.telemetry.Telemetry`` sink (set by
        #: ``Telemetry.attach_pool``); when present every tick emits a
        #: duration sample + water-fill/debt gauges + a trace slice
        self.telemetry = None
        self._tick_t0 = 0.0
        #: TTL deadlines for the (rare) entitlements that declare one —
        #: expiry scans these, not the whole membership
        self._ttl_deadline: dict[str, float] = {}
        # Replica count last AUTHORIZED by the fleet planner (None until
        # a planner has run: the virtual node then still advertises the
        # full entitleable ceiling).
        self._authorized: Optional[int] = None
        # Entitleable capacity: what may ever be promised (maxReplicas).
        self.provider.create_node(spec.name, self.entitleable_capacity())

    # -- capacity -------------------------------------------------------------
    def entitleable_capacity(self) -> Resources:
        return self.spec.per_replica.scale(self.spec.scaling.max_replicas)

    def capacity(self) -> Resources:
        """Runtime capacity from live replicas."""
        return self.spec.per_replica.scale(self.replicas)

    def set_replicas(self, n: int, planned: bool = False) -> list[str]:
        """Autoscaler / failure-injection entry point.

        ``planned=False`` (failure injection, recovery, the scalar
        oracle) moves RUNTIME capacity only: the virtual node keeps its
        promise ceiling, entitlements stay bound, and the scarcity
        shows up as shrunken allocations + debt (paper Exp. 2 — an
        outage must not unbind tenants).  ``planned=True`` (the fleet
        planner) is a deliberate capacity decision: the promise ceiling
        moves with it through :meth:`authorize_replicas`, preempting
        the least-protected leases if the committed reservations no
        longer fit.  Returns the preempted entitlement names (always
        empty for unplanned changes)."""
        self.replicas = max(0, n)
        if planned:
            return self.authorize_replicas(n)
        return []

    def authorize_replicas(self, n: int) -> list[str]:
        """Move the virtual node's promise ceiling to ``n`` replicas
        (the fleet planner's decision).  A shrink below the committed
        lease reservations preempts in reverse-protection order (the
        §4.1 scheduler pass); a grow reschedules pending leases.
        Entitlement states are re-synced from the lease outcomes —
        preempted entitlements degrade, re-bound ones recover.
        Returns the entitlement names whose leases were preempted."""
        n = max(0, int(n))
        self._authorized = n
        preempted = self.provider.set_capacity(
            self.spec.name, self.spec.per_replica.scale(n))
        self._sync_lease_states()
        prefix = "lease-"
        return [name[len(prefix):] for name in preempted
                if name.startswith(prefix)]

    def _sync_lease_states(self) -> None:
        """Reconcile entitlement Bound/Degraded states with the actual
        lease bind outcomes after a virtual-node capacity change."""
        for name, st in self.status.items():
            if st.state not in (EntitlementState.BOUND,
                                EntitlementState.DEGRADED):
                continue
            bound = self.provider.is_bound(f"lease-{name}")
            st.state = (EntitlementState.BOUND if bound
                        else EntitlementState.DEGRADED)

    def reserved_baseline(self) -> Resources:
        """Σ baselines the pool has promised to keep provisionable —
        dedicated/guaranteed/elastic entitlements in Bound OR Degraded
        state (a Degraded promise is precisely what the planner must
        raise capacity for).  Spot/preemptible reserve nothing.  This
        is the reserved floor of the scale policy (``core.autoscaler``
        / ``core.fleet``) — computed as three masked column sums over
        the resident arrays."""
        from repro.core.resident import STATE_CODES
        c = self.store.col
        sc = c["state_code"]
        mask = (c["alive"]
                & ((sc == STATE_CODES[EntitlementState.BOUND])
                   | (sc == STATE_CODES[EntitlementState.DEGRADED]))
                & _RESERVING_CLASS[c["class_code"]])
        return Resources(
            float(np.sum(c["baseline_tps"][mask], dtype=np.float64)),
            float(np.sum(c["baseline_kv"][mask], dtype=np.float64)),
            float(np.sum(c["baseline_conc"][mask], dtype=np.float64)))

    def demand_snapshot(self) -> dict[str, float]:
        """Public copy of the per-entitlement demand EWMA (tok/s) the
        accounting tick maintains — the same values the latest
        ``TickRecord.demand_tps`` carries.  Planners read THIS, never
        the resident columns directly."""
        col = self.store.col["demand_tps"]
        return {n: float(col[s]) for n, s in self.store.slot_of.items()}

    def demand_total_tps(self) -> float:
        """Σ demand EWMA over the pool — one masked column sum (what
        fleet planning aggregates per pool)."""
        return float(np.sum(
            self.store.col["demand_tps"][self.store.col["alive"]]))

    # -- legacy private surfaces (dict facades over the columns) --------------
    @property
    def _demand_tps(self) -> _DictView:
        return _DictView(self.store, "demand_tps")

    @property
    def _demand_window(self) -> _DictView:
        return _DictView(self.store, "demand_window")

    # -- entitlement lifecycle --------------------------------------------------
    def _write_statics(self, slot: int, espec: EntitlementSpec) -> None:
        """Spec-derived static columns for one row — the single place
        both `add_entitlement` and `attach_entitlement` initialize
        from, so a future static column cannot diverge between the
        create and migration paths."""
        c = self.store.col
        c["class_code"][slot] = CLASS_CODES[espec.qos.service_class]
        c["baseline_tps"][slot] = espec.baseline.tokens_per_second
        c["baseline_kv"][slot] = espec.baseline.kv_bytes
        c["baseline_conc"][slot] = espec.baseline.concurrency
        c["slo_ms"][slot] = espec.qos.slo_target_ms
        # Both callers later write st.state (which invalidates), but the
        # mirror contract is per-write: statics land → mirror drops.
        self.store.mark_dirty()

    def add_entitlement(self, espec: EntitlementSpec, now: float = 0.0
                        ) -> EntitlementState:
        slot = self.store.allocate(espec.name)
        self.entitlements[espec.name] = espec
        self._write_statics(slot, espec)
        self.store.col["created_at"][slot] = now
        st = ResidentStatus(self.store, slot)
        self.status[espec.name] = st
        if espec.ttl_s is not None:
            self._ttl_deadline[espec.name] = now + espec.ttl_s
        # Lease request: protected + elastic reserve their baseline on
        # the virtual node; spot/preemptible request nothing.
        reserve = (espec.baseline
                   if espec.qos.service_class not in
                   (ServiceClass.SPOT, ServiceClass.PREEMPTIBLE)
                   else Resources.zero())
        lease = LeasePod(
            name=f"lease-{espec.name}",
            entitlement=espec.name,
            request=reserve,
            protection_weight=prio.CLASS_WEIGHT[espec.qos.service_class],
        )
        bound = self.provider.submit(self.spec.name, lease)
        st.state = EntitlementState.BOUND if bound else EntitlementState.DEGRADED
        # Fund the bucket at baseline immediately; ticks refine it.
        self.ledger.ensure(espec.name, espec.baseline.tokens_per_second, now)
        return st.state

    def remove_entitlement(self, name: str, now: float = 0.0) -> None:
        """Tear down an entitlement COMPLETELY.  Every piece of state
        keyed by the name must go: surviving in-flight records would
        make a later ``on_complete``/``on_evict`` KeyError on the
        missing status row, a surviving ledger bucket would keep
        refilling a dead tenant's budget, and a surviving resident row
        would leak into every future tick.  The freed row is zeroed
        (inert under every kernel mask) and recycled."""
        self.provider.delete(f"lease-{name}")
        # evict in-flight requests first (status row must still exist):
        # charges are refunded, then the whole bucket is dropped anyway
        slot = self.store.slot_of.get(name)
        if slot is not None:
            rows = self.table.record_slots_of_owner(slot)
            if rows.size:
                self.evict_rows([self.table.rid_of[s] for s in rows], now)
        self.entitlements.pop(name, None)
        self.status.pop(name, None)
        self.ledger.drop(name)
        self._ttl_deadline.pop(name, None)
        if name in self.store:
            self.store.release(name)
        # the freed reservation may have re-bound pending leases
        self._sync_lease_states()

    def detach_entitlement(self, name: str, now: float = 0.0
                           ) -> EntitlementMigration:
        """Detach an entitlement for migration to another pool
        (``PoolManager.migrate_entitlement``).  Unlike
        :meth:`remove_entitlement` nothing is forgotten: the ledger
        bucket (accrued level + outstanding charges), the status row
        (debt, burst, usage counters), the in-flight records and the
        demand signal are all MATERIALIZED into the migration payload
        — only the lease reservation is released here, and the
        resident row is recycled."""
        if name not in self.entitlements:
            raise KeyError(f"no entitlement {name!r} in pool "
                           f"{self.spec.name!r}")
        self.provider.delete(f"lease-{name}")
        # MATERIALIZE in-flight records before their rows die (the
        # charge halves go separately through ``ledger.detach``)
        t = self.table
        rows = t.record_slots_of_owner(self.store.slot_of[name])
        recs = [t.materialize_record(s) for s in rows]
        for s in rows:
            t.clear_record(int(s))
        bucket, charges = self.ledger.detach(name)
        slot = self.store.slot_of[name]
        c = self.store.col
        mig = EntitlementMigration(
            espec=self.entitlements.pop(name),
            status=self.store.snapshot_status(name),
            bucket=bucket, charges=charges, in_flight=recs,
            demand_window=float(c["demand_window"][slot]),
            demand_tps=float(c["demand_tps"][slot]))
        self.status.pop(name, None)
        self._ttl_deadline.pop(name, None)
        self.store.release(name)
        # the freed reservation may have re-bound a previously
        # preempted/pending lease — Degraded stickiness here would deny
        # a now-bound tenant with NOT_BOUND until the next authorize
        self._sync_lease_states()
        return mig

    def attach_entitlement(self, mig: EntitlementMigration,
                           now: float = 0.0) -> EntitlementState:
        """Adopt a migrated entitlement: submit its lease on THIS
        pool's virtual node (baseline reserve, same rule as
        :meth:`add_entitlement`) and restore every piece of carried
        state into a fresh resident row.  Debt is preserved verbatim —
        an underserved tenant arrives at the new pool with the
        priority boost it is owed (cross-pool debt, ROADMAP item 4)."""
        espec = mig.espec
        name = espec.name
        if name in self.entitlements:
            raise ValueError(f"entitlement {name!r} already in pool "
                             f"{self.spec.name!r}")
        espec.pool = self.spec.name
        slot = self.store.allocate(name)
        self.entitlements[name] = espec
        self._write_statics(slot, espec)
        self.store.load_status(slot, mig.status)
        st = ResidentStatus(self.store, slot)
        self.status[name] = st
        if espec.ttl_s is not None:
            self._ttl_deadline[name] = mig.status.created_at + espec.ttl_s
        reserve = (espec.baseline
                   if espec.qos.service_class not in
                   (ServiceClass.SPOT, ServiceClass.PREEMPTIBLE)
                   else Resources.zero())
        lease = LeasePod(
            name=f"lease-{name}",
            entitlement=name,
            request=reserve,
            protection_weight=prio.CLASS_WEIGHT[espec.qos.service_class],
        )
        bound = self.provider.submit(self.spec.name, lease)
        st.state = (EntitlementState.BOUND if bound
                    else EntitlementState.DEGRADED)
        if mig.bucket is not None:
            self.ledger.attach(name, mig.bucket, mig.charges, now)
        else:
            self.ledger.ensure(name, espec.baseline.tokens_per_second, now)
            self.ledger.attach(name, None, mig.charges, now)
        for rec in mig.in_flight:
            self.in_flight[rec.request_id] = rec
        self.store.col["demand_window"][slot] = mig.demand_window
        self.store.col["demand_tps"][slot] = mig.demand_tps
        return st.state

    def expire_entitlements(self, now: float) -> None:
        """TTL pass — scans only the entitlements that DECLARE a TTL
        (deadlines indexed at add/attach), so the common no-TTL pool
        pays nothing here."""
        if not self._ttl_deadline:
            return
        for name in [n for n, dl in self._ttl_deadline.items()
                     if now >= dl]:
            del self._ttl_deadline[name]
            st = self.status.get(name)
            if st is None or st.state == EntitlementState.EXPIRED:
                continue
            st.state = EntitlementState.EXPIRED
            self.provider.delete(f"lease-{name}")

    # -- priority --------------------------------------------------------------
    def pool_avg_slo(self) -> float:
        if self.spec.fixed_avg_slo_ms is not None:
            return self.spec.fixed_avg_slo_ms
        bound = self.store.col["bound"]
        n = int(np.count_nonzero(bound))
        if n == 0:
            return prio.pool_average_slo([])
        return float(np.sum(self.store.col["slo_ms"][bound],
                            dtype=np.float64) / n)

    def priority(self, name: str) -> float:
        """Live Eq. 1 weight for ONE entitlement (admission check 5).

        Single-request admission is inherently scalar, so this uses the
        scalar oracle directly; the accounting tick computes the same
        weights for ALL rows on the vectorized control plane (pinned
        equal by ``tests/test_control_plane.py``)."""
        espec = self.entitlements[name]
        st = self.status[name]
        return prio.priority_weight(
            espec.qos.service_class,
            espec.qos.slo_target_ms,
            self.pool_avg_slo(),
            st.burst,
            st.debt,
            self.spec.coefficients,
        )

    # -- in-flight bookkeeping (called by admission / completion) -----------------
    def register_admit(self, rec: InFlight, demand_tokens: float) -> None:
        st = self.status[rec.entitlement]
        st.in_flight += 1
        st.kv_bytes_in_use += rec.kv_bytes
        st.admitted_total += 1
        self.table.put_record(rec)
        slot = self.store.slot_of[rec.entitlement]
        self.store.col["demand_window"][slot] += demand_tokens

    @hot_path
    def register_admit_batch(self, recs: list[InFlight],
                             demand_tokens: dict[str, float]) -> None:
        """One scheduling quantum's admits in a single call — same
        bookkeeping as :meth:`register_admit`, but as masked
        scatter-adds on the store columns (``np.add.at`` applies
        updates in request order, so the f64 KV accumulation matches
        the scalar loop bit for bit) plus one batched row insertion
        into the request table."""
        if recs:
            slot_of = self.store.slot_of
            n = len(recs)
            owners = np.fromiter(
                (slot_of[r.entitlement] for r in recs),
                np.int64, count=n)
            self.table.put_records(recs, owners)
            sc = self.store.col
            np.add.at(sc["in_flight"], owners, 1)
            np.add.at(sc["kv_in_use"], owners, np.fromiter(
                (r.kv_bytes for r in recs), np.float64, count=n))
            np.add.at(sc["admitted_total"], owners, 1)
        window = self.store.col["demand_window"]
        for ent, tokens in demand_tokens.items():
            window[self.store.slot_of[ent]] += tokens

    @hot_path
    def admit_rows(self, request_ids: list, owners: np.ndarray,
                   kv_bytes: np.ndarray, charged_tokens: np.ndarray,
                   now: float,
                   demand_tokens: Optional[dict] = None,
                   slots: Optional[np.ndarray] = None) -> np.ndarray:
        """Array-native :meth:`register_admit_batch` — the gateway
        quantum hot path: no per-request ``InFlight`` objects, row
        insertion and counter updates are batched column ops.
        ``slots`` skips id resolution when the caller already holds
        the rows (``Ledger.charge_rows`` returns them).  Returns the
        new row slots (the caller tags spill legs on them)."""
        slots = self.table.admit_rows(
            request_ids, owners, kv_bytes, charged_tokens, now,
            slots=slots)
        sc = self.store.col
        np.add.at(sc["in_flight"], owners, 1)
        np.add.at(sc["kv_in_use"], owners, kv_bytes)
        np.add.at(sc["admitted_total"], owners, 1)
        if demand_tokens:
            window = sc["demand_window"]
            slot_of = self.store.slot_of
            for ent, tokens in demand_tokens.items():
                window[slot_of[ent]] += tokens
        return slots

    def register_deny(self, entitlement: str, demand_tokens: float,
                      low_priority: bool) -> None:
        st = self.status[entitlement]
        st.denied_total += 1
        if low_priority:
            st.denied_low_priority += 1
        # Denied demand still counts as demand (drives backfill/scaling).
        slot = self.store.slot_of[entitlement]
        self.store.col["demand_window"][slot] += demand_tokens

    @hot_path
    def register_deny_batch(self, entitlements: list,
                            demand_tokens: np.ndarray,
                            low_priority: np.ndarray) -> None:
        """One scheduling quantum's denials as masked scatter-adds —
        same bookkeeping as :meth:`register_deny` per element."""
        if not entitlements:
            return
        slot_of = self.store.slot_of
        # repro: allow[hot-path-scalar-loop] -- C-speed fromiter gather; a name->slot dict lookup has no vectorized form
        slots = np.fromiter((slot_of[e] for e in entitlements),
                            np.int64, count=len(entitlements))
        sc = self.store.col
        np.add.at(sc["denied_total"], slots, 1)
        lp = np.asarray(low_priority, bool)
        if lp.any():
            np.add.at(sc["denied_low_priority"], slots[lp], 1)
        np.add.at(sc["demand_window"], slots,
                  np.asarray(demand_tokens, np.float64))

    def on_start(self, request_id: str) -> None:
        """Backend callback: the request acquired a decode slot (its KV
        is now resident) — this is what §3.1's concurrency r counts."""
        t = self.table
        slot = t.slot_of.get(request_id)
        if slot is None or not t.col["has_record"][slot] \
                or t.col["resident"][slot]:
            return
        t.col["resident"][slot] = True
        owner = int(t.col["owner"][slot])
        self.store.col["resident"][owner] += 1

    def on_complete(self, request_id: str, actual_output_tokens: int,
                    now: float) -> Optional[InFlight]:
        """Gateway completion callback (paper §4.3): settle the charge,
        update usage counters that feed burst/debt at the next tick.

        This is the retained scalar ORACLE for :meth:`settle_rows`
        (pinned equal by ``tests/test_request_lifecycle.py``).

        Returns the settled ``InFlight`` record (None if unknown),
        MATERIALIZED — the row is recycled by the time this returns,
        and read-after-call on ``self.in_flight`` would silently miss.
        The record's ``settled_tokens`` is stamped with the actual
        cost."""
        t = self.table
        slot = t.slot_of.get(request_id)
        if slot is None or not t.col["has_record"][slot]:
            return None
        rec = t.materialize_record(slot)
        st = self.status[rec.entitlement]
        st.in_flight = max(0, st.in_flight - 1)
        if rec.resident:
            st.resident = max(0, st.resident - 1)
        st.kv_bytes_in_use = max(0.0, st.kv_bytes_in_use - rec.kv_bytes)
        st.completed_total += 1
        t.clear_record(slot)
        actual = self.ledger.settle(request_id, actual_output_tokens, now)
        st.window_tokens += actual
        st.tokens_total += actual
        rec.settled_tokens = actual
        return rec

    def on_evict(self, request_id: str, now: float) -> Optional[InFlight]:
        """Request terminated before completion (preemption/failure).
        Scalar oracle for :meth:`evict_rows`.  Returns the evicted
        ``InFlight`` record (None if unknown), materialized."""
        t = self.table
        slot = t.slot_of.get(request_id)
        if slot is None or not t.col["has_record"][slot]:
            return None
        rec = t.materialize_record(slot)
        st = self.status[rec.entitlement]
        st.in_flight = max(0, st.in_flight - 1)
        if rec.resident:
            st.resident = max(0, st.resident - 1)
        st.kv_bytes_in_use = max(0.0, st.kv_bytes_in_use - rec.kv_bytes)
        t.clear_record(slot)
        self.ledger.cancel(request_id, now)
        return rec

    # -- batched request lifecycle (the vectorized row-ops) -----------------------
    @hot_path
    def _lifecycle_rows(self, request_ids: list) -> tuple:
        """Resolve a batch of request ids to live record rows.  Returns
        ``(known mask, row slots of the known ids, entitlements list)``
        — the only per-request Python in the batched lifecycle (a dict
        hit and a list index per id)."""
        t = self.table
        n = len(request_ids)
        known = np.zeros(n, bool)
        slots = np.zeros(n, np.int64)
        get = t.slot_of.get
        has = t.col["has_record"]
        for i, rid in enumerate(request_ids):
            s = get(rid)
            if s is not None and has[s]:
                known[i] = True
                slots[i] = s
        ents: list = [None] * n
        ks = slots[known]
        if ks.size:
            name_of = self.store.name_of
            owners = t.col["owner"][ks]
            for i, o in zip(np.flatnonzero(known).tolist(),
                            owners.tolist()):
                ents[i] = name_of[o]
        return known, ks, ents

    @hot_path
    def _fold_record_rows(self, ks: np.ndarray, owners: np.ndarray,
                          completed: bool) -> None:
        """Fold a batch of record-half teardowns into the store
        columns.  Bit-parity with the scalar loop: ``np.add.at`` is
        unbuffered and applies in index order (the same f64 chain as
        sequential updates), and clamping ONCE after all decrements
        equals the scalar clamp-each — decrements are monotone, so
        once the running value hits the clamp floor every later scalar
        step re-clamps to the same 0."""
        c = self.table.col
        sc = self.store.col
        np.add.at(sc["in_flight"], owners, -1)
        res = c["resident"][ks]
        if res.any():
            np.add.at(sc["resident"], owners[res], -1)
        np.add.at(sc["kv_in_use"], owners, -c["kv_bytes"][ks])
        if completed:
            np.add.at(sc["completed_total"], owners, 1)
        touched = np.unique(owners)
        sc["in_flight"][touched] = np.maximum(
            sc["in_flight"][touched], 0)
        sc["resident"][touched] = np.maximum(
            sc["resident"][touched], 0)
        sc["kv_in_use"][touched] = np.maximum(
            sc["kv_in_use"][touched], 0.0)

    @hot_path
    def settle_rows(self, request_ids: list, actual_output_tokens,
                    now: float) -> SettleBatch:
        """One quantum's completions as vectorized row-ops — the
        batched :meth:`on_complete` (``on_complete_batch`` is the
        threaded alias).  Refunds, window/usage counters and
        kv/in-flight/resident decrements fold into masked column
        updates; rows release in batch order, so future slot recycling
        matches a scalar loop.  Each request id must appear at most
        once per batch.  Returns a :class:`SettleBatch` aligned with
        the inputs."""
        known, ks, ents = self._lifecycle_rows(request_ids)
        n = len(request_ids)
        settled = np.zeros(n, np.float64)
        spills: list = []
        if not ks.size:
            return SettleBatch(known, ents, settled, spills)
        t = self.table
        c = t.col
        owners = c["owner"][ks].astype(np.int64)
        self._fold_record_rows(ks, owners, completed=True)
        actual = self.ledger.settle_rows(
            ks, np.asarray(actual_output_tokens, np.int64)[known], now)
        settled[known] = actual
        sc = self.store.col
        np.add.at(sc["window_tokens"], owners, actual)
        np.add.at(sc["tokens_total"], owners, actual)
        spill = t.spill_from
        hits = [(j, int(s)) for j, s in enumerate(ks.tolist())
                if spill[s] is not None]
        if hits:
            for j, s in hits:
                rec = t.materialize_record(s)
                rec.settled_tokens = float(actual[j])
                spills.append(rec)
        t.release_rows(ks)
        return SettleBatch(known, ents, settled, spills)

    @hot_path
    def evict_rows(self, request_ids: list, now: float) -> SettleBatch:
        """One batch of evictions as vectorized row-ops — the batched
        :meth:`on_evict`: full refunds, usage decrements, no completion
        counters.  Returns a :class:`SettleBatch` (``settled_tokens``
        all zero — evictions settle nothing)."""
        known, ks, ents = self._lifecycle_rows(request_ids)
        settled = np.zeros(len(request_ids), np.float64)
        if not ks.size:
            return SettleBatch(known, ents, settled, [])
        owners = self.table.col["owner"][ks].astype(np.int64)
        self._fold_record_rows(ks, owners, completed=False)
        self.ledger.cancel_rows(ks, now)
        self.table.release_rows(ks)
        return SettleBatch(known, ents, settled, [])

    @hot_path
    def on_complete_batch(self, request_ids: list, actual_output_tokens,
                          now: float) -> SettleBatch:
        """Batched :meth:`on_complete` — one vectorized settle per
        scheduling quantum (threaded through ``PoolManager`` and
        ``Gateway``; the simulators drain completions once per step)."""
        return self.settle_rows(request_ids, actual_output_tokens, now)

    def gauges(self) -> dict:
        """Pool-level observability gauges as zero-arg callables — the
        single source both ``stats()`` (the legacy dict view) and the
        telemetry registry (``Telemetry.attach_pool`` binds each
        callable as a ``repro_pool_*`` gauge series) read through."""
        return {
            "in_flight": self.pool_in_flight,
            "resident": self.total_resident,
            "request_rows": lambda: self.table.capacity,
            "unknown_settles": lambda: self.ledger.unknown_settles,
        }

    def stats(self) -> dict:
        """Pool-level observability counters (request lifecycle) —
        a thin evaluation of :meth:`gauges`."""
        return {name: fn() for name, fn in self.gauges().items()}

    def audit_snapshot(self) -> dict:
        """Cheap public consistency snapshot for external invariant
        checkers (the chaos harness runs these after every quantum).
        Everything here is a masked column reduction — no per-row
        Python, no device sync, no state mutation.

        ``per_slot_in_flight`` / ``per_slot_resident`` recount the
        request table by owner (bincount over record rows), so a
        checker can diff them against the store's ``in_flight`` /
        ``resident`` counters without touching private columns."""
        sc = self.store.col
        tc = self.table.col
        alive = sc["alive"]
        width = self.store.capacity
        has_rec = tc["has_record"]
        owners = tc["owner"][has_rec].astype(np.int64)
        per_slot_in_flight = np.bincount(owners, minlength=width)
        res_owners = tc["owner"][has_rec & tc["resident"]].astype(np.int64)
        per_slot_resident = np.bincount(res_owners, minlength=width)
        live = np.flatnonzero(alive)
        return {
            "store": self.store.row_accounting(),
            "table": self.table.row_accounting(),
            "replicas": self.replicas,
            "authorized_replicas": self._authorized,
            "max_replicas": self.spec.scaling.max_replicas,
            "slots_per_replica": self.spec.per_replica.concurrency,
            "alive_slots": live,
            "alive_names": self.store.live_names(),
            "in_flight_col": sc["in_flight"][live],
            "resident_col": sc["resident"][live],
            "kv_in_use_col": sc["kv_in_use"][live],
            "debt_col": sc["debt"][live].astype(np.float64),
            "class_code_col": sc["class_code"][live],
            "per_slot_in_flight": per_slot_in_flight[live],
            "per_slot_resident": per_slot_resident[live],
            "mirror_drift": self.store.mirror_drift(),
            "unknown_settles": self.ledger.unknown_settles,
        }

    # -- contention & reclamation -------------------------------------------------
    def pool_in_flight(self) -> int:
        return len(self.in_flight)

    def total_resident(self) -> int:
        return int(self.store.col["resident"].sum())

    def has_free_slots(self) -> bool:
        return self.total_resident() < self.capacity().concurrency

    def contended(self) -> bool:
        """Demand exceeds supply: more admitted requests in flight than
        the pool has decode slots — i.e. someone is *waiting*.  A pool
        running at exactly full occupancy with an empty queue is busy,
        not contended (paper Exp. 1 phase 1: spot fills the pool)."""
        return self.pool_in_flight() > self.capacity().concurrency

    @hot_path
    def _priority_rows(self, slots: np.ndarray) -> np.ndarray:
        """Vectorized Eq. 1 over entitlement rows — the same factor
        chain as ``priority.priority_weight``, term for term, reading
        burst/debt from the store columns (the identical f32-sourced
        values the scalar ``priority()`` reads through its status
        view)."""
        sc = self.store.col
        coeff = self.spec.coefficients
        avg = self.pool_avg_slo()
        w_class = _CLASS_WEIGHT_F64[sc["class_code"][slots]]
        slo = sc["slo_ms"][slots].astype(np.float64)
        burst = sc["burst"][slots].astype(np.float64)
        debt = sc["debt"][slots].astype(np.float64)
        slo_factor = 1.0 / (1.0 + coeff.alpha_slo * (slo / avg))
        burst_factor = 1.0 / (1.0 + coeff.alpha_burst
                              * np.maximum(0.0, burst))
        debt_factor = np.maximum(1e-3, 1.0 + coeff.alpha_debt * debt)
        return w_class * slo_factor * burst_factor * debt_factor

    @hot_path
    def inflight_owner_slots(self) -> np.ndarray:
        """Distinct entitlement slots owning at least one in-flight
        record, ascending — one masked pass over the request table."""
        c = self.table.col
        return np.unique(c["owner"][c["has_record"]]).astype(np.int64)

    @hot_path
    def admission_threshold(self) -> float:
        """Min priority among currently-admitted requests (paper §4.3),
        evaluated at the owners' LIVE priorities: debt and burst evolve
        after admission, and the threshold must reflect what those
        tenants are entitled to *now* — otherwise a tenant whose debt is
        rising would strictly exceed its own older snapshots and push
        unbounded work into a contended pool.

        One vectorized Eq. 1 evaluation over the distinct owner rows
        (instead of O(#owners) scalar ``priority()`` calls), guarded
        against an empty owner set — every in-flight owner having been
        removed used to raise ``ValueError`` from an empty ``min``.

        Only meaningful when contended; returns 0.0 (admit-all) otherwise."""
        if not self.contended() or not self.in_flight:
            return 0.0
        owners = self.inflight_owner_slots()
        # lifecycle invariant: rows never outlive their entitlement —
        # but guard anyway (the old per-name filter, vectorized)
        owners = owners[self.store.col["alive"][owners]]
        if not owners.size:
            return 0.0
        return float(np.min(self._priority_rows(owners)))

    @hot_path
    def reclaim_preemptible(self) -> list[str]:
        """Table-1 eviction: returns request ids of preemptible in-flight
        requests to terminate (KV reclaimed, pod killed).  The caller
        (engine) performs the kill and then `on_evict`s each.

        One vectorized pass over the request table: gather each row's
        owner slot, mask by live record + live owner + preemptible
        class code.  ``slot_of`` is insertion-ordered, which is the
        same order the old per-record scan produced."""
        t = self.table
        if not t.slot_of:
            return []
        rids = list(t.slot_of.keys())
        slots = np.fromiter(t.slot_of.values(), np.int64, count=len(rids))
        tc = t.col
        owners = tc["owner"][slots]
        sc = self.store.col
        mask = (tc["has_record"][slots]
                & sc["alive"][owners]
                & (sc["class_code"][owners]
                   == CLASS_CODES[ServiceClass.PREEMPTIBLE]))
        if not mask.any():
            return []
        return [rid for rid, keep in zip(rids, mask) if keep]

    # -- the accounting tick ------------------------------------------------------
    #
    # The resident path: ``_measure`` folds the accounting window with a
    # handful of vectorized column expressions, ``tick`` runs the fused
    # kernel over the FULL resident arrays (free slots are inert
    # unbound rows; the shape is the pow2 store capacity, so membership
    # churn never retraces), and ``_absorb_tick`` adopts the kernel's
    # output arrays as the new truth.  ``begin_tick``/``apply_tick``
    # survive as the compact gather/scatter halves for tests and
    # callers that drive the kernel themselves.

    @hot_path
    def _measure(self, now: float) -> float:
        """Step 1 (measurement): fold the accounting window into the
        measured/demand columns.  O(width) numpy, no per-row Python.

        The demand EWMA is dt-aware: the retained fraction per tick is
        ``exp(-dt/τ)`` with ``τ = spec.demand_tau_s`` — at the default
        (τ = accounting_interval_s / ln 2) a tick at the nominal
        interval retains exactly ½, the historical fixed blend, while
        irregular tick spacing now yields a tick-rate-independent time
        constant."""
        self._tick_t0 = time.perf_counter()
        dt = max(1e-9, now - self._last_tick)
        self._last_tick = now
        self.expire_entitlements(now)
        c = self.store.col
        c["measured_tps"][:] = measured = c["window_tokens"] / dt
        c["window_tokens"][:] = 0.0
        inst = c["demand_window"] / dt
        tau = self.spec.demand_tau_s
        if tau is None:
            # exp(-dt·ln2 / interval) via exp2: EXACTLY ½ at dt=interval
            retain = 2.0 ** (-dt / self.spec.accounting_interval_s)
        else:
            retain = math.exp(-dt / max(tau, 1e-9))
        # demand signal: EWMA for stability, floored by live usage
        c["demand_tps"][:] = np.maximum(
            retain * c["demand_tps"] + (1.0 - retain) * inst, measured)
        c["demand_window"][:] = 0.0
        return dt

    @hot_path
    def _kernel_inputs(self) -> tuple:
        """f32 device views of the measurement columns (full width)."""
        c = self.store.col
        return (jnp.asarray(c["measured_tps"].astype(np.float32)),
                jnp.asarray(c["kv_in_use"].astype(np.float32)),
                jnp.asarray(c["resident"].astype(np.float32)),
                jnp.asarray(c["demand_tps"].astype(np.float32)))

    def begin_tick(self, now: float) -> TickInputs:
        """Measurement + compact gather: live rows only, in slot order
        (row i of every array ↔ ``names[i]``).  Kept for tests and
        callers that run the kernel themselves; the resident ``tick``
        path skips the compaction entirely."""
        self._measure(now)
        idx = self.store.live_slots()
        c = self.store.col
        state = ControlState(
            class_code=jnp.asarray(c["class_code"][idx]),
            bound=jnp.asarray(c["bound"][idx]),
            baseline_tps=jnp.asarray(c["baseline_tps"][idx]),
            baseline_kv=jnp.asarray(c["baseline_kv"][idx]),
            baseline_conc=jnp.asarray(c["baseline_conc"][idx]),
            slo_ms=jnp.asarray(c["slo_ms"][idx]),
            burst=jnp.asarray(c["burst"][idx]),
            debt=jnp.asarray(c["debt"][idx]),
        )
        return TickInputs(
            names=list(self.store.live_names()),
            state=state,
            capacity_tps=self.capacity().tokens_per_second,
            measured_tps=jnp.asarray(
                c["measured_tps"][idx].astype(np.float32)),
            used_kv=jnp.asarray(c["kv_in_use"][idx].astype(np.float32)),
            used_conc=jnp.asarray(c["resident"][idx].astype(np.float32)),
            demand_tps=jnp.asarray(c["demand_tps"][idx].astype(np.float32)),
            avg_slo_ms=self.pool_avg_slo(),
        )

    def apply_tick(self, now: float, names: list[str],
                   new_burst: np.ndarray, new_debt: np.ndarray,
                   alloc: np.ndarray, weights: np.ndarray) -> TickRecord:
        """Scatter compact kernel outputs back into the resident
        columns (steps 5–6) and append the observability record.  Row i
        of every array belongs to ``names[i]``."""
        slot_of = self.store.slot_of
        slots = np.fromiter((slot_of[n] for n in names),
                            np.int64, count=len(names))
        c = self.store.col
        alloc64 = np.asarray(alloc, np.float64)
        c["burst"][slots] = np.asarray(new_burst, np.float32)
        c["debt"][slots] = np.asarray(new_debt, np.float32)
        c["eff_tps"][slots] = alloc64
        self.store.mark_dirty()
        mask = np.zeros(self.store.capacity, bool)
        mask[slots] = True
        rates = np.zeros(self.store.capacity, np.float64)
        rates[slots] = alloc64
        self.ledger.set_rate_rows(mask, rates, now)
        rec = TickRecord.from_arrays(
            now, self.capacity().tokens_per_second, list(names),
            allocations=alloc64,
            priorities=np.asarray(weights, np.float64),
            debts=c["debt"][slots].astype(np.float64),
            bursts=c["burst"][slots].astype(np.float64),
            in_flight=c["in_flight"][slots].copy(),
            demand_tps=c["demand_tps"][slots].copy(),
        )
        self.history.append(rec)
        return rec

    @hot_path
    def _absorb_tick(self, now: float, new_state: ControlState,
                     alloc: np.ndarray, weights: np.ndarray,
                     adopt_device: bool = True) -> TickRecord:
        """Adopt FULL-WIDTH kernel outputs as the new resident truth:
        burst/debt columns sync from the output state (free slots see
        zero inputs and stay zero), allocations land in the effective
        column, and ONE vectorized ledger row-op re-rates every live
        bucket.  No per-row Python."""
        s = self.store
        c = s.col
        if adopt_device:
            s.adopt_device(new_state)
        else:
            c["burst"][:] = np.asarray(new_state.burst)
            c["debt"][:] = np.asarray(new_state.debt)
            s.mark_dirty()
        alive = c["alive"]
        alloc64 = np.asarray(alloc, np.float64)
        c["eff_tps"][:] = np.where(alive, alloc64, c["eff_tps"])
        self.ledger.set_rate_rows(alive, alloc64, now)
        idx = s.live_slots()
        rec = TickRecord.from_arrays(
            now, self.capacity().tokens_per_second, s.live_names(),
            allocations=alloc64[idx],
            priorities=np.asarray(weights, np.float64)[idx],
            debts=c["debt"][idx].astype(np.float64),
            bursts=c["burst"][idx].astype(np.float64),
            in_flight=c["in_flight"][idx].copy(),
            demand_tps=c["demand_tps"][idx].copy(),
        )
        self.history.append(rec)
        if self.telemetry is not None:
            # once per tick (O(pools), not O(requests)): duration +
            # water-fill/debt totals into the registry + trace timeline
            self.telemetry.on_tick(
                self.spec.name, now,
                time.perf_counter() - self._tick_t0,
                alloc_total=float(alloc64[idx].sum()),
                debt_total=float(c["debt"][idx].sum()),
                in_flight=int(c["in_flight"][idx].sum()))
        return rec

    @hot_path
    def tick(self, now: float) -> TickRecord:
        """One accounting tick on the unified control plane, straight
        over the resident arrays: vectorized window fold → ONE fused
        kernel dispatch at the store's (pow2) width → vectorized
        absorb.  Free slots ride along as inert unbound rows, so
        entitlement churn within a capacity bucket never retraces the
        jitted kernel."""
        self._measure(now)
        measured, used_kv, used_conc, demand = self._kernel_inputs()
        mesh = shard_plane.pool_mesh(self)
        if mesh is None:
            new_state, alloc, weights = control_plane.control_tick(
                self.store.device_state(),
                jnp.float32(self.capacity().tokens_per_second),
                measured, used_kv, used_conc, demand,
                jnp.float32(self.pool_avg_slo()),
                coeff=self.spec.coefficients)
        else:
            # sharded dispatch — bit-identical decisions (the tick's
            # tree reductions decompose exactly across mesh blocks)
            new_state, alloc, weights = shard_plane.shard_tick(
                self.store.device_state(),
                jnp.float32(self.capacity().tokens_per_second),
                measured, used_kv, used_conc, demand,
                jnp.float32(self.pool_avg_slo()),
                coeff=self.spec.coefficients, mesh=mesh)
        return self._absorb_tick(now, new_state, np.asarray(alloc),
                                 np.asarray(weights))
