"""Token pools — the paper's control-plane contribution.

Public API surface:

- types: ServiceClass, Resources, QoS, EntitlementSpec, PoolSpec, ...
- control_plane: THE tick — jit-compiled array-of-rows state machine
  (single pool and vmapped multi-pool), plus the scalar test oracle
- priority: Eq. (1)-(3) scalar oracle math
- resident: ResidentStore — the structure-of-arrays that OWNS each
  pool's control-plane state (statuses, buckets and snapshots are
  views over its rows)
- pool: TokenPool controller (stateful shell over the control plane)
- pool_manager: PoolManager (batched fleet tick + spill-over routing)
- admission: AdmissionController (the §4.3 five-check pipeline)
- virtual_node: VirtualNodeProvider (scheduler-as-admission, §4.1)
- autoscaler: entitlement-driven capacity planning (single-pool oracle)
- fleet: FleetPlanner — one fused plan_fleet dispatch for the whole
  fleet + cross-pool entitlement rebalancing with carried debt
- vectorized: batched admission replay + control-plane bridges
- ledger / state: token buckets and the Redis-contract state store
"""
from repro.core.admission import AdmissionController
from repro.core.autoscaler import (
    Autoscaler,
    AutoscalerConfig,
    ScaleDecision,
    replicas_for,
)
from repro.core.fleet import (
    FleetPlan,
    FleetPlanner,
    FleetPlannerConfig,
    RebalanceProposal,
    plan_fleet,
)
from repro.core.control_plane import (
    ControlState,
    OracleRow,
    control_tick,
    control_tick_pools,
    reference_tick,
)
from repro.core.ledger import Charge, Ledger, RowBucket, TokenBucket
from repro.core.request_table import (
    InFlight,
    InFlightMap,
    InFlightRow,
    RequestTable,
)
from repro.core.resident import ResidentStatus, ResidentStore
from repro.core.pool import (
    EntitlementMigration,
    SettleBatch,
    TickInputs,
    TickRecord,
    TokenPool,
    waterfill,
)
from repro.core.pool_manager import PoolManager, RouteEntry, as_manager
from repro.core.priority import (
    burst_overconsumption,
    burst_update,
    debt_update,
    pool_average_slo,
    priority_breakdown,
    priority_weight,
    service_gap,
)
from repro.core.state import CASConflict, StateStore
from repro.core.vectorized import (
    QuantumSnapshot,
    admit_quantum,
    arrays_from_pool,
    quantum_snapshot,
    running_min_live,
)
from repro.core.types import (
    AdmissionDecision,
    AdmissionRequest,
    DenyReason,
    EntitlementSpec,
    EntitlementState,
    EntitlementStatus,
    PoolSpec,
    PriorityCoefficients,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    kv_bytes_per_token,
    max_concurrency,
)
from repro.core.virtual_node import LeasePod, VirtualNode, VirtualNodeProvider

__all__ = [
    "AdmissionController", "AdmissionDecision", "AdmissionRequest",
    "Autoscaler", "AutoscalerConfig", "CASConflict", "Charge",
    "ControlState", "DenyReason", "EntitlementMigration",
    "EntitlementSpec", "EntitlementState", "EntitlementStatus",
    "FleetPlan", "FleetPlanner", "FleetPlannerConfig", "InFlight",
    "InFlightMap", "InFlightRow", "LeasePod", "Ledger", "OracleRow",
    "PoolManager", "PoolSpec",
    "PriorityCoefficients", "QoS", "QuantumSnapshot",
    "RebalanceProposal", "RequestTable", "ResidentStatus",
    "ResidentStore", "Resources", "RouteEntry", "RowBucket",
    "ScaleDecision",
    "ScalingBounds", "ServiceClass", "SettleBatch", "StateStore",
    "TickInputs",
    "TickRecord", "TokenBucket", "TokenPool", "VirtualNode",
    "VirtualNodeProvider", "admit_quantum", "arrays_from_pool",
    "as_manager", "burst_overconsumption", "burst_update",
    "control_tick", "control_tick_pools", "debt_update",
    "kv_bytes_per_token", "max_concurrency", "plan_fleet",
    "pool_average_slo", "priority_breakdown", "priority_weight",
    "quantum_snapshot", "reference_tick", "replicas_for",
    "running_min_live", "service_gap", "waterfill",
]
