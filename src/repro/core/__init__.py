"""Token pools — the paper's control-plane contribution.

Public API surface:

- types: ServiceClass, Resources, QoS, EntitlementSpec, PoolSpec, ...
- priority: Eq. (1)-(3) scalar math
- pool: TokenPool controller (allocation, reclamation, debt tick)
- admission: AdmissionController (the §4.3 five-check pipeline)
- virtual_node: VirtualNodeProvider (scheduler-as-admission, §4.1)
- autoscaler: entitlement-driven capacity planning
- vectorized: jit-compiled batch control plane (beyond-paper scale)
- ledger / state: token buckets and the Redis-contract state store
"""
from repro.core.admission import AdmissionController
from repro.core.autoscaler import Autoscaler, AutoscalerConfig, ScaleDecision
from repro.core.ledger import Charge, Ledger, TokenBucket
from repro.core.pool import InFlight, TickRecord, TokenPool, waterfill
from repro.core.priority import (
    burst_overconsumption,
    burst_update,
    debt_update,
    pool_average_slo,
    priority_breakdown,
    priority_weight,
    service_gap,
)
from repro.core.state import CASConflict, StateStore
from repro.core.types import (
    AdmissionDecision,
    AdmissionRequest,
    DenyReason,
    EntitlementSpec,
    EntitlementState,
    EntitlementStatus,
    PoolSpec,
    PriorityCoefficients,
    QoS,
    Resources,
    ScalingBounds,
    ServiceClass,
    kv_bytes_per_token,
    max_concurrency,
)
from repro.core.virtual_node import LeasePod, VirtualNode, VirtualNodeProvider

__all__ = [
    "AdmissionController", "AdmissionDecision", "AdmissionRequest",
    "Autoscaler", "AutoscalerConfig", "CASConflict", "Charge", "DenyReason",
    "EntitlementSpec", "EntitlementState", "EntitlementStatus", "InFlight",
    "LeasePod", "Ledger", "PoolSpec", "PriorityCoefficients", "QoS",
    "Resources", "ScaleDecision", "ScalingBounds", "ServiceClass",
    "StateStore", "TickRecord", "TokenBucket", "TokenPool", "VirtualNode",
    "VirtualNodeProvider", "burst_overconsumption", "burst_update",
    "debt_update", "kv_bytes_per_token", "max_concurrency",
    "pool_average_slo", "priority_breakdown", "priority_weight",
    "service_gap", "waterfill",
]
