"""Autoscaler — entitlement-driven capacity planning (paper Fig. 1,
"Dynamo planner" role).

Token pools authorize *both* admission and autoscaling from the same
capacity model: the desired replica count is derived from the very
entitlement/demand signals that admission uses, so what is promised and
what is provisioned stay consistent.

Policy (deterministic, hysteresis-damped):

  desired = ceil( max(reserved_baselines, demand_ewma · headroom)
                  / per_replica_tps )
  clamped to [minReplicas, maxReplicas]

  - ``reserved_baselines`` = Σ baselines of bound dedicated/guaranteed/
    elastic entitlements: the pool must always be able to serve its
    promises (paper: entitlements authorize autoscaling).
  - ``demand_ewma`` tracks total admitted + denied token demand, so
    denial pressure from burstable classes (spot backfill) can raise
    capacity up to the cap — burst is satisfied by *reallocating unused
    tokens first*, and only sustained unmet demand triggers scaling.
  - scale-down requires ``cooldown_ticks`` consecutive low-demand ticks
    (anti-flap); scale-up is immediate (protecting SLOs beats cost).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.pool import TokenPool
from repro.core.types import PROTECTED_CLASSES, EntitlementState, ServiceClass


@dataclasses.dataclass
class AutoscalerConfig:
    headroom: float = 1.2          # demand multiplier before scaling
    demand_ewma: float = 0.5       # smoothing of the demand signal
    cooldown_ticks: int = 5        # consecutive low ticks before shrink


@dataclasses.dataclass
class ScaleDecision:
    current: int
    desired: int
    reserved_tps: float
    demand_tps: float
    reason: str


class Autoscaler:
    def __init__(self, pool: TokenPool,
                 config: AutoscalerConfig = AutoscalerConfig()) -> None:
        self.pool = pool
        self.config = config
        self._demand = 0.0
        self._low_ticks = 0

    def reserved_tps(self) -> float:
        total = 0.0
        for name, espec in self.pool.entitlements.items():
            st = self.pool.status[name]
            if st.state != EntitlementState.BOUND:
                continue
            if espec.qos.service_class in PROTECTED_CLASSES or \
                    espec.qos.service_class is ServiceClass.ELASTIC:
                total += espec.baseline.tokens_per_second
        return total

    def observe_demand(self, demand_tps: float) -> None:
        g = self.config.demand_ewma
        self._demand = g * self._demand + (1 - g) * demand_tps

    def plan(self) -> ScaleDecision:
        pool = self.pool
        per_replica = pool.spec.per_replica.tokens_per_second
        reserved = self.reserved_tps()
        need_tps = max(reserved, self._demand * self.config.headroom)
        desired = max(1, math.ceil(need_tps / max(per_replica, 1e-9)))
        lo = pool.spec.scaling.min_replicas
        hi = pool.spec.scaling.max_replicas
        desired = min(hi, max(lo, desired))

        current = pool.replicas
        if desired > current:
            self._low_ticks = 0
            reason = "scale_up:demand" if self._demand * self.config.headroom \
                > reserved else "scale_up:reserved"
        elif desired < current:
            self._low_ticks += 1
            if self._low_ticks < self.config.cooldown_ticks:
                desired = current        # hold during cooldown
                reason = "hold:cooldown"
            else:
                reason = "scale_down"
                self._low_ticks = 0
        else:
            self._low_ticks = 0
            reason = "steady"
        return ScaleDecision(current=current, desired=desired,
                             reserved_tps=reserved,
                             demand_tps=self._demand, reason=reason)

    def step(self) -> ScaleDecision:
        """Observe current pool demand, plan, and apply."""
        total_demand = sum(self.pool._demand_tps.values())
        self.observe_demand(total_demand)
        decision = self.plan()
        if decision.desired != decision.current:
            self.pool.set_replicas(decision.desired)
            # capacity change flows into the virtual node so new
            # entitlements are admitted against updated entitleable
            # capacity only if maxReplicas changed — runtime capacity
            # is tracked by the pool itself.
        return decision
