"""Autoscaler — entitlement-driven capacity planning (paper Fig. 1,
"Dynamo planner" role).

Token pools authorize *both* admission and autoscaling from the same
capacity model: the desired replica count is derived from the very
entitlement/demand signals that admission uses, so what is promised and
what is provisioned stay consistent.

Policy (deterministic, hysteresis-damped):

  desired = ceil( max(replicas_for(reserved_baselines),
                      demand_ewma · headroom / per_replica_tps) )
  clamped to [minReplicas, maxReplicas]

  - ``reserved_baselines`` = Σ baselines (all three resource
    dimensions) of dedicated/guaranteed/elastic entitlements the pool
    has ACCEPTED — Bound *and* Degraded: a Degraded entitlement is a
    promise the pool cannot currently honor, which is exactly the
    signal that must raise capacity (counting only Bound would
    deadlock the authorize-shrink loop: a planner-shrunk pool could
    never grow back for a newly joined tenant).
  - ``demand_ewma`` tracks total admitted + denied token demand
    (seeded with the first observation — decaying up from 0.0 would
    under-provision the cold start), so denial pressure from
    burstable classes (spot backfill) can raise capacity up to the
    cap — burst is satisfied by *reallocating unused tokens first*,
    and only sustained unmet demand triggers scaling.
  - scale-down requires ``cooldown_ticks`` consecutive low-demand ticks
    (anti-flap); scale-up is immediate (protecting SLOs beats cost).

This scalar, single-pool planner is the PARITY ORACLE for the fleet
kernel: ``core.fleet.plan_fleet`` executes the same policy for every
pool of the fleet in one fused vmapped dispatch, and
``tests/test_fleet.py`` pins the two decision-identical.  Any policy
change here must be mirrored in the kernel.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core.pool import TickRecord, TokenPool
from repro.core.types import Resources


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    headroom: float = 1.2          # demand multiplier before scaling
    demand_ewma: float = 0.5       # smoothing of the demand signal
    cooldown_ticks: int = 5        # consecutive low ticks before shrink


@dataclasses.dataclass
class ScaleDecision:
    current: int
    desired: int
    reserved_tps: float
    demand_tps: float
    reason: str
    #: which pool this decision is for (filled by the fleet planner;
    #: the single-pool Autoscaler leaves its own pool implicit)
    pool: str = ""


def replicas_for(need: Resources, per_replica: Resources) -> float:
    """Fractional replicas required to hold ``need`` — the max over
    the three resource dimensions.  A dimension the replica shape does
    not provide (per-replica 0) but the need requires is unsatisfiable
    (inf → clamps to maxReplicas)."""

    def dim(need_v: float, per_v: float) -> float:
        if per_v > 0.0:
            return need_v / per_v
        return math.inf if need_v > 0.0 else 0.0

    return max(dim(need.tokens_per_second, per_replica.tokens_per_second),
               dim(need.kv_bytes, per_replica.kv_bytes),
               dim(need.concurrency, per_replica.concurrency))


class Autoscaler:
    def __init__(self, pool: TokenPool,
                 config: Optional[AutoscalerConfig] = None) -> None:
        # config is constructed per instance: a shared mutable default
        # instance would alias tuning across every autoscaler.  (The
        # other dataclass-valued defaults in core/ — QoS and
        # PriorityCoefficients — are frozen, so sharing them is safe.)
        self.pool = pool
        self.config = config if config is not None else AutoscalerConfig()
        self._demand: Optional[float] = None     # None until first obs
        self._low_ticks = 0

    def reserved_baseline(self) -> Resources:
        return self.pool.reserved_baseline()

    def reserved_tps(self) -> float:
        return self.reserved_baseline().tokens_per_second

    @property
    def demand_tps(self) -> float:
        return self._demand if self._demand is not None else 0.0

    def observe_demand(self, demand_tps: float) -> None:
        # float32 arithmetic end-to-end: this scalar policy is the
        # parity oracle for the f32 `fleet.plan_fleet` kernel, and f64
        # here would flip ceil() on exact replica boundaries (e.g.
        # 400·1.2/240 straddles 2.0 differently in the two widths).
        d = np.float32(demand_tps)
        if self._demand is None:          # seed with the first observation
            self._demand = float(d)
            return
        g = self.config.demand_ewma
        self._demand = float(np.float32(g) * np.float32(self._demand)
                             + np.float32(1.0 - g) * d)

    def plan(self) -> ScaleDecision:
        pool = self.pool
        per = pool.spec.per_replica
        reserved = self.reserved_baseline()

        def dim(need: float, per_v: float) -> np.float32:
            need, per_v = np.float32(need), np.float32(per_v)
            if per_v > 0.0:
                return need / max(per_v, np.float32(1e-30))
            return np.float32(np.inf if need > 0.0 else 0.0)

        need_reserved = max(
            dim(reserved.tokens_per_second, per.tokens_per_second),
            dim(reserved.kv_bytes, per.kv_bytes),
            dim(reserved.concurrency, per.concurrency))
        need_demand = dim(
            np.float32(self.demand_tps) * np.float32(self.config.headroom),
            per.tokens_per_second)
        need = max(need_reserved, need_demand)
        # unsatisfiable dimension (inf need) clamps to maxReplicas
        desired = max(1, math.ceil(min(float(need), 1e9)))
        lo = pool.spec.scaling.min_replicas
        hi = pool.spec.scaling.max_replicas
        desired = min(hi, max(lo, desired))

        current = pool.replicas
        if desired > current:
            self._low_ticks = 0
            reason = ("scale_up:demand" if need_demand > need_reserved
                      else "scale_up:reserved")
        elif desired < current:
            self._low_ticks += 1
            if self._low_ticks < self.config.cooldown_ticks:
                desired = current        # hold during cooldown
                reason = "hold:cooldown"
            else:
                reason = "scale_down"
                self._low_ticks = 0
        else:
            self._low_ticks = 0
            reason = "steady"
        return ScaleDecision(current=current, desired=desired,
                             reserved_tps=reserved.tokens_per_second,
                             demand_tps=self.demand_tps, reason=reason,
                             pool=pool.spec.name)

    def step(self, record: Optional[TickRecord] = None) -> ScaleDecision:
        """Observe demand, plan, and apply.

        Demand is fed from the ``TickRecord.demand_tps`` the control
        plane already emits (pass the pool's latest record); without
        one, the pool's public :meth:`TokenPool.demand_snapshot` is
        read — never the private accounting dicts.
        """
        demand = (record.demand_tps if record is not None
                  else self.pool.demand_snapshot())
        self.observe_demand(sum(demand.values()))
        decision = self.plan()
        if decision.desired != decision.current:
            # The scalar oracle only moves RUNTIME capacity; the fleet
            # planner (PoolManager.plan_quantum) additionally reconciles
            # the virtual-node promise ceiling via authorize_replicas.
            self.pool.set_replicas(decision.desired)
        return decision
