"""Vectorized control plane — jit-compiled jnp implementation of the
paper's math for O(10^4..10^6) entitlements.

The paper evaluates priority/debt/burst per request in Python against
Redis state (~ms each).  At the 1000+-node scale this repo targets, a
pool can hold hundreds of thousands of entitlements and the accounting
tick itself becomes the bottleneck.  This module re-expresses the whole
tick — Eq. 3 burst EWMA, Eq. 1 priority, priority-weighted
water-filling allocation, Eq. 2 debt EWMA — as fused jnp array ops, and
request admission for a scheduling quantum as a ``lax.fori_loop`` (an
exact sequential replay, jit-compiled).

``tests/test_vectorized_equiv.py`` pins these equal (within float
tolerance) to the scalar reference in ``core.priority`` /
``core.pool.waterfill`` / ``core.admission`` using hypothesis.

Everything here is pure-functional: state arrays in, state arrays out.
Entitlements are rows; service classes are small int codes.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import PriorityCoefficients, ServiceClass

# class codes (row order matters: used for lookups)
CLASS_CODES: dict[ServiceClass, int] = {
    ServiceClass.DEDICATED: 0,
    ServiceClass.GUARANTEED: 1,
    ServiceClass.ELASTIC: 2,
    ServiceClass.SPOT: 3,
    ServiceClass.PREEMPTIBLE: 4,
}
_W = jnp.array([1000.0, 1000.0, 100.0, 1.0, 0.1])       # CLASS_WEIGHT
_PROTECTED = jnp.array([True, True, False, False, False])
_BURSTOK = jnp.array([True, False, True, True, True])    # Table 1 "Burst"
_DEBTOK = jnp.array([False, False, True, False, False])  # debt classes
_ELASTIC = jnp.array([False, False, True, False, False])


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PoolArrays:
    """Per-entitlement state-of-the-world, array-of-rows layout."""

    class_code: jax.Array        # int32 [N]
    bound: jax.Array             # bool  [N]
    baseline_tps: jax.Array      # f32 [N] λ_e
    baseline_kv: jax.Array       # f32 [N] χ_e
    baseline_conc: jax.Array     # f32 [N] r_e
    slo_ms: jax.Array            # f32 [N] ℓ*_e
    burst: jax.Array             # f32 [N] b_e
    debt: jax.Array              # f32 [N] d_e


def priority_batch(arr: PoolArrays, pool_avg_slo: jax.Array,
                   coeff: PriorityCoefficients) -> jax.Array:
    """Eq. (1), row-parallel."""
    w_class = _W[arr.class_code]
    slo_f = 1.0 / (1.0 + coeff.alpha_slo * (arr.slo_ms / pool_avg_slo))
    burst_f = 1.0 / (1.0 + coeff.alpha_burst * jnp.maximum(arr.burst, 0.0))
    debt_f = jnp.maximum(1e-3, 1.0 + coeff.alpha_debt * arr.debt)
    return w_class * slo_f * burst_f * debt_f


def burst_delta_batch(used_tps: jax.Array, used_kv: jax.Array,
                      used_conc: jax.Array, arr: PoolArrays) -> jax.Array:
    """Eq. (3), row-parallel, matching the scalar zero-baseline rule."""

    def term(used, base):
        rel = jnp.where(base > 0.0, jnp.maximum(0.0, used / jnp.maximum(
            base, 1e-30) - 1.0), jnp.where(used > 0.0, 1.0, 0.0))
        return rel

    return (term(used_tps, arr.baseline_tps)
            + term(used_kv, arr.baseline_kv)
            + term(used_conc, arr.baseline_conc))


def ewma(prev: jax.Array, x: jax.Array, gamma: float) -> jax.Array:
    """Eq. (2) form: γ·prev + (1−γ)·x."""
    return gamma * prev + (1.0 - gamma) * x


def waterfill_batch(capacity: jax.Array, want: jax.Array,
                    weight: jax.Array, max_rounds: int = 32) -> jax.Array:
    """Priority-weighted progressive water-filling (jnp mirror of
    ``core.pool.waterfill``).  Runs the same cap-and-redistribute rounds
    inside a ``lax.while_loop``; converges in ≤ #distinct-caps rounds,
    bounded by ``max_rounds`` for compile-time safety."""
    want = jnp.maximum(want, 0.0)
    active0 = want > 1e-12

    def cond(state):
        alloc, remaining, active, i = state
        return (remaining > 1e-9) & jnp.any(active) & (i < max_rounds)

    def body(state):
        alloc, remaining, active, i = state
        w = jnp.where(active, weight, 0.0)
        total_w = jnp.sum(w)
        n_active = jnp.sum(active)
        total_w_safe = jnp.where(total_w > 0.0, total_w, 1.0)
        share = jnp.where(
            total_w > 0.0,
            remaining * (w / total_w_safe),
            jnp.where(active, remaining / jnp.maximum(n_active, 1), 0.0))
        room = want - alloc
        take = jnp.minimum(room, share)
        take = jnp.where(active, take, 0.0)
        alloc = alloc + take
        remaining = remaining - jnp.sum(take)
        # done when the share covered the remaining room — compare take
        # to room with a magnitude-scaled epsilon (f32-safe; an absolute
        # 1e-12 misfires once want ≳ 1e2 in float32)
        newly_done = active & (take >= room
                               - 1e-6 * jnp.maximum(1.0, want))
        # scalar loop breaks when a round fills nobody
        progress = jnp.any(newly_done)
        active = active & ~newly_done
        i = jnp.where(progress, i + 1, max_rounds)
        return alloc, remaining, active, i

    alloc0 = jnp.zeros_like(want)
    alloc, _, _, _ = jax.lax.while_loop(
        cond, body, (alloc0, jnp.maximum(capacity, 0.0), active0,
                     jnp.asarray(0)))
    return alloc


def allocate_tps_batch(capacity: jax.Array, arr: PoolArrays,
                       weights: jax.Array, demand_tps: jax.Array
                       ) -> jax.Array:
    """Mirror of ``TokenPool._allocate_tps`` (funding + work
    conservation): protected funded at baseline (emergency-scaled if
    their *active* use exceeds capacity) → elastic demand-capped
    baselines water-filled → burst backfill of the surplus."""
    live = arr.bound
    protected = live & _PROTECTED[arr.class_code]
    base_p = jnp.where(protected, arr.baseline_tps, 0.0)
    active_p = jnp.minimum(base_p, jnp.where(protected, demand_tps, 0.0))
    total_active_p = jnp.sum(active_p)
    emergency = total_active_p > capacity
    scale = jnp.where(emergency,
                      capacity / jnp.maximum(total_active_p, 1e-30), 1.0)
    alloc_p = base_p * scale
    remaining = jnp.where(
        emergency, 0.0, jnp.maximum(0.0, capacity - total_active_p))

    elastic = live & _ELASTIC[arr.class_code]
    want_e = jnp.where(elastic,
                       jnp.minimum(arr.baseline_tps, demand_tps), 0.0)
    fill_e = waterfill_batch(remaining, want_e,
                             jnp.where(elastic, weights, 0.0))
    alloc = alloc_p + fill_e
    remaining = jnp.maximum(0.0, remaining - jnp.sum(fill_e))

    burst_ok = live & _BURSTOK[arr.class_code]
    used = jnp.where(protected, active_p,
                     jnp.minimum(alloc, demand_tps))
    want_b = jnp.where(burst_ok,
                       jnp.maximum(0.0, demand_tps - used), 0.0)
    fill_b = waterfill_batch(remaining, want_b,
                             jnp.where(burst_ok, weights, 0.0))
    return alloc + fill_b


@partial(jax.jit, static_argnames=("coeff",))
def tick_batch(arr: PoolArrays, capacity_tps: jax.Array,
               measured_tps: jax.Array, used_kv: jax.Array,
               used_conc: jax.Array, demand_tps: jax.Array,
               coeff: PriorityCoefficients = PriorityCoefficients(),
               ) -> tuple[PoolArrays, jax.Array, jax.Array]:
    """One full accounting tick, fused: returns (new state, allocations,
    priority weights).  Mirrors ``TokenPool.tick`` steps 2–5."""
    # pool-average SLO over bound members
    n_bound = jnp.maximum(jnp.sum(arr.bound), 1)
    avg_slo = jnp.sum(jnp.where(arr.bound, arr.slo_ms, 0.0)) / n_bound
    avg_slo = jnp.maximum(avg_slo, 1e-9)

    delta = burst_delta_batch(measured_tps, used_kv, used_conc, arr)
    burst = ewma(arr.burst, delta, coeff.gamma_burst)
    arr1 = dataclasses.replace(arr, burst=burst)

    weights = priority_batch(arr1, avg_slo, coeff)
    alloc = allocate_tps_batch(capacity_tps, arr1, weights, demand_tps)

    served = jnp.maximum(measured_tps, jnp.minimum(alloc, demand_tps))
    entitled_now = jnp.minimum(arr.baseline_tps,
                               jnp.maximum(demand_tps, served))
    gap = jnp.where(
        (demand_tps > 1e-9) & (arr.baseline_tps > 0.0),
        (entitled_now - served) / jnp.maximum(arr.baseline_tps, 1e-30),
        0.0)
    gap = jnp.clip(gap, -coeff.gap_clip, coeff.gap_clip)
    debtok = _DEBTOK[arr1.class_code]
    debt = jnp.where(
        debtok,
        jnp.clip(ewma(arr1.debt, gap, coeff.gamma_debt),
                 coeff.debt_min, coeff.debt_max),
        arr1.debt)
    arr2 = dataclasses.replace(arr1, debt=debt)
    return arr2, alloc, weights


@partial(jax.jit, static_argnames=("coeff", "slack"))
def admit_quantum(arr: PoolArrays,
                  bucket_level: jax.Array,       # f32 [N] tokens available
                  in_flight: jax.Array,          # i32 [N] RESIDENT seqs
                  kv_in_use: jax.Array,          # f32 [N]
                  pool_in_flight: jax.Array,     # i32 []
                  pool_conc_cap: jax.Array,      # f32 []
                  running_min_priority: jax.Array,  # f32 [] (inf if none)
                  pool_avg_slo: jax.Array,       # f32 []
                  req_ent: jax.Array,            # i32 [M] entitlement row
                  req_tokens: jax.Array,         # f32 [M] input+max_tokens
                  req_kv: jax.Array,             # f32 [M] kv bytes needed
                  coeff: PriorityCoefficients = PriorityCoefficients(),
                  slack: float = 0.0,
                  ) -> tuple[jax.Array, jax.Array]:
    """Exact sequential admission replay for one scheduling quantum.

    Requests are processed in array order (arrival order).  Returns
    (admitted bool [M], deny_reason int [M]) with reason codes:
    0=admitted, 1=not_bound, 2=concurrency, 3=token_budget, 4=low_priority.
    State updates (bucket charge, in-flight increments, running-min
    threshold) are applied between requests exactly as the scalar
    controller does — but inside one fused XLA loop.
    """
    M = req_ent.shape[0]
    weights = priority_batch(arr, pool_avg_slo, coeff)

    def body(i, state):
        (bucket, infl, kv, pool_infl, run_min, admitted, reason) = state
        e = req_ent[i]
        tok = req_tokens[i]
        kvn = req_kv[i]
        w = weights[e]

        ok_bound = arr.bound[e]
        r_lim = arr.baseline_conc[e]
        # spot with no explicit limit is bounded by pool concurrency
        is_spot = arr.class_code[e] == CLASS_CODES[ServiceClass.SPOT]
        r_eff = jnp.where((r_lim <= 0) & is_spot, pool_conc_cap, r_lim)
        ok_conc = (r_eff <= 0) | (infl[e] < r_eff)
        ok_budget = bucket[e] >= tok
        chi = arr.baseline_kv[e]
        ok_kv = (chi <= 0) | (kv[e] + kvn <= chi)
        contended = pool_infl > pool_conc_cap
        shielded = _PROTECTED[arr.class_code[e]]
        ok_prio = shielded | ~contended | (w > run_min * (1.0 - slack))

        admit = ok_bound & ok_conc & ok_budget & ok_kv & ok_prio
        reason_i = jnp.where(
            ~ok_bound, 1,
            jnp.where(~ok_conc, 2,
                      jnp.where(~(ok_budget & ok_kv), 3,
                                jnp.where(~ok_prio, 4, 0))))

        bucket = bucket.at[e].add(jnp.where(admit, -tok, 0.0))
        # NOTE: `infl` counts RESIDENT sequences (check 3).  Admission
        # alone does not make a request resident — dispatch does — so
        # within one quantum the resident counts are frozen; only the
        # pool-level admitted count moves (contention, check 5).
        kv = kv.at[e].add(jnp.where(admit, kvn, 0.0))
        pool_infl = pool_infl + jnp.where(admit, 1, 0)
        run_min = jnp.where(admit, jnp.minimum(run_min, w), run_min)
        admitted = admitted.at[i].set(admit)
        reason = reason.at[i].set(reason_i)
        return (bucket, infl, kv, pool_infl, run_min, admitted, reason)

    state0 = (bucket_level, in_flight, kv_in_use, pool_in_flight,
              running_min_priority,
              jnp.zeros((M,), dtype=bool), jnp.zeros((M,), dtype=jnp.int32))
    out = jax.lax.fori_loop(0, M, body, state0)
    return out[5], out[6]


def arrays_from_pool(pool) -> tuple[PoolArrays, jax.Array, jax.Array, jax.Array]:
    """Bridge: snapshot a scalar ``TokenPool`` into array form.
    Returns (PoolArrays, bucket_levels, in_flight, kv_in_use) with rows
    in sorted-entitlement-name order."""
    names = sorted(pool.entitlements)
    from repro.core.types import EntitlementState
    cc, bound, btps, bkv, bconc, slo, burst, debt = [], [], [], [], [], [], [], []
    levels, infl, kvu = [], [], []
    for n in names:
        e, s = pool.entitlements[n], pool.status[n]
        cc.append(CLASS_CODES[e.qos.service_class])
        bound.append(s.state == EntitlementState.BOUND)
        btps.append(e.baseline.tokens_per_second)
        bkv.append(e.baseline.kv_bytes)
        bconc.append(e.baseline.concurrency)
        slo.append(e.qos.slo_target_ms)
        burst.append(s.burst)
        debt.append(s.debt)
        levels.append(pool.ledger.ensure(
            n, e.baseline.tokens_per_second, 0.0).level)
        infl.append(s.resident)          # check 3 counts resident seqs
        kvu.append(s.kv_bytes_in_use)
    arr = PoolArrays(
        class_code=jnp.array(cc, dtype=jnp.int32),
        bound=jnp.array(bound),
        baseline_tps=jnp.array(btps, dtype=jnp.float32),
        baseline_kv=jnp.array(bkv, dtype=jnp.float32),
        baseline_conc=jnp.array(bconc, dtype=jnp.float32),
        slo_ms=jnp.array(slo, dtype=jnp.float32),
        burst=jnp.array(burst, dtype=jnp.float32),
        debt=jnp.array(debt, dtype=jnp.float32),
    )
    return (arr, jnp.array(levels, dtype=jnp.float32),
            jnp.array(infl, dtype=jnp.int32),
            jnp.array(kvu, dtype=jnp.float32))
