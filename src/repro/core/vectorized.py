"""Vectorized admission path + back-compat shims over the unified
control plane.

The tick math that used to live here is now THE control plane
(``core.control_plane``) — ``TokenPool.tick`` and ``PoolManager.tick``
execute it directly.  This module keeps:

- :func:`admit_quantum` — exact sequential admission replay for one
  scheduling quantum as a jit-compiled ``lax.fori_loop`` (used for
  offline replay / throughput benchmarking of the §4.3 pipeline);
- :func:`arrays_from_pool` — bridge snapshotting a scalar ``TokenPool``
  into array form;
- aliases (``PoolArrays``, ``tick_batch``, ``waterfill_batch``, …) so
  existing imports keep working.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.control_plane import (
    BURSTOK_MASK as _BURSTOK,
    CLASS_CODES,
    CLASS_W as _W,
    ControlState,
    DEBTOK_MASK as _DEBTOK,
    ELASTIC_MASK as _ELASTIC,
    PROTECTED_MASK as _PROTECTED,
    allocate_rows as allocate_tps_batch,
    burst_delta_rows as burst_delta_batch,
    control_tick,
    ewma,
    priority_rows as priority_batch,
    waterfill_rows as waterfill_batch,
)
from repro.core.types import PriorityCoefficients, ServiceClass

#: Back-compat name: the array-of-rows state is the ControlState.
PoolArrays = ControlState


@partial(jax.jit, static_argnames=("coeff",))
def tick_batch(arr: ControlState, capacity_tps: jax.Array,
               measured_tps: jax.Array, used_kv: jax.Array,
               used_conc: jax.Array, demand_tps: jax.Array,
               coeff: PriorityCoefficients = PriorityCoefficients(),
               ) -> tuple[ControlState, jax.Array, jax.Array]:
    """Legacy entry point: one tick with ℓ̄* computed as the live mean
    over bound rows (``control_tick`` takes it explicitly instead, so
    the pool can pin it via ``PoolSpec.fixed_avg_slo_ms``)."""
    n_bound = jnp.maximum(jnp.sum(arr.bound), 1)
    avg_slo = jnp.sum(jnp.where(arr.bound, arr.slo_ms, 0.0)) / n_bound
    return control_tick(arr, capacity_tps, measured_tps, used_kv,
                        used_conc, demand_tps,
                        jnp.maximum(avg_slo, 1e-9), coeff=coeff)


@partial(jax.jit, static_argnames=("coeff", "slack"))
def admit_quantum(arr: ControlState,
                  bucket_level: jax.Array,       # f32 [N] tokens available
                  in_flight: jax.Array,          # i32 [N] RESIDENT seqs
                  kv_in_use: jax.Array,          # f32 [N]
                  pool_in_flight: jax.Array,     # i32 []
                  pool_conc_cap: jax.Array,      # f32 []
                  running_min_priority: jax.Array,  # f32 [] (inf if none)
                  pool_avg_slo: jax.Array,       # f32 []
                  req_ent: jax.Array,            # i32 [M] entitlement row
                  req_tokens: jax.Array,         # f32 [M] input+max_tokens
                  req_kv: jax.Array,             # f32 [M] kv bytes needed
                  coeff: PriorityCoefficients = PriorityCoefficients(),
                  slack: float = 0.0,
                  ) -> tuple[jax.Array, jax.Array]:
    """Exact sequential admission replay for one scheduling quantum.

    Requests are processed in array order (arrival order).  Returns
    (admitted bool [M], deny_reason int [M]) with reason codes:
    0=admitted, 1=not_bound, 2=concurrency, 3=token_budget, 4=low_priority.
    State updates (bucket charge, in-flight increments, running-min
    threshold) are applied between requests exactly as the scalar
    controller does — but inside one fused XLA loop.
    """
    M = req_ent.shape[0]
    weights = priority_batch(arr, pool_avg_slo, coeff)

    def body(i, state):
        (bucket, infl, kv, pool_infl, run_min, admitted, reason) = state
        e = req_ent[i]
        tok = req_tokens[i]
        kvn = req_kv[i]
        w = weights[e]

        ok_bound = arr.bound[e]
        r_lim = arr.baseline_conc[e]
        # spot with no explicit limit is bounded by pool concurrency
        is_spot = arr.class_code[e] == CLASS_CODES[ServiceClass.SPOT]
        r_eff = jnp.where((r_lim <= 0) & is_spot, pool_conc_cap, r_lim)
        ok_conc = (r_eff <= 0) | (infl[e] < r_eff)
        ok_budget = bucket[e] >= tok
        chi = arr.baseline_kv[e]
        ok_kv = (chi <= 0) | (kv[e] + kvn <= chi)
        contended = pool_infl > pool_conc_cap
        shielded = _PROTECTED[arr.class_code[e]]
        ok_prio = shielded | ~contended | (w > run_min * (1.0 - slack))

        admit = ok_bound & ok_conc & ok_budget & ok_kv & ok_prio
        reason_i = jnp.where(
            ~ok_bound, 1,
            jnp.where(~ok_conc, 2,
                      jnp.where(~(ok_budget & ok_kv), 3,
                                jnp.where(~ok_prio, 4, 0))))

        bucket = bucket.at[e].add(jnp.where(admit, -tok, 0.0))
        # NOTE: `infl` counts RESIDENT sequences (check 3).  Admission
        # alone does not make a request resident — dispatch does — so
        # within one quantum the resident counts are frozen; only the
        # pool-level admitted count moves (contention, check 5).
        kv = kv.at[e].add(jnp.where(admit, kvn, 0.0))
        pool_infl = pool_infl + jnp.where(admit, 1, 0)
        run_min = jnp.where(admit, jnp.minimum(run_min, w), run_min)
        admitted = admitted.at[i].set(admit)
        reason = reason.at[i].set(reason_i)
        return (bucket, infl, kv, pool_infl, run_min, admitted, reason)

    state0 = (bucket_level, in_flight, kv_in_use, pool_in_flight,
              running_min_priority,
              jnp.zeros((M,), dtype=bool), jnp.zeros((M,), dtype=jnp.int32))
    out = jax.lax.fori_loop(0, M, body, state0)
    return out[5], out[6]


def arrays_from_pool(pool) -> tuple[ControlState, jax.Array, jax.Array,
                                    jax.Array]:
    """Bridge: snapshot a scalar ``TokenPool`` into array form.
    Returns (ControlState, bucket_levels, in_flight, kv_in_use) with
    rows in sorted-entitlement-name order (the pool's own row order)."""
    names = sorted(pool.entitlements)
    from repro.core.types import EntitlementState
    cc, bound, btps, bkv, bconc, slo, burst, debt = [], [], [], [], [], [], [], []
    levels, infl, kvu = [], [], []
    for n in names:
        e, s = pool.entitlements[n], pool.status[n]
        cc.append(CLASS_CODES[e.qos.service_class])
        bound.append(s.state == EntitlementState.BOUND)
        btps.append(e.baseline.tokens_per_second)
        bkv.append(e.baseline.kv_bytes)
        bconc.append(e.baseline.concurrency)
        slo.append(e.qos.slo_target_ms)
        burst.append(s.burst)
        debt.append(s.debt)
        levels.append(pool.ledger.ensure(
            n, e.baseline.tokens_per_second, 0.0).level)
        infl.append(s.resident)          # check 3 counts resident seqs
        kvu.append(s.kv_bytes_in_use)
    arr = ControlState(
        class_code=jnp.array(cc, dtype=jnp.int32),
        bound=jnp.array(bound),
        baseline_tps=jnp.array(btps, dtype=jnp.float32),
        baseline_kv=jnp.array(bkv, dtype=jnp.float32),
        baseline_conc=jnp.array(bconc, dtype=jnp.float32),
        slo_ms=jnp.array(slo, dtype=jnp.float32),
        burst=jnp.array(burst, dtype=jnp.float32),
        debt=jnp.array(debt, dtype=jnp.float32),
    )
    return (arr, jnp.array(levels, dtype=jnp.float32),
            jnp.array(infl, dtype=jnp.int32),
            jnp.array(kvu, dtype=jnp.float32))
