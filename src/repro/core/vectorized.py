"""Vectorized admission path + back-compat shims over the unified
control plane.

The tick math that used to live here is now THE control plane
(``core.control_plane``) — ``TokenPool.tick`` and ``PoolManager.tick``
execute it directly.  This module keeps:

- :func:`admit_quantum` — exact sequential admission replay for one
  scheduling quantum as a jit-compiled ``lax.fori_loop``: this IS the
  gateway's default request path (``Gateway.handle_quantum`` batches
  each (pool, leg) group through one dispatch);
- :func:`arrays_from_pool` / :func:`quantum_snapshot` — O(1) views
  over a ``TokenPool``'s RESIDENT arrays (``core.resident``): the
  kernel state is the store's cached device mirror and bucket levels
  are one vectorized projection, with nothing mutated and nothing
  gathered per row;
- aliases (``PoolArrays``, ``tick_batch``, ``waterfill_batch``, …) so
  existing imports keep working.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.control_plane import (
    BURSTOK_MASK as _BURSTOK,
    CLASS_CODES,
    CLASS_W as _W,
    ControlState,
    DEBTOK_MASK as _DEBTOK,
    ELASTIC_MASK as _ELASTIC,
    PROTECTED_MASK as _PROTECTED,
    allocate_rows as allocate_tps_batch,
    burst_delta_rows as burst_delta_batch,
    control_tick,
    ewma,
    priority_rows as priority_batch,
    waterfill_rows as waterfill_batch,
)
from repro.core.markers import kernel
from repro.core.types import PriorityCoefficients, ServiceClass

#: Back-compat name: the array-of-rows state is the ControlState.
PoolArrays = ControlState


@kernel(oracle="repro.core.pool.TokenPool.tick")
@partial(jax.jit, static_argnames=("coeff",))
def tick_batch(arr: ControlState, capacity_tps: jax.Array,
               measured_tps: jax.Array, used_kv: jax.Array,
               used_conc: jax.Array, demand_tps: jax.Array,
               coeff: PriorityCoefficients = PriorityCoefficients(),
               ) -> tuple[ControlState, jax.Array, jax.Array]:
    """Legacy entry point: one tick with ℓ̄* computed as the live mean
    over bound rows (``control_tick`` takes it explicitly instead, so
    the pool can pin it via ``PoolSpec.fixed_avg_slo_ms``)."""
    n_bound = jnp.maximum(jnp.sum(arr.bound), 1)
    avg_slo = jnp.sum(jnp.where(arr.bound, arr.slo_ms, 0.0)) / n_bound
    return control_tick(arr, capacity_tps, measured_tps, used_kv,
                        used_conc, demand_tps,
                        jnp.maximum(avg_slo, 1e-9), coeff=coeff)


@kernel(oracle="repro.core.admission.AdmissionController.decide")
@partial(jax.jit, static_argnames=("coeff", "slack"))
def admit_quantum(arr: ControlState,
                  bucket_level: jax.Array,       # f32 [N] tokens available
                  in_flight: jax.Array,          # i32 [N] RESIDENT seqs
                  kv_in_use: jax.Array,          # f32 [N]
                  pool_in_flight: jax.Array,     # i32 []
                  pool_conc_cap: jax.Array,      # f32 []
                  running_min_priority: jax.Array,  # f32 [] (inf if none)
                  pool_avg_slo: jax.Array,       # f32 []
                  req_ent: jax.Array,            # i32 [M] entitlement row
                  req_tokens: jax.Array,         # f32 [M] input+max_tokens
                  req_kv: jax.Array,             # f32 [M] kv bytes needed
                  pool_resident: jax.Array = None,  # i32 [] RESIDENT seqs
                  req_live: Optional[jax.Array] = None,  # bool [M] padding
                  weights: Optional[jax.Array] = None,   # f32 [N] Eq. 1
                  coeff: PriorityCoefficients = PriorityCoefficients(),
                  slack: float = 0.0,
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Exact sequential admission replay for one scheduling quantum.

    Requests are processed in array order (arrival order).  Returns
    (admitted bool [M], deny_reason int [M], priority f32 [M]) with
    reason codes: 0=admitted, 1=not_bound, 2=concurrency,
    3=token_budget, 4=low_priority.  State updates (bucket charge,
    in-flight increments, running-min threshold) are applied between
    requests exactly as the scalar controller does — but inside one
    fused XLA loop.

    ``running_min_priority`` must be seeded with the LIVE priorities of
    the entitlements that currently own in-flight requests (what
    ``TokenPool.admission_threshold`` computes — use
    :func:`running_min_live`), not the stale per-record snapshots;
    ``pool_resident`` is the pool-wide count of RESIDENT sequences
    (frozen within a quantum — admission does not place KV, dispatch
    does) feeding the burst-class free-slot escape of check 3.
    ``req_live=False`` marks padding rows: they are denied without
    touching any state, so quanta can be padded to a power-of-two
    length without retracing or perturbing the replay.  Pass the
    snapshot's ``weights`` (``QuantumSnapshot.weights``) to reuse the
    Eq. 1 row weights the ``running_min_priority`` seed was computed
    from — the SAME array makes self-threshold ties bit-exact by
    construction; when omitted they are recomputed here.
    """
    from repro.core.control_plane import TRACE_COUNTS
    TRACE_COUNTS["admit_quantum"] += 1         # repro: allow[retrace-hazard] -- trace-time counter: runs only while compiling, counts variants
    M = req_ent.shape[0]
    if pool_resident is None:
        # legacy callers: no resident count ⇒ no free-slot escape
        pool_resident = jnp.asarray(pool_conc_cap, jnp.float32)
    if weights is None:
        weights = priority_batch(arr, pool_avg_slo, coeff)

    def body(i, state):
        (bucket, infl, kv, pool_infl, run_min, admitted, reason) = state
        e = req_ent[i]
        tok = req_tokens[i]
        kvn = req_kv[i]
        w = weights[e]

        ok_bound = arr.bound[e]
        r_lim = arr.baseline_conc[e]
        # spot with no explicit limit is bounded by pool concurrency
        is_spot = arr.class_code[e] == CLASS_CODES[ServiceClass.SPOT]
        r_eff = jnp.where((r_lim <= 0) & is_spot, pool_conc_cap, r_lim)
        # Burst-capable classes (Table 1) may exceed r_e while the pool
        # has idle decode slots and nobody is waiting — the concurrency
        # dimension of work-conserving backfill (scalar check 3's
        # BURST_CLASSES escape; the overage then raises b_e and lowers
        # their priority).  Resident counts are frozen within a quantum,
        # but contention evolves with the admitted count below.
        burst_escape = (_BURSTOK[arr.class_code[e]]
                        & (pool_resident < pool_conc_cap)
                        & ~(pool_infl > pool_conc_cap))
        ok_conc = (r_eff <= 0) | (infl[e] < r_eff) | burst_escape
        ok_budget = bucket[e] >= tok
        chi = arr.baseline_kv[e]
        ok_kv = (chi <= 0) | (kv[e] + kvn <= chi)
        contended = pool_infl > pool_conc_cap
        shielded = _PROTECTED[arr.class_code[e]]
        ok_prio = shielded | ~contended | (w > run_min * (1.0 - slack))

        live = (jnp.bool_(True) if req_live is None else req_live[i])
        admit = live & ok_bound & ok_conc & ok_budget & ok_kv & ok_prio
        reason_i = jnp.where(
            ~ok_bound, 1,
            jnp.where(~ok_conc, 2,
                      jnp.where(~(ok_budget & ok_kv), 3,
                                jnp.where(~ok_prio, 4, 0))))

        bucket = bucket.at[e].add(jnp.where(admit, -tok, 0.0))
        # NOTE: `infl` counts RESIDENT sequences (check 3).  Admission
        # alone does not make a request resident — dispatch does — so
        # within one quantum the resident counts are frozen; only the
        # pool-level admitted count moves (contention, check 5).
        kv = kv.at[e].add(jnp.where(admit, kvn, 0.0))
        pool_infl = pool_infl + jnp.where(admit, 1, 0)
        run_min = jnp.where(admit, jnp.minimum(run_min, w), run_min)
        admitted = admitted.at[i].set(admit)
        reason = reason.at[i].set(reason_i)
        return (bucket, infl, kv, pool_infl, run_min, admitted, reason)

    state0 = (bucket_level, in_flight, kv_in_use, pool_in_flight,
              running_min_priority,
              jnp.zeros((M,), dtype=bool), jnp.zeros((M,), dtype=jnp.int32))
    out = jax.lax.fori_loop(0, M, body, state0)
    return out[5], out[6], weights[req_ent]


def arrays_from_pool(pool, now: float = 0.0
                     ) -> tuple[ControlState, jax.Array, jax.Array,
                                jax.Array]:
    """Bridge: view a ``TokenPool``'s RESIDENT arrays in kernel form.
    Returns (ControlState, bucket_levels, in_flight, kv_in_use) with
    rows in resident-slot order (``pool.store.slot_of`` maps names to
    rows); free slots ride along as inert unbound rows, so the width
    is the store's pow2 capacity and never retraces the kernels.

    Pure read: bucket levels are projected to ``now`` with one
    vectorized ``Ledger.peek_levels`` expression — snapshotting
    neither creates buckets nor advances refill clocks, so observing a
    pool cannot change any later admission decision.  The
    ``ControlState`` is the store's cached device mirror: after a tick
    this is O(1) Python (no per-row gather)."""
    import numpy as np

    c = pool.store.col
    # scalar fallback rate for bucketless rows: effective-or-baseline,
    # the same `eff or baseline` rule the scalar §4.3 pipeline applies
    fallback = np.where(c["eff_tps"] != 0.0, c["eff_tps"],
                        c["baseline_tps"].astype(np.float64))
    levels = pool.ledger.peek_levels(fallback, now)
    return (pool.store.device_state(),
            jnp.asarray(levels.astype(np.float32)),
            jnp.asarray(c["resident"].astype(np.int32)),
            jnp.asarray(c["kv_in_use"].astype(np.float32)))


def running_min_live(pool) -> float:
    """Seed for ``running_min_priority``: the minimum LIVE priority
    among entitlements that currently own in-flight requests — exactly
    what ``TokenPool.admission_threshold`` evaluates when the pool is
    contended (debt/burst evolve after admission, so per-record
    priority snapshots would overstate the threshold).  +inf when the
    pool is empty.

    Scalar-oracle form (float64); :func:`quantum_snapshot` seeds the
    kernel with the float32 equivalent instead so a request whose OWN
    entitlement sets the threshold ties bit-exactly inside the kernel
    (the strict ``>`` of check 5 must not flip on a 1-ulp precision
    gap between the seed and the kernel's weight)."""
    owners = {r.entitlement for r in pool.in_flight.values()}
    ws = [pool.priority(e) for e in owners if e in pool.entitlements]
    return min(ws) if ws else float("inf")


def _running_min_f32(pool, weights: jax.Array,
                     row_of: dict[str, int]) -> float:
    """float32 twin of :func:`running_min_live`, evaluated on the SAME
    Eq. 1 weight array handed to ``admit_quantum`` — one computation
    serves both the seed and the kernel, so a request whose own
    entitlement sets the threshold ties bit-exactly.

    Owner rows come straight off the request table's owner column
    (``np.unique`` — already the sorted distinct slot list) instead of
    a per-record Python set walk; owner slots ARE store row indices,
    which is what ``weights`` is indexed by."""
    rows = pool.inflight_owner_slots()
    if not rows.size:
        return float("inf")
    return float(jnp.min(weights[jnp.asarray(rows, jnp.int32)]))


@dataclasses.dataclass
class QuantumSnapshot:
    """Everything ``admit_quantum`` needs about one pool, snapshotted
    once per (pool, leg) batch by the gateway.  ``row_of`` maps
    entitlement name → row index in the arrays; ``weights`` holds the
    Eq. 1 row weights (pass them back to ``admit_quantum`` so the
    kernel and the ``running_min_priority`` seed share one array)."""

    names: list[str]
    row_of: dict[str, int]
    state: ControlState
    bucket_level: jax.Array
    in_flight: jax.Array
    kv_in_use: jax.Array
    weights: jax.Array
    pool_in_flight: int
    pool_resident: int
    pool_conc_cap: float
    running_min_priority: float
    pool_avg_slo: float


def quantum_snapshot(pool, now: float) -> QuantumSnapshot:
    """Snapshot a ``TokenPool`` for one batched admission quantum.
    Pure read (see :func:`arrays_from_pool`): the state arrays are
    views of the pool's resident arrays — no per-row Python gather
    (the name→slot map and name list are C-speed container copies, so
    a held snapshot stays internally consistent even if membership
    churns after it was taken)."""
    state, levels, infl, kvu = arrays_from_pool(pool, now)
    row_of = dict(pool.store.slot_of)
    avg_slo = float(pool.pool_avg_slo())
    weights = priority_batch(state, jnp.float32(avg_slo),
                             pool.spec.coefficients)
    return QuantumSnapshot(
        names=list(pool.store.live_names()),
        row_of=row_of,
        state=state,
        bucket_level=levels,
        in_flight=infl,
        kv_in_use=kvu,
        weights=weights,
        pool_in_flight=pool.pool_in_flight(),
        pool_resident=pool.total_resident(),
        pool_conc_cap=float(pool.capacity().concurrency),
        running_min_priority=_running_min_f32(pool, weights, row_of),
        pool_avg_slo=avg_slo,
    )
