"""Sharded control plane — ``shard_map`` row-axis partitioning of the
fused kernels for 10^7+ entitlements.

The single-device tick costs ~154 ms at 1M rows (``BENCH_tick.json``)
and scales linearly in the row count: past a few million entitlements
the row axis is the wall.  This module wraps the SAME kernel bodies in
``shard_map`` over a 1-D device mesh (axis ``"rows"``):

* every per-row quantity (burst EWMA, Eq. 1 weights, debt gap, the
  water-filling want/take vectors) is computed on the device that owns
  the row block — elementwise math shards embarrassingly;
* only the pool-level aggregates the math genuinely couples cross the
  mesh: the protected reserved floor, the water-filling round totals
  (active weight / count / filled), the demand remainder, and the
  admission quantum's per-request row gathers — each an ``all_gather``
  of S scalars (or one psum of one-hot request contributions);
* decisions are BIT-IDENTICAL to the single-device kernels: the row
  reductions in ``control_plane`` use a fixed positional binary tree
  (``tree_sum``/``tree_any``), so per-shard subtrees + the top tree
  over the gathered shard roots reproduce the exact single-device adds
  in the exact same order (see the shard-stable reduction note there).
  ``tests/test_shard_plane.py`` pins single-device == multi-device ==
  scalar oracle on a forced-host CPU mesh
  (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

Admission (``shard_admit_quantum``) splits into the part that scales
with rows and the part that scales with requests: the O(N) work — Eq. 1
weights and the per-request row gathers — runs sharded, then the
inherently sequential O(M) replay runs replicated on a COMPACTED state
(each request's row remapped to a dense id in request space) through
the unmodified ``admit_quantum`` body, so the sequential decision
stream is the same f32 adds in the same order by construction.

Churn stays device-local through ``ShardedResidentStore``
(``core.resident``): per-shard free lists and per-shard device-mirror
blocks mean entitlement add/remove re-uploads one block, not the pool.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.control_plane import (
    TRACE_COUNTS,
    ControlState,
    _tick_impl,
    bucket_width,
    priority_rows,
)
from repro.core.markers import kernel
from repro.core.types import PriorityCoefficients
from repro.core.vectorized import admit_quantum

#: the one mesh axis of the control plane — entitlement rows.
AXIS = "rows"

#: mesh cache: ``Mesh`` is a static jit argument, so every call site
#: must present the SAME object per device count or the dispatch cache
#: fragments (the sanitizer's retrace pass flags inline ``Mesh(...)``
#: construction at shard-kernel call sites for exactly this reason).
_MESH_CACHE: dict[int, Mesh] = {}


def _pow2_floor(n: int) -> int:
    return 1 << (max(1, n).bit_length() - 1)


def row_mesh(n_devices: Optional[int] = None) -> Mesh:
    """The cached 1-D ``rows`` mesh over ``n_devices`` devices (default:
    the largest power of two the backend offers).  Forced-host CPU
    meshes (``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
    come through here exactly like real accelerator meshes."""
    devs = jax.devices()
    if n_devices is None:
        n_devices = _pow2_floor(len(devs))
    if n_devices > len(devs):
        raise ValueError(
            f"row_mesh({n_devices}) exceeds {len(devs)} visible devices")
    if n_devices & (n_devices - 1):
        raise ValueError(f"mesh size must be a power of two, got "
                         f"{n_devices}")
    mesh = _MESH_CACHE.get(n_devices)
    if mesh is None:
        mesh = Mesh(np.array(devs[:n_devices]), (AXIS,))
        _MESH_CACHE[n_devices] = mesh
    return mesh


def shard_width(n_rows: int, mesh: Mesh) -> int:
    """Row pad width for a sharded dispatch: the pow2 bucket_width,
    floored at the mesh size so every device owns an equal (pow2)
    block.  Equal pow2 blocks are what make the tree reductions
    decompose exactly (and what ``shard_map`` requires)."""
    return max(bucket_width(n_rows), mesh.size)


def pool_mesh(pool) -> Optional[Mesh]:
    """The mesh a pool's tick/admission should dispatch on, or None to
    stay single-device: requires a ``ShardedResidentStore`` (per-shard
    free lists keep churn device-local) and ≥2 devices; the mesh never
    exceeds the store's shard count, so device blocks align with
    free-list shards."""
    shards = getattr(pool.store, "n_shards", 0)
    if shards < 2:
        return None
    size = min(_pow2_floor(len(jax.devices())), shards)
    if size < 2:
        return None
    return row_mesh(size)


# -- the sharded tick ---------------------------------------------------------

@kernel(oracle="repro.core.control_plane.control_tick")
@partial(jax.jit, static_argnames=("coeff", "mesh"))
def shard_tick(state: ControlState, capacity_tps: jax.Array,
               measured_tps: jax.Array, used_kv: jax.Array,
               used_conc: jax.Array, demand_tps: jax.Array,
               avg_slo_ms: jax.Array,
               coeff: PriorityCoefficients = PriorityCoefficients(),
               *, mesh: Mesh,
               ) -> tuple[ControlState, jax.Array, jax.Array]:
    """:func:`control_plane.control_tick` under ``shard_map``: row
    arrays split into per-device blocks, pool scalars replicated, the
    shared ``_tick_impl`` body run per block with ``axis_name`` set so
    its tree reductions combine across the mesh.  Row count must be a
    multiple of the mesh size (use :func:`shard_width`).  Output state,
    allocations and weights come back row-sharded; decisions are
    bit-identical to the single-device kernel."""
    TRACE_COUNTS["shard_tick"] += 1            # repro: allow[retrace-hazard] -- trace-time counter: runs only while compiling, counts variants

    def block(s, cap, m, kv, conc, d, slo):
        return _tick_impl(s, cap, m, kv, conc, d, slo, coeff,
                          axis_name=AXIS)

    row, rep = P(AXIS), P()
    return shard_map(
        block, mesh=mesh,
        in_specs=(row, rep, row, row, row, row, rep),
        out_specs=(row, row, row),
        check_rep=False,
    )(state, capacity_tps, measured_tps, used_kv, used_conc,
      demand_tps, avg_slo_ms)


# -- the sharded admission quantum --------------------------------------------

def _one_hot_gather(own, li, col):
    """Gather ``col[li]`` where this shard owns the row, summed across
    shards: exactly one shard contributes each element (the rest add
    zero — exact for f32), so the psum IS the global gather."""
    v = col[li]
    squeeze_bool = v.dtype == jnp.bool_
    if squeeze_bool:
        v = v.astype(jnp.int32)
    out = jax.lax.psum(jnp.where(own, v, jnp.zeros_like(v)), AXIS)
    return out.astype(bool) if squeeze_bool else out


def _gather_block(state, bucket, infl, kv, w_rows, ents):
    """One shard's half of the admission quantum: dense per-request
    gathers of every row quantity the sequential replay reads."""
    idx = jax.lax.axis_index(AXIS)
    n_local = state.class_code.shape[0]
    loc = ents - idx * n_local
    own = (loc >= 0) & (loc < n_local)
    li = jnp.clip(loc, 0, n_local - 1)
    g = partial(_one_hot_gather, own, li)
    return (g(w_rows), g(state.bound), g(state.class_code),
            g(state.baseline_conc), g(state.baseline_kv),
            g(bucket), g(infl), g(kv))


def _gather_compute_block(state, bucket, infl, kv, avg_slo, ents,
                          *, coeff):
    """Gather block that also computes the Eq. 1 weights on the shard
    (elementwise → bitwise equal to the single-device computation)."""
    w_rows = priority_rows(state, avg_slo, coeff)
    return _gather_block(state, bucket, infl, kv, w_rows, ents)


@kernel(oracle="repro.core.vectorized.admit_quantum")
@partial(jax.jit, static_argnames=("coeff", "slack", "mesh"))
def shard_admit_quantum(arr: ControlState,
                        bucket_level: jax.Array,      # f32 [N]
                        in_flight: jax.Array,         # i32 [N]
                        kv_in_use: jax.Array,         # f32 [N]
                        pool_in_flight: jax.Array,    # i32 []
                        pool_conc_cap: jax.Array,     # f32 []
                        running_min_priority: jax.Array,  # f32 []
                        pool_avg_slo: jax.Array,      # f32 []
                        req_ent: jax.Array,           # i32 [M]
                        req_tokens: jax.Array,        # f32 [M]
                        req_kv: jax.Array,            # f32 [M]
                        pool_resident: jax.Array = None,   # i32 []
                        req_live: Optional[jax.Array] = None,  # bool [M]
                        weights: Optional[jax.Array] = None,   # f32 [N]
                        coeff: PriorityCoefficients = PriorityCoefficients(),
                        slack: float = 0.0,
                        *, mesh: Mesh,
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """:func:`vectorized.admit_quantum` with the row axis sharded.

    The O(N) half — Eq. 1 weights (when not passed) and the per-request
    row gathers — runs under ``shard_map``; the O(M) sequential replay
    then runs replicated on a request-space COMPACTION of the touched
    rows: ``req_ent`` is remapped to dense ids (``jnp.unique`` over the
    static quantum width), the gathered row state is scattered into
    [M]-wide arrays, and the unmodified :func:`admit_quantum` body
    replays the quantum on them.  Every value the replay reads and
    every f32 update it applies is element-for-element the same as the
    single-device kernel's, in the same order — decisions, deny
    reasons and returned priorities are bit-identical."""
    TRACE_COUNTS["shard_admit_quantum"] += 1   # repro: allow[retrace-hazard] -- trace-time counter: runs only while compiling, counts variants
    n_requests = req_ent.shape[0]
    if pool_resident is None:
        pool_resident = jnp.asarray(pool_conc_cap, jnp.float32)

    row, rep = P(AXIS), P()
    if weights is None:
        gathered = shard_map(
            partial(_gather_compute_block, coeff=coeff), mesh=mesh,
            in_specs=(row, row, row, row, rep, rep),
            out_specs=rep, check_rep=False,
        )(arr, bucket_level, in_flight, kv_in_use, pool_avg_slo, req_ent)
    else:
        gathered = shard_map(
            _gather_block, mesh=mesh,
            in_specs=(row, row, row, row, row, rep),
            out_specs=rep, check_rep=False,
        )(arr, bucket_level, in_flight, kv_in_use, weights, req_ent)
    (req_w, bound_g, class_g, bconc_g, bkv_g,
     bucket_g, infl_g, kv_g) = gathered

    # compact the touched rows into request space: at most M distinct
    # rows appear in a quantum, so the replicated replay never touches
    # an [N] array — its width is the (already padded) quantum width.
    _, inverse = jnp.unique(req_ent, size=n_requests, fill_value=0,
                            return_inverse=True)
    cids = inverse.reshape(n_requests).astype(jnp.int32)

    def scatter(vals, dtype):
        # duplicate ids write identical values — deterministic
        return jnp.zeros((n_requests,), dtype).at[cids].set(
            vals.astype(dtype))

    zeros_f = jnp.zeros((n_requests,), jnp.float32)
    arr_c = ControlState(
        class_code=scatter(class_g, jnp.int32),
        bound=scatter(bound_g, bool),
        baseline_tps=zeros_f,
        baseline_kv=scatter(bkv_g, jnp.float32),
        baseline_conc=scatter(bconc_g, jnp.float32),
        slo_ms=jnp.ones((n_requests,), jnp.float32),
        burst=zeros_f,
        debt=zeros_f,
    )
    return admit_quantum(
        arr_c,
        scatter(bucket_g, jnp.float32),
        scatter(infl_g, jnp.int32),
        scatter(kv_g, jnp.float32),
        pool_in_flight, pool_conc_cap, running_min_priority,
        pool_avg_slo, cids, req_tokens, req_kv,
        pool_resident=pool_resident, req_live=req_live,
        weights=scatter(req_w, jnp.float32),
        coeff=coeff, slack=slack)


# -- the sharded fleet plan ---------------------------------------------------

@kernel(oracle="repro.core.fleet.plan_fleet")
@partial(jax.jit, static_argnames=("config", "mesh"))
def shard_plan_fleet(current: jax.Array, lo: jax.Array, hi: jax.Array,
                     per_tps: jax.Array, per_kv: jax.Array,
                     per_conc: jax.Array, res_tps: jax.Array,
                     res_kv: jax.Array, res_conc: jax.Array,
                     demand_tps: jax.Array, ewma_prev: jax.Array,
                     seeded: jax.Array, low_ticks: jax.Array,
                     config=None,
                     *, mesh: Mesh,
                     ) -> tuple[jax.Array, jax.Array, jax.Array,
                                jax.Array, jax.Array]:
    """:func:`fleet.plan_fleet` with the POOL axis sharded.  The scale
    policy is per-pool elementwise (no cross-pool reduction), so each
    device plans its block independently — trivially bit-identical;
    the rebalancer's cross-pool matching stays host-side."""
    TRACE_COUNTS["shard_plan_fleet"] += 1      # repro: allow[retrace-hazard] -- trace-time counter: runs only while compiling, counts variants
    # deferred: fleet → autoscaler → pool → shard_plane would cycle at
    # module import time; resolved once per trace, never per dispatch
    from repro.core.fleet import FleetPlannerConfig, plan_fleet
    if config is None:
        config = FleetPlannerConfig()

    def block(c, l, h, pt, pk, pc, rt, rk, rc, d, e, s, lt):
        return plan_fleet(c, l, h, pt, pk, pc, rt, rk, rc, d, e, s, lt,
                          config=config)

    row = P(AXIS)
    return shard_map(
        block, mesh=mesh,
        in_specs=tuple([row] * 13),
        out_specs=tuple([row] * 5),
        check_rep=False,
    )(current, lo, hi, per_tps, per_kv, per_conc,
      res_tps, res_kv, res_conc, demand_tps, ewma_prev, seeded,
      low_ticks)
