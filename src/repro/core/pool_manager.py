"""PoolManager — the multi-pool control plane.

Related work treats the *pool* as the unit of cost-efficient serving
(Token-Budget-Aware Pool Routing, arXiv:2604.09613; Dual-Pool
Token-Budget Routing, arXiv:2604.08075): a platform runs several
TokenPools (different models, hardware classes, or regions) and an API
key maps to an ORDERED list of (pool, entitlement) legs with spill-over
— a request denied by its preferred pool may be served by a cheaper /
less-loaded one instead of bouncing a 429 back to the client.

This module provides the two multi-pool layers on top of the unified
control plane:

1. **Batched accounting** — ``PoolManager.tick`` gathers every pool's
   entitlement rows, stacks them along a pool axis (padding narrower
   pools with inert unbound rows), and executes
   ``control_plane.control_tick_pools`` — ONE fused vmapped dispatch
   for the whole fleet.  Pools with different priority coefficients
   (a static jit argument) are grouped and dispatched per group.

2. **Routing** — ``route_order`` ranks the legs of a route: the static
   client preference by default, or budget/latency-aware
   (``spill_policy="headroom"``) ranking legs by remaining token-bucket
   budget and pool load, in the spirit of dual-pool routing.  Pools
   with zero live replicas are unavailable and always skipped.

The gateway owns the per-request admission pipeline; the manager owns
pool membership, ordering, and completion attribution.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core import control_plane
from repro.core.control_plane import ControlState
from repro.core.markers import hot_path
from repro.core.pool import InFlight, TickRecord, TokenPool
from repro.core.types import EntitlementSpec, PoolSpec
from repro.core.virtual_node import VirtualNodeProvider


@dataclasses.dataclass(frozen=True)
class RouteEntry:
    """One leg of a multi-pool route: admit ``entitlement`` on ``pool``."""

    pool: str
    entitlement: str


#: Spill policies understood by ``route_order``.
SPILL_POLICIES = ("static", "headroom")


class PoolManager:
    """Holds the fleet of TokenPools and batches their accounting."""

    def __init__(self, pools: Iterable[TokenPool] = ()) -> None:
        self.pools: dict[str, TokenPool] = {}
        #: fleet capacity planner (created lazily by ``plan_quantum``;
        #: assign one to customize ``FleetPlannerConfig``)
        self.planner = None
        #: ``hook(pool, decision, now)`` — when set, scale decisions
        #: are handed to it instead of applied instantly, so a
        #: deployment (or ``MultiPoolSimulator``) can model
        #: provisioning lag and scale-down draining.  The promise
        #: ceiling (``authorize_replicas``) always moves at decision
        #: time regardless.
        self.provision_hook = None
        #: per-coefficient-group cache of the stacked [P, W] device
        #: state fed to ``control_tick_pools`` — the kernel's own
        #: output is next tick's input, so steady-state fleet ticks
        #: re-upload NOTHING (validity: each pool's ``device_state()``
        #: must still be the state slice the last tick adopted;
        #: growth/``mark_dirty``/churn swap that object out and the
        #: changed pool's row is re-spliced device-side)
        self._stack_cache: dict[object, dict] = {}
        #: observability: whole-group stack reuses vs pool rows
        #: re-stacked (tests pin steady-state ticking at zero restacks)
        self.stack_reuses = 0
        self.stack_restacks = 0
        for p in pools:
            self.adopt(p)

    # -- membership -----------------------------------------------------------
    def add_pool(self, spec: PoolSpec,
                 provider: Optional[VirtualNodeProvider] = None,
                 now: float = 0.0) -> TokenPool:
        pool = TokenPool(spec, provider=provider, now=now)
        return self.adopt(pool)

    def adopt(self, pool: TokenPool) -> TokenPool:
        if pool.spec.name in self.pools:
            raise ValueError(f"duplicate pool {pool.spec.name!r}")
        self.pools[pool.spec.name] = pool
        return pool

    def pool(self, name: str) -> TokenPool:
        return self.pools[name]

    def default_pool(self) -> TokenPool:
        if not self.pools:
            raise LookupError("PoolManager has no pools")
        return next(iter(self.pools.values()))

    def add_entitlement(self, espec: EntitlementSpec,
                        now: float = 0.0):
        """Route an entitlement spec to the pool it names."""
        return self.pools[espec.pool].add_entitlement(espec, now=now)

    def available(self, name: str) -> bool:
        pool = self.pools.get(name)
        return pool is not None and pool.replicas > 0

    def owner_of(self, entitlement: str,
                 hint: Optional[str] = None) -> Optional[str]:
        """Pool currently holding ``entitlement`` (``hint`` = the pool
        a route leg *claims*, checked first).  Rebalancing migrates
        entitlements between pools, so a stored route's legs can go
        stale — resolution follows the entitlement, not the leg."""
        if hint is not None:
            pool = self.pools.get(hint)
            if pool is not None and entitlement in pool.entitlements:
                return hint
        for name, pool in self.pools.items():
            if entitlement in pool.entitlements:
                return name
        return None

    # -- routing ---------------------------------------------------------------
    def route_order(self, entries: list[RouteEntry], input_tokens: int,
                    max_tokens: Optional[int], now: float,
                    policy: str = "static") -> list[RouteEntry]:
        """Rank a route's legs; unavailable pools are dropped.

        ``static``   — the client's declared preference order.
        ``headroom`` — budget/latency-aware: legs whose token bucket can
        afford this request's charge (input + effective max_tokens,
        using each leg's own pool default) rank before legs that would
        deny on budget; within each group, larger remaining bucket
        budget wins, with the pool's load factor
        admitted-in-flight / concurrency (queueing latency proxy) as
        the tiebreak.  Preference order breaks exact ties so the
        policy degrades to ``static`` on fresh pools.
        """
        return [e for _, e in self.route_order_indexed(
            entries, input_tokens, max_tokens, now, policy=policy)]

    def route_order_indexed(self, entries: list[RouteEntry],
                            input_tokens: int, max_tokens: Optional[int],
                            now: float, policy: str = "static",
                            ) -> list[tuple[int, RouteEntry]]:
        """:meth:`route_order`, but each leg carries its position in the
        client's DECLARED route.  The gateway reports that position as
        ``spill_hops`` — re-searching the declared route for the
        admitting leg (``route.index``) would misattribute repeated
        legs and, under ``headroom`` reordering, renumbered ones.

        Legs follow MIGRATED entitlements: a leg whose entitlement the
        rebalancer has moved to another pool is rewritten to the
        current owner, so stored routes keep working across
        cross-pool rebalances."""
        remapped = []
        for e in entries:
            owner = self.owner_of(e.entitlement, hint=e.pool)
            remapped.append(e if owner is None or owner == e.pool
                            else RouteEntry(owner, e.entitlement))
        live = [(i, e) for i, e in enumerate(remapped)
                if self.available(e.pool)]
        if policy == "static":
            return live
        if policy != "headroom":
            raise ValueError(f"unknown spill policy {policy!r}; "
                             f"expected one of {SPILL_POLICIES}")

        def score(pos_entry):
            pos, e = pos_entry
            pool = self.pools[e.pool]
            espec = pool.entitlements.get(e.entitlement)
            if espec is None:
                return (1, float("inf"), float("inf"), pos)
            charged = input_tokens + (
                max_tokens if max_tokens is not None
                else pool.spec.default_max_tokens)
            bucket = pool.ledger.ensure(
                e.entitlement,
                pool.status[e.entitlement].effective.tokens_per_second
                or espec.baseline.tokens_per_second, now)
            bucket.refill(now)
            affordable = 0 if bucket.level >= charged else 1
            conc = max(1.0, pool.capacity().concurrency)
            load = pool.pool_in_flight() / conc
            return (affordable, -bucket.level, load, pos)

        return sorted(live, key=score)

    # -- completion attribution -------------------------------------------------
    def find_pool_of(self, request_id: str) -> Optional[TokenPool]:
        for pool in self.pools.values():
            if request_id in pool.in_flight:
                return pool
        return None

    def on_complete(self, request_id: str, actual_output_tokens: int,
                    now: float) -> Optional[tuple[str, InFlight]]:
        """Settle a completion on whichever pool admitted the request.
        Returns (pool name, settled record) or None if unknown.  A
        request served by a SPILL leg additionally transfers the
        corresponding debt credit from the preferred entitlement to the
        serving one (:meth:`transfer_spill_debt`)."""
        pool = self.find_pool_of(request_id)
        if pool is None:
            return None
        rec = pool.on_complete(request_id, actual_output_tokens, now)
        if rec is None:
            return None
        if rec.spill_from is not None:
            self.transfer_spill_debt(rec, pool.spec.name, now)
        return (pool.spec.name, rec)

    def transfer_spill_debt(self, rec: InFlight, serving_pool: str,
                            now: float) -> float:
        """Per-request cross-pool debt transfer (ROADMAP item 4, the
        per-request half): a request the client PREFERRED on leg
        ``rec.spill_from`` but that was served by a spill leg moves the
        service-equivalent debt credit between the two entitlements on
        completion —

          * the preferred entitlement's debt DRAINS: it was recorded as
            denied demand there (raising debt every tick), yet the
            tenant did get served, just elsewhere;
          * the serving entitlement INHERITS the drained amount (when
            it is debt-bearing): the underserved tenant carries its
            priority boost to the spill target, so the spilled traffic
            keeps being served there.

        The credit is the Eq. 2 gap-equivalent of the settled tokens:
        one completion of ``settled_tokens`` over its service window
        covers ``settled / (λ_e · window)`` of the preferred baseline,
        clipped and EWMA-weighted exactly like a tick's gap sample.
        Clamps: the source never drains below ``debt_min``, the target
        never exceeds ``debt_max``.  Returns the transferred amount."""
        from repro.core.types import DEBT_CLASSES

        pref_pool, pref_ent = rec.spill_from
        if pref_ent == rec.entitlement:
            return 0.0
        src_name = self.owner_of(pref_ent, hint=pref_pool)
        if src_name is None:
            return 0.0
        spool = self.pools[src_name]
        espec = spool.entitlements[pref_ent]
        base = espec.baseline.tokens_per_second
        if (espec.qos.service_class not in DEBT_CLASSES or base <= 0.0
                or rec.settled_tokens <= 0.0):
            return 0.0
        coeff = spool.spec.coefficients
        window = max(now - rec.admitted_at,
                     spool.spec.accounting_interval_s)
        gap_credit = min(coeff.gap_clip,
                         rec.settled_tokens / (base * window))
        credit = (1.0 - coeff.gamma_debt) * gap_credit
        src_st = spool.status[pref_ent]
        delta = min(credit, src_st.debt - coeff.debt_min)
        if delta <= 0.0:
            return 0.0
        dpool = self.pools.get(serving_pool)
        dspec = (dpool.entitlements.get(rec.entitlement)
                 if dpool is not None else None)
        if dspec is not None \
                and dspec.qos.service_class in DEBT_CLASSES:
            dst = dpool.status[rec.entitlement]
            dmax = dpool.spec.coefficients.debt_max
            delta = min(delta, dmax - dst.debt)
            if delta <= 0.0:
                return 0.0
            dst.debt = dst.debt + delta
        src_st.debt = src_st.debt - delta
        return delta

    @hot_path
    def on_complete_batch(self, completions: list, now: float) -> list:
        """Batched :meth:`on_complete` — ``completions`` is a list of
        ``(request_id, actual_output_tokens)`` pairs; each admitting
        pool settles its share in ONE vectorized ``settle_rows`` call.
        Returns a list aligned with the input:
        ``(pool name, entitlement, settled_tokens)`` per known request,
        ``None`` per unknown one.  Spill-debt transfers run after each
        pool's settle, in batch order — transfers touch only debt,
        which no settle reads, so per-pool results match the scalar
        interleaving exactly."""
        results: list = [None] * len(completions)
        if not completions:
            return results
        if len(self.pools) == 1:
            pool = next(iter(self.pools.values()))
            groups = {pool.spec.name: list(range(len(completions)))}
        else:
            groups = {}
            for i, (rid, _) in enumerate(completions):
                pool = self.find_pool_of(rid)
                if pool is not None:
                    groups.setdefault(pool.spec.name, []).append(i)
        for name, idxs in groups.items():
            pool = self.pools[name]
            batch = pool.on_complete_batch(
                [completions[i][0] for i in idxs],
                [completions[i][1] for i in idxs], now)
            known = batch.known
            ents = batch.entitlements
            settled = batch.settled_tokens
            for k, i in enumerate(idxs):
                if known[k]:
                    results[i] = (name, ents[k], float(settled[k]))
            for rec in batch.spills:
                self.transfer_spill_debt(rec, name, now)
        return results

    def on_evict(self, request_id: str, now: float
                 ) -> Optional[tuple[str, InFlight]]:
        pool = self.find_pool_of(request_id)
        if pool is None:
            return None
        rec = pool.on_evict(request_id, now)
        return (pool.spec.name, rec) if rec is not None else None

    # -- the batched accounting tick --------------------------------------------
    @hot_path
    def tick(self, now: float) -> dict[str, TickRecord]:
        """Tick EVERY pool through one fused multi-pool kernel dispatch
        per coefficient group (coefficients are a static jit argument,
        so pools sharing them share a compiled kernel).

        The stacked inputs are the pools' RESIDENT arrays: each pool's
        vectorized window fold runs in place, its device-mirrored state
        is padded to the group's (pow2) width — free slots and padding
        are both inert unbound rows — and the kernel outputs are
        absorbed back into each store with vectorized row ops.  No
        per-entitlement Python anywhere on this path."""
        groups: dict[object, list[TokenPool]] = {}
        for pool in self.pools.values():
            groups.setdefault(pool.spec.coefficients, []).append(pool)

        records: dict[str, TickRecord] = {}
        for coeff, group in groups.items():
            if len(group) == 1:
                pool = group[0]
                records[pool.spec.name] = pool.tick(now)
                continue
            for p in group:
                p._measure(now)
            # Store capacities are already powers of two; the group
            # width is the widest store, so entitlement churn within
            # any pool's capacity bucket does not retrace the kernel.
            width = control_plane.bucket_width(
                max(p.store.capacity for p in group))

            def padded(k):
                out = np.zeros((len(group), width), np.float32)
                for i, p in enumerate(group):
                    out[i, :p.store.capacity] = p.store.col[k]
                return jnp.asarray(out)

            members = tuple(p.spec.name for p in group)
            cache = self._stack_cache.get(coeff)
            if (cache is not None and cache["members"] == members
                    and cache["width"] == width):
                states = cache["stacked"]
                stale = [k for k, p in enumerate(group)
                         if p.store.device_state()
                         is not cache["sources"][k]]
                if stale:
                    # splice only the changed pools' rows back in
                    # (device-side row writes; clean pools re-upload
                    # nothing)
                    for k in stale:
                        row = control_plane.pad_state(
                            group[k].store.device_state(), width)
                        states = ControlState(**{
                            f.name: getattr(states, f.name)
                            .at[k].set(getattr(row, f.name))
                            for f in dataclasses.fields(ControlState)})
                    self.stack_restacks += len(stale)
                else:
                    self.stack_reuses += 1
            else:
                states = control_plane.stack_states(
                    [p.store.device_state() for p in group], width=width)
                self.stack_restacks += len(group)
            new_state, alloc, weights = control_plane.control_tick_pools(
                states,
                jnp.asarray([p.capacity().tokens_per_second
                             for p in group], jnp.float32),
                padded("measured_tps"),
                padded("kv_in_use"),
                padded("resident"),
                padded("demand_tps"),
                jnp.asarray([p.pool_avg_slo() for p in group],
                            jnp.float32),
                coeff=coeff)
            burst = np.asarray(new_state.burst)
            debt = np.asarray(new_state.debt)
            alloc = np.asarray(alloc)
            weights = np.asarray(weights)
            sources: list[ControlState] = []
            for k, pool in enumerate(group):
                w = pool.store.capacity
                sliced = ControlState(
                    class_code=new_state.class_code[k, :w],
                    bound=new_state.bound[k, :w],
                    baseline_tps=new_state.baseline_tps[k, :w],
                    baseline_kv=new_state.baseline_kv[k, :w],
                    baseline_conc=new_state.baseline_conc[k, :w],
                    slo_ms=new_state.slo_ms[k, :w],
                    burst=jnp.asarray(burst[k, :w]),
                    debt=jnp.asarray(debt[k, :w]),
                )
                records[pool.spec.name] = pool._absorb_tick(
                    now, sliced, alloc[k, :w], weights[k, :w])
                sources.append(pool.store.device_state())
            # the kernel's [P, W] output IS next tick's input stack:
            # live rows carry the adopted per-pool state bit for bit,
            # and padding rows are inert under the tick (zero
            # baselines ⇒ zero burst delta, unbound ⇒ zero debt), so
            # steady-state fleet ticks re-upload nothing
            self._stack_cache[coeff] = {
                "members": members, "width": width,
                "stacked": new_state, "sources": sources}
        return records


    # -- fleet capacity planning -------------------------------------------------
    def migrate_entitlement(self, name: str, src: str, dst: str,
                            now: float = 0.0):
        """Move ``name`` from pool ``src`` to pool ``dst``, carrying
        its ledger bucket level, debt/burst, in-flight records and
        demand signal (invariants: ``core.fleet`` module docstring).
        The destination's authorized ceiling is raised first if a
        planner had shrunk it, so the arriving reserve does not
        spuriously degrade.  Returns the entitlement's state on the
        destination."""
        from repro.core.autoscaler import replicas_for
        from repro.core.types import ServiceClass

        spool, dpool = self.pools[src], self.pools[dst]
        espec = spool.entitlements[name]
        if dpool._authorized is not None \
                and espec.qos.service_class not in (
                    ServiceClass.SPOT, ServiceClass.PREEMPTIBLE):
            node = dpool.provider.node(dst)
            needed = replicas_for(node.allocated + espec.baseline,
                                  dpool.spec.per_replica)
            needed = min(int(np.ceil(min(needed, 1e9))),
                         dpool.spec.scaling.max_replicas)
            if needed > dpool._authorized:
                dpool.authorize_replicas(needed)
        mig = spool.detach_entitlement(name, now)
        try:
            return dpool.attach_entitlement(mig, now)
        except Exception:
            # roll back: re-adopt on the source so nothing is lost —
            # bucket level, debt/burst, charges and in-flight records
            # all travel back with the same migration payload
            spool.attach_entitlement(mig, now)
            raise

    def plan_quantum(self, now: float, records=None):
        """One closed-loop planning round for the fleet: batched tick →
        ONE fused ``plan_fleet`` dispatch → apply.

        Per decision the pool's PROMISE ceiling moves immediately
        (``authorize_replicas`` — a shrink below committed reservations
        preempts leases via the virtual-node scheduler pass), while
        LIVE replicas move through ``provision_hook`` when one is set
        (provisioning lag / drain modelling) or instantly otherwise.
        Rebalance proposals are then executed via
        :meth:`migrate_entitlement`.  Pass the tick's ``records`` to
        reuse an accounting tick this quantum already ran."""
        from repro.core.fleet import FleetPlanner

        if records is None:
            records = self.tick(now)
        if self.planner is None:
            self.planner = FleetPlanner()
        plan = self.planner.plan(self.pools, records, now)
        for name, d in plan.decisions.items():
            pool = self.pools[name]
            if pool._authorized != d.desired:
                prev = (pool._authorized if pool._authorized is not None
                        else d.current)
                if prev != d.desired:
                    plan.scale_events[name] = (prev, d.desired)
                preempted = pool.authorize_replicas(d.desired)
                if preempted:
                    plan.preempted[name] = preempted
            if d.desired != d.current:
                if self.provision_hook is not None:
                    self.provision_hook(pool, d, now)
                else:
                    pool.set_replicas(d.desired)
        for prop in plan.migrations:
            # a pool can FAIL between planning and execution (the plan
            # and the outage land in the same quantum): migrating into
            # a dead pool would strand the entitlement behind zero
            # capacity, so the proposal is skipped — the planner will
            # re-propose next round if the target recovers
            if not self.available(prop.dst):
                plan.skipped.append(prop)
                continue
            self.migrate_entitlement(prop.entitlement, prop.src,
                                     prop.dst, now)
            plan.applied.append(prop)
        return plan


PoolOrManager = Union[TokenPool, PoolManager]


def as_manager(pools: PoolOrManager) -> PoolManager:
    """Wrap a bare TokenPool into a single-pool manager (legacy API)."""
    if isinstance(pools, PoolManager):
        return pools
    return PoolManager([pools])
